"""AOT lowering: JAX models -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`). The HLO text parser reassigns ids, so text
round-trips cleanly. Lowered with return_tuple=True; the Rust side
unwraps with `to_tuple1()`.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import artifact_registry


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    registry = artifact_registry()
    manifest = {"format": "hlo-text", "return_tuple": True, "artifacts": []}
    for name, spec in sorted(registry.items()):
        if only and only != name:
            continue
        lowered = jax.jit(spec["fn"]).lower(*spec["args"])
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(spec["meta"])
        entry["file"] = os.path.basename(path)
        entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        manifest["artifacts"].append(entry)
        print(f"[aot] {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", default=None, help="build a single artifact")
    args = p.parse_args(argv)
    build_all(args.out_dir, args.only)


if __name__ == "__main__":
    sys.exit(main())
