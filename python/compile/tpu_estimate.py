"""TPU resource estimation for the Layer-1 Pallas kernels (§Perf L1).

The image's CPU PJRT plugin can only run Pallas in interpret mode, so
real-TPU performance is *estimated* analytically from the kernel's
BlockSpec structure (DESIGN.md §Hardware-Adaptation): VMEM footprint,
VPU lane utilization, and a roofline-style cycle estimate. These
numbers justify the blocking choices; they are asserted by tests so a
structural regression (e.g. a block that no longer fits VMEM) fails CI.

Model (TPU v4-class, per core):
  - VMEM: 16 MiB usable per core
  - VPU: 8 sublanes x 128 lanes, one 32-bit op per lane per cycle
  - the row dimension maps to lanes; ROW_BLOCK = 128 rows fills the
    lane dimension exactly (the paper's 128 row-ALUs <-> 128 lanes)
"""

from __future__ import annotations

from dataclasses import dataclass

from .kernels.fast_shift_add import ROW_BLOCK

VMEM_BYTES = 16 * 1024 * 1024
VPU_LANES = 128
VPU_SUBLANES = 8


@dataclass(frozen=True)
class KernelEstimate:
    """Static resource estimate for one FAST batch-op kernel call."""

    rows: int
    q: int
    # VMEM bytes for one grid step (bits, op_bits, carry, out blocks).
    vmem_block_bytes: int
    vmem_frac: float
    # Lane utilization of the [ROW_BLOCK]-wide vector ops.
    lane_utilization: float
    # Vector ops per shift cycle (xor/and/or for sum+carry, shift, insert).
    vector_ops_per_cycle: int
    # Estimated VPU cycles per grid step (q cycles x ops, lanes-parallel).
    est_cycles_per_block: int
    grid_steps: int

    @property
    def est_total_cycles(self) -> int:
        return self.est_cycles_per_block * self.grid_steps


def estimate_shift_add(rows: int, q: int, dtype_bytes: int = 4) -> KernelEstimate:
    """Estimate for fast_shift_add_bits at [rows, q]."""
    if rows % ROW_BLOCK != 0:
        raise ValueError(f"rows={rows} not a multiple of ROW_BLOCK={ROW_BLOCK}")
    if not 1 <= q <= 32:
        raise ValueError(f"q={q} out of range")
    # Blocks resident per grid step: bits[128,q] in+out, op[128,q], cin[128].
    block = ROW_BLOCK * q * dtype_bytes
    vmem = 3 * block + ROW_BLOCK * dtype_bytes
    # One shift cycle = FA (2 xor + 3 and + 2 or = 7 lane ops) + roll
    # (register shuffle, ~1 op) + MSB insert (~1 op).
    ops_per_cycle = 9
    # Each lane op covers ROW_BLOCK rows; one sublane pass per op when
    # the row block exactly fills the lane dim.
    cycles_per_block = q * ops_per_cycle
    return KernelEstimate(
        rows=rows,
        q=q,
        vmem_block_bytes=vmem,
        vmem_frac=vmem / VMEM_BYTES,
        lane_utilization=min(1.0, ROW_BLOCK / VPU_LANES),
        vector_ops_per_cycle=ops_per_cycle,
        est_cycles_per_block=cycles_per_block,
        grid_steps=rows // ROW_BLOCK,
    )


def render(est: KernelEstimate) -> str:
    return (
        f"fast_shift_add [{est.rows}x{est.q}]\n"
        f"  VMEM per grid step : {est.vmem_block_bytes / 1024:.1f} KiB"
        f" ({100 * est.vmem_frac:.3f}% of 16 MiB)\n"
        f"  lane utilization   : {100 * est.lane_utilization:.0f}%"
        f" (ROW_BLOCK={ROW_BLOCK} rows == {VPU_LANES} lanes)\n"
        f"  est. VPU cycles    : {est.est_cycles_per_block}/block"
        f" x {est.grid_steps} steps = {est.est_total_cycles}\n"
    )


def main() -> None:
    for rows, q in [(128, 8), (128, 16), (128, 32), (1024, 16)]:
        print(render(estimate_shift_add(rows, q)))


if __name__ == "__main__":
    main()
