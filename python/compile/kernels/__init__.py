"""Layer-1 Pallas kernels for the FAST SRAM functional model.

- fast_shift_add: bit-serial row-parallel add / subtract (the paper's FA
  row-ALU, Figs. 3-5)
- fast_logic: row-parallel AND/OR/XOR (the paper's reconfigurable 1-bit
  ALU extension, Section III.E)
- ref: pure-jnp oracle every kernel is tested against
"""

from . import fast_logic, fast_shift_add, ref  # noqa: F401
from .fast_logic import LOGIC_OPS, fast_logic_bits  # noqa: F401
from .fast_shift_add import (  # noqa: F401
    ROW_BLOCK,
    fast_shift_add_bits,
    fast_shift_sub_bits,
)
