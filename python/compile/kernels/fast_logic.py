"""Layer-1 Pallas kernel: FAST row-parallel bitwise logic update.

Section III.E of the paper: "it can also realize more complex functions
by replacing the 1-bit full adder into other 1-bit operation units."
This kernel models that reconfiguration — the per-row ALU evaluates a
1-bit logic function (AND / OR / XOR) instead of a full adder, and the
row still takes q shift cycles to rotate every bit past the ALU.

Same schedule and BlockSpec mapping as fast_shift_add (see that module's
docstring); no carry latch is needed for logic ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fast_shift_add import ROW_BLOCK

#: Supported 1-bit ALU configurations for the logic variant.
LOGIC_OPS = ("and", "or", "xor")


def _logic_kernel(bits_ref, op_ref, out_ref, *, q: int, op: str):
    # Unrolled like _shift_add_kernel (§Perf L1): q is static and small,
    # and straight-line elementwise code fuses where a `while` cannot.
    bits = bits_ref[...]
    for t in range(q):
        a = bits[:, 0]
        b = op_ref[:, t]
        if op == "and":
            s = a & b
        elif op == "or":
            s = a | b
        else:  # xor
            s = a ^ b
        bits = jnp.roll(bits, -1, axis=1)
        bits = bits.at[:, q - 1].set(s)
    out_ref[...] = bits


def fast_logic_bits(
    bits: jnp.ndarray,
    op_bits: jnp.ndarray,
    *,
    q: int,
    op: str,
    interpret: bool = True,
) -> jnp.ndarray:
    """Row-parallel bitwise logic over bit-plane state.

    Args:
      bits:    [R, q] uint32 {0,1} — array contents, LSB at col 0.
      op_bits: [R, q] uint32 {0,1} — per-row operand.
      q:       bit width (static).
      op:      one of LOGIC_OPS.

    Returns [R, q] updated contents. R must be a multiple of ROW_BLOCK.
    """
    if op not in LOGIC_OPS:
        raise ValueError(f"op must be one of {LOGIC_OPS}, got {op!r}")
    r, qq = bits.shape
    if qq != q:
        raise ValueError(f"bits.shape[1]={qq} != q={q}")
    if r % ROW_BLOCK != 0:
        raise ValueError(f"R={r} must be a multiple of ROW_BLOCK={ROW_BLOCK}")
    grid = (r // ROW_BLOCK,)
    kernel = functools.partial(_logic_kernel, q=q, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, q), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, q), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, q), jnp.uint32),
        interpret=interpret,
    )(bits, op_bits)
