"""Pure-jnp / numpy oracle for the FAST bit-serial kernels.

This module is the *correctness ground truth* for Layer 1. Every Pallas
kernel in this package is checked against these functions by pytest
(python/tests/) before the AOT artifacts are built, and the Rust
behavioural array model cross-checks against the AOT artifacts at
`cargo test` time — so all three implementations share one semantics:

    q-bit modular integer arithmetic per row, fully parallel over rows.

Words are uint32 with only the low ``q`` bits significant.
Bit-planes are uint32 {0,1} matrices of shape [R, q], LSB at column 0
(column 0 is the cell adjacent to the row's 1-bit ALU; a "shift right"
in the paper moves every bit one cell toward the ALU).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "mask",
    "pack_bits",
    "unpack_bits",
    "add_words",
    "sub_words",
    "logic_words",
    "bit_serial_add_reference",
]


def mask(q: int) -> jnp.ndarray:
    """All-ones mask for a q-bit word, as uint32 (valid for 1 <= q <= 32)."""
    if not 1 <= q <= 32:
        raise ValueError(f"bit width q must be in [1, 32], got {q}")
    # (1 << 32) would overflow a uint32 shift; derive by right-shifting.
    if q == 32:
        return jnp.uint32(0xFFFFFFFF)
    return jnp.uint32(0xFFFFFFFF) >> jnp.uint32(32 - q)


def unpack_bits(words: jnp.ndarray, q: int) -> jnp.ndarray:
    """[R] uint32 words -> [R, q] uint32 bit-planes, LSB at column 0."""
    words = words.astype(jnp.uint32)
    shifts = jnp.arange(q, dtype=jnp.uint32)
    return (words[:, None] >> shifts[None, :]) & jnp.uint32(1)


def pack_bits(bits: jnp.ndarray, q: int) -> jnp.ndarray:
    """[R, q] uint32 bit-planes -> [R] uint32 words."""
    shifts = jnp.arange(q, dtype=jnp.uint32)
    return jnp.sum(
        bits.astype(jnp.uint32) << shifts[None, :], axis=1, dtype=jnp.uint32
    )


def add_words(a: jnp.ndarray, b: jnp.ndarray, q: int) -> jnp.ndarray:
    """Row-parallel q-bit modular addition: (a + b) mod 2^q."""
    return (a.astype(jnp.uint32) + b.astype(jnp.uint32)) & mask(q)


def sub_words(a: jnp.ndarray, b: jnp.ndarray, q: int) -> jnp.ndarray:
    """Row-parallel q-bit modular subtraction: (a - b) mod 2^q.

    The hardware realizes this as an add of the one's complement with
    carry-in = 1 (two's complement) through the same 1-bit FA.
    """
    return (a.astype(jnp.uint32) - b.astype(jnp.uint32)) & mask(q)


def logic_words(a: jnp.ndarray, b: jnp.ndarray, q: int, op: str) -> jnp.ndarray:
    """Row-parallel bitwise logic — the paper's "replace the FA with other
    1-bit operation units" extension (Section III.E)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    if op == "and":
        r = a & b
    elif op == "or":
        r = a | b
    elif op == "xor":
        r = a ^ b
    else:
        raise ValueError(f"unknown logic op {op!r}")
    return r & mask(q)


def bit_serial_add_reference(
    bits: jnp.ndarray, op_bits: jnp.ndarray, carry_in: jnp.ndarray, q: int
) -> jnp.ndarray:
    """Step-by-step emulation of the hardware schedule (Fig. 4/5):

    cycle t:  the LSB cell (col 0) feeds the FA together with external
              operand bit t and the latched carry (node T1); the row
              shifts right (col 1 -> col 0, ...); the FA sum re-enters
              the vacated MSB slot (col q-1).

    After q cycles the row holds (a + b + cin) mod 2^q with the LSB back
    at column 0.  Deliberately a plain Python loop over cycles so it
    reads like the paper's timing diagram; used only as a test oracle.
    """
    bits = bits.astype(jnp.uint32)
    op_bits = op_bits.astype(jnp.uint32)
    carry = carry_in.astype(jnp.uint32)
    for t in range(q):
        a = bits[:, 0]
        b = op_bits[:, t]
        s = a ^ b ^ carry
        carry = (a & b) | (a & carry) | (b & carry)
        bits = jnp.roll(bits, -1, axis=1)
        bits = bits.at[:, q - 1].set(s)
    return bits
