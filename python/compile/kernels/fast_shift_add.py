"""Layer-1 Pallas kernel: FAST bit-serial, row-parallel add/sub.

This kernel is the functional model of the paper's compute hot-spot: the
128-row FAST macro executing a q-bit add with write-back in q shift
cycles, *concurrently in every row* (Figs. 3-5).

Hardware -> kernel mapping (see DESIGN.md §Hardware-Adaptation):

  SRAM row of q shiftable cells   -> one row of a [R, q] uint32 bit-plane
                                     matrix held in VMEM
  128 per-row 1-bit ALUs          -> one [R]-wide vector lane op per cycle
                                     (the VPU's 8x128 vregs play the role
                                     of the 128 row-ALUs)
  q shift cycles                  -> jax.lax.fori_loop over q iterations;
                                     each iteration does the cyclic right
                                     shift (roll) + 1-bit full-adder slice
  carry latch (node T1, Fig. 5a)  -> the loop-carried `carry` vector
  macro height (128 rows)         -> BlockSpec row block of 128; taller
                                     arrays tile the grid over row blocks,
                                     exactly like stacking FAST macros

The kernel MUST be lowered with interpret=True on this image: real-TPU
Pallas lowering emits a Mosaic custom-call that the CPU PJRT plugin
cannot execute. interpret=True lowers to plain HLO ops, which both jit
execution here and the Rust PJRT runtime can run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's macro height: 128 rows per FAST subarray. Taller inputs are
# tiled over a grid of row blocks (== stacking macros in a bank).
ROW_BLOCK = 128


def _shift_add_kernel(bits_ref, op_ref, cin_ref, out_ref, *, q: int):
    """One FAST macro batch op: q shift cycles + per-row 1-bit FA.

    bits_ref: [B, q]  stored word bit-planes, LSB at col 0
    op_ref:   [B, q]  external operand bit-planes
    cin_ref:  [B]     carry-in (0 for add, 1 for two's-complement sub)
    out_ref:  [B, q]  updated word bit-planes (write-back)

    The q-cycle schedule is UNROLLED (q is compile-time static and
    small): a `fori_loop` lowers to an HLO `while` whose per-iteration
    buffer round-trips dominate at these sizes — unrolling lets XLA fuse
    the whole batch op into straight-line elementwise code (§Perf L1:
    2.1× on the PJRT-CPU execution path).
    """

    carry = cin_ref[...]
    bits = bits_ref[...]
    for t in range(q):
        a = bits[:, 0]          # LSB cell feeds the row ALU
        b = op_ref[:, t]        # external operand bit for this cycle
        s = a ^ b ^ carry       # FA sum
        carry = (a & b) | (a & carry) | (b & carry)  # FA carry -> T1 latch
        # Cyclic right shift: every cell hands its datum to the neighbour
        # closer to the ALU; the FA sum re-enters the vacated MSB slot.
        bits = jnp.roll(bits, -1, axis=1)
        bits = bits.at[:, q - 1].set(s)
    out_ref[...] = bits


def fast_shift_add_bits(
    bits: jnp.ndarray,
    op_bits: jnp.ndarray,
    carry_in: jnp.ndarray,
    *,
    q: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Row-parallel bit-serial add over bit-plane state.

    Args:
      bits:     [R, q] uint32 {0,1} — array contents, LSB at col 0.
      op_bits:  [R, q] uint32 {0,1} — per-row external addend.
      carry_in: [R] uint32 {0,1} — FA carry-in (two's-complement subtract
                passes inverted op_bits with carry_in = 1).
      q:        bit width (compile-time static; sets the cycle count).

    Returns:
      [R, q] uint32 {0,1} — updated contents, LSB back at col 0.

    R must be a multiple of ROW_BLOCK (pad in the caller; the Layer-2
    wrappers in model.py do this). Each grid step is one 128-row macro.
    """
    r, qq = bits.shape
    if qq != q:
        raise ValueError(f"bits.shape[1]={qq} != q={q}")
    if r % ROW_BLOCK != 0:
        raise ValueError(f"R={r} must be a multiple of ROW_BLOCK={ROW_BLOCK}")
    grid = (r // ROW_BLOCK,)
    kernel = functools.partial(_shift_add_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, q), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, q), lambda i: (i, 0)),
            pl.BlockSpec((ROW_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, q), jnp.uint32),
        interpret=interpret,
    )(bits, op_bits, carry_in)


def fast_shift_sub_bits(
    bits: jnp.ndarray,
    op_bits: jnp.ndarray,
    *,
    q: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Row-parallel bit-serial subtract: add the one's complement of the
    operand with carry-in 1 (two's complement), through the same FA path —
    exactly how the hardware reuses the adder."""
    ones = jnp.ones((bits.shape[0],), dtype=jnp.uint32)
    return fast_shift_add_bits(
        bits, op_bits ^ jnp.uint32(1), ones, q=q, interpret=interpret
    )
