"""Build-time compile path for the FAST SRAM reproduction.

Python exists ONLY at artifact-build time: `python -m compile.aot` lowers
the Layer-2 JAX models (which call the Layer-1 Pallas kernels) to HLO
text under artifacts/, and the Rust coordinator loads those via PJRT.
Nothing in this package is imported on the request path.
"""
