"""Layer-2 JAX models: word-level compute graphs over the FAST kernels.

These are the functions that get AOT-lowered to HLO text and executed by
the Rust runtime (rust/src/runtime/). The interface contract with Rust:

  - all word I/O is uint32 vectors of static length R (row count);
  - only the low q bits of each word are significant; results are
    masked to q bits (q-bit modular arithmetic, like the hardware);
  - outputs are 1-tuples (lowered with return_tuple=True), unwrapped on
    the Rust side with `to_tuple1()`.

The models wrap the Layer-1 Pallas kernels with pack/unpack interface
logic — mirroring the chip, where the bitline/decoder periphery converts
between word-oriented bus transactions and the in-array bit-plane state.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import (
    LOGIC_OPS,
    ROW_BLOCK,
    fast_logic_bits,
    fast_shift_add_bits,
    ref,
)

# ---------------------------------------------------------------------------
# Word-level batch operations (one FAST macro-bank batch op each)
# ---------------------------------------------------------------------------


def batch_add_words(
    table: jnp.ndarray, deltas: jnp.ndarray, *, q: int, interpret: bool = True
) -> Tuple[jnp.ndarray]:
    """Fully-concurrent delta update: table[r] <- (table[r] + deltas[r]) mod 2^q
    for every row r at once. One FAST batch op (q shift cycles)."""
    bits = ref.unpack_bits(table, q)
    op_bits = ref.unpack_bits(deltas, q)
    cin = jnp.zeros((table.shape[0],), dtype=jnp.uint32)
    out = fast_shift_add_bits(bits, op_bits, cin, q=q, interpret=interpret)
    return (ref.pack_bits(out, q),)


def batch_sub_words(
    table: jnp.ndarray, deltas: jnp.ndarray, *, q: int, interpret: bool = True
) -> Tuple[jnp.ndarray]:
    """Fully-concurrent subtract: table[r] <- (table[r] - deltas[r]) mod 2^q.
    Two's complement through the same FA path (invert + carry-in 1)."""
    bits = ref.unpack_bits(table, q)
    op_bits = ref.unpack_bits(deltas, q) ^ jnp.uint32(1)
    cin = jnp.ones((table.shape[0],), dtype=jnp.uint32)
    out = fast_shift_add_bits(bits, op_bits, cin, q=q, interpret=interpret)
    return (ref.pack_bits(out, q),)


def batch_logic_words(
    table: jnp.ndarray,
    operands: jnp.ndarray,
    *,
    q: int,
    op: str,
    interpret: bool = True,
) -> Tuple[jnp.ndarray]:
    """Fully-concurrent bitwise update with a reconfigured 1-bit ALU."""
    bits = ref.unpack_bits(table, q)
    op_bits = ref.unpack_bits(operands, q)
    out = fast_logic_bits(bits, op_bits, q=q, op=op, interpret=interpret)
    return (ref.pack_bits(out, q),)


def accumulate_rounds(
    table: jnp.ndarray, rounds: jnp.ndarray, *, q: int, interpret: bool = True
) -> Tuple[jnp.ndarray]:
    """T successive fully-concurrent batch adds (graph-computing pattern:
    each round is one dense, row-disjoint message-delivery sweep prepared
    by the Layer-3 coordinator).

    table:  [R]    uint32
    rounds: [T, R] uint32 per-round delta vectors
    """

    def step(tab, deltas):
        (out,) = batch_add_words(tab, deltas, q=q, interpret=interpret)
        return out, ()

    out, _ = jax.lax.scan(step, table, rounds)
    return (out,)


# ---------------------------------------------------------------------------
# Artifact registry — everything aot.py lowers, with example args + metadata
# ---------------------------------------------------------------------------

ArtifactFn = Callable[..., Tuple[jnp.ndarray, ...]]


def _u32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def artifact_registry() -> Dict[str, Dict[str, Any]]:
    """All AOT artifacts: name -> {fn, args (ShapeDtypeStructs), meta}.

    The `meta` dict is written to artifacts/manifest.json so the Rust
    runtime can discover shapes/semantics without parsing HLO.
    """
    reg: Dict[str, Dict[str, Any]] = {}

    def add(name: str, fn: ArtifactFn, args, **meta):
        reg[name] = {"fn": fn, "args": args, "meta": {"name": name, **meta}}

    # The paper's showcase macro: 128 rows. q = 16 is Table I's OP width.
    for q in (8, 16, 32):
        add(
            f"fast_add_128x{q}",
            functools.partial(batch_add_words, q=q),
            (_u32((128,)), _u32((128,))),
            op="add", rows=128, q=q,
            inputs=[["u32", [128]], ["u32", [128]]], outputs=[["u32", [128]]],
        )
    add(
        "fast_sub_128x16",
        functools.partial(batch_sub_words, q=16),
        (_u32((128,)), _u32((128,))),
        op="sub", rows=128, q=16,
        inputs=[["u32", [128]], ["u32", [128]]], outputs=[["u32", [128]]],
    )
    for lop in LOGIC_OPS:
        add(
            f"fast_{lop}_128x16",
            functools.partial(batch_logic_words, q=16, op=lop),
            (_u32((128,)), _u32((128,))),
            op=lop, rows=128, q=16,
            inputs=[["u32", [128]], ["u32", [128]]], outputs=[["u32", [128]]],
        )
    # A bank of 8 stacked macros (1024 rows), the multi-macro grid path.
    add(
        "fast_add_1024x16",
        functools.partial(batch_add_words, q=16),
        (_u32((1024,)), _u32((1024,))),
        op="add", rows=1024, q=16,
        inputs=[["u32", [1024]], ["u32", [1024]]], outputs=[["u32", [1024]]],
    )
    # Multi-round accumulate (graph computing inner loop), T = 8 rounds.
    add(
        "fast_scan8_128x16",
        functools.partial(accumulate_rounds, q=16),
        (_u32((128,)), _u32((8, 128))),
        op="scan_add", rows=128, q=16, rounds=8,
        inputs=[["u32", [128]], ["u32", [8, 128]]], outputs=[["u32", [128]]],
    )
    return reg


assert ROW_BLOCK == 128, "artifact registry assumes the paper's 128-row macro"
