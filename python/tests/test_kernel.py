"""Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps row counts, bit widths and data distributions; every
case asserts exact equality (integer semantics, no tolerance).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ROW_BLOCK,
    fast_logic_bits,
    fast_shift_add_bits,
    fast_shift_sub_bits,
    ref,
)

QS = [1, 2, 4, 8, 13, 16, 24, 32]


def rand_words(rng, r, q):
    return jnp.asarray(rng.integers(0, 2**q, size=r, dtype=np.uint32))


def run_add(a, b, q, cin=0):
    bits = ref.unpack_bits(a, q)
    op_bits = ref.unpack_bits(b, q)
    carry = jnp.full((a.shape[0],), cin, dtype=jnp.uint32)
    out = fast_shift_add_bits(bits, op_bits, carry, q=q)
    return ref.pack_bits(out, q)


@pytest.mark.parametrize("q", QS)
def test_add_single_macro(q):
    rng = np.random.default_rng(q)
    a, b = rand_words(rng, ROW_BLOCK, q), rand_words(rng, ROW_BLOCK, q)
    got = np.asarray(run_add(a, b, q))
    want = np.asarray(ref.add_words(a, b, q))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("r", [ROW_BLOCK, 2 * ROW_BLOCK, 4 * ROW_BLOCK])
def test_add_multi_macro_grid(r):
    """Grid over row blocks == stacking 128-row macros in a bank."""
    q = 16
    rng = np.random.default_rng(r)
    a, b = rand_words(rng, r, q), rand_words(rng, r, q)
    got = np.asarray(run_add(a, b, q))
    np.testing.assert_array_equal(got, np.asarray(ref.add_words(a, b, q)))


def test_add_rejects_non_multiple_rows():
    q = 8
    a = jnp.zeros((100, q), jnp.uint32)
    with pytest.raises(ValueError):
        fast_shift_add_bits(a, a, jnp.zeros(100, jnp.uint32), q=q)


def test_add_rejects_width_mismatch():
    a = jnp.zeros((ROW_BLOCK, 8), jnp.uint32)
    with pytest.raises(ValueError):
        fast_shift_add_bits(a, a, jnp.zeros(ROW_BLOCK, jnp.uint32), q=16)


def test_full_carry_chain_wraps():
    q = 16
    a = jnp.full((ROW_BLOCK,), (1 << q) - 1, dtype=jnp.uint32)
    b = jnp.ones((ROW_BLOCK,), dtype=jnp.uint32)
    np.testing.assert_array_equal(np.asarray(run_add(a, b, q)), 0)


def test_carry_in_one():
    q = 8
    a = jnp.full((ROW_BLOCK,), 10, dtype=jnp.uint32)
    b = jnp.full((ROW_BLOCK,), 20, dtype=jnp.uint32)
    np.testing.assert_array_equal(np.asarray(run_add(a, b, q, cin=1)), 31)


def test_identity_add_zero():
    q = 16
    rng = np.random.default_rng(0)
    a = rand_words(rng, ROW_BLOCK, q)
    z = jnp.zeros_like(a)
    np.testing.assert_array_equal(np.asarray(run_add(a, z, q)), np.asarray(a))


@settings(max_examples=30, deadline=None)
@given(
    q=st.sampled_from(QS),
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 3),
)
def test_add_hypothesis_sweep(q, seed, blocks):
    rng = np.random.default_rng(seed)
    r = blocks * ROW_BLOCK
    a, b = rand_words(rng, r, q), rand_words(rng, r, q)
    got = np.asarray(run_add(a, b, q))
    np.testing.assert_array_equal(got, np.asarray(ref.add_words(a, b, q)))


@settings(max_examples=20, deadline=None)
@given(q=st.sampled_from([4, 8, 16, 32]), seed=st.integers(0, 2**31 - 1))
def test_sub_hypothesis_sweep(q, seed):
    rng = np.random.default_rng(seed)
    a, b = rand_words(rng, ROW_BLOCK, q), rand_words(rng, ROW_BLOCK, q)
    out = fast_shift_sub_bits(ref.unpack_bits(a, q), ref.unpack_bits(b, q), q=q)
    got = np.asarray(ref.pack_bits(out, q))
    np.testing.assert_array_equal(got, np.asarray(ref.sub_words(a, b, q)))


def test_sub_self_is_zero():
    q = 16
    rng = np.random.default_rng(7)
    a = rand_words(rng, ROW_BLOCK, q)
    out = fast_shift_sub_bits(ref.unpack_bits(a, q), ref.unpack_bits(a, q), q=q)
    np.testing.assert_array_equal(np.asarray(ref.pack_bits(out, q)), 0)


@pytest.mark.parametrize("op", ["and", "or", "xor"])
@pytest.mark.parametrize("q", [4, 16, 32])
def test_logic_kernel(op, q):
    rng = np.random.default_rng(hash(op) % 2**31)
    a, b = rand_words(rng, ROW_BLOCK, q), rand_words(rng, ROW_BLOCK, q)
    out = fast_logic_bits(ref.unpack_bits(a, q), ref.unpack_bits(b, q), q=q, op=op)
    got = np.asarray(ref.pack_bits(out, q))
    np.testing.assert_array_equal(got, np.asarray(ref.logic_words(a, b, q, op)))


def test_logic_rejects_bad_op():
    a = jnp.zeros((ROW_BLOCK, 8), jnp.uint32)
    with pytest.raises(ValueError):
        fast_logic_bits(a, a, q=8, op="nand")


def test_kernel_matches_cycle_accurate_reference():
    """Pallas kernel == the step-by-step hardware-schedule oracle,
    not just the end-to-end integer result."""
    q = 16
    rng = np.random.default_rng(42)
    a, b = rand_words(rng, ROW_BLOCK, q), rand_words(rng, ROW_BLOCK, q)
    bits, op_bits = ref.unpack_bits(a, q), ref.unpack_bits(b, q)
    cin = jnp.zeros((ROW_BLOCK,), jnp.uint32)
    got = fast_shift_add_bits(bits, op_bits, cin, q=q)
    want = ref.bit_serial_add_reference(bits, op_bits, cin, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
