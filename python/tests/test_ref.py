"""Oracle self-consistency: ref.py vs plain numpy integer semantics."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

QS = [1, 4, 7, 8, 13, 16, 24, 31, 32]


def rand_words(rng, r, q):
    return rng.integers(0, 2**q, size=r, dtype=np.uint32)


@pytest.mark.parametrize("q", QS)
def test_mask(q):
    m = int(ref.mask(q))
    assert m == (1 << q) - 1


@pytest.mark.parametrize("q", QS)
def test_pack_unpack_roundtrip(q):
    rng = np.random.default_rng(q)
    w = rand_words(rng, 64, q)
    bits = ref.unpack_bits(jnp.asarray(w), q)
    assert bits.shape == (64, q)
    assert set(np.unique(np.asarray(bits))) <= {0, 1}
    back = np.asarray(ref.pack_bits(bits, q))
    np.testing.assert_array_equal(back, w)


@pytest.mark.parametrize("q", QS)
def test_add_words_matches_numpy(q):
    rng = np.random.default_rng(q + 100)
    a, b = rand_words(rng, 256, q), rand_words(rng, 256, q)
    got = np.asarray(ref.add_words(jnp.asarray(a), jnp.asarray(b), q))
    want = (a.astype(np.uint64) + b) % (1 << q)
    np.testing.assert_array_equal(got, want.astype(np.uint32))


@pytest.mark.parametrize("q", QS)
def test_sub_words_matches_numpy(q):
    rng = np.random.default_rng(q + 200)
    a, b = rand_words(rng, 256, q), rand_words(rng, 256, q)
    got = np.asarray(ref.sub_words(jnp.asarray(a), jnp.asarray(b), q))
    want = (a.astype(np.int64) - b) % (1 << q)
    np.testing.assert_array_equal(got, want.astype(np.uint32))


@pytest.mark.parametrize("op", ["and", "or", "xor"])
@pytest.mark.parametrize("q", [8, 16, 32])
def test_logic_words(op, q):
    rng = np.random.default_rng(q)
    a, b = rand_words(rng, 128, q), rand_words(rng, 128, q)
    got = np.asarray(ref.logic_words(jnp.asarray(a), jnp.asarray(b), q, op))
    f = {"and": np.bitwise_and, "or": np.bitwise_or, "xor": np.bitwise_xor}[op]
    np.testing.assert_array_equal(got, f(a, b) & np.uint32((1 << q) - 1))


def test_logic_rejects_unknown_op():
    a = jnp.zeros(4, jnp.uint32)
    with pytest.raises(ValueError):
        ref.logic_words(a, a, 8, "nand")


@pytest.mark.parametrize("q", [0, 33, -1])
def test_mask_rejects_bad_width(q):
    with pytest.raises(ValueError):
        ref.mask(q)


@settings(max_examples=25, deadline=None)
@given(
    q=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    cin=st.sampled_from([0, 1]),
)
def test_bit_serial_reference_is_modular_add(q, seed, cin):
    """The cycle-by-cycle hardware schedule == q-bit modular add."""
    rng = np.random.default_rng(seed)
    a, b = rand_words(rng, 32, q), rand_words(rng, 32, q)
    bits = ref.unpack_bits(jnp.asarray(a), q)
    op_bits = ref.unpack_bits(jnp.asarray(b), q)
    carry = jnp.full((32,), cin, dtype=jnp.uint32)
    out = ref.bit_serial_add_reference(bits, op_bits, carry, q)
    got = np.asarray(ref.pack_bits(out, q))
    want = ((a.astype(np.uint64) + b + cin) % (1 << q)).astype(np.uint32)
    np.testing.assert_array_equal(got, want)


def test_bit_serial_carry_chain():
    """Worst-case ripple: 0xFFFF + 1 must wrap to 0 (full carry chain)."""
    q = 16
    a = jnp.asarray(np.full(8, (1 << q) - 1, dtype=np.uint32))
    b = jnp.asarray(np.ones(8, dtype=np.uint32))
    out = ref.bit_serial_add_reference(
        ref.unpack_bits(a, q), ref.unpack_bits(b, q),
        jnp.zeros(8, jnp.uint32), q,
    )
    np.testing.assert_array_equal(np.asarray(ref.pack_bits(out, q)), 0)
