"""Layer-2 model tests: word-level wrappers, scan, masking, registry."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def rand(rng, shape, q):
    return jnp.asarray(rng.integers(0, 2**q, size=shape, dtype=np.uint32))


@pytest.mark.parametrize("q", [8, 16, 32])
def test_batch_add_words(q):
    rng = np.random.default_rng(q)
    a, b = rand(rng, 128, q), rand(rng, 128, q)
    (got,) = model.batch_add_words(a, b, q=q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.add_words(a, b, q)))


@pytest.mark.parametrize("q", [8, 16])
def test_batch_sub_words(q):
    rng = np.random.default_rng(q + 1)
    a, b = rand(rng, 128, q), rand(rng, 128, q)
    (got,) = model.batch_sub_words(a, b, q=q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.sub_words(a, b, q)))


@pytest.mark.parametrize("op", ["and", "or", "xor"])
def test_batch_logic_words(op):
    q = 16
    rng = np.random.default_rng(3)
    a, b = rand(rng, 128, q), rand(rng, 128, q)
    (got,) = model.batch_logic_words(a, b, q=q, op=op)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.logic_words(a, b, q, op))
    )


def test_result_masked_to_q_bits():
    """Inputs with junk above bit q-1 must not leak into results."""
    q = 8
    a = jnp.asarray(np.array([0xFFFFFF00 | 5] * 128, dtype=np.uint32))
    b = jnp.asarray(np.array([0xABCDEF00 | 7] * 128, dtype=np.uint32))
    (got,) = model.batch_add_words(a, b, q=q)
    assert np.all(np.asarray(got) == 12)


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
def test_accumulate_rounds(t, seed):
    q = 16
    rng = np.random.default_rng(seed)
    table = rand(rng, 128, q)
    rounds = rand(rng, (t, 128), q)
    (got,) = model.accumulate_rounds(table, rounds, q=q)
    want = np.asarray(table, dtype=np.uint64)
    for i in range(t):
        want = (want + np.asarray(rounds)[i]) % (1 << q)
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.uint32))


def test_registry_complete_and_wellformed():
    reg = model.artifact_registry()
    # Everything the Rust runtime expects must be present.
    for required in [
        "fast_add_128x8", "fast_add_128x16", "fast_add_128x32",
        "fast_sub_128x16", "fast_and_128x16", "fast_or_128x16",
        "fast_xor_128x16", "fast_add_1024x16", "fast_scan8_128x16",
    ]:
        assert required in reg, required
    for name, spec in reg.items():
        meta = spec["meta"]
        assert meta["name"] == name
        assert meta["rows"] % 128 == 0
        assert 1 <= meta["q"] <= 32
        assert meta["inputs"] and meta["outputs"]


def test_registry_fns_run():
    """Every registered artifact fn executes on its example shapes."""
    reg = model.artifact_registry()
    rng = np.random.default_rng(0)
    for name, spec in reg.items():
        args = [
            jnp.asarray(rng.integers(0, 2**16, size=a.shape, dtype=np.uint32))
            for a in spec["args"]
        ]
        out = spec["fn"](*args)
        assert isinstance(out, tuple) and len(out) == 1, name
        assert out[0].dtype == jnp.uint32, name
