"""Structural regression tests on the L1 TPU resource estimates."""

import pytest

from compile.tpu_estimate import (
    VMEM_BYTES,
    VPU_LANES,
    estimate_shift_add,
    render,
)


@pytest.mark.parametrize("q", [8, 16, 32])
def test_block_fits_comfortably_in_vmem(q):
    est = estimate_shift_add(128, q)
    # The whole working set must stay far below VMEM so double-buffering
    # and multiple concurrent blocks remain possible.
    assert est.vmem_frac < 0.01, f"block uses {est.vmem_frac:.2%} of VMEM"


def test_row_block_saturates_lanes():
    est = estimate_shift_add(128, 16)
    assert est.lane_utilization == 1.0
    assert VPU_LANES == 128


def test_cycles_scale_linearly_with_q():
    c8 = estimate_shift_add(128, 8).est_cycles_per_block
    c16 = estimate_shift_add(128, 16).est_cycles_per_block
    c32 = estimate_shift_add(128, 32).est_cycles_per_block
    assert c16 == 2 * c8
    assert c32 == 2 * c16


def test_grid_scales_with_rows_not_cycles():
    small = estimate_shift_add(128, 16)
    big = estimate_shift_add(1024, 16)
    assert big.grid_steps == 8 * small.grid_steps
    assert big.est_cycles_per_block == small.est_cycles_per_block


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        estimate_shift_add(100, 16)
    with pytest.raises(ValueError):
        estimate_shift_add(128, 0)
    with pytest.raises(ValueError):
        estimate_shift_add(128, 33)


def test_render_mentions_key_figures():
    s = render(estimate_shift_add(128, 16))
    assert "VMEM" in s and "lane utilization" in s
    assert "100%" in s


def test_vmem_constant_sane():
    assert VMEM_BYTES == 16 * 1024 * 1024
