"""AOT pipeline smoke tests: lowering to HLO text + manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_to_hlo_text_smoke():
    reg = model.artifact_registry()
    spec = reg["fast_add_128x16"]
    lowered = jax.jit(spec["fn"]).lower(*spec["args"])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "u32[128]" in text


def test_build_single_artifact(tmp_path):
    aot.build_all(str(tmp_path), only="fast_add_128x16")
    files = os.listdir(tmp_path)
    assert "fast_add_128x16.hlo.txt" in files
    assert "manifest.json" in files
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["return_tuple"] is True
    (entry,) = manifest["artifacts"]
    assert entry["name"] == "fast_add_128x16"
    assert entry["rows"] == 128 and entry["q"] == 16
    text = (tmp_path / entry["file"]).read_text()
    import hashlib
    assert hashlib.sha256(text.encode()).hexdigest() == entry["sha256"]


def test_lowered_artifact_executes_correctly():
    """Execute the exact computation that gets shipped to Rust (compiled
    from its stablehlo) and check the numbers — the strongest build-time
    signal that the artifact semantics are right."""
    reg = model.artifact_registry()
    spec = reg["fast_add_128x16"]
    compiled = jax.jit(spec["fn"]).lower(*spec["args"]).compile()
    rng = np.random.default_rng(123)
    a = jnp.asarray(rng.integers(0, 2**16, size=128, dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**16, size=128, dtype=np.uint32))
    (got,) = compiled(a, b)
    want = (np.asarray(a).astype(np.uint64) + np.asarray(b)) % (1 << 16)
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.uint32))
