//! Shared micro-bench harness for the `cargo bench` targets (criterion
//! is not in the offline vendor set — DESIGN.md §7).
//!
//! Methodology: warmup iterations, then `iters` timed runs; report the
//! 10%-trimmed mean ± stddev and min, which is robust to scheduler
//! noise on shared machines. Black-box the result to defeat DCE.
#![allow(dead_code)] // each bench binary uses a subset of the helpers

use std::hint::black_box;
use std::time::Instant;

use fast_sram::util::stats;

/// One benchmark's timing summary (nanoseconds per iteration).
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub trimmed_mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

/// Time `f` and print a criterion-style line.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let s = BenchStats {
        trimmed_mean_ns: stats::trimmed_mean(&samples, 0.1),
        stddev_ns: stats::stddev(&samples),
        min_ns: stats::min(&samples),
        iters,
    };
    println!(
        "bench {name:<44} {:>12.0} ns/iter (± {:>8.0}, min {:>10.0}, n={})",
        s.trimmed_mean_ns, s.stddev_ns, s.min_ns, s.iters
    );
    s
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// True when CI asked for the reduced-size smoke run
/// (`FAST_BENCH_SMOKE=1`; any value other than "0" enables it).
pub fn smoke_mode() -> bool {
    std::env::var("FAST_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Simple throughput formatter.
pub fn ops_per_sec(ops: u64, ns: f64) -> f64 {
    ops as f64 / (ns / 1e9)
}
