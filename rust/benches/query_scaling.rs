//! Query-scaling bench: in-array reduction throughput of the
//! plane-wise kernels (bit-plane tier) vs the scalar reference path
//! (word-fast tier) as the row count sweeps 128 / 1024 / 8192 — the
//! acceptance bar for the plane-wise engine (≥ 20× the scalar path's
//! row-reductions/s at 8192 rows, on the `sum` reduction).
//!
//! Before timing anything, every size runs a cross-backend equivalence
//! check (values + canonical pass reports across phase / word /
//! bit-plane / digital), so a kernel that got fast by getting wrong
//! fails here, not in the plot.
//!
//! Run: `cargo bench --bench query_scaling`
//! Writes: ../BENCH_query_scaling.json (relative to rust/)
//! Env: FAST_BENCH_SMOKE=1 shrinks iteration counts for CI smoke runs
//! (sizes are unchanged so the acceptance ratio stays meaningful).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fast_sram::coordinator::{Backend, BitPlaneBackend, DigitalBackend, FastBackend};
use fast_sram::fastmem::Fidelity;
use fast_sram::query::{seeded_mask, QuerySpec, Reduction};
use fast_sram::util::rng::Rng;

const Q: usize = 16;
const SIZES: [usize; 3] = [128, 1024, 8192];

/// Identical pseudo-random row state for every backend at a size.
fn state(rows: usize) -> Vec<u32> {
    let mut rng = Rng::new(0x9E4B + rows as u64);
    (0..rows).map(|_| rng.below(1 << Q) as u32).collect()
}

fn load(b: &mut dyn Backend, init: &[u32]) {
    for (r, v) in init.iter().enumerate() {
        b.write_row(r, *v).expect("loading bench state");
    }
}

/// The reductions the bench times; `sum` carries the acceptance bar.
fn specs(rows: usize) -> Vec<(&'static str, QuerySpec)> {
    vec![
        ("sum", QuerySpec::all(Reduction::Sum)),
        (
            "range+mask",
            QuerySpec::masked(
                Reduction::RangeCount { lo: 100, hi: 40_000 },
                seeded_mask(11, 75, rows),
            ),
        ),
    ]
}

/// Cross-backend equivalence check: every reduction must answer the
/// same value with the same canonical pass report on all four
/// backends before any of them gets timed.
fn verify(rows: usize) {
    let init = state(rows);
    let mut backends: Vec<(&'static str, Box<dyn Backend>)> = vec![
        (
            "phase",
            Box::new(FastBackend::with_rows_fidelity(rows, Q, Fidelity::PhaseAccurate)),
        ),
        (
            "word",
            Box::new(FastBackend::with_rows_fidelity(rows, Q, Fidelity::WordFast)),
        ),
        ("bitplane", Box::new(BitPlaneBackend::with_rows(rows, Q))),
        ("digital", Box::new(DigitalBackend::new(rows, Q))),
    ];
    for (_, b) in &mut backends {
        load(b.as_mut(), &init);
    }
    for (name, spec) in specs(rows) {
        let mut outcomes = Vec::new();
        for (label, b) in &mut backends {
            outcomes.push((*label, b.query(&spec).expect("query")));
        }
        let (_, want) = &outcomes[0];
        for (label, got) in &outcomes[1..] {
            assert_eq!(
                (got.value, got.report),
                (want.value, want.report),
                "{name} diverged on {label} at {rows} rows"
            );
        }
    }
    println!("verify {rows:>5} rows: all backends agree (values + reports)");
}

/// Timed queries per (impl, rows) — scaled so each run stays in
/// sensible wall-clock territory while remaining measurable.
fn queries_for(plane: bool, rows: usize, smoke: bool) -> usize {
    let full = if plane {
        match rows {
            128 => 40_000,
            1024 => 8000,
            _ => 1600,
        }
    } else {
        match rows {
            128 => 8000,
            1024 => 1200,
            _ => 160,
        }
    };
    if smoke { (full / 10).max(1) } else { full }
}

struct QueryResultRow {
    rows: usize,
    imp: &'static str,
    reduction: &'static str,
    queries: usize,
    wall_ms: f64,
    red_rows_per_sec: f64,
}

fn bench_impl(rows: usize, plane: bool, smoke: bool) -> Vec<QueryResultRow> {
    let init = state(rows);
    let mut backend: Box<dyn Backend> = if plane {
        Box::new(BitPlaneBackend::with_rows(rows, Q))
    } else {
        Box::new(FastBackend::with_rows_fidelity(rows, Q, Fidelity::WordFast))
    };
    load(backend.as_mut(), &init);
    let imp = if plane { "plane" } else { "scalar" };
    let queries = queries_for(plane, rows, smoke);
    let mut out = Vec::new();
    for (reduction, spec) in specs(rows) {
        backend.query(&spec).expect("warmup query");
        let t0 = Instant::now();
        let mut sink = 0u64;
        for _ in 0..queries {
            sink = sink.wrapping_add(backend.query(&spec).expect("query").value);
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        // Defeat dead-code elimination through the accumulated values.
        std::hint::black_box(sink);
        out.push(QueryResultRow {
            rows,
            imp,
            reduction,
            queries,
            wall_ms: wall * 1e3,
            red_rows_per_sec: (rows * queries) as f64 / wall,
        });
    }
    out
}

fn main() {
    let smoke = harness::smoke_mode();
    harness::section(&format!(
        "query scaling: rows {SIZES:?} x q={Q}, plane-wise vs scalar{}",
        if smoke { " [smoke]" } else { "" }
    ));

    // Equivalence first: a fast-but-wrong kernel must fail loudly.
    for rows in SIZES {
        verify(rows);
    }

    let mut results: Vec<QueryResultRow> = Vec::new();
    for rows in SIZES {
        for plane in [false, true] {
            for r in bench_impl(rows, plane, smoke) {
                println!(
                    "{:>5} rows | {:<6} | {:<10} | {:>6} queries | {:>9.2} ms | {:>14.0} red-rows/s",
                    r.rows, r.imp, r.reduction, r.queries, r.wall_ms, r.red_rows_per_sec
                );
                results.push(r);
            }
        }
    }

    let ops = |rows: usize, imp: &str, reduction: &str| {
        results
            .iter()
            .find(|r| r.rows == rows && r.imp == imp && r.reduction == reduction)
            .expect("result present")
            .red_rows_per_sec
    };
    let speedup = ops(8192, "plane", "sum") / ops(8192, "scalar", "sum");
    let pass = speedup >= 20.0;
    println!(
        "\nacceptance: plane {:.0} vs scalar {:.0} red-rows/s at 8192 rows (sum) \
         -> {:.1}x ({})",
        ops(8192, "plane", "sum"),
        ops(8192, "scalar", "sum"),
        speedup,
        if pass { "PASS" } else { "FAIL (need >= 20x)" }
    );

    let mut rows_json = String::new();
    for r in &results {
        if !rows_json.is_empty() {
            rows_json.push_str(",\n");
        }
        rows_json.push_str(&format!(
            "    {{\"rows\": {}, \"impl\": \"{}\", \"reduction\": \"{}\", \"queries\": {}, \"wall_ms\": {:.3}, \"red_rows_per_sec\": {:.0}}}",
            r.rows, r.imp, r.reduction, r.queries, r.wall_ms, r.red_rows_per_sec
        ));
    }
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"query_scaling\",\n  \"status\": \"measured\",\n  \"mode\": \"{}\",\n  \"q\": {Q},\n  \"host_parallelism\": {host_threads},\n  \"results\": [\n{rows_json}\n  ],\n  \"acceptance\": {{\"criterion\": \"red_rows_per_sec(plane) >= 20 * red_rows_per_sec(scalar) at 8192 rows on sum\", \"speedup\": {speedup:.1}, \"pass\": {pass}}}\n}}\n",
        if smoke { "smoke" } else { "full" }
    );
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_query_scaling.json");
    std::fs::write(out_path, json).expect("writing BENCH_query_scaling.json");
    println!("results written to {out_path}");

    assert!(
        pass,
        "plane-wise queries must be >= 20x the scalar path at 8192 rows, got {speedup:.1}x"
    );
}
