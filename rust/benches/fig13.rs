//! Bench E-F13: regenerate Fig. 13 (shmoo plot) and time the sweep.
//!
//! Run: `cargo bench --bench fig13`

#[path = "harness.rs"]
mod harness;

use fast_sram::experiments::fig13;
use fast_sram::timing::{ShmooConfig, ShmooModel};

fn main() {
    harness::section("Fig. 13 — shmoo plot");
    let grid = fig13::run();
    print!("{}", fig13::render(&grid));

    let f10 = grid.max_pass_freq(1.0).unwrap();
    let f12 = grid.max_pass_freq(1.2).unwrap();
    assert!((f10 - 0.8).abs() < 0.11, "silicon anchor @1.0V drifted: {f10}");
    assert!((f12 - 1.2).abs() < 0.11, "silicon anchor @1.2V drifted: {f12}");

    harness::section("sweep cost");
    let model = ShmooModel::default();
    let mut cfg = ShmooConfig::default();
    cfg.vdd_steps = 61;
    cfg.freq_steps = 181; // fine grid
    harness::bench("shmoo sweep 61x181", 2, 20, || model.sweep(&cfg));
}
