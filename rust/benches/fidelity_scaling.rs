//! Fidelity-scaling bench: batch-op throughput of the three model
//! tiers (phase-accurate / word-fast / bit-plane) as the row count
//! sweeps 128 / 1024 / 8192 — the acceptance bar for the bit-plane
//! tier (≥ 20× the word-fast tier's row-ops/s at 8192 rows).
//!
//! Before timing anything, every size runs a short cross-tier
//! equivalence check (values + lifetime toggle counters), so a tier
//! that got fast by getting wrong fails here, not in the plot.
//!
//! Run: `cargo bench --bench fidelity_scaling`
//! Writes: ../BENCH_fidelity_scaling.json (relative to rust/)
//! Env: FAST_BENCH_SMOKE=1 shrinks iteration counts for CI smoke runs
//! (sizes are unchanged so the acceptance ratio stays meaningful).

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use fast_sram::fastmem::{FastArray, Fidelity};
use fast_sram::util::rng::Rng;

const Q: usize = 16;
const SIZES: [usize; 3] = [128, 1024, 8192];

/// Timed batches per (tier, rows) — scaled so each tier's run stays in
/// sensible wall-clock territory while remaining measurable.
fn batches_for(f: Fidelity, rows: usize, smoke: bool) -> usize {
    let full = match f {
        Fidelity::PhaseAccurate => match rows {
            128 => 30,
            1024 => 8,
            _ => 3,
        },
        Fidelity::WordFast => match rows {
            128 => 2000,
            1024 => 400,
            _ => 100,
        },
        Fidelity::BitPlane => match rows {
            128 => 20_000,
            1024 => 4000,
            _ => 1000,
        },
    };
    if smoke { (full / 10).max(1) } else { full }
}

/// Identical pseudo-random operand streams for every tier at a size.
fn streams(rows: usize) -> (Vec<u32>, Vec<Vec<u32>>) {
    let mut rng = Rng::new(0xF1DE + rows as u64);
    let init: Vec<u32> = (0..rows).map(|_| rng.below(1 << Q) as u32).collect();
    let deltas = (0..4)
        .map(|_| (0..rows).map(|_| rng.below(1 << Q) as u32).collect())
        .collect();
    (init, deltas)
}

/// Cross-tier equivalence check: same short batch sequence on all
/// three tiers must yield identical state and toggle counters.
fn verify(rows: usize) {
    let (init, deltas) = streams(rows);
    let mut arrays: Vec<FastArray> = [
        Fidelity::PhaseAccurate,
        Fidelity::WordFast,
        Fidelity::BitPlane,
    ]
    .into_iter()
    .map(|f| FastArray::with_fidelity(rows, Q, f))
    .collect();
    for a in &mut arrays {
        a.load(&init);
        for d in &deltas {
            a.batch_add(d);
        }
    }
    let want = arrays[0].peek_rows();
    let want_toggles = arrays[0].toggles();
    for a in &arrays[1..] {
        assert_eq!(a.peek_rows(), want, "tier state diverged at {rows} rows");
        assert_eq!(
            a.toggles(),
            want_toggles,
            "tier toggle accounting diverged at {rows} rows"
        );
    }
    println!("verify {rows:>5} rows: all tiers agree (values + toggles)");
}

struct TierResult {
    rows: usize,
    /// Tier label from `Fidelity`'s Display impl (single source of truth).
    tier: String,
    batches: usize,
    wall_ms: f64,
    row_ops_per_sec: f64,
}

fn bench_tier(rows: usize, fidelity: Fidelity, batches: usize) -> TierResult {
    let (init, deltas) = streams(rows);
    let mut a = FastArray::with_fidelity(rows, Q, fidelity);
    a.load(&init);
    a.batch_add(&deltas[0]); // warmup: allocator, lazy transpose
    let t0 = Instant::now();
    for i in 0..batches {
        a.batch_add(&deltas[i % deltas.len()]);
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    // Defeat dead-code elimination through the result state.
    assert!(std::hint::black_box(a.peek_word(0, 0).unwrap()) <= 0xFFFF);
    TierResult {
        rows,
        tier: fidelity.to_string(),
        batches,
        wall_ms: wall * 1e3,
        row_ops_per_sec: (rows * batches) as f64 / wall,
    }
}

fn main() {
    let smoke = harness::smoke_mode();
    harness::section(&format!(
        "fidelity scaling: rows {SIZES:?} x q={Q}, tiers phase/word/bitplane{}",
        if smoke { " [smoke]" } else { "" }
    ));

    // Equivalence first: a fast-but-wrong tier must fail loudly.
    for rows in SIZES {
        verify(rows);
    }

    let mut results: Vec<TierResult> = Vec::new();
    for rows in SIZES {
        for f in [Fidelity::PhaseAccurate, Fidelity::WordFast, Fidelity::BitPlane] {
            let r = bench_tier(rows, f, batches_for(f, rows, smoke));
            println!(
                "{:>5} rows | {:<8} | {:>6} batches | {:>9.2} ms | {:>14.0} row-ops/s",
                r.rows, r.tier, r.batches, r.wall_ms, r.row_ops_per_sec
            );
            results.push(r);
        }
    }

    let ops = |rows: usize, tier: &str| {
        results
            .iter()
            .find(|r| r.rows == rows && r.tier == tier)
            .expect("result present")
            .row_ops_per_sec
    };
    let speedup = ops(8192, "bitplane") / ops(8192, "word");
    let pass = speedup >= 20.0;
    println!(
        "\nacceptance: bitplane {:.0} vs word {:.0} row-ops/s at 8192 rows \
         -> {:.1}x ({})",
        ops(8192, "bitplane"),
        ops(8192, "word"),
        speedup,
        if pass { "PASS" } else { "FAIL (need >= 20x)" }
    );

    let mut rows_json = String::new();
    for r in &results {
        if !rows_json.is_empty() {
            rows_json.push_str(",\n");
        }
        rows_json.push_str(&format!(
            "    {{\"rows\": {}, \"tier\": \"{}\", \"batches\": {}, \"wall_ms\": {:.3}, \"row_ops_per_sec\": {:.0}}}",
            r.rows, r.tier, r.batches, r.wall_ms, r.row_ops_per_sec
        ));
    }
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let json = format!(
        "{{\n  \"bench\": \"fidelity_scaling\",\n  \"status\": \"measured\",\n  \"mode\": \"{}\",\n  \"q\": {Q},\n  \"host_parallelism\": {host_threads},\n  \"results\": [\n{rows_json}\n  ],\n  \"acceptance\": {{\"criterion\": \"row_ops_per_sec(bitplane) >= 20 * row_ops_per_sec(word) at 8192 rows\", \"speedup\": {speedup:.1}, \"pass\": {pass}}}\n}}\n",
        if smoke { "smoke" } else { "full" }
    );
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fidelity_scaling.json");
    std::fs::write(out_path, json).expect("writing BENCH_fidelity_scaling.json");
    println!("results written to {out_path}");

    assert!(
        pass,
        "bit-plane tier must be >= 20x the word-fast tier at 8192 rows, got {speedup:.1}x"
    );
}
