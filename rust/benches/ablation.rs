//! Ablation bench: quantify each coordinator design choice that
//! DESIGN.md calls out — coalescing, seal threshold, bank count, and
//! word width (the Fig. 5c reconfiguration) — on the same workload.
//!
//! Run: `cargo bench --bench ablation`

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use fast_sram::coordinator::{
    Batcher, EngineConfig, FastBackend, UpdateEngine, UpdateRequest,
};
use fast_sram::energy::FastModel;
use fast_sram::util::rng::Rng;

/// Modeled macro time for a stream with a given seal threshold.
fn run_with_seal(rows: usize, seal: Option<usize>, updates: usize) -> (u64, f64, f64) {
    let mut cfg = EngineConfig::new(rows, 16);
    cfg.seal_at_rows = seal;
    cfg.seal_deadline = Duration::from_micros(300);
    cfg.queue_cap = 16_384;
    let e = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();
    let mut rng = Rng::new(5);
    let mut chunk = Vec::with_capacity(2048);
    for _ in 0..updates {
        chunk.push(UpdateRequest::add(rng.below(rows as u64) as usize, 3));
        if chunk.len() == 2048 {
            e.submit_many(std::mem::take(&mut chunk)).unwrap();
        }
    }
    e.submit_many(chunk).unwrap();
    e.drain_shard(0).unwrap(); // single-shard config: one per-shard drain
    let s = e.stats();
    let out = (s.batches, s.modeled_ns, s.rows_per_batch);
    e.shutdown().unwrap();
    out
}

fn main() {
    let rows = 1024;
    let updates = 100_000;

    harness::section("ablation 1 — coalescing batcher vs naive one-batch-per-request");
    {
        let (batches, modeled_ns, rpb) = run_with_seal(rows, Some(rows * 3 / 4), updates);
        // Naive lower bound: every request becomes its own 16-cycle batch.
        let per_batch = FastModel::default().batch_op(128, 16).latency_ns;
        let naive_ns = per_batch * updates as f64;
        println!(
            "coalescing ON : {batches} batches, {rpb:.1} rows/batch, modeled {:.2} µs",
            modeled_ns / 1000.0
        );
        println!(
            "coalescing OFF (bound): {updates} batches, modeled {:.2} µs  -> {:.0}x worse",
            naive_ns / 1000.0,
            naive_ns / modeled_ns
        );
        assert!(naive_ns / modeled_ns > 50.0);
    }

    harness::section("ablation 2 — seal threshold sweep (batch size vs flush rate)");
    for seal in [Some(64usize), Some(256), Some(768), None] {
        let (batches, modeled_ns, rpb) = run_with_seal(rows, seal, updates);
        println!(
            "seal_at_rows {:>8}: {batches:>5} batches | {rpb:>7.1} rows/batch | modeled {:>9.2} µs",
            seal.map(|s| s.to_string()).unwrap_or_else(|| "deadline".into()),
            modeled_ns / 1000.0
        );
    }

    harness::section("ablation 3 — bank count at fixed 1024-row capacity");
    let model = FastModel::default();
    for banks in [1usize, 2, 4, 8] {
        let rows_per_bank = 1024 / banks;
        let batch = model.batch_op(rows_per_bank, 16);
        // One full-capacity update: all banks fire concurrently.
        println!(
            "{banks} x {rows_per_bank} rows: batch latency {:.2} ns, energy {:.1} pJ \
             (tall banks pay shift-skew; more banks pay area)",
            batch.latency_ns,
            banks as f64 * batch.energy_fj / 1000.0
        );
    }

    harness::section("ablation 4 — word width (Fig. 5c route reconfiguration)");
    for q in [8usize, 16, 32] {
        let c = model.batch_op(128, q);
        let per_op = model.calc_per_op(128, q);
        println!(
            "q={q:>2}: batch {:>5.2} ns | {:>7.3} pJ/OP | words/row at 32 cols: {}",
            c.latency_ns,
            per_op.energy_pj(),
            32 / q
        );
    }

    harness::section("wall-clock: batcher with vs without coalescible traffic");
    let mut rng = Rng::new(9);
    let hot: Vec<UpdateRequest> = (0..50_000)
        .map(|_| UpdateRequest::add(rng.below(32) as usize, 1))
        .collect();
    let cold: Vec<UpdateRequest> = (0..50_000)
        .map(|_| UpdateRequest::add(rng.below(1024) as usize, 1))
        .collect();
    harness::bench("batcher 50k hot-row requests", 1, 10, || {
        let mut b = Batcher::new(1024, 16, None);
        for r in &hot {
            let _ = b.push(*r);
        }
        b.force_flush()
    });
    harness::bench("batcher 50k uniform requests", 1, 10, || {
        let mut b = Batcher::new(1024, 16, None);
        for r in &cold {
            let _ = b.push(*r);
        }
        b.force_flush()
    });
}
