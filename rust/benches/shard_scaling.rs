//! Shard-scaling bench: end-to-end engine throughput as the worker
//! shard count sweeps 1/2/4/8 on a fixed offered load — the
//! no-concurrency-collapse acceptance bar for the sharded coordinator
//! (4-shard throughput must not fall below 1-shard).
//!
//! A fixed pool of producer threads submits the same total update
//! stream for every configuration, so the only variable is the number
//! of engine worker shards batching and applying updates.
//!
//! Run: `cargo bench --bench shard_scaling`
//! Writes: ../BENCH_shard_scaling.json (relative to rust/)
//! Env: FAST_BENCH_SMOKE=1 shrinks the offered load for CI smoke runs.

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use fast_sram::coordinator::{EngineConfig, FastBackend, UpdateEngine, UpdateRequest};
use fast_sram::util::rng::Rng;

const ROWS: usize = 1024;
const Q: usize = 16;
const PRODUCERS: usize = 4;
const CHUNK: usize = 2048;

fn updates_per_producer() -> usize {
    if harness::smoke_mode() { 20_000 } else { 100_000 }
}

struct RunResult {
    shards: usize,
    wall_ms: f64,
    ops_per_sec: f64,
    batches: u64,
    rows_per_batch: f64,
    sealed_full: u64,
    sealed_deadline: u64,
    coalesce_hits: u64,
}

fn run(shards: usize) -> RunResult {
    let mut cfg = EngineConfig::sharded(ROWS, Q, shards);
    cfg.seal_deadline = Duration::from_micros(200);
    cfg.queue_cap = 16_384;
    let engine = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();

    // Pre-generate identical streams so every configuration sees the
    // same offered load.
    let updates = updates_per_producer();
    let streams: Vec<Vec<UpdateRequest>> = (0..PRODUCERS)
        .map(|t| {
            let mut rng = Rng::new(7700 + t as u64);
            (0..updates)
                .map(|_| UpdateRequest::add(rng.below(ROWS as u64) as usize, 1 + rng.below(99) as u32))
                .collect()
        })
        .collect();

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            scope.spawn(move || {
                for chunk in stream.chunks(CHUNK) {
                    engine.submit_many(chunk.to_vec()).unwrap();
                }
            });
        }
    });
    engine.drain_all().unwrap();
    let wall = t0.elapsed();

    let s = engine.stats();
    let total = (PRODUCERS * updates) as u64;
    assert_eq!(s.completed, total, "no request may be dropped");
    let out = RunResult {
        shards,
        wall_ms: wall.as_secs_f64() * 1e3,
        ops_per_sec: total as f64 / wall.as_secs_f64(),
        batches: s.batches,
        rows_per_batch: s.rows_per_batch,
        sealed_full: s.shards.iter().map(|sc| sc.sealed_full).sum(),
        sealed_deadline: s.shards.iter().map(|sc| sc.sealed_deadline).sum(),
        coalesce_hits: s.shards.iter().map(|sc| sc.coalesce_hits).sum(),
    };
    engine.shutdown().unwrap();
    out
}

fn main() {
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let updates = updates_per_producer();
    harness::section(&format!(
        "shard scaling: {ROWS} rows x {Q} bits, {PRODUCERS} producers x {updates} updates (host parallelism {host_threads})"
    ));

    let mut results = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        // Warm-up run to stabilize allocator/thread caches, then the
        // measured run.
        let _ = run(shards);
        let r = run(shards);
        println!(
            "shards {shards}: {:>8.1} ms | {:>10.0} ops/s | {:>6} batches | {:>6.1} rows/batch | seals full/deadline {}/{}",
            r.wall_ms, r.ops_per_sec, r.batches, r.rows_per_batch, r.sealed_full, r.sealed_deadline
        );
        results.push(r);
    }

    let ops1 = results.iter().find(|r| r.shards == 1).unwrap().ops_per_sec;
    let ops4 = results.iter().find(|r| r.shards == 4).unwrap().ops_per_sec;
    let pass = ops4 >= ops1;
    println!(
        "\nacceptance: 4-shard {:.0} ops/s vs 1-shard {:.0} ops/s -> {}",
        ops4,
        ops1,
        if pass { "PASS (no concurrency collapse)" } else { "FAIL" }
    );

    let mut rows_json = String::new();
    for r in &results {
        if !rows_json.is_empty() {
            rows_json.push_str(",\n");
        }
        rows_json.push_str(&format!(
            "    {{\"shards\": {}, \"wall_ms\": {:.3}, \"ops_per_sec\": {:.0}, \"batches\": {}, \"rows_per_batch\": {:.2}, \"sealed_full\": {}, \"sealed_deadline\": {}, \"coalesce_hits\": {}}}",
            r.shards, r.wall_ms, r.ops_per_sec, r.batches, r.rows_per_batch, r.sealed_full, r.sealed_deadline, r.coalesce_hits
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"shard_scaling\",\n  \"status\": \"measured\",\n  \"mode\": \"{}\",\n  \"rows\": {ROWS},\n  \"q\": {Q},\n  \"producers\": {PRODUCERS},\n  \"updates_total\": {},\n  \"host_parallelism\": {host_threads},\n  \"results\": [\n{rows_json}\n  ],\n  \"acceptance\": {{\"criterion\": \"ops_per_sec(shards=4) >= ops_per_sec(shards=1)\", \"pass\": {pass}}}\n}}\n",
        if harness::smoke_mode() { "smoke" } else { "full" },
        PRODUCERS * updates
    );
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_shard_scaling.json");
    std::fs::write(out_path, json).expect("writing BENCH_shard_scaling.json");
    println!("results written to {out_path}");

    // On a multi-core host the sharded engine must not collapse; a
    // single-core host cannot exhibit worker parallelism, so the bar
    // is only enforced where it is meaningful. The hard assert allows
    // 10% scheduler noise (shared CI runners) — "collapse" means
    // dramatically worse, not a jitter loss; the JSON records the
    // strict comparison either way.
    if host_threads >= 2 {
        assert!(
            ops4 >= 0.9 * ops1,
            "concurrency collapse: 4-shard {ops4:.0} ops/s vs 1-shard {ops1:.0} ops/s"
        );
    }
}
