//! Shard-scaling bench: thin wrapper over the library's measured-
//! performance harness (`fast_sram::bench`) — the same grid `fast
//! bench engine` runs, so `cargo bench --bench shard_scaling` and the
//! CLI produce one `BENCH_shard_scaling.json` schema between them.
//!
//! Run: `cargo bench --bench shard_scaling`  (or `fast bench engine`)
//! Writes: ../BENCH_shard_scaling.json (relative to rust/)
//! Env: FAST_BENCH_SMOKE=1 shrinks the offered load for CI smoke runs.

#[path = "harness.rs"]
mod harness;

use fast_sram::bench::{run_engine_grid, GridConfig};

fn main() {
    let cfg = GridConfig::standard();
    harness::section(&format!(
        "shard scaling grid: {} rows x {} bits, {} updates/producer{}",
        cfg.rows,
        cfg.q,
        cfg.updates_per_producer,
        if cfg.smoke { " [smoke]" } else { "" }
    ));
    let report = run_engine_grid(&cfg).expect("engine grid");
    print!("{}", report.render_text());

    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_shard_scaling.json");
    report
        .write_json(std::path::Path::new(out_path))
        .expect("writing BENCH_shard_scaling.json");
    println!("results written to {out_path}");

    // Where the question is meaningful (full mode, >= 8-way host), a
    // collapse is a hard failure; the 3x target itself is recorded in
    // the JSON — measured, not asserted.
    if report.acceptance_judgeable() {
        let ratio = report.scaling_ratio().expect("judgeable implies ratio");
        assert!(
            ratio >= 0.9,
            "concurrency collapse: 8-shard/1-shard ratio {ratio:.2} at 8 producers"
        );
    }
}
