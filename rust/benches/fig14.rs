//! Bench E-F14: regenerate Fig. 14 (area breakdown) across die sizes.
//!
//! Run: `cargo bench --bench fig14`

#[path = "harness.rs"]
mod harness;

use fast_sram::energy::AreaModel;
use fast_sram::experiments::fig14;

fn main() {
    harness::section("Fig. 14 — area breakdown (showcase die)");
    let f = fig14::run(128, 16);
    print!("{}", fig14::render(&f));
    assert!((f.cell_overhead - 0.70).abs() < 0.01);
    assert!((f.macro_overhead - 0.417).abs() < 0.02);

    harness::section("overhead trend across die sizes");
    let m = AreaModel::default();
    println!("rows cols | FAST µm² | SRAM µm² | overhead");
    println!("----------+----------+----------+---------");
    for (rows, cols) in [(128usize, 16usize), (256, 16), (512, 16), (128, 32), (1024, 16)] {
        let fa = m.fast_macro(rows, cols);
        let sa = m.sram_macro(rows, cols);
        println!(
            "{rows:>4} {cols:>4} | {fa:>8.0} | {sa:>8.0} | {:>6.1}%",
            100.0 * (fa / sa - 1.0)
        );
    }
    harness::bench("area breakdown eval", 10, 1000, || m.fast_breakdown(128, 16));
}
