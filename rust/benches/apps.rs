//! Bench E-APP: application workloads through the full coordinator,
//! FAST vs the digital near-memory baseline (Section III.C).
//!
//! Run: `cargo bench --bench apps`

#[path = "harness.rs"]
mod harness;

use fast_sram::experiments::apps_bench::{compare, render, Workload};

fn main() {
    harness::section("E-APP — workload comparison (modeled macro time)");
    let mut pairs = Vec::new();
    for w in [
        Workload::UniformDeltas { updates: 20_000 },
        Workload::SkewedDeltas { updates: 20_000 },
        Workload::GraphRounds { nodes: 128, avg_degree: 4, rounds: 4 },
    ] {
        pairs.push(compare(128, 16, w, 7).expect("workload run"));
    }
    print!("{}", render(&pairs));

    for (f, d) in &pairs {
        let speedup = d.modeled_ns / f.modeled_ns.max(1e-9);
        assert!(
            speedup > 2.0,
            "FAST must beat digital on {}: {speedup:.1}x",
            f.workload
        );
    }

    harness::section("1024-row (8-bank) uniform deltas");
    let (f, d) = compare(1024, 16, Workload::UniformDeltas { updates: 20_000 }, 9)
        .expect("workload run");
    print!("{}", render(&[(f.clone(), d.clone())]));
    let speedup = d.modeled_ns / f.modeled_ns.max(1e-9);
    println!("modeled speedup at 1024 rows: {speedup:.1}x");
    assert!(speedup > 4.0);
}
