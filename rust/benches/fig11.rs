//! Bench E-F11: regenerate Fig. 11 (batch latency + area-normalized
//! efficiency vs rows) and time multi-bank behavioural execution
//! across the row sweep.
//!
//! Run: `cargo bench --bench fig11`

#[path = "harness.rs"]
mod harness;

use fast_sram::coordinator::{BankSet, BatchKind};
use fast_sram::experiments::fig11;
use fast_sram::util::rng::Rng;

fn main() {
    harness::section("Fig. 11 — model sweep");
    let pts = fig11::run();
    print!("{}", fig11::render(&pts));

    // Shape assertions.
    let flat: Vec<_> = pts.iter().filter(|p| p.q == 16).collect();
    let first = flat.first().unwrap();
    let last = flat.last().unwrap();
    assert!(last.fast_latency_ns < 1.2 * first.fast_latency_ns);
    assert!(last.normalized_advantage() > first.normalized_advantage());

    harness::section("bank-parallel wall-clock across row counts (q=16)");
    let mut rng = Rng::new(4);
    for banks in [1usize, 2, 4, 8] {
        let rows = banks * 128;
        let mut set = BankSet::new(banks, 128, 16);
        let deltas: Vec<u32> = (0..rows).map(|_| rng.below(1 << 16) as u32).collect();
        harness::bench(&format!("bankset apply {rows} rows ({banks} banks)"), 2, 15, || {
            set.apply(BatchKind::Add, &deltas).unwrap()
        });
    }
}
