//! Bench E-T1: regenerate Table I and time the three execution paths
//! of the 128-row / 16-bit batch op (behavioural, bank-parallel, XLA).
//!
//! Run: `cargo bench --bench table1`

#[path = "harness.rs"]
mod harness;

use fast_sram::coordinator::BankSet;
use fast_sram::coordinator::BatchKind;
use fast_sram::experiments::table1;
use fast_sram::fastmem::FastArray;
use fast_sram::runtime::Runtime;
use fast_sram::util::rng::Rng;

fn main() {
    harness::section("Table I — model regeneration");
    let t = table1::run(128, 16);
    print!("{}", table1::render(&t));
    assert!((t.energy_ratio - 5.5).abs() < 0.3, "energy ratio drifted");
    assert!((t.speed_ratio - 27.2).abs() < 1.5, "speed ratio drifted");

    harness::section("wall-clock of one 128x16 batch op per path");
    let mut rng = Rng::new(1);
    let deltas: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();

    let mut array = FastArray::new(128, 16);
    harness::bench("behavioural/batch_add(128x16)", 3, 30, || {
        array.batch_add(&deltas)
    });

    let mut banks = BankSet::new(1, 128, 16);
    harness::bench("bankset/apply(1 bank)", 3, 30, || {
        banks.apply(BatchKind::Add, &deltas).unwrap()
    });

    if let Ok(rt) = Runtime::load_filtered("artifacts", |n| n == "fast_add_128x16") {
        let art = rt.get("fast_add_128x16").unwrap();
        let mut state = vec![0u32; 128];
        harness::bench("xla/exec2(fast_add_128x16)", 3, 30, || {
            state = art.exec2(&state, &deltas).unwrap();
        });
    } else {
        println!("(artifacts not built — skipping XLA path; run `make artifacts`)");
    }

    println!("\nmodeled macro batch time: {:.2} ns (16 cycles x 0.2 ns)", 16.0 * 0.2);
}
