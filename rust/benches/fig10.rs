//! Bench E-F10: regenerate Fig. 10 (energy and latency vs bit width)
//! and time the behavioural array across the same width sweep.
//!
//! Run: `cargo bench --bench fig10`

#[path = "harness.rs"]
mod harness;

use fast_sram::experiments::fig10;
use fast_sram::fastmem::FastArray;
use fast_sram::util::rng::Rng;

fn main() {
    harness::section("Fig. 10 — model sweep");
    let pts = fig10::run();
    print!("{}", fig10::render(&pts));

    // Shape assertions (who wins, how it trends).
    for p in &pts {
        assert!(
            p.speedup() > 1.0,
            "FAST must win on batch latency at {}x{}",
            p.rows,
            p.q
        );
    }
    let p512_8 = pts.iter().find(|p| p.rows == 512 && p.q == 8).unwrap();
    assert!(p512_8.energy_ratio() > 4.0, "paper: >4x at 512 rows / 8-bit");

    harness::section("behavioural array wall-clock across widths (128 rows)");
    let mut rng = Rng::new(2);
    for q in [4usize, 8, 16, 32] {
        let mut a = FastArray::new(128, q);
        let deltas: Vec<u32> = (0..128)
            .map(|_| rng.below(1u64 << q) as u32)
            .collect();
        harness::bench(&format!("batch_add 128x{q}"), 2, 20, || a.batch_add(&deltas));
    }
}
