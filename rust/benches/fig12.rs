//! Bench E-F12: regenerate Fig. 12 (noise tolerance / Monte Carlo eye
//! pattern) and time the per-sample transient cost.
//!
//! Run: `cargo bench --bench fig12`

#[path = "harness.rs"]
mod harness;

use fast_sram::analog::montecarlo::MonteCarlo;
use fast_sram::experiments::fig12;

fn main() {
    harness::section("Fig. 12 — Monte Carlo noise margin (500 samples)");
    let f = fig12::run(500, 42);
    print!("{}", fig12::render(&f));
    assert!(
        (0.25..0.45).contains(&f.mc.worst_margin()),
        "worst-case margin must sit near the paper's 300 mV"
    );
    assert_eq!(f.mc.yield_frac(), 1.0);

    harness::section("transient sim cost");
    let mc = MonteCarlo::default();
    harness::bench("one MC sample (4-cell chain, 4 cycles)", 1, 10, || {
        mc.run(1, 7)
    });
}
