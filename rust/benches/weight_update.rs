//! Weight-update bench: the VGG-7 8-bit weight-update task swept over
//! row counts, each size replaying one recorded trace on the word-fast
//! FAST backend, the bit-plane backend and the digital baseline via
//! `experiments::weight_update::run` — which refuses to report unless
//! every backend's final weights are bit-identical to the host oracle,
//! so a backend that got fast by getting wrong fails here, not in the
//! table. The acceptance bar is the paper-anchored pair at the 128×8
//! acceptance config: modeled speedup ≥ 50× and energy efficiency
//! ≥ 3× for FAST vs the digital baseline (paper: 96.0× / 4.4×).
//!
//! Run: `cargo bench --bench weight_update`
//! Writes: ../BENCH_weight_update.json (relative to rust/)
//! Env: FAST_BENCH_SMOKE=1 shrinks step counts for CI smoke runs
//! (sizes are unchanged so the acceptance ratios stay meaningful).

#[path = "harness.rs"]
mod harness;

use fast_sram::apps::trainer::{TrainerConfig, MIN_ENERGY_EFF_X, MIN_SPEEDUP_X};
use fast_sram::experiments::weight_update;

const Q: usize = 8;
const SIZES: [usize; 3] = [128, 512, 1024];

fn config(rows: usize, smoke: bool) -> TrainerConfig {
    let mut cfg = TrainerConfig::vgg7(rows, Q);
    cfg.epochs = 1;
    cfg.steps_per_epoch = if smoke { 2 } else { 16 };
    cfg
}

struct RunResult {
    rows: usize,
    backend: &'static str,
    updates: u64,
    wall_ms: f64,
    modeled_us_per_epoch: f64,
    modeled_nj_per_epoch: f64,
}

fn main() {
    let smoke = harness::smoke_mode();
    harness::section(&format!(
        "VGG-7 weight update: rows {SIZES:?} x q={Q}, backends word/bitplane/digital{}",
        if smoke { " [smoke]" } else { "" }
    ));

    let mut results: Vec<RunResult> = Vec::new();
    let mut acceptance: Option<(f64, f64)> = None;
    for rows in SIZES {
        let cfg = config(rows, smoke);
        // run() replays one recorded trace on all three backends and
        // errors out if any diverges from the host-semantics oracle.
        let report = weight_update::run(&cfg).expect("cross-backend weight-update run");
        for r in &report.runs {
            println!(
                "{:>5} rows | {:<20} | {:>6} updates | {:>9.2} ms wall | {:>9.3} µs/epoch | {:>9.2} nJ/epoch",
                rows,
                r.backend,
                r.updates,
                r.wall_us / 1000.0,
                r.ns_per_epoch() / 1000.0,
                r.pj_per_epoch() / 1000.0,
            );
            results.push(RunResult {
                rows,
                backend: r.backend,
                updates: r.updates,
                wall_ms: r.wall_us / 1000.0,
                modeled_us_per_epoch: r.ns_per_epoch() / 1000.0,
                modeled_nj_per_epoch: r.pj_per_epoch() / 1000.0,
            });
        }
        println!(
            "{rows:>5} rows | FAST vs digital: {:.1}x speed, {:.1}x energy",
            report.speedup, report.energy_eff
        );
        if rows == 128 {
            acceptance = Some((report.speedup, report.energy_eff));
        }
    }

    let (speedup, energy_eff) = acceptance.expect("128-row acceptance point present");
    let pass = speedup >= MIN_SPEEDUP_X && energy_eff >= MIN_ENERGY_EFF_X;
    println!(
        "\nacceptance @128x8: {speedup:.1}x speed (need >= {MIN_SPEEDUP_X}), \
         {energy_eff:.1}x energy (need >= {MIN_ENERGY_EFF_X}) -> {}",
        if pass { "PASS" } else { "FAIL" }
    );

    let mut rows_json = String::new();
    for r in &results {
        if !rows_json.is_empty() {
            rows_json.push_str(",\n");
        }
        rows_json.push_str(&format!(
            "    {{\"rows\": {}, \"backend\": \"{}\", \"updates\": {}, \"wall_ms\": {:.3}, \
             \"modeled_us_per_epoch\": {:.4}, \"modeled_nj_per_epoch\": {:.4}}}",
            r.rows, r.backend, r.updates, r.wall_ms, r.modeled_us_per_epoch, r.modeled_nj_per_epoch
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"weight_update\",\n  \"status\": \"measured\",\n  \"mode\": \"{}\",\n  \
         \"q\": {Q},\n  \"results\": [\n{rows_json}\n  ],\n  \"acceptance\": {{\"criterion\": \
         \"modeled speedup >= {MIN_SPEEDUP_X}x and energy efficiency >= {MIN_ENERGY_EFF_X}x for FAST vs \
         digital at 128 rows x 8 bits (paper anchors: 96.0x / 4.4x)\", \"speedup\": {speedup:.1}, \
         \"energy_eff\": {energy_eff:.1}, \"pass\": {pass}}}\n}}\n",
        if smoke { "smoke" } else { "full" }
    );
    let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_weight_update.json");
    std::fs::write(out_path, json).expect("writing BENCH_weight_update.json");
    println!("results written to {out_path}");

    assert!(
        pass,
        "paper-anchored bars not met at 128x8: {speedup:.1}x speed / {energy_eff:.1}x energy"
    );
}
