//! Bench E-F7/E-F8: regenerate the transient waveform figures and time
//! the RC simulator.
//!
//! Run: `cargo bench --bench waveforms`

#[path = "harness.rs"]
mod harness;

use fast_sram::experiments::waveforms;

fn main() {
    harness::section("Fig. 7 — shift transients (4 cells, 800 MHz)");
    let f7 = waveforms::run_fig7(1.25);
    print!("{}", waveforms::render_fig7(&f7, 72));
    assert_eq!(f7.initial, f7.after_full_rotation);

    harness::section("Fig. 8 — 4-bit add transients");
    let f8 = waveforms::run_fig8(1.25, 0b0101, 0b0110);
    print!("{}", waveforms::render_fig8(&f8, 72));
    assert_eq!(f8.result, 0b1011);

    harness::section("transient simulator cost");
    harness::bench("fig7 sim (4 cells x 4 cycles + traces)", 1, 8, || {
        waveforms::run_fig7(1.25)
    });
    harness::bench("fig8 sim (FA add, 4 cycles + traces)", 1, 8, || {
        waveforms::run_fig8(1.25, 5, 6)
    });
}
