//! L3 performance bench: wall-clock cost of the coordinator itself —
//! batcher throughput, engine submit path, bank-parallel scaling, WAL
//! durability overhead, and XLA execution latency. This is the §Perf
//! measurement target for Layer 3 (the coordinator must not be the
//! bottleneck).
//!
//! Run: `cargo bench --bench coordinator_perf`
//! Writes: ../BENCH_wal_overhead.json (relative to rust/)
//! Env: FAST_BENCH_SMOKE=1 shrinks the WAL-overhead load for CI smoke
//! runs (the acceptance ratio is asserted in full mode only — smoke
//! loads are too small for a stable ratio, but the JSON still flips to
//! status=measured so the CI gate can check the bench actually ran).

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use fast_sram::coordinator::{
    Batcher, EngineConfig, FastBackend, UpdateEngine, UpdateRequest, XlaBackend,
};
use fast_sram::durability::{DurabilityConfig, FsyncPolicy};
use fast_sram::util::rng::Rng;

fn main() {
    harness::section("batcher micro-benchmarks");
    let mut rng = Rng::new(1);
    let reqs: Vec<UpdateRequest> = (0..100_000)
        .map(|_| UpdateRequest::add(rng.below(1024) as usize, rng.below(1 << 16) as u32))
        .collect();
    let s = harness::bench("batcher push+flush 100k reqs (1024 rows)", 1, 10, || {
        let mut b = Batcher::new(1024, 16, None);
        for r in &reqs {
            let _ = b.push(*r);
        }
        b.force_flush()
    });
    println!(
        "  -> batcher throughput: {:.1} M req/s",
        harness::ops_per_sec(100_000, s.trimmed_mean_ns) / 1e6
    );

    harness::section("engine end-to-end submit throughput (wall-clock)");
    for (label, rows) in [("1 bank / 128 rows", 128usize), ("8 banks / 1024 rows", 1024)] {
        let mut cfg = EngineConfig::new(rows, 16);
        cfg.seal_deadline = Duration::from_micros(200);
        cfg.queue_cap = 65_536;
        let engine = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        let n = 200_000u64;
        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        for _ in 0..n {
            let row = rng.below(rows as u64) as usize;
            engine
                .submit_blocking(UpdateRequest::add(row, 1))
                .unwrap();
        }
        engine.drain_shard(0).unwrap();
        let dt = t0.elapsed();
        let stats = engine.stats();
        println!(
            "engine[{label}]: {:.2} M updates/s wall | {} batches | {:.1} rows/batch | apply p99 {} ns",
            n as f64 / dt.as_secs_f64() / 1e6,
            stats.batches,
            stats.rows_per_batch,
            stats.apply_wall.p99_ns
        );
        engine.shutdown().unwrap();
    }

    harness::section("bulk submit (submit_many) throughput");
    {
        let rows = 1024usize;
        let mut cfg = EngineConfig::new(rows, 16);
        cfg.seal_deadline = Duration::from_micros(200);
        cfg.queue_cap = 1024;
        let engine = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        let n = 400_000u64;
        let mut rng = Rng::new(13);
        let t0 = Instant::now();
        let mut chunk = Vec::with_capacity(4096);
        for _ in 0..n {
            chunk.push(UpdateRequest::add(rng.below(rows as u64) as usize, 1));
            if chunk.len() == 4096 {
                engine.submit_many(std::mem::take(&mut chunk)).unwrap();
                chunk.reserve(4096);
            }
        }
        engine.submit_many(chunk).unwrap();
        engine.drain_shard(0).unwrap();
        let dt = t0.elapsed();
        let stats = engine.stats();
        println!(
            "engine[bulk 1024 rows]: {:.2} M updates/s wall | {} batches | {:.1} rows/batch",
            n as f64 / dt.as_secs_f64() / 1e6,
            stats.batches,
            stats.rows_per_batch
        );
        engine.shutdown().unwrap();
    }

    harness::section("WAL durability overhead (ticketed, fsync=interval)");
    {
        // Acceptance bar (ISSUE 5): WAL-on ticketed throughput within
        // 1.5x of WAL-off with fsync=interval — durability must ride
        // the group-commit seals, not add a syscall per request.
        let rows = 1024usize;
        let n: u64 = if harness::smoke_mode() { 40_000 } else { 400_000 };
        let run = |wal_dir: Option<std::path::PathBuf>| -> (f64, u64, u64) {
            let mut cfg = EngineConfig::sharded(rows, 16, 4);
            cfg.seal_deadline = Duration::from_micros(200);
            cfg.queue_cap = 16_384;
            if let Some(dir) = wal_dir {
                let mut d = DurabilityConfig::new(dir);
                d.fsync = FsyncPolicy::Interval(Duration::from_micros(2000));
                cfg.durability = Some(d);
            }
            let engine = UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
            })
            .unwrap();
            let mut rng = Rng::new(99);
            let mut chunk = Vec::with_capacity(2048);
            let mut tickets = Vec::new();
            let t0 = Instant::now();
            for _ in 0..n {
                chunk.push(UpdateRequest::add(rng.below(rows as u64) as usize, 1));
                if chunk.len() == 2048 {
                    tickets.extend(engine.submit_many_ticketed(std::mem::take(&mut chunk)).unwrap());
                    chunk.reserve(2048);
                }
            }
            tickets.extend(engine.submit_many_ticketed(chunk).unwrap());
            engine.drain_all().unwrap();
            for t in &tickets {
                t.wait().unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            let s = engine.stats();
            let fsyncs: u64 = s.shards.iter().map(|sc| sc.wal_fsyncs).sum();
            let records: u64 = s.shards.iter().map(|sc| sc.wal_records).sum();
            engine.shutdown().unwrap();
            (n as f64 / dt, records, fsyncs)
        };

        let tmp = std::env::temp_dir().join(format!("fast-wal-bench-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let (off_ops, _, _) = run(None);
        let (on_ops, records, fsyncs) = run(Some(tmp.clone()));
        let _ = std::fs::remove_dir_all(&tmp);
        let ratio = off_ops / on_ops;
        let pass = ratio <= 1.5;
        println!(
            "wal off {:.2} M ups/s | wal on {:.2} M ups/s | ratio {ratio:.2}x \
             | {records} records / {fsyncs} fsyncs -> {}",
            off_ops / 1e6,
            on_ops / 1e6,
            if pass { "PASS (<= 1.5x)" } else { "FAIL (> 1.5x)" }
        );
        let json = format!(
            "{{\n  \"bench\": \"wal_overhead\",\n  \"status\": \"measured\",\n  \"mode\": \"{}\",\n  \
             \"rows\": {rows},\n  \"q\": 16,\n  \"shards\": 4,\n  \"updates\": {n},\n  \
             \"fsync\": \"interval-2000us\",\n  \"wal_off_ops_per_sec\": {off_ops:.0},\n  \
             \"wal_on_ops_per_sec\": {on_ops:.0},\n  \"ratio\": {ratio:.3},\n  \
             \"wal_records\": {records},\n  \"wal_fsyncs\": {fsyncs},\n  \
             \"acceptance\": {{\"criterion\": \"wal_off / wal_on <= 1.5 (fsync=interval)\", \"pass\": {pass}}}\n}}\n",
            if harness::smoke_mode() { "smoke" } else { "full" },
        );
        let out_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_wal_overhead.json");
        std::fs::write(out_path, json).expect("writing BENCH_wal_overhead.json");
        println!("wrote {out_path}");
        // Smoke loads are too small for a stable ratio (startup and
        // recovery costs dominate); enforce the bar in full runs only.
        assert!(
            harness::smoke_mode() || pass,
            "WAL-on throughput fell below the 1.5x bar: ratio {ratio:.2}x"
        );
    }

    harness::section("XLA artifact execution latency");
    match XlaBackend::new("artifacts", 128, 16) {
        Ok(mut backend) => {
            use fast_sram::coordinator::{Backend, BatchKind};
            let deltas = vec![1u32; 128];
            harness::bench("xla apply 128x16", 3, 50, || {
                backend.apply(BatchKind::Add, &deltas).unwrap()
            });
            let mut big = XlaBackend::new("artifacts", 1024, 16).unwrap();
            let deltas = vec![1u32; 1024];
            harness::bench("xla apply 1024x16", 3, 50, || {
                big.apply(BatchKind::Add, &deltas).unwrap()
            });
        }
        Err(e) => println!("(skipping XLA benches: {e:#})"),
    }
}
