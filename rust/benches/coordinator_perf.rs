//! L3 performance bench: wall-clock cost of the coordinator itself —
//! batcher throughput, engine submit path, bank-parallel scaling, and
//! XLA execution latency. This is the §Perf measurement target for
//! Layer 3 (the coordinator must not be the bottleneck).
//!
//! Run: `cargo bench --bench coordinator_perf`

#[path = "harness.rs"]
mod harness;

use std::time::{Duration, Instant};

use fast_sram::coordinator::{
    Batcher, EngineConfig, FastBackend, UpdateEngine, UpdateRequest, XlaBackend,
};
use fast_sram::util::rng::Rng;

fn main() {
    harness::section("batcher micro-benchmarks");
    let mut rng = Rng::new(1);
    let reqs: Vec<UpdateRequest> = (0..100_000)
        .map(|_| UpdateRequest::add(rng.below(1024) as usize, rng.below(1 << 16) as u32))
        .collect();
    let s = harness::bench("batcher push+flush 100k reqs (1024 rows)", 1, 10, || {
        let mut b = Batcher::new(1024, 16, None);
        for r in &reqs {
            let _ = b.push(*r);
        }
        b.force_flush()
    });
    println!(
        "  -> batcher throughput: {:.1} M req/s",
        harness::ops_per_sec(100_000, s.trimmed_mean_ns) / 1e6
    );

    harness::section("engine end-to-end submit throughput (wall-clock)");
    for (label, rows) in [("1 bank / 128 rows", 128usize), ("8 banks / 1024 rows", 1024)] {
        let mut cfg = EngineConfig::new(rows, 16);
        cfg.seal_deadline = Duration::from_micros(200);
        cfg.queue_cap = 65_536;
        let engine = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        let n = 200_000u64;
        let mut rng = Rng::new(7);
        let t0 = Instant::now();
        for _ in 0..n {
            let row = rng.below(rows as u64) as usize;
            engine
                .submit_blocking(UpdateRequest::add(row, 1))
                .unwrap();
        }
        engine.drain_shard(0).unwrap();
        let dt = t0.elapsed();
        let stats = engine.stats();
        println!(
            "engine[{label}]: {:.2} M updates/s wall | {} batches | {:.1} rows/batch | apply p99 {} ns",
            n as f64 / dt.as_secs_f64() / 1e6,
            stats.batches,
            stats.rows_per_batch,
            stats.apply_wall.p99_ns
        );
        engine.shutdown().unwrap();
    }

    harness::section("bulk submit (submit_many) throughput");
    {
        let rows = 1024usize;
        let mut cfg = EngineConfig::new(rows, 16);
        cfg.seal_deadline = Duration::from_micros(200);
        cfg.queue_cap = 1024;
        let engine = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        let n = 400_000u64;
        let mut rng = Rng::new(13);
        let t0 = Instant::now();
        let mut chunk = Vec::with_capacity(4096);
        for _ in 0..n {
            chunk.push(UpdateRequest::add(rng.below(rows as u64) as usize, 1));
            if chunk.len() == 4096 {
                engine.submit_many(std::mem::take(&mut chunk)).unwrap();
                chunk.reserve(4096);
            }
        }
        engine.submit_many(chunk).unwrap();
        engine.drain_shard(0).unwrap();
        let dt = t0.elapsed();
        let stats = engine.stats();
        println!(
            "engine[bulk 1024 rows]: {:.2} M updates/s wall | {} batches | {:.1} rows/batch",
            n as f64 / dt.as_secs_f64() / 1e6,
            stats.batches,
            stats.rows_per_batch
        );
        engine.shutdown().unwrap();
    }

    harness::section("XLA artifact execution latency");
    match XlaBackend::new("artifacts", 128, 16) {
        Ok(mut backend) => {
            use fast_sram::coordinator::{Backend, BatchKind};
            let deltas = vec![1u32; 128];
            harness::bench("xla apply 128x16", 3, 50, || {
                backend.apply(BatchKind::Add, &deltas).unwrap()
            });
            let mut big = XlaBackend::new("artifacts", 1024, 16).unwrap();
            let deltas = vec![1u32; 1024];
            harness::bench("xla apply 1024x16", 3, 50, || {
                big.apply(BatchKind::Add, &deltas).unwrap()
            });
        }
        Err(e) => println!("(skipping XLA benches: {e:#})"),
    }
}
