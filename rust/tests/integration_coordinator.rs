//! Integration + property tests for the coordinator stack: batcher
//! semantics, engine end-to-end equivalence, bank striping, and the
//! width-reconfiguration planner. Uses the in-crate quickprop
//! framework (proptest is not in the offline vendor set).

use fast_sram::coordinator::{
    Batcher, DigitalBackend, EngineConfig, FastBackend, UpdateEngine, UpdateOp, UpdateRequest,
};
use fast_sram::fastmem::{AluOp, FastArray, RouteFabric};
use fast_sram::util::bits;
use fast_sram::util::quickprop::{check, Gen};

/// Host-side oracle applying requests one by one.
fn apply_reference(state: &mut [u32], req: &UpdateRequest, q: usize) {
    let m = bits::mask(q);
    let cur = state[req.row];
    state[req.row] = match req.op {
        UpdateOp::Add => bits::add_mod(cur, req.operand, q),
        UpdateOp::Sub => bits::sub_mod(cur, req.operand, q),
        UpdateOp::And => cur & req.operand & m,
        UpdateOp::Or => (cur | req.operand) & m,
        UpdateOp::Xor => (cur ^ req.operand) & m,
    };
}

fn random_request(g: &mut Gen, rows: usize, q: usize) -> UpdateRequest {
    let ops = [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor];
    UpdateRequest {
        row: g.usize_in(0, rows - 1),
        op: *g.choose(&ops),
        operand: g.u32_any() & bits::mask(q),
    }
}

/// PROPERTY: flushing the batcher and applying its batches to a FAST
/// array is equivalent to applying every request sequentially.
#[test]
fn prop_batcher_preserves_request_semantics() {
    check("batcher semantics", 60, |g| {
        let rows = 16;
        let q = *g.choose(&[8usize, 16]);
        let n_reqs = g.usize_in(1, 120);
        let seal = if g.bool() { Some(g.usize_in(1, rows)) } else { None };

        let mut array = FastArray::new(rows, q);
        let mut reference = vec![0u32; rows];
        let mut batcher = Batcher::new(rows, q, seal);

        let apply_batch = |array: &mut FastArray, batch: fast_sram::coordinator::Batch| {
            match batch.kind.alu_op() {
                AluOp::Add => array.batch_add(&batch.operands),
                op => array.batch_logic(op, &batch.operands),
            };
        };

        for _ in 0..n_reqs {
            let req = random_request(g, rows, q);
            apply_reference(&mut reference, &req, q);
            if let Some((batch, _)) = batcher.push(req) {
                apply_batch(&mut array, batch);
            }
        }
        if let Some(batch) = batcher.force_flush() {
            apply_batch(&mut array, batch);
        }
        // Harness verification read: peek, so port/energy accounting
        // keeps modeling the workload only.
        array.peek_rows() == reference
    });
}

/// PROPERTY: coalescing never changes the number of *completed*
/// requests, and rows_touched <= requests.
#[test]
fn prop_batch_accounting_consistent() {
    check("batch accounting", 60, |g| {
        let rows = 32;
        let q = 16;
        let mut batcher = Batcher::new(rows, q, None);
        let n = g.usize_in(1, 200);
        let mut pushed = 0usize;
        let mut flushed_requests = 0usize;
        let mut ok = true;
        for _ in 0..n {
            let req = random_request(g, rows, q);
            pushed += 1;
            if let Some((b, _)) = batcher.push(req) {
                flushed_requests += b.requests;
                ok &= b.rows_touched <= b.requests;
                ok &= b.operands.len() == rows;
            }
        }
        if let Some(b) = batcher.force_flush() {
            flushed_requests += b.requests;
            ok &= b.rows_touched <= b.requests;
        }
        ok && flushed_requests == pushed
    });
}

/// PROPERTY: the engine (async worker + batcher + banks) matches the
/// sequential oracle for arbitrary request streams.
#[test]
fn prop_engine_end_to_end_equivalence() {
    check("engine equivalence", 12, |g| {
        let rows = 256; // 2 banks
        let q = 16;
        let cfg = EngineConfig::new(rows, q);
        let engine = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        let mut reference = vec![0u32; rows];
        let n = g.usize_in(1, 400);
        for _ in 0..n {
            let req = random_request(g, rows, q);
            apply_reference(&mut reference, &req, q);
            engine.submit_blocking(req).unwrap();
        }
        let got = engine.snapshot().unwrap();
        engine.shutdown().unwrap();
        got == reference
    });
}

/// Engine on the digital baseline must produce identical state ("same
/// function as the FAST SRAM").
#[test]
fn engine_fast_and_digital_agree() {
    let rows = 128;
    let q = 16;
    let make = |fast: bool| {
        let cfg = EngineConfig::new(rows, q);
        if fast {
            UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
            })
            .unwrap()
        } else {
            UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(DigitalBackend::new(plan.rows, plan.q)))
            })
            .unwrap()
        }
    };
    let ef = make(true);
    let ed = make(false);
    let mut rng = fast_sram::util::rng::Rng::new(123);
    for _ in 0..3000 {
        let row = rng.below(rows as u64) as usize;
        let v = rng.below(1 << 16) as u32;
        let req = if rng.chance(0.5) {
            UpdateRequest::add(row, v)
        } else {
            UpdateRequest::sub(row, v)
        };
        ef.submit_blocking(req).unwrap();
        ed.submit_blocking(req).unwrap();
    }
    assert_eq!(ef.snapshot().unwrap(), ed.snapshot().unwrap());
    // And the modeled cost asymmetry is the paper's whole point:
    let sf = ef.stats();
    let sd = ed.stats();
    assert!(sf.modeled_ns < sd.modeled_ns, "FAST must be faster in macro time");
    ef.shutdown().unwrap();
    ed.shutdown().unwrap();
}

/// Width reconfiguration (Fig. 5c) through the array: merge two 8-bit
/// words into a 16-bit word and verify cross-boundary carries.
#[test]
fn width_reconfig_cross_boundary_carry() {
    let fabric = RouteFabric::new(16, 8);
    let mut a = FastArray::with_fabric(8, fabric, 8, AluOp::Add).unwrap();
    for r in 0..8 {
        a.write_word(r, 0, 0xFF).unwrap(); // low byte all-ones
        a.write_word(r, 1, r as u32).unwrap(); // high byte
    }
    a.reconfigure_width(16).unwrap();
    let deltas = vec![1u32; 8];
    a.batch_add(&deltas);
    for r in 0..8 {
        // 0x__FF + 1 must carry into the high byte.
        assert_eq!(
            a.read_word(r, 0).unwrap(),
            ((r as u32) << 8 | 0xFF) + 1,
            "row {r}"
        );
    }
    // Back to 8-bit: words split again (bit-preserving).
    a.reconfigure_width(8).unwrap();
    for r in 0..8 {
        assert_eq!(a.read_word(r, 0).unwrap(), 0x00);
        assert_eq!(a.read_word(r, 1).unwrap(), r as u32 + 1);
    }
}

/// PROPERTY: batch ops on a segmented array match per-word host math.
#[test]
fn prop_segmented_batches_match_word_math() {
    check("segmented batch math", 20, |g| {
        let widths = [4usize, 8, 16];
        let base = *g.choose(&widths);
        let words = g.usize_in(1, 32 / base.max(4)).max(1);
        let row_width = base * words;
        if row_width > 32 {
            return true; // skip invalid combos
        }
        let rows = g.usize_in(1, 8);
        let fabric = RouteFabric::new(row_width, base);
        let mut a = match FastArray::with_fabric(rows, fabric, base, AluOp::Add) {
            Ok(a) => a,
            Err(_) => return true,
        };
        let wpr = a.words_per_row();
        let mut init = vec![0u32; rows * wpr];
        for (i, v) in init.iter_mut().enumerate() {
            *v = (g.u32_any()) & bits::mask(base);
            let (r, s) = (i / wpr, i % wpr);
            a.write_word(r, s, *v).unwrap();
        }
        let ops: Vec<u32> = (0..rows * wpr)
            .map(|_| g.u32_any() & bits::mask(base))
            .collect();
        a.batch_apply_segmented(&ops).unwrap();
        (0..rows * wpr).all(|i| {
            let (r, s) = (i / wpr, i % wpr);
            a.read_word(r, s).unwrap() == bits::add_mod(init[i], ops[i], base)
        })
    });
}

/// PROPERTY: the §III.E multiply extension matches host arithmetic and
/// composes with adds (distributivity under mod 2^q).
#[test]
fn prop_batch_mul_matches_host_and_distributes() {
    check("batch mul", 20, |g| {
        let q = *g.choose(&[8usize, 16]);
        let rows = 8;
        let mut a = FastArray::new(rows, q);
        let init: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
        let mults: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
        let deltas: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();

        // (init + delta) * mult, computed on the array...
        a.load(&init);
        a.batch_add(&deltas);
        a.batch_mul(&mults).unwrap();
        let got = a.peek_rows();

        // ...must equal host math.
        (0..rows).all(|r| {
            let sum = bits::add_mod(init[r], deltas[r], q) as u64;
            let want = ((sum * mults[r] as u64) as u32) & bits::mask(q);
            got[r] == want
        })
    });
}

/// Backpressure: rejected + completed == submitted after drain.
#[test]
fn backpressure_accounting_invariant() {
    let rows = 128;
    let q = 16;
    let mut cfg = EngineConfig::new(rows, q);
    cfg.queue_cap = 4;
    let engine = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();
    let mut accepted = 0u64;
    for i in 0..50_000u64 {
        if engine
            .submit(UpdateRequest::add((i % 128) as usize, 1))
            .is_ok()
        {
            accepted += 1;
        }
    }
    engine.drain_shard(0).unwrap();
    let s = engine.stats();
    assert_eq!(s.submitted, 50_000);
    assert_eq!(s.completed, accepted);
    assert_eq!(s.rejected, 50_000 - accepted);
    engine.shutdown().unwrap();
}
