//! Durability integration tests: kill-and-restart recovery is
//! bit-identical for any kill point, across shard counts and fidelity
//! tiers.
//!
//! - *Torn-write property*: truncate a valid WAL at EVERY byte offset
//!   → recovery never panics or errors, always yields the state of an
//!   exact record prefix (plus a randomized multi-shard quickprop
//!   variant).
//! - *Snapshot + tail equivalence*: workload half 1 → compact
//!   (snapshot, prune) → workload half 2 → recovered state ==
//!   full-trace host semantics, at 1/2/4/8 shards × phase/word/
//!   bitplane.
//! - *Double-recovery idempotence*: recovering an already-recovered
//!   directory changes nothing.
//! - *Trace interop*: `wal export` replayed through the engine
//!   reproduces the recovered state bit for bit.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fast_sram::apps::trace::{state_digest, BackendKind, Trace};
use fast_sram::coordinator::{
    Backend, BitPlaneBackend, EngineConfig, FastBackend, ShardPlan, UpdateEngine,
    UpdateRequest,
};
use fast_sram::durability::{
    self, segment, DurabilityConfig, FsyncPolicy, Manifest,
};
use fast_sram::fastmem::Fidelity;
use fast_sram::util::bits;
use fast_sram::util::quickprop::{check, Gen};
use fast_sram::util::rng::Rng;
use fast_sram::Result;

fn tmpdir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!(
        "fast-dur-{tag}-{}-{nanos}",
        std::process::id()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic durable config: only explicit drains seal, fsync on
/// every record unless overridden.
fn durable_cfg(rows: usize, q: usize, shards: usize, dir: &Path) -> EngineConfig {
    let mut cfg = EngineConfig::sharded(rows, q, shards);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_secs(3600);
    let mut d = DurabilityConfig::new(dir.to_path_buf());
    d.fsync = FsyncPolicy::Always;
    cfg.durability = Some(d);
    cfg
}

#[derive(Debug, Clone, Copy)]
enum Tier {
    Phase,
    Word,
    BitPlane,
}

fn start_tier(cfg: EngineConfig, tier: Tier) -> UpdateEngine {
    let result = match tier {
        Tier::Phase => UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows_fidelity(
                p.rows,
                p.q,
                Fidelity::PhaseAccurate,
            )) as Box<dyn Backend>)
        }),
        Tier::Word => UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
        }),
        Tier::BitPlane => UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(BitPlaneBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
        }),
    };
    result.unwrap()
}

/// A seeded update/write/flush mix (uniform_trace has no writes; the
/// WAL must sequence writes between commits too).
fn mixed_trace(rows: usize, q: usize, events: usize, seed: u64) -> Trace {
    let mut t = Trace::new(format!("mixed-{rows}x{q}"), rows, q, seed);
    let mut rng = Rng::new(seed);
    for i in 0..events {
        let row = rng.below(rows as u64) as usize;
        let v = rng.below(bits::mask(q) as u64 + 1) as u32;
        if rng.chance(0.1) {
            t.push_write(row, v);
        } else if rng.chance(0.3) {
            t.push_update(UpdateRequest::sub(row, v));
        } else {
            t.push_update(UpdateRequest::add(row, v));
        }
        if (i + 1) % 50 == 0 {
            t.push_flush();
        }
    }
    t
}

/// Split a trace into two halves sharing the header.
fn split_trace(t: &Trace) -> (Trace, Trace) {
    let mid = t.events.len() / 2;
    let mut a = Trace::new(t.name.clone(), t.rows, t.q, t.seed);
    let mut b = Trace::new(t.name.clone(), t.rows, t.q, t.seed);
    a.events = t.events[..mid].to_vec();
    b.events = t.events[mid..].to_vec();
    (a, b)
}

#[test]
fn durable_engine_recovers_after_clean_shutdown() {
    let dir = tmpdir("clean");
    let trace = mixed_trace(64, 8, 400, 11);
    let want = trace.reference_state();

    let e = start_tier(durable_cfg(64, 8, 2, &dir), Tier::Word);
    let rep = trace.replay(&e).unwrap();
    assert_eq!(rep.final_state, want);
    e.shutdown().unwrap();

    // Offline recovery sees the same state…
    let rec = durability::recover(&dir).unwrap();
    assert_eq!(rec.state, want);
    assert_eq!(rec.digest, state_digest(&want));
    assert!(rec.torn.is_empty(), "clean shutdown leaves no torn tail");

    // …and a restarted durable engine serves it (reads + appends).
    let e2 = start_tier(durable_cfg(64, 8, 2, &dir), Tier::Word);
    assert_eq!(e2.read(5).unwrap(), want[5]);
    assert_eq!(e2.snapshot().unwrap(), want);
    // commit_seq continues from the recovered watermark.
    let seq_before = e2.committed_seq(0).unwrap();
    assert_eq!(seq_before, rec.per_shard[0].commit_seq);
    e2.submit_blocking(UpdateRequest::add(0, 3)).unwrap();
    assert_eq!(e2.drain_shard(0).unwrap(), seq_before + 1);
    e2.shutdown().unwrap();

    let rec2 = durability::recover(&dir).unwrap();
    let mut want2 = want.clone();
    want2[0] = bits::add_mod(want2[0], 3, 8);
    assert_eq!(rec2.state, want2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn double_recovery_is_idempotent() {
    let dir = tmpdir("idem");
    let trace = mixed_trace(32, 8, 200, 23);
    let e = start_tier(durable_cfg(32, 8, 4, &dir), Tier::Word);
    trace.replay(&e).unwrap();
    e.shutdown().unwrap();

    let a = durability::recover_repair(&dir).unwrap();
    let b = durability::recover_repair(&dir).unwrap();
    assert_eq!(a.state, b.state);
    assert_eq!(a.per_shard, b.per_shard);
    assert_eq!(a.digest, b.digest);
    // A start/shutdown cycle with no traffic changes nothing either.
    let e2 = start_tier(durable_cfg(32, 8, 4, &dir), Tier::Word);
    e2.shutdown().unwrap();
    let c = durability::recover(&dir).unwrap();
    assert_eq!(c.state, a.state);
    assert_eq!(c.per_shard, a.per_shard);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shape_mismatch_is_refused() {
    let dir = tmpdir("shape");
    let e = start_tier(durable_cfg(64, 8, 2, &dir), Tier::Word);
    e.shutdown().unwrap();
    // Same dir, different rows / q / shards: refused at start.
    let r = UpdateEngine::start(durable_cfg(128, 8, 2, &dir), |p: &ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
    });
    assert!(r.is_err(), "rows mismatch must be refused");
    let r = UpdateEngine::start(durable_cfg(64, 16, 2, &dir), |p: &ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
    });
    assert!(r.is_err(), "q mismatch must be refused");
    let r = UpdateEngine::start(durable_cfg(64, 8, 4, &dir), |p: &ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
    });
    assert!(r.is_err(), "shard-count mismatch must be refused");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The torn-write property, exhaustively: build a WAL of N single-row
/// commits, then for EVERY byte-truncation of the segment file,
/// recovery must succeed and yield exactly the state of the first k
/// records for some k — never a panic, never a gap, never a
/// half-applied record.
#[test]
fn torn_write_truncation_is_prefix_consistent_at_every_byte() {
    let rows = 16usize;
    let q = 8usize;
    let n = 24usize;
    let dir = tmpdir("torn-src");

    // One commit per drain; track the expected state after each.
    let mut expected: Vec<Vec<u32>> = vec![vec![0u32; rows]];
    {
        let e = start_tier(durable_cfg(rows, q, 1, &dir), Tier::Word);
        let mut rng = Rng::new(7);
        for i in 0..n {
            let row = i % rows;
            let v = 1 + rng.below(200) as u32;
            e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
            e.drain_shard(0).unwrap();
            let mut next = expected.last().unwrap().clone();
            next[row] = bits::add_mod(next[row], v, q);
            expected.push(next);
        }
        e.shutdown().unwrap();
    }
    let segs = segment::list_segments(&dir, 0).unwrap();
    assert_eq!(segs.len(), 1, "the workload fits one segment");
    let seg_bytes = std::fs::read(&segs[0].path).unwrap();
    let full_len = seg_bytes.len();

    let scratch = tmpdir("torn-cut");
    for cut in 0..=full_len {
        // Rebuild a one-segment WAL dir truncated at `cut`.
        let _ = std::fs::remove_dir_all(&scratch);
        std::fs::create_dir_all(segment::shard_dir(&scratch, 0)).unwrap();
        Manifest { rows, q, shards: 1 }.write_atomic(&scratch).unwrap();
        std::fs::write(
            segment::segment_path(&scratch, 0, 1),
            &seg_bytes[..cut],
        )
        .unwrap();

        let rep = durability::recover_repair(&scratch)
            .unwrap_or_else(|e| panic!("recovery must not fail at cut {cut}: {e:#}"));
        let k = rep.records_replayed as usize;
        assert!(k <= n, "cut {cut}: replayed {k} > {n} records");
        assert_eq!(
            rep.state, expected[k],
            "cut {cut}: state is not the {k}-record prefix"
        );
        assert_eq!(rep.per_shard[0].commit_seq, k as u64, "cut {cut}");
        if cut == full_len {
            assert_eq!(k, n, "the untruncated log replays fully");
            assert!(rep.torn.is_empty());
        }
        // Repair is idempotent: a second recovery finds a clean log
        // with the same state.
        let again = durability::recover(&scratch).unwrap();
        assert_eq!(again.state, rep.state, "cut {cut}: repair not idempotent");
        assert!(again.torn.is_empty(), "cut {cut}: torn tail survived repair");

        // Spot-check that a durable engine can restart and extend the
        // repaired log (every 97th offset, to keep the test fast).
        if cut % 97 == 0 {
            let e = start_tier(durable_cfg(rows, q, 1, &scratch), Tier::Word);
            e.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
            e.drain_shard(0).unwrap();
            e.shutdown().unwrap();
            let after = durability::recover(&scratch).unwrap();
            let mut want = expected[k].clone();
            want[0] = bits::add_mod(want[0], 1, q);
            assert_eq!(after.state, want, "cut {cut}: post-repair append diverged");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&scratch);
}

/// Randomized multi-shard torn-tail property: truncate one shard's
/// segment at a random offset; recovery must succeed, be idempotent,
/// and a restarted engine must serve and extend the repaired log.
#[test]
fn prop_torn_tails_recover_on_random_multi_shard_workloads() {
    check("torn multi-shard recovery", 12, |g: &mut Gen| {
        let shards = *g.choose(&[1usize, 2, 4]);
        let rows = 32usize;
        let q = 8usize;
        let dir = tmpdir("torn-prop");
        let trace = mixed_trace(rows, q, 60 + g.usize_in(0, 80), g.u64_any());
        {
            let mut cfg = durable_cfg(rows, q, shards, &dir);
            // Vary the fsync policy; shutdown syncs regardless.
            if let Some(d) = &mut cfg.durability {
                d.fsync = *g.choose(&[
                    FsyncPolicy::Always,
                    FsyncPolicy::Interval(Duration::from_micros(500)),
                    FsyncPolicy::Off,
                ]);
            }
            let e = start_tier(cfg, Tier::Word);
            trace.replay(&e).unwrap();
            e.shutdown().unwrap();
        }
        let victim = g.usize_in(0, shards - 1);
        let segs = segment::list_segments(&dir, victim).unwrap();
        let ok = if let Some(seg) = segs.last() {
            let bytes = std::fs::read(&seg.path).unwrap();
            let cut = g.usize_in(0, bytes.len());
            std::fs::write(&seg.path, &bytes[..cut]).unwrap();
            let a = durability::recover_repair(&dir);
            let a = match a {
                Ok(a) => a,
                Err(e) => panic!("recovery failed after truncation: {e:#}"),
            };
            let b = durability::recover(&dir).unwrap();
            let restart_ok = {
                let e = start_tier(durable_cfg(rows, q, shards, &dir), Tier::Word);
                let served = e.snapshot().unwrap();
                e.shutdown().unwrap();
                served == a.state
            };
            a.state == b.state && b.torn.is_empty() && restart_ok
        } else {
            true // untouched shard had no traffic — nothing to tear
        };
        let _ = std::fs::remove_dir_all(&dir);
        ok
    });
}

/// Snapshot + tail equivalence across the shard × fidelity matrix:
/// half the workload, compact (snapshot + prune), the other half on a
/// fresh process, and the recovered state must equal full-trace host
/// semantics bit for bit.
#[test]
fn snapshot_plus_tail_matches_full_replay_across_shards_and_tiers() {
    let rows = 64usize;
    let q = 8usize;
    let full = mixed_trace(rows, q, 240, 31);
    let want = full.reference_state();
    let (t1, t2) = split_trace(&full);

    for &shards in &[1usize, 2, 4, 8] {
        for &tier in &[Tier::Phase, Tier::Word, Tier::BitPlane] {
            let dir = tmpdir(&format!("snap-{shards}"));

            let e1 = start_tier(durable_cfg(rows, q, shards, &dir), tier);
            t1.replay(&e1).unwrap();
            e1.shutdown().unwrap();

            let compacted = durability::compact(&dir).unwrap();
            assert!(compacted.segments_removed > 0, "{shards} shards / {tier:?}");

            let e2 = start_tier(durable_cfg(rows, q, shards, &dir), tier);
            let rep2 = t2.replay(&e2).unwrap();
            assert_eq!(rep2.final_state, want, "{shards} shards / {tier:?}");
            e2.shutdown().unwrap();

            let rec = durability::recover(&dir).unwrap();
            assert_eq!(rec.state, want, "{shards} shards / {tier:?}");
            assert!(rec.snapshot.is_some(), "tail must sit on the snapshot");
            assert_eq!(rec.digest, state_digest(&want));
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn wal_export_replays_to_the_recovered_state() {
    let dir = tmpdir("export");
    let trace = mixed_trace(64, 8, 300, 43);
    let e = start_tier(durable_cfg(64, 8, 2, &dir), Tier::Word);
    trace.replay(&e).unwrap();
    e.shutdown().unwrap();
    // Compact midway so the export has to fold a snapshot AND a tail.
    durability::compact(&dir).unwrap();
    let e2 = start_tier(durable_cfg(64, 8, 2, &dir), Tier::Word);
    e2.submit_blocking(UpdateRequest::add(1, 9)).unwrap();
    e2.write(2, 77).unwrap();
    e2.drain_shard(e2.shard_of(1).unwrap()).unwrap();
    e2.shutdown().unwrap();

    let rec = durability::recover(&dir).unwrap();
    let exported = durability::export_trace(&dir, "wal-export").unwrap();
    assert_eq!(exported.rows, 64);
    assert_eq!(exported.q, 8);
    // Independent check through the real engine, not just the oracle.
    let rep = exported
        .replay_on(BackendKind::Fast(Fidelity::WordFast), 1)
        .unwrap();
    assert_eq!(rep.final_state, rec.state);
    assert_eq!(state_digest(&rep.final_state), rec.digest);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn segment_rotation_and_compaction_reclaim_space() -> Result<()> {
    let dir = tmpdir("rotate");
    let rows = 32usize;
    let q = 8usize;
    let mut cfg = durable_cfg(rows, q, 1, &dir);
    if let Some(d) = &mut cfg.durability {
        d.segment_bytes = 1024; // force rotation quickly
        d.fsync = FsyncPolicy::Off;
    }
    let e = start_tier(cfg, Tier::Word);
    let mut rng = Rng::new(5);
    let mut want = vec![0u32; rows];
    for _ in 0..120 {
        let row = rng.below(rows as u64) as usize;
        let v = 1 + rng.below(100) as u32;
        e.submit_blocking(UpdateRequest::add(row, v))?;
        e.drain_shard(0)?;
        want[row] = bits::add_mod(want[row], v, q);
    }
    let stats = e.stats();
    assert!(stats.shards[0].wal_records >= 120);
    assert!(stats.shards[0].wal_rotations >= 1, "1 KiB segments must rotate");
    assert!(stats.shards[0].wal_bytes > 0);
    e.shutdown()?;

    assert!(segment::list_segments(&dir, 0)?.len() > 1);
    let rec = durability::recover(&dir)?;
    assert_eq!(rec.state, want, "multi-segment replay");

    let comp = durability::compact(&dir)?;
    assert!(comp.segments_removed > 1);
    assert!(comp.bytes_reclaimed > 0);
    assert!(segment::list_segments(&dir, 0)?.is_empty());
    let rec2 = durability::recover(&dir)?;
    assert_eq!(rec2.state, want, "snapshot-only recovery");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

#[test]
fn mid_log_corruption_is_flagged_and_repair_keeps_the_prefix() {
    let dir = tmpdir("midlog");
    let rows = 16usize;
    let q = 8usize;
    let mut cfg = durable_cfg(rows, q, 1, &dir);
    if let Some(d) = &mut cfg.durability {
        d.segment_bytes = 1024;
        d.fsync = FsyncPolicy::Off;
    }
    let e = start_tier(cfg, Tier::Word);
    for i in 0..120 {
        e.submit_blocking(UpdateRequest::add(i % rows, 1)).unwrap();
        e.drain_shard(0).unwrap();
    }
    e.shutdown().unwrap();
    let segs = segment::list_segments(&dir, 0).unwrap();
    assert!(segs.len() > 1);
    // Corrupt a frame in the FIRST segment: everything after it —
    // including whole later segments — must be reported unreachable.
    let mut bytes = std::fs::read(&segs[0].path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&segs[0].path, bytes).unwrap();

    let rep = durability::recover(&dir).unwrap();
    assert_eq!(rep.torn.len(), 1);
    assert!(rep.torn[0].dropped_segments > 0, "later segments are unreachable");

    // Mid-log corruption strands acknowledged commits: tail-only
    // repair (and therefore a durable engine start) must REFUSE, and
    // only the explicit force path may discard the stranded data.
    assert!(durability::recover_repair(&dir).is_err(), "silent mid-log repair");
    let refused = UpdateEngine::start(durable_cfg(rows, q, 1, &dir), |p: &ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
    });
    assert!(refused.is_err(), "durable start must refuse a mid-log-corrupt dir");

    let repaired = durability::recover_force(&dir).unwrap();
    let k = repaired.records_replayed as usize;
    assert!(k < 120);
    // Prefix semantics: k single-row +1 adds in round-robin order.
    let mut want = vec![0u32; rows];
    for i in 0..k {
        want[i % rows] = bits::add_mod(want[i % rows], 1, q);
    }
    assert_eq!(repaired.state, want);
    assert_eq!(segment::list_segments(&dir, 0).unwrap().len(), 1);
    let clean = durability::recover(&dir).unwrap();
    assert!(clean.torn.is_empty());
    assert_eq!(clean.state, want);
    let _ = std::fs::remove_dir_all(&dir);
}
