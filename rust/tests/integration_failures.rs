//! Failure injection: the engine and its callers must degrade cleanly
//! when the backend errors, when construction fails, and under
//! protocol violations in the cell model.

use fast_sram::coordinator::{
    AppliedBatch, Backend, BatchKind, EngineConfig, FastBackend, UpdateEngine, UpdateRequest,
};
use fast_sram::fastmem::{CellError, ShiftCell};
use fast_sram::Result;

/// A backend that fails after N successful batches.
struct FlakyBackend {
    inner: FastBackend,
    remaining_ok: usize,
}

impl Backend for FlakyBackend {
    fn name(&self) -> &'static str {
        "flaky"
    }

    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn q(&self) -> usize {
        self.inner.q()
    }

    fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<AppliedBatch> {
        if self.remaining_ok == 0 {
            anyhow::bail!("injected backend fault");
        }
        self.remaining_ok -= 1;
        self.inner.apply(kind, operands)
    }

    fn read_row(&mut self, row: usize) -> Result<u32> {
        self.inner.read_row(row)
    }

    fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
        self.inner.write_row(row, value)
    }

    fn snapshot(&mut self) -> Result<Vec<u32>> {
        self.inner.snapshot()
    }
}

#[test]
fn backend_construction_failure_propagates_to_start() {
    let cfg = EngineConfig::new(128, 16);
    let err = match UpdateEngine::start(cfg, |_plan| anyhow::bail!("no device")) {
        Err(e) => e,
        Ok(_) => panic!("start must fail when the backend cannot be built"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("no device"), "got: {msg}");
}

#[test]
fn backend_fault_surfaces_on_shutdown_and_stops_worker() {
    let cfg = EngineConfig::new(128, 16);
    let engine = UpdateEngine::start(cfg, |_plan| {
        Ok(Box::new(FlakyBackend {
            inner: FastBackend::new(1, 128, 16),
            remaining_ok: 1,
        }))
    })
    .unwrap();
    // First drain succeeds, second hits the injected fault.
    engine.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
    engine.drain_shard(0).unwrap();
    engine.submit_blocking(UpdateRequest::add(1, 1)).unwrap();
    // The worker dies on the fault; subsequent API calls must error
    // (not hang), and shutdown must report the fault.
    let mut saw_error = false;
    for _ in 0..100 {
        if engine.drain_shard(0).is_err() {
            saw_error = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(saw_error, "engine kept accepting after backend fault");
    let err = engine.shutdown().unwrap_err();
    assert!(format!("{err:#}").contains("injected backend fault"));
}

#[test]
fn rows_mismatch_between_config_and_backend_fails_fast() {
    let cfg = EngineConfig::new(256, 16);
    let engine =
        UpdateEngine::start(cfg, |_plan| Ok(Box::new(FastBackend::new(1, 128, 16)))).unwrap();
    // Worker detects the mismatch and exits; first interaction errors.
    let mut errored = false;
    for _ in 0..100 {
        if engine.drain_shard(0).is_err() {
            errored = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(errored, "rows mismatch must not go unnoticed");
}

#[test]
fn cell_protocol_violations_are_hard_errors() {
    let mut c = ShiftCell::new(1);
    // φ2 without φ1:
    assert!(matches!(c.phase2(), Err(CellError::PhaseOrder(_, _))));
    // Mid-shift static access:
    c.phase1(0).unwrap();
    assert_eq!(c.read_static(), Err(CellError::DynamicRead));
    assert_eq!(c.write_static(1), Err(CellError::DynamicRead));
    // Recover by completing the protocol.
    c.phase2().unwrap();
    c.phase3().unwrap();
    assert_eq!(c.read_static().unwrap(), 0);
}

#[test]
fn engine_read_out_of_range_errors_without_poisoning() {
    let cfg = EngineConfig::new(128, 16);
    let engine =
        UpdateEngine::start(cfg, |_plan| Ok(Box::new(FastBackend::new(1, 128, 16)))).unwrap();
    assert!(engine.read(500).is_err());
    // Engine still healthy afterwards.
    engine.submit_blocking(UpdateRequest::add(3, 9)).unwrap();
    assert_eq!(engine.read(3).unwrap(), 9);
    engine.shutdown().unwrap();
}

#[test]
fn xla_backend_missing_artifacts_is_a_clean_error() {
    let res = fast_sram::coordinator::XlaBackend::new("/nonexistent/dir", 128, 16);
    assert!(res.is_err());
}
