//! Differential coverage for the whole `apps` layer: `DeltaTable`,
//! `GraphEngine` and `Histogram` each run the same scripted, seeded
//! workload across every fidelity tier (phase-accurate, word-fast,
//! bit-plane) plus the digital baseline, and must produce results
//! bit-identical to a host-semantics reference. The three FAST tiers
//! must additionally agree on the modeled energy account *exactly* —
//! the tier is a speed knob, never a semantics or accounting change.
//!
//! Engines are built through `BackendKind::start`, which disables the
//! group-commit deadline and size seals, so batch structure (and
//! therefore the energy report) is a pure function of the scripted
//! workload — deterministic across runs and hosts.

use std::collections::HashMap;

use fast_sram::apps::BackendKind;
use fast_sram::apps::{reference_round, CsrGraph, DeltaTable, GraphEngine, Histogram};
use fast_sram::fastmem::Fidelity;
use fast_sram::util::bits;
use fast_sram::util::rng::Rng;

/// Every executor the apps must agree across.
const KINDS: [BackendKind; 4] = [
    BackendKind::Fast(Fidelity::PhaseAccurate),
    BackendKind::Fast(Fidelity::WordFast),
    BackendKind::BitPlane,
    BackendKind::Digital,
];

fn is_fast(kind: BackendKind) -> bool {
    !matches!(kind, BackendKind::Digital)
}

// ---------------------------------------------------------------------------
// DeltaTable
// ---------------------------------------------------------------------------

/// Scripted table workload: returns (scan result, modeled energy pJ).
fn run_table(kind: BackendKind) -> (Vec<(u64, u32)>, f64) {
    const ROWS: usize = 128;
    const Q: usize = 16;
    let mut t = DeltaTable::new(kind.start(ROWS, Q, 1).unwrap());
    let mut rng = Rng::new(0xDE17A);
    for _ in 0..3000 {
        let key = rng.below(100);
        let delta = 1 + rng.below(500) as u32;
        match rng.below(10) {
            0 => t.put(key, delta).unwrap(),
            1 | 2 => t.decrement(key, delta).unwrap(),
            _ => t.increment(key, delta).unwrap(),
        }
    }
    let pairs = t.scan().unwrap();
    let energy = t.stats().modeled_energy_pj;
    t.close().unwrap();
    (pairs, energy)
}

/// The same workload on a plain HashMap with host modular arithmetic.
fn reference_table() -> Vec<(u64, u32)> {
    const Q: usize = 16;
    let mut map: HashMap<u64, u32> = HashMap::new();
    let mut rng = Rng::new(0xDE17A);
    for _ in 0..3000 {
        let key = rng.below(100);
        let delta = 1 + rng.below(500) as u32;
        let slot = map.entry(key).or_insert(0);
        match rng.below(10) {
            0 => *slot = delta,
            1 | 2 => *slot = bits::sub_mod(*slot, delta, Q),
            _ => *slot = bits::add_mod(*slot, delta, Q),
        }
    }
    let mut out: Vec<(u64, u32)> = map.into_iter().collect();
    out.sort_unstable();
    out
}

#[test]
fn delta_table_is_bit_identical_across_tiers_and_backends() {
    let want = reference_table();
    let mut fast_energy: Option<f64> = None;
    for kind in KINDS {
        let (pairs, energy) = run_table(kind);
        assert_eq!(pairs, want, "{}", kind.label());
        assert!(energy > 0.0, "{}", kind.label());
        if is_fast(kind) {
            match fast_energy {
                None => fast_energy = Some(energy),
                Some(e) => assert_eq!(
                    energy,
                    e,
                    "{}: FAST tiers must agree on energy exactly",
                    kind.label()
                ),
            }
        } else {
            // The digital baseline sweeps every row per batch — it must
            // cost measurably more than FAST on the same workload.
            assert!(
                energy > fast_energy.unwrap(),
                "digital {energy} pJ must exceed fast {} pJ",
                fast_energy.unwrap()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// GraphEngine
// ---------------------------------------------------------------------------

fn run_graph(kind: BackendKind) -> (Vec<u32>, f64) {
    const Q: usize = 16;
    let g = CsrGraph::ring_with_chords(96, 7);
    let feats: Vec<u32> = (0..96).map(|i| (i as u32 * 131 + 17) & bits::mask(Q)).collect();
    let mut ge = GraphEngine::new(g, kind.start(128, Q, 1).unwrap()).unwrap();
    ge.set_features(&feats).unwrap();
    ge.run(3, 1).unwrap();
    let out = ge.features().unwrap();
    let energy = ge.stats().modeled_energy_pj;
    ge.close().unwrap();
    (out, energy)
}

#[test]
fn graph_propagation_is_bit_identical_across_tiers_and_backends() {
    const Q: usize = 16;
    let g = CsrGraph::ring_with_chords(96, 7);
    let feats: Vec<u32> = (0..96).map(|i| (i as u32 * 131 + 17) & bits::mask(Q)).collect();
    let mut want = feats;
    for _ in 0..3 {
        want = reference_round(&g, &want, Q, |f| f >> 1);
    }
    let mut fast_energy: Option<f64> = None;
    for kind in KINDS {
        let (out, energy) = run_graph(kind);
        assert_eq!(out, want, "{}", kind.label());
        if is_fast(kind) {
            match fast_energy {
                None => fast_energy = Some(energy),
                Some(e) => assert_eq!(energy, e, "{}", kind.label()),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

fn run_histogram(kind: BackendKind) -> (Vec<u32>, f64) {
    let mut h = Histogram::new(kind.start(64, 16, 1).unwrap(), 0.0, 1.0, 48).unwrap();
    let mut rng = Rng::new(0x415706);
    for _ in 0..4000 {
        let v = rng.f64();
        if rng.chance(0.1) {
            h.record_weighted(v, 1 + rng.below(9) as u32).unwrap();
        } else {
            h.record(v).unwrap();
        }
    }
    let counts = h.counts().unwrap();
    let energy = h.stats().modeled_energy_pj;
    h.close().unwrap();
    (counts, energy)
}

#[test]
fn histogram_is_bit_identical_across_tiers_and_backends() {
    // Host reference: same seeded stream, same bucket function.
    let probe = Histogram::new(
        BackendKind::Fast(Fidelity::WordFast).start(64, 16, 1).unwrap(),
        0.0,
        1.0,
        48,
    )
    .unwrap();
    let mut rng = Rng::new(0x415706);
    let mut want = vec![0u32; 48];
    for _ in 0..4000 {
        let v = rng.f64();
        let w = if rng.chance(0.1) { 1 + rng.below(9) as u32 } else { 1 };
        want[probe.bucket_of(v)] += w;
    }
    probe.close().unwrap();

    let mut fast_energy: Option<f64> = None;
    for kind in KINDS {
        let (counts, energy) = run_histogram(kind);
        assert_eq!(counts, want, "{}", kind.label());
        if is_fast(kind) {
            match fast_energy {
                None => fast_energy = Some(energy),
                Some(e) => assert_eq!(energy, e, "{}", kind.label()),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Sharded apps stay on the reference too (env-selectable tier so the
// CI fidelity matrix exercises every tier through the apps layer).
// ---------------------------------------------------------------------------

#[test]
fn sharded_app_engines_match_single_shard_results() {
    let tier = Fidelity::from_env_or(Fidelity::WordFast);
    let kind = BackendKind::Fast(tier);
    let single = run_table_sharded(kind, 1);
    for shards in [2usize, 4] {
        assert_eq!(run_table_sharded(kind, shards), single, "shards = {shards}");
    }
}

fn run_table_sharded(kind: BackendKind, shards: usize) -> Vec<(u64, u32)> {
    let mut t = DeltaTable::new(kind.start(128, 16, shards).unwrap());
    let mut rng = Rng::new(0x5A4D);
    for _ in 0..1500 {
        let key = rng.below(90);
        if rng.chance(0.25) {
            t.decrement(key, 1 + rng.below(100) as u32).unwrap();
        } else {
            t.increment(key, 1 + rng.below(100) as u32).unwrap();
        }
    }
    let pairs = t.scan().unwrap();
    t.close().unwrap();
    pairs
}
