//! Differential test net for the in-array query engine (PR-6): every
//! reduction must be bit-identical across the phase-accurate,
//! word-fast and bit-plane tiers AND the digital baseline, and equal
//! to an independent host-side scalar oracle — with the plane-wise
//! activity accounting (`cell_toggles` / `alu_evals`) exactly equal
//! across the fast tiers. Plus the ordering property: interleaved
//! update/query streams observe read-your-writes at every shard count,
//! the non-counting-read regression net, and a live `--stdio` server
//! exercising the `QRY` wire verbs end to end.

use std::io::{BufRead, BufReader, Write};
use std::process::{Command, Stdio};
use std::time::Duration;

use fast_sram::coordinator::{
    Backend, BitPlaneBackend, DigitalBackend, EngineConfig, FastBackend, UpdateEngine,
    UpdateRequest,
};
use fast_sram::fastmem::{AluOp, BatchReport, BitPlaneArray, FastArray, Fidelity};
use fast_sram::query::{
    broadcast_vec, plane_reduce, scalar_reduce, seeded_mask, QuerySpec, Reduction,
};
use fast_sram::util::bits;
use fast_sram::util::quickprop::{check, Gen};
use fast_sram::util::rng::Rng;

/// Independent host oracle: value and canonical pass report computed
/// from first principles (straight iteration over the state vector),
/// sharing no code with `scalar_reduce`/`plane_reduce`.
fn oracle(spec: &QuerySpec, state: &[u32], q: usize) -> (u64, BatchReport) {
    let m = bits::mask(q);
    let enabled: Vec<usize> = (0..state.len()).filter(|&r| spec.enabled(r)).collect();
    let mut value = match spec.red {
        Reduction::Min => u64::from(m),
        _ => 0u64,
    };
    let mut toggles = 0u64;
    for &r in &enabled {
        let v = state[r] & m;
        value = match &spec.red {
            Reduction::Popcount => value + u64::from(v.count_ones()),
            Reduction::Sum => value.wrapping_add(u64::from(v)),
            Reduction::Min => value.min(u64::from(v)),
            Reduction::Max => value.max(u64::from(v)),
            Reduction::RangeCount { lo, hi } => {
                value + u64::from(*lo <= v && v <= *hi)
            }
            Reduction::Dot { vec } => value.wrapping_add(u64::from(v) * u64::from(vec[r])),
        };
        // One full rotate-read pass: each cell toggles twice per
        // circular 0↔1 transition around the q-bit ring.
        let rot = ((v << 1) | (v >> (q - 1))) & m;
        toggles += 2 * u64::from((v ^ rot).count_ones());
    }
    let streams: u64 = match spec.red {
        Reduction::Dot { .. } => 2,
        _ => 1,
    };
    let report = BatchReport {
        cycles: q as u64,
        rows_active: enabled.len() as u64,
        cell_toggles: q as u64 * toggles,
        alu_evals: streams * q as u64 * enabled.len() as u64,
    };
    (value, report)
}

fn random_spec(g: &mut Gen, rows: usize, q: usize) -> QuerySpec {
    let m = bits::mask(q);
    let red = match g.usize_in(0, 5) {
        0 => Reduction::Popcount,
        1 => Reduction::Sum,
        2 => Reduction::Min,
        3 => Reduction::Max,
        4 => {
            let a = g.u32_any() & m;
            let b = g.u32_any() & m;
            Reduction::RangeCount { lo: a.min(b), hi: a.max(b) }
        }
        _ => Reduction::Dot { vec: broadcast_vec(g.u64_any(), rows, q) },
    };
    if g.bool() {
        QuerySpec::masked(red, seeded_mask(g.u64_any(), g.u32_below(101), rows))
    } else {
        QuerySpec::all(red)
    }
}

/// PROPERTY (satellite 1): every reduction, on random widths, row
/// counts and masks, answers the same value with the same canonical
/// pass report on all four backends — and matches the independent
/// host oracle; the modeled cost is exactly equal across the three
/// fast tiers (the energy story holds tier-independently).
#[test]
fn prop_reductions_identical_across_backends_vs_host_oracle() {
    check("query backend equivalence", 25, |g| {
        let rows = g.usize_in(1, 96);
        let q = *g.choose(&[4usize, 8, 16, 32]);
        let state: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
        let mut backends: Vec<Box<dyn Backend>> = vec![
            Box::new(FastBackend::with_rows_fidelity(rows, q, Fidelity::PhaseAccurate)),
            Box::new(FastBackend::with_rows_fidelity(rows, q, Fidelity::WordFast)),
            Box::new(BitPlaneBackend::with_rows(rows, q)),
            Box::new(DigitalBackend::new(rows, q)),
        ];
        for b in &mut backends {
            for (r, v) in state.iter().enumerate() {
                b.write_row(r, *v).unwrap();
            }
        }
        let mut ok = true;
        for _ in 0..3 {
            let spec = random_spec(g, rows, q);
            let (want, want_report) = oracle(&spec, &state, q);
            let outcomes: Vec<_> =
                backends.iter_mut().map(|b| b.query(&spec).unwrap()).collect();
            for o in &outcomes {
                ok &= o.value == want && o.report == want_report;
            }
            // Exact cost equality across the fast tiers (indices
            // 0..3 are phase/word/bitplane).
            ok &= outcomes[0].cost == outcomes[1].cost
                && outcomes[1].cost == outcomes[2].cost
                && outcomes[0].banks_active == outcomes[2].banks_active;
            // The library scalar reference agrees with the oracle too.
            let (sv, sr) = scalar_reduce(&spec, &state, q).unwrap();
            ok &= sv == want && sr == want_report;
        }
        ok
    });
}

/// PROPERTY (satellite 1): on multi-segment plane stacks, the
/// plane-wise kernels agree with the scalar reference segment by
/// segment — values and reports — for every reduction and mask.
#[test]
fn prop_multi_segment_plane_reduce_matches_scalar() {
    check("segmented plane reduce", 20, |g| {
        const LAYOUTS: [&[usize]; 3] = [&[8, 8], &[4, 12, 16], &[16]];
        let widths: &[usize] = g.choose(&LAYOUTS);
        let rows = g.usize_in(1, 80);
        let mut arr = BitPlaneArray::new(rows, widths);
        let mut rng = Rng::new(g.u64_any());
        arr.fill_from(|_, seg| rng.below(1u64 << widths[seg]) as u32);
        let mut ok = true;
        for (seg, &w) in widths.iter().enumerate() {
            let column: Vec<u32> = (0..rows).map(|r| arr.read_word(r, seg)).collect();
            for _ in 0..2 {
                let spec = random_spec(g, rows, w);
                let plane = plane_reduce(&arr, seg, &spec).unwrap();
                let scalar = scalar_reduce(&spec, &column, w).unwrap();
                ok &= plane == scalar && plane == oracle(&spec, &column, w);
            }
        }
        ok
    });
}

fn engine_for(tier: Fidelity, rows: usize, q: usize, shards: usize) -> UpdateEngine {
    let mut cfg = EngineConfig::sharded(rows, q, shards);
    cfg.seal_deadline = Duration::from_micros(300);
    match tier {
        Fidelity::BitPlane => UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap(),
        f => UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, f)))
        })
        .unwrap(),
    }
}

/// Engine-level cross-tier equality: the same update stream followed
/// by the same queries yields byte-for-byte identical `QueryResult`s
/// (value, report, banks, modeled cost, observed seqs) on all three
/// fast tiers.
#[test]
fn engine_query_results_identical_across_fast_tiers() {
    let (rows, q, shards) = (128usize, 16usize, 2usize);
    let specs = [
        QuerySpec::all(Reduction::Popcount),
        QuerySpec::all(Reduction::Min),
        QuerySpec::masked(Reduction::Sum, seeded_mask(2, 60, rows)),
        QuerySpec::masked(
            Reduction::Dot { vec: broadcast_vec(8, rows, q) },
            seeded_mask(3, 40, rows),
        ),
    ];
    let mut per_tier = Vec::new();
    for tier in [Fidelity::PhaseAccurate, Fidelity::WordFast, Fidelity::BitPlane] {
        // Deterministic sealing (no deadline races): batches seal only
        // on the explicit drain, so the observed commit seqs are
        // identical across tiers too.
        let mut cfg = EngineConfig::sharded(rows, q, shards);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        let engine = match tier {
            Fidelity::BitPlane => UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
            })
            .unwrap(),
            f => UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, f)))
            })
            .unwrap(),
        };
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..400 {
            let row = rng.below(rows as u64) as usize;
            let v = rng.below(1 << q) as u32;
            engine.submit_blocking(UpdateRequest::add(row, v)).unwrap();
        }
        engine.drain_all().unwrap();
        let results: Vec<_> = specs.iter().map(|s| engine.query(s).unwrap()).collect();
        engine.shutdown().unwrap();
        per_tier.push((tier, results));
    }
    let (_, want) = &per_tier[0];
    for (tier, got) in &per_tier[1..] {
        assert_eq!(got, want, "tier {tier:?} diverged from phase-accurate");
    }
}

/// PROPERTY (satellite 2): interleaved update/query streams observe
/// read-your-writes at 1/2/4/8 shards. Producers own disjoint rows
/// (row % producers == t); a query masked to a producer's own rows
/// must equal its private host model exactly, and every commit whose
/// ticket was issued before the query carries a `commit_seq` at or
/// below the seq the query observed on that shard.
#[test]
fn interleaved_queries_observe_read_your_writes() {
    let producers = 4usize;
    let rows = 64usize;
    let q = 8usize;
    let tier = Fidelity::from_env_or(Fidelity::WordFast);
    for shards in [1usize, 2, 4, 8] {
        let engine = engine_for(tier, rows, q, shards);
        let ctx = format!("shards={shards} tier={tier:?}");
        std::thread::scope(|scope| {
            for t in 0..producers {
                let engine = &engine;
                let ctx = &ctx;
                scope.spawn(move || {
                    let mut rng = Rng::new(0x9E77E7 + 977 * t as u64);
                    let own: Vec<usize> = (0..rows).filter(|r| r % producers == t).collect();
                    let mut mask = vec![0u64; rows.div_ceil(64)];
                    for &r in &own {
                        mask[r / 64] |= 1u64 << (r % 64);
                    }
                    let mut model: Vec<u32> = vec![0; own.len()];
                    let mut outstanding = Vec::new();
                    for i in 0..250 {
                        if rng.chance(0.25) {
                            // Query this thread's rows: the forced
                            // seal inside the worker makes every
                            // prior submission visible.
                            let spec =
                                QuerySpec::masked(Reduction::Sum, mask.clone());
                            let r = engine.query(&spec).unwrap();
                            let want: u64 = model.iter().map(|&v| u64::from(v)).sum();
                            assert_eq!(
                                r.value, want,
                                "{ctx} t={t} i={i}: query must reflect every \
                                 prior update by this producer"
                            );
                            assert_eq!(r.report.rows_active, own.len() as u64, "{ctx}");
                            // Ordering: tickets issued before the
                            // query resolve at or below the seq the
                            // query observed on their shard.
                            for tk in outstanding.drain(..) {
                                let c: fast_sram::coordinator::Commit =
                                    tk.wait().expect("ticket resolves");
                                assert!(
                                    c.commit_seq <= r.shard_seqs[c.shard],
                                    "{ctx} t={t} i={i}: commit seq {} on shard {} \
                                     observed seq {}",
                                    c.commit_seq,
                                    c.shard,
                                    r.shard_seqs[c.shard]
                                );
                            }
                        } else {
                            let slot = rng.below(own.len() as u64) as usize;
                            let v = rng.below(1 << q) as u32;
                            model[slot] = bits::add_mod(model[slot], v, q);
                            outstanding.push(
                                engine
                                    .submit_blocking_ticketed(UpdateRequest::add(own[slot], v))
                                    .unwrap(),
                            );
                        }
                    }
                });
            }
        });
        let stats = engine.stats();
        assert!(stats.queries > 0, "{ctx}: queries were exercised");
        engine.shutdown().unwrap();
    }
}

/// Regression (satellite 4): non-counting reads really are
/// non-counting — `peek_rows`/`peek_word` leave the port and energy
/// counters untouched on every tier, and a plane-wise reduction
/// leaves the plane stack's lifetime toggle counter untouched.
#[test]
fn non_counting_reads_leave_counters_untouched() {
    for tier in [Fidelity::PhaseAccurate, Fidelity::WordFast, Fidelity::BitPlane] {
        let rows = 48usize;
        let q = 16usize;
        let mut a = FastArray::with_fidelity(rows, q, tier);
        let mut rng = Rng::new(9 + tier as u64);
        let init: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
        a.load(&init);
        a.set_op(AluOp::Add);
        let deltas: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
        a.batch_apply_segmented(&deltas).unwrap();
        let _ = a.read_word(0, 0).unwrap(); // one counted read for contrast

        let before = (a.port_reads(), a.port_writes(), a.batch_ops(), a.batch_cycles(), a.toggles());
        let snap = a.peek_rows();
        for r in 0..rows {
            assert_eq!(a.peek_word(r, 0).unwrap(), snap[r], "{tier:?}");
        }
        let after = (a.port_reads(), a.port_writes(), a.batch_ops(), a.batch_cycles(), a.toggles());
        assert_eq!(after, before, "{tier:?}: peeks must not count as port traffic");
        assert_eq!(before.0, 1, "{tier:?}: only the explicit read_word counted");
        assert_eq!(a.peek_rows(), snap, "{tier:?}: peeks must not disturb state");
    }

    // Plane tier: a reduction is a pure read — lifetime toggles and
    // state are bit-for-bit unchanged.
    let mut arr = BitPlaneArray::new(40, &[16]);
    let mut rng = Rng::new(77);
    arr.fill_from(|_, _| rng.below(1 << 16) as u32);
    arr.apply(AluOp::Add, &[3u32; 40]);
    let toggles = arr.toggles();
    let before: Vec<u32> = (0..40).map(|r| arr.read_word(r, 0)).collect();
    for spec in [
        QuerySpec::all(Reduction::Popcount),
        QuerySpec::masked(Reduction::Max, seeded_mask(1, 50, 40)),
    ] {
        plane_reduce(&arr, 0, &spec).unwrap();
    }
    assert_eq!(arr.toggles(), toggles, "plane reductions must not charge toggles");
    let after: Vec<u32> = (0..40).map(|r| arr.read_word(r, 0)).collect();
    assert_eq!(after, before, "plane reductions must not disturb state");
}

/// Satellite 3 (stdio leg): a live `fast serve --stdio` process
/// answers `QRY` lines in lockstep — results round-trip, malformed
/// lines get one typed `ERR` reply instead of a hang, and the session
/// keeps serving afterwards.
#[test]
fn stdio_server_answers_and_rejects_qry_lines() {
    let mut child = Command::new(env!("CARGO_BIN_EXE_fast"))
        .args(["serve", "--stdio", "--rows", "64", "--q", "16", "--shards", "2"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning fast serve --stdio");
    let mut stdin = child.stdin.take().unwrap();
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    let mut roundtrip = |line: &str, stdin: &mut std::process::ChildStdin| -> String {
        writeln!(stdin, "{line}").unwrap();
        let mut reply = String::new();
        stdout.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "server hung up on {line:?}");
        reply.trim_end().to_string()
    };

    let banner = roundtrip("HELLO", &mut stdin);
    assert!(banner.starts_with("OK fast-serve-v1 rows=64 q=16 shards=2"), "{banner}");
    assert_eq!(roundtrip("{\"t\":\"w\",\"r\":1,\"v\":5}", &mut stdin), "OK");
    assert!(roundtrip("{\"t\":\"u\",\"o\":\"add\",\"r\":2,\"v\":9}", &mut stdin).starts_with("OK"));

    let r = roundtrip("QRY sum", &mut stdin);
    assert!(r.starts_with("OK qry sum value=14 "), "{r}");
    let r = roundtrip("QRY max mask 4 100", &mut stdin);
    assert!(r.contains(" value=9 "), "{r}");

    // Malformed lines: one typed ERR each, never a hang or a death.
    for bad in ["QRY", "QRY median", "QRY range 9", "QRY sum nonsense"] {
        let r = roundtrip(bad, &mut stdin);
        assert!(r.starts_with("ERR "), "{bad:?} -> {r}");
    }
    // The session is still healthy after the rejects.
    let r = roundtrip("QRY sum", &mut stdin);
    assert!(r.starts_with("OK qry sum value=14 "), "{r}");

    // EOF is a clean shutdown.
    drop(stdin);
    let status = child.wait().expect("server exit");
    assert!(status.success(), "server must exit 0 on EOF, got {status}");
}
