//! Differential property tests for the three fidelity tiers: the
//! phase-accurate, word-fast and bit-plane datapaths must produce
//! identical values *and* identical activity accounting
//! (`cell_toggles` / `alu_evals` / lifetime toggle counters) for every
//! op, width, segment layout and row-enable mask — otherwise the
//! energy model would silently drift when a faster tier is selected.

use fast_sram::coordinator::{
    BitPlaneBackend, EngineConfig, FastBackend, UpdateEngine, UpdateRequest,
};
use fast_sram::fastmem::{
    AluOp, BatchReport, BitPlaneArray, FastArray, Fidelity, RouteFabric,
};
use fast_sram::util::bits;
use fast_sram::util::quickprop::check;

const OPS: [AluOp; 5] = [AluOp::Add, AluOp::Sub, AluOp::And, AluOp::Or, AluOp::Xor];

/// Host-side reference for one op on one word.
fn host_apply(op: AluOp, v: u32, o: u32, q: usize) -> u32 {
    match op {
        AluOp::Add => bits::add_mod(v, o, q),
        AluOp::Sub => bits::sub_mod(v, o, q),
        AluOp::And => v & o,
        AluOp::Or => (v | o) & bits::mask(q),
        AluOp::Xor => (v ^ o) & bits::mask(q),
        AluOp::Pass => v,
    }
}

/// PROPERTY: all three tiers agree on values, batch reports and
/// lifetime toggle counters for random widths, ops and batch streams
/// (single-segment rows; the row count crosses u64-lane boundaries).
#[test]
fn prop_fidelity_tiers_equivalent_single_segment() {
    check("fidelity tier equivalence", 20, |g| {
        let rows = g.usize_in(1, 70);
        let q = *g.choose(&[4usize, 8, 16, 32]);
        let mut tiers = [
            FastArray::with_fidelity(rows, q, Fidelity::PhaseAccurate),
            FastArray::with_fidelity(rows, q, Fidelity::WordFast),
            FastArray::with_fidelity(rows, q, Fidelity::BitPlane),
        ];
        let mut reference: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
        for a in &mut tiers {
            a.load(&reference);
        }
        let mut ok = true;
        for _ in 0..3 {
            let op = *g.choose(&OPS);
            let deltas: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
            for (r, d) in reference.iter_mut().zip(&deltas) {
                *r = host_apply(op, *r, *d, q);
            }
            let reports: Vec<BatchReport> = tiers
                .iter_mut()
                .map(|a| {
                    a.set_op(op);
                    a.batch_apply_segmented(&deltas).unwrap()
                })
                .collect();
            ok &= reports[0] == reports[1] && reports[1] == reports[2];
            for a in &tiers {
                ok &= a.peek_rows() == reference;
            }
        }
        ok &= tiers[0].toggles() == tiers[1].toggles();
        ok &= tiers[1].toggles() == tiers[2].toggles();
        ok
    });
}

/// PROPERTY: tier equivalence holds for multi-word segment layouts
/// (per-segment operands, mixed port accesses between batches).
#[test]
fn prop_fidelity_tiers_equivalent_segmented() {
    check("fidelity tier equivalence (segmented)", 15, |g| {
        // (row_width, base_width) → uniform segments of base_width.
        let (row_w, base) = *g.choose(&[(16usize, 8usize), (32, 8), (16, 4), (24, 12)]);
        let rows = g.usize_in(1, 40);
        let mut tiers = [
            Fidelity::PhaseAccurate,
            Fidelity::WordFast,
            Fidelity::BitPlane,
        ]
        .map(|f| {
            let fabric = RouteFabric::new(row_w, base);
            let mut a = FastArray::with_fabric(rows, fabric, base, AluOp::Add).unwrap();
            a.set_fidelity(f);
            a
        });
        let wpr = tiers[0].words_per_row();
        let mut reference = vec![0u32; rows * wpr];
        for (i, v) in reference.iter_mut().enumerate() {
            *v = g.u32_any() & bits::mask(base);
            for a in &mut tiers {
                a.write_word(i / wpr, i % wpr, *v).unwrap();
            }
        }
        let mut ok = true;
        for round in 0..3 {
            let op = *g.choose(&OPS);
            let ops: Vec<u32> = (0..rows * wpr)
                .map(|_| g.u32_any() & bits::mask(base))
                .collect();
            for (r, d) in reference.iter_mut().zip(&ops) {
                *r = host_apply(op, *r, *d, base);
            }
            let reports: Vec<BatchReport> = tiers
                .iter_mut()
                .map(|a| {
                    a.set_op(op);
                    a.batch_apply_segmented(&ops).unwrap()
                })
                .collect();
            ok &= reports[0] == reports[1] && reports[1] == reports[2];
            // Interleave a counted port access mid-stream on odd
            // rounds: the lazy transpose in/out must be transparent.
            if round == 1 {
                let probe = g.usize_in(0, rows * wpr - 1);
                for a in &mut tiers {
                    ok &= a.read_word(probe / wpr, probe % wpr).unwrap()
                        == reference[probe];
                }
            }
        }
        for a in &tiers {
            for (i, &want) in reference.iter().enumerate() {
                ok &= a.peek_word(i / wpr, i % wpr).unwrap() == want;
            }
        }
        ok &= tiers[0].toggles() == tiers[2].toggles();
        ok
    });
}

/// PROPERTY: a masked bit-plane batch updates exactly the enabled rows
/// and accounts activity for exactly those rows (the complement-run
/// toggle sum equals the full run).
#[test]
fn prop_bitplane_masks_gate_rows_exactly() {
    check("bitplane row masks", 30, |g| {
        let rows = g.usize_in(1, 200);
        let q = *g.choose(&[8usize, 16]);
        let op = *g.choose(&OPS);
        let init: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
        let ops: Vec<u32> = (0..rows).map(|_| g.u32_any() & bits::mask(q)).collect();
        let lanes = rows.div_ceil(64);
        let mut enable = vec![0u64; lanes];
        let mut enabled = Vec::new();
        for r in 0..rows {
            if g.bool() {
                enable[r / 64] |= 1u64 << (r % 64);
                enabled.push(r);
            }
        }

        let mut a = BitPlaneArray::new(rows, &[q]);
        a.fill_from(|r, _| init[r]);
        let rep = a.apply_masked(op, &ops, &enable);

        let mut full = BitPlaneArray::new(rows, &[q]);
        full.fill_from(|r, _| init[r]);
        let rep_full = full.apply(op, &ops);
        let mut comp = vec![0u64; lanes];
        for (l, c) in comp.iter_mut().enumerate() {
            *c = !enable[l];
        }
        let mut b = BitPlaneArray::new(rows, &[q]);
        b.fill_from(|r, _| init[r]);
        let rep_comp = b.apply_masked(op, &ops, &comp);

        let mut ok = rep.rows_active == enabled.len() as u64;
        ok &= rep.alu_evals == (q * enabled.len()) as u64;
        ok &= rep.cell_toggles + rep_comp.cell_toggles == rep_full.cell_toggles;
        for r in 0..rows {
            let want = if enabled.contains(&r) {
                host_apply(op, init[r], ops[r], q)
            } else {
                init[r]
            };
            ok &= a.read_word(r, 0) == want;
        }
        ok
    });
}

/// The sharded engine produces identical state on the word-fast and
/// bit-plane backends for the same request stream — the tier is an
/// implementation detail, not a semantics change.
#[test]
fn engine_bitplane_backend_matches_word_backend() {
    for shards in [1usize, 4] {
        let rows = 512;
        let q = 16;
        let make = |bitplane: bool| {
            let cfg = EngineConfig::sharded(rows, q, shards);
            if bitplane {
                UpdateEngine::start(cfg, move |plan| {
                    Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
                })
                .unwrap()
            } else {
                UpdateEngine::start(cfg, move |plan| {
                    Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
                })
                .unwrap()
            }
        };
        let word = make(false);
        let plane = make(true);
        let mut rng = fast_sram::util::rng::Rng::new(808 + shards as u64);
        for _ in 0..5000 {
            let row = rng.below(rows as u64) as usize;
            let v = rng.below(1 << q) as u32;
            let req = if rng.chance(0.3) {
                UpdateRequest::sub(row, v)
            } else {
                UpdateRequest::add(row, v)
            };
            word.submit_blocking(req).unwrap();
            plane.submit_blocking(req).unwrap();
        }
        assert_eq!(
            word.snapshot().unwrap(),
            plane.snapshot().unwrap(),
            "shards = {shards}"
        );
        let sp = plane.stats();
        assert_eq!(sp.backend, "fast-bitplane");
        assert_eq!(sp.completed, 5000);
        word.shutdown().unwrap();
        plane.shutdown().unwrap();
    }
}

/// Applying one coalesced batch through the bit-plane backend charges
/// the same modeled energy as the word-fast backend (bit-identical
/// floats, not just approximately equal).
#[test]
fn engine_energy_identical_across_tiers() {
    let rows = 256;
    let q = 16;
    let run = |bitplane: bool| {
        let mut cfg = EngineConfig::new(rows, q);
        // Deterministic sealing: only the size seal (or the final
        // flush) may seal, so both runs batch identically and the
        // energy comparison is exact rather than timing-dependent.
        cfg.seal_at_rows = Some(rows);
        cfg.seal_deadline = std::time::Duration::from_secs(3600);
        let e = if bitplane {
            UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
            })
            .unwrap()
        } else {
            UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
            })
            .unwrap()
        };
        for r in 0..rows {
            e.submit_blocking(UpdateRequest::add(r, (r as u32) | 1)).unwrap();
        }
        e.drain_shard(0).unwrap();
        let s = e.stats();
        e.shutdown().unwrap();
        (s.modeled_energy_pj, s.modeled_ns)
    };
    let (ew, tw) = run(false);
    let (ep, tp) = run(true);
    assert_eq!(ew, ep, "modeled energy must not drift across tiers");
    assert_eq!(tw, tp, "modeled latency must not drift across tiers");
}

/// PROPERTY: transpose64 is the LSB-first transpose and an involution
/// (the bit-plane tier's correctness rests on it).
#[test]
fn prop_transpose64_involution_and_orientation() {
    check("transpose64", 60, |g| {
        let mut a = [0u64; 64];
        for w in a.iter_mut() {
            *w = g.u64_any();
        }
        let orig = a;
        bits::transpose64(&mut a);
        let r = g.usize_in(0, 63);
        let c = g.usize_in(0, 63);
        let mut ok = (a[c] >> r) & 1 == (orig[r] >> c) & 1;
        bits::transpose64(&mut a);
        ok &= a == orig;
        ok
    });
}
