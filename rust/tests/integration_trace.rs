//! Trace round-trip and replay-invariance tests: record → serialize →
//! parse → replay must be lossless on bytes, and replay must be
//! bit-identical on final state across backends, fidelity tiers and
//! shard counts — with the energy account bit-identical across FAST
//! tiers (always) and across shard counts (for dense traces, whose
//! flush groups touch every shard).

use fast_sram::apps::trace::{state_digest, uniform_trace, BackendKind, Trace};
use fast_sram::apps::trainer::{self, TrainerConfig};
use fast_sram::coordinator::{UpdateOp, UpdateRequest};
use fast_sram::fastmem::Fidelity;

fn small_vgg7(rows: usize, q: usize) -> TrainerConfig {
    let mut cfg = TrainerConfig::vgg7(rows, q);
    cfg.epochs = 1;
    cfg.steps_per_epoch = 3;
    cfg
}

// ---------------------------------------------------------------------------
// Round-trip: serialize → parse → serialize is the identity on bytes
// ---------------------------------------------------------------------------

#[test]
fn trainer_trace_round_trips_byte_identically() {
    let trace = trainer::record_trace(&small_vgg7(128, 8)).unwrap();
    let text = trace.to_jsonl();
    let parsed = Trace::parse_jsonl(&text).unwrap();
    assert_eq!(parsed, trace, "parse must reconstruct the trace exactly");
    assert_eq!(parsed.to_jsonl(), text, "re-serialization must be byte-identical");
}

#[test]
fn mixed_op_trace_round_trips_byte_identically() {
    // Exercise every event type and op spelling the format supports.
    let mut trace = uniform_trace(64, 12, 700, 99);
    trace.push_write(63, 0xFFF);
    for (i, op) in [UpdateOp::And, UpdateOp::Or, UpdateOp::Xor, UpdateOp::Add, UpdateOp::Sub]
        .into_iter()
        .enumerate()
    {
        trace.push_update(UpdateRequest { row: i, op, operand: (i as u32 * 7 + 1) & 0xFFF });
    }
    trace.push_flush();
    let text = trace.to_jsonl();
    let parsed = Trace::parse_jsonl(&text).unwrap();
    assert_eq!(parsed, trace);
    assert_eq!(parsed.to_jsonl(), text);
}

#[test]
fn file_round_trip_preserves_replay_results() {
    let dir = std::env::temp_dir().join(format!("fast_trace_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.trace");

    let trace = trainer::record_trace(&small_vgg7(64, 8)).unwrap();
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded, trace);

    let a = trace.replay_on(BackendKind::Fast(Fidelity::WordFast), 1).unwrap();
    let b = loaded.replay_on(BackendKind::Fast(Fidelity::WordFast), 1).unwrap();
    assert_eq!(a.final_state, b.final_state);
    assert_eq!(a.stats.modeled_energy_pj, b.stats.modeled_energy_pj);
    assert_eq!(state_digest(&a.final_state), state_digest(&b.final_state));
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Replay invariances
// ---------------------------------------------------------------------------

#[test]
fn replay_is_deterministic_per_backend() {
    let trace = trainer::record_trace(&small_vgg7(128, 8)).unwrap();
    for kind in [BackendKind::Fast(Fidelity::WordFast), BackendKind::Digital] {
        let a = trace.replay_on(kind, 1).unwrap();
        let b = trace.replay_on(kind, 1).unwrap();
        assert_eq!(a.final_state, b.final_state, "{}", kind.label());
        assert_eq!(
            a.stats.modeled_energy_pj, b.stats.modeled_energy_pj,
            "{}: energy must reproduce bit-identically",
            kind.label()
        );
        assert_eq!(a.stats.modeled_ns, b.stats.modeled_ns, "{}", kind.label());
        assert_eq!(a.stats.batches, b.stats.batches, "{}", kind.label());
    }
}

#[test]
fn replay_state_is_bit_identical_across_backends() {
    let trace = trainer::record_trace(&small_vgg7(128, 8)).unwrap();
    let want = trace.reference_state();
    let fast = trace.replay_on(BackendKind::Fast(Fidelity::WordFast), 1).unwrap();
    let plane = trace.replay_on(BackendKind::BitPlane, 1).unwrap();
    let digital = trace.replay_on(BackendKind::Digital, 1).unwrap();
    assert_eq!(fast.final_state, want);
    assert_eq!(plane.final_state, want);
    assert_eq!(digital.final_state, want);
    // The cost asymmetry the paper claims, on the identical workload:
    assert!(
        digital.stats.modeled_ns > 20.0 * fast.stats.modeled_ns,
        "digital {} ns vs fast {} ns",
        digital.stats.modeled_ns,
        fast.stats.modeled_ns
    );
    assert!(digital.stats.modeled_energy_pj > fast.stats.modeled_energy_pj);
}

#[test]
fn replay_energy_is_bit_identical_across_fidelity_tiers() {
    // Phase-accurate is ~100× word-fast per batch — keep the trace small.
    let trace = trainer::record_trace(&small_vgg7(64, 8)).unwrap();
    let word = trace.replay_on(BackendKind::Fast(Fidelity::WordFast), 1).unwrap();
    let phase = trace.replay_on(BackendKind::Fast(Fidelity::PhaseAccurate), 1).unwrap();
    let plane = trace.replay_on(BackendKind::BitPlane, 1).unwrap();
    for (label, rep) in [("phase", &phase), ("bitplane", &plane)] {
        assert_eq!(rep.final_state, word.final_state, "{label}");
        assert_eq!(
            rep.stats.modeled_energy_pj, word.stats.modeled_energy_pj,
            "{label}: tier change must not move the energy account"
        );
        assert_eq!(rep.stats.modeled_ns, word.stats.modeled_ns, "{label}");
    }
}

#[test]
fn replay_is_invariant_across_shard_counts() {
    // Tier from the CI fidelity matrix (FAST_TEST_FIDELITY), word-fast
    // by default — the invariance must hold on every tier. (Fast(tier)
    // routes a bitplane tier to the dedicated backend by itself.)
    let tier = Fidelity::from_env_or(Fidelity::WordFast);
    let kind = BackendKind::Fast(tier);
    let cfg = small_vgg7(if tier == Fidelity::PhaseAccurate { 64 } else { 128 }, 8);
    let trace = trainer::record_trace(&cfg).unwrap();
    let one = trace.replay_on(kind, 1).unwrap();
    assert_eq!(one.final_state, trace.reference_state());
    for shards in [2usize, 4] {
        let sharded = trace.replay_on(kind, shards).unwrap();
        assert_eq!(sharded.final_state, one.final_state, "shards = {shards}");
        // Dense trainer traces touch every shard in every flush group,
        // so the per-bank energy accounting sums to the same total.
        assert!(
            (sharded.stats.modeled_energy_pj - one.stats.modeled_energy_pj).abs() < 1e-9,
            "shards = {shards}: {} vs {} pJ",
            sharded.stats.modeled_energy_pj,
            one.stats.modeled_energy_pj
        );
    }
}

#[test]
fn uniform_trace_replays_identically_on_fast_and_digital() {
    let trace = uniform_trace(128, 8, 4000, 0xBEEF);
    let want = trace.reference_state();
    let fast = trace.replay_on(BackendKind::Fast(Fidelity::WordFast), 1).unwrap();
    let digital = trace.replay_on(BackendKind::Digital, 1).unwrap();
    assert_eq!(fast.final_state, want);
    assert_eq!(digital.final_state, want);
    assert_eq!(fast.stats.completed, 4000);
    assert_eq!(digital.stats.completed, 4000);
}
