//! Integration tests over the PJRT runtime: load the AOT artifacts,
//! validate them against host semantics, and cross-check the XLA
//! backend against the phase-accurate behavioural model — the "two
//! implementations, one semantics" guarantee of the reproduction.
//!
//! These tests need two things the default offline build does not
//! have, so they *skip* (pass with an eprintln note) rather than fail
//! when either is missing:
//!
//! 1. the AOT artifacts (`artifacts/manifest.json`, authored by
//!    `python/compile/aot.py`), and
//! 2. a real PJRT runtime (`--features pjrt` plus the xla bindings
//!    crate; the default build uses the stub in
//!    `src/runtime/xla_stub.rs`, which errors at client construction).

use fast_sram::coordinator::Backend;
use fast_sram::coordinator::{
    BatchKind, EngineConfig, FastBackend, UpdateEngine, UpdateRequest, XlaBackend,
};
use fast_sram::runtime::{validate, Runtime};
use fast_sram::util::bits;
use fast_sram::util::rng::Rng;

/// The artifact directory, if artifacts exist AND a real PJRT client
/// can be constructed. `None` = skip the test (with a note on stderr).
fn pjrt_or_skip() -> Option<std::path::PathBuf> {
    // Tests run with CWD = package root.
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "skipping PJRT test: artifacts/manifest.json missing \
             (generate with python/compile/aot.py)"
        );
        return None;
    }
    // A filtered load that keeps nothing still constructs the client —
    // the cheapest possible availability probe.
    match Runtime::load_filtered(&dir, |_| false) {
        Ok(_) => Some(dir),
        Err(e) => {
            eprintln!("skipping PJRT test: runtime unavailable: {e:#}");
            None
        }
    }
}

fn runtime_or_skip() -> Option<Runtime> {
    let dir = pjrt_or_skip()?;
    Some(Runtime::load_dir(dir).expect("probe succeeded but full load failed"))
}

#[test]
fn manifest_loads_and_lists_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.len() >= 9, "expected >= 9 artifacts, got {}", rt.len());
    for required in [
        "fast_add_128x8",
        "fast_add_128x16",
        "fast_add_128x32",
        "fast_sub_128x16",
        "fast_and_128x16",
        "fast_or_128x16",
        "fast_xor_128x16",
        "fast_add_1024x16",
        "fast_scan8_128x16",
    ] {
        assert!(rt.get(required).is_ok(), "missing artifact {required}");
    }
    assert_eq!(rt.get("fast_add_128x16").unwrap().meta.q, 16);
    assert_eq!(rt.get("fast_add_1024x16").unwrap().meta.rows, 1024);
}

#[test]
fn filtered_load_compiles_subset() {
    let Some(dir) = pjrt_or_skip() else { return };
    let rt = Runtime::load_filtered(dir, |n| n == "fast_add_128x16").unwrap();
    assert_eq!(rt.len(), 1);
    assert!(rt.get("fast_xor_128x16").is_err());
}

#[test]
fn all_two_input_artifacts_validate_against_host_semantics() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in rt.names() {
        let art = rt.get(name).unwrap();
        if art.meta.op == "scan_add" {
            let checked = validate::validate_scan(art, 2, 99).unwrap();
            assert!(checked > 0);
        } else {
            let checked = validate::validate2(art, 2, 99).unwrap();
            assert!(checked > 0, "{name}");
        }
    }
}

#[test]
fn artifact_rejects_wrong_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.get("fast_add_128x16").unwrap();
    assert!(art.exec2(&[0u32; 64], &[0u32; 128]).is_err());
    assert!(art.exec2(&[0u32; 128], &[0u32; 129]).is_err());
    assert!(art.exec_scan(&[0u32; 128], &[0u32; 128]).is_err()); // not a scan
}

#[test]
fn scan_artifact_accumulates_rounds() {
    let Some(rt) = runtime_or_skip() else { return };
    let art = rt.get("fast_scan8_128x16").unwrap();
    let t = art.meta.rounds.unwrap();
    assert_eq!(t, 8);
    let table = vec![1u32; 128];
    let rounds = vec![2u32; 8 * 128];
    let out = art.exec_scan(&table, &rounds).unwrap();
    assert!(out.iter().all(|&v| v == 1 + 16));
}

/// The centrepiece: the XLA (Pallas-kernel) backend and the
/// phase-accurate behavioural backend process the same request stream
/// through identical engines and must agree bit-for-bit.
#[test]
fn xla_and_behavioural_backends_agree_on_random_streams() {
    let Some(dir) = pjrt_or_skip() else { return };
    let rows = 128;
    let q = 16;
    let cfg = EngineConfig::new(rows, q);
    let xla = UpdateEngine::start(cfg.clone(), move |plan| {
        Ok(Box::new(XlaBackend::new(&dir, plan.rows, plan.q)?))
    })
    .unwrap();
    let beh = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();

    let mut rng = Rng::new(2024);
    for _ in 0..1500 {
        let row = rng.below(rows as u64) as usize;
        let v = rng.below(1 << q) as u32;
        let req = match rng.below(4) {
            0 => UpdateRequest::sub(row, v),
            1 => UpdateRequest { row, op: fast_sram::coordinator::UpdateOp::Xor, operand: v },
            _ => UpdateRequest::add(row, v),
        };
        xla.submit_blocking(req).unwrap();
        beh.submit_blocking(req).unwrap();
    }
    let a = xla.snapshot().unwrap();
    let b = beh.snapshot().unwrap();
    assert_eq!(a, b, "XLA artifact and behavioural model diverged");
    assert_eq!(xla.stats().backend, "fast-xla");
    xla.shutdown().unwrap();
    beh.shutdown().unwrap();
}

#[test]
fn xla_backend_multi_macro_1024() {
    let Some(dir) = pjrt_or_skip() else { return };
    let mut backend = XlaBackend::new(dir, 1024, 16).unwrap();
    let mut rng = Rng::new(5);
    let init: Vec<u32> = (0..1024).map(|_| rng.below(1 << 16) as u32).collect();
    for (r, &v) in init.iter().enumerate() {
        backend.write_row(r, v).unwrap();
    }
    let deltas: Vec<u32> = (0..1024).map(|_| rng.below(1 << 16) as u32).collect();
    backend.apply(BatchKind::Add, &deltas).unwrap();
    let snap = backend.snapshot().unwrap();
    for r in 0..1024 {
        assert_eq!(snap[r], bits::add_mod(init[r], deltas[r], 16));
    }
}

#[test]
fn logic_artifacts_match_host_ops() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Rng::new(3);
    let a: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
    let b: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
    for (name, f) in [
        ("fast_and_128x16", (|x: u32, y: u32| x & y) as fn(u32, u32) -> u32),
        ("fast_or_128x16", |x, y| x | y),
        ("fast_xor_128x16", |x, y| x ^ y),
    ] {
        let got = rt.get(name).unwrap().exec2(&a, &b).unwrap();
        for r in 0..128 {
            assert_eq!(got[r], f(a[r], b[r]) & 0xFFFF, "{name} row {r}");
        }
    }
}
