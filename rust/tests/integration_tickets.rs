//! Ticket-semantics properties for the request/response pipeline
//! (PR-4 satellite): seeded multi-producer checks that
//!
//!   (a) every submitted request's ticket resolves exactly once,
//!   (b) tickets for the same shard resolve in nondecreasing
//!       `commit_seq` order,
//!   (c) read-your-writes holds for interleaved read/update streams,
//!
//! across 1/2/4/8 shards and all three fidelity tiers
//! (phase-accurate, word-fast, bit-plane) — plus the per-shard-drain
//! regression: a read seals only the owning shard's pending batch.

use std::time::Duration;

use fast_sram::coordinator::{
    BitPlaneBackend, Commit, EngineConfig, FastBackend, UpdateEngine, UpdateOp, UpdateRequest,
};
use fast_sram::fastmem::Fidelity;
use fast_sram::util::bits;
use fast_sram::util::rng::Rng;

fn engine_for(tier: Fidelity, rows: usize, q: usize, shards: usize) -> UpdateEngine {
    let mut cfg = EngineConfig::sharded(rows, q, shards);
    // Seals come from kind changes, reads, drains and this deadline —
    // tickets must resolve under every seal path.
    cfg.seal_deadline = Duration::from_micros(300);
    match tier {
        Fidelity::BitPlane => UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap(),
        f => UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, f)))
        })
        .unwrap(),
    }
}

fn apply_host(state: &mut u32, op: UpdateOp, operand: u32, q: usize) {
    let m = bits::mask(q);
    *state = match op {
        UpdateOp::Add => bits::add_mod(*state, operand, q),
        UpdateOp::Sub => bits::sub_mod(*state, operand, q),
        UpdateOp::And => *state & operand & m,
        UpdateOp::Or => (*state | operand) & m,
        UpdateOp::Xor => (*state ^ operand) & m,
    };
}

/// The three ticket properties under concurrent producers, across
/// shard counts and fidelity tiers. Producers own disjoint row sets
/// (row % producers == t), so each thread's host model is exact and
/// read-your-writes is decidable mid-stream.
#[test]
fn tickets_resolve_once_in_order_with_read_your_writes() {
    let producers = 4usize;
    let rows = 64usize;
    let q = 8usize;
    for shards in [1usize, 2, 4, 8] {
        for tier in [Fidelity::WordFast, Fidelity::BitPlane, Fidelity::PhaseAccurate] {
            // Phase-accurate is ~100× word-fast per batch: trim load.
            let per_thread = if tier == Fidelity::PhaseAccurate { 120 } else { 700 };
            let engine = engine_for(tier, rows, q, shards);
            let ops =
                [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor];
            let ctx = format!("shards={shards} tier={tier:?}");

            // Each producer returns (its commits in submission order,
            // its final row model).
            let outcomes: Vec<(Vec<Commit>, Vec<(usize, u32)>)> =
                std::thread::scope(|scope| {
                    let mut handles = Vec::new();
                    for t in 0..producers {
                        let engine = &engine;
                        let ctx = &ctx;
                        handles.push(scope.spawn(move || {
                            let mut rng = Rng::new(0x71C4E7 + 131 * t as u64);
                            let own: Vec<usize> =
                                (0..rows).filter(|r| r % producers == t).collect();
                            let mut model: Vec<(usize, u32)> =
                                own.iter().map(|&r| (r, 0u32)).collect();
                            let mut tickets = Vec::with_capacity(per_thread);
                            for i in 0..per_thread {
                                let slot = rng.below(own.len() as u64) as usize;
                                let row = own[slot];
                                if rng.chance(0.2) {
                                    // (c) interleaved read: must see every
                                    // update this thread already submitted.
                                    let got = engine.read(row).unwrap();
                                    assert_eq!(
                                        got, model[slot].1,
                                        "{ctx} t={t} i={i}: read-your-writes at row {row}"
                                    );
                                } else {
                                    let op = ops[rng.below(ops.len() as u64) as usize];
                                    let operand = rng.below(1 << q) as u32;
                                    apply_host(&mut model[slot].1, op, operand, q);
                                    tickets.push(
                                        engine
                                            .submit_blocking_ticketed(UpdateRequest {
                                                row,
                                                op,
                                                operand,
                                            })
                                            .unwrap(),
                                    );
                                }
                            }
                            // Commit our shards so every ticket can resolve,
                            // then harvest the commits in submission order.
                            engine.drain_all().unwrap();
                            let commits: Vec<Commit> = tickets
                                .iter()
                                .map(|tk| tk.wait().expect("ticket must resolve"))
                                .collect();
                            // (a) exactly once: resolution is terminal and
                            // stable — a second wait sees the same commit.
                            for (tk, c) in tickets.iter().zip(&commits) {
                                assert!(tk.is_resolved());
                                assert_eq!(tk.wait().unwrap(), *c, "{ctx}: commit must be stable");
                            }
                            (commits, model)
                        }));
                    }
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });

            // (b) per-shard nondecreasing commit_seq in submission order.
            let mut issued = 0u64;
            for (commits, _) in &outcomes {
                let mut last = vec![0u64; shards];
                for c in commits {
                    assert!(c.shard < shards, "{ctx}");
                    assert!(
                        c.commit_seq >= last[c.shard],
                        "{ctx}: shard {} seq {} after {}",
                        c.shard,
                        c.commit_seq,
                        last[c.shard]
                    );
                    last[c.shard] = c.commit_seq;
                    assert!(c.modeled_ns > 0.0, "{ctx}: commit carries apply metadata");
                    issued += 1;
                }
            }

            // (a) the books: every ticket issued resolved exactly once.
            let stats = engine.stats();
            assert_eq!(stats.tickets_resolved, issued, "{ctx}");
            assert_eq!(stats.completed, issued, "{ctx}: drains left nothing pending");
            for sc in &stats.shards {
                assert_eq!(sc.commit_wall.count, sc.tickets_resolved, "{ctx}");
            }

            // Final state equals the union of the producers' models.
            let snap = engine.snapshot().unwrap();
            for (_, model) in &outcomes {
                for &(row, want) in model {
                    assert_eq!(snap[row], want, "{ctx}: row {row}");
                }
            }
            engine.shutdown().unwrap();
        }
    }
}

/// Epoch-waiter exactly-once property (PR-9): tickets are sequence
/// waiters on a shared per-shard commit-epoch hub, resolved by one
/// publish + wake per seal. Hammer `wait_timeout` polling loops
/// against racing batch-wakes: every ticket must yield exactly one
/// commit — never zero (lost wake), never a second distinct one
/// (double resolve) — and timeouts that fire mid-race must be
/// harmless retries. Also pins the wake-batch histogram: one drain of
/// N pending tickets is one histogram sample of N waiters.
#[test]
fn epoch_waiters_resolve_exactly_once_under_timeout_races() {
    let rows = 64usize;
    let q = 8usize;
    let shards = 4usize;
    for trial in 0..20u64 {
        let mut cfg = EngineConfig::sharded(rows, q, shards);
        // Only explicit drains seal, so the drainer thread fully
        // controls when the batch-wake fires.
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        let engine = UpdateEngine::start(cfg, |plan: &fast_sram::coordinator::ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();

        // A burst of tickets on every shard (rows 0..16 cover all 4).
        let per_burst = 16usize;
        let tickets: Vec<_> = (0..per_burst)
            .map(|i| {
                engine
                    .submit_blocking_ticketed(UpdateRequest::add(i, 1 + (i as u32 & 3)))
                    .unwrap()
            })
            .collect();

        std::thread::scope(|scope| {
            // One waiter per ticket, spinning on short timeouts — the
            // worst case for a lost-wake bug: waiters constantly
            // leaving and re-entering the hub's wait queue while the
            // single publish lands.
            let mut waiters = Vec::new();
            for (i, tk) in tickets.iter().enumerate() {
                let mut rng = Rng::new(0xE70C4 + trial * 131 + i as u64);
                waiters.push(scope.spawn(move || {
                    let mut resolutions = Vec::new();
                    loop {
                        let timeout = Duration::from_micros(rng.below(200));
                        match tk.wait_timeout(timeout).unwrap() {
                            Some(commit) => {
                                resolutions.push(commit);
                                break;
                            }
                            None => continue,
                        }
                    }
                    // Terminal and stable: later waits agree.
                    assert!(tk.is_resolved());
                    assert_eq!(tk.wait().unwrap(), resolutions[0]);
                    assert_eq!(tk.wait_timeout(Duration::ZERO).unwrap(), Some(resolutions[0]));
                    resolutions[0]
                }));
            }
            // Let the waiters pile onto the hub, then fire the wakes.
            std::thread::sleep(Duration::from_micros(200 * (trial % 4)));
            engine.drain_all().unwrap();
            for w in waiters {
                w.join().unwrap();
            }
        });

        let s = engine.stats();
        assert_eq!(s.tickets_resolved, per_burst as u64, "trial {trial}");
        let mut wake_samples = 0u64;
        let mut wake_waiters = 0u64;
        for sc in &s.shards {
            wake_samples += sc.wake_batch.count;
            wake_waiters += (sc.wake_batch.mean_ns * sc.wake_batch.count as f64).round() as u64;
        }
        // One drain, 4 shards, each resolving its 4 tickets in one
        // seal: exactly one wake-batch sample per shard, and the
        // histogram's waiter total equals the tickets resolved.
        assert_eq!(wake_samples, shards as u64, "trial {trial}");
        assert_eq!(wake_waiters, per_burst as u64, "trial {trial}");
        engine.shutdown().unwrap();
    }
}

/// Regression (satellite 1): a read drains only the owning shard's
/// pending entry — other shards' batchers stay untouched, and even the
/// owning shard keeps its batch open when the read's row is not
/// pending in it.
#[test]
fn read_drains_only_the_owning_shard() {
    let shards = 4usize;
    let mut cfg = EngineConfig::sharded(64, 16, shards);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_secs(3600); // nothing seals by policy
    let engine = UpdateEngine::start(cfg, |plan: &fast_sram::coordinator::ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();

    // One pending update on every shard (rows 0..4 route to shards 0..4).
    for row in 0..shards {
        engine.submit_blocking(UpdateRequest::add(row, 10 + row as u32)).unwrap();
    }

    // A read on shard 0 of a NON-pending row (4 & 3 == 0): no seal at all.
    assert_eq!(engine.read(4).unwrap(), 0);
    assert_eq!(engine.stats().batches, 0, "untouched-row read must not seal");

    // A read of the pending row seals shard 0 — and ONLY shard 0.
    assert_eq!(engine.read(0).unwrap(), 10);
    let s = engine.stats();
    assert_eq!(s.batches, 1);
    assert_eq!(s.shards[0].sealed_forced, 1);
    for shard in 1..shards {
        assert_eq!(
            s.shards[shard].batches_sealed, 0,
            "shard {shard}'s batcher must be undisturbed by shard 0's read"
        );
    }

    // The other shards still hold their batches open: each drain seals
    // exactly one batch now, with the pending value intact.
    for shard in 1..shards {
        assert_eq!(engine.drain_shard(shard).unwrap(), 1, "shard {shard}");
        assert_eq!(engine.read(shard).unwrap(), 10 + shard as u32);
    }
    let s = engine.stats();
    assert_eq!(s.batches, shards as u64);
    engine.shutdown().unwrap();
}

/// Writes respect the same per-row drain: an absolute write seals the
/// owning shard only when that shard pends an update for the same row.
#[test]
fn write_drains_only_when_the_row_is_pending() {
    let mut cfg = EngineConfig::sharded(64, 16, 2);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_secs(3600);
    let engine = UpdateEngine::start(cfg, |plan: &fast_sram::coordinator::ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();
    engine.submit_blocking(UpdateRequest::add(0, 5)).unwrap(); // shard 0 pends row 0
    // Write to a different shard-0 row: no seal, batch stays open.
    engine.write(2, 99).unwrap();
    assert_eq!(engine.stats().batches, 0);
    // Write to the pending row: the +5 lands first, then the overwrite.
    engine.write(0, 1000).unwrap();
    assert_eq!(engine.stats().batches, 1);
    engine.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
    assert_eq!(engine.read(0).unwrap(), 1001);
    assert_eq!(engine.read(2).unwrap(), 99);
    engine.shutdown().unwrap();
}
