//! Concurrency integration tests for the sharded update engine: many
//! producer threads firing interleaved row updates must match a
//! sequential reference apply, coalescing must never drop or reorder
//! same-row deltas within a shard, and the per-shard accounting must
//! stay consistent under contention.

use std::time::Duration;

use fast_sram::coordinator::{
    EngineConfig, FastBackend, UpdateEngine, UpdateOp, UpdateRequest,
};
use fast_sram::util::bits;
use fast_sram::util::rng::Rng;

fn sharded_engine(rows: usize, q: usize, shards: usize) -> UpdateEngine {
    let cfg = EngineConfig::sharded(rows, q, shards);
    UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap()
}

/// Host-side oracle applying requests one by one.
fn apply_reference(state: &mut [u32], req: &UpdateRequest, q: usize) {
    let m = bits::mask(q);
    let cur = state[req.row];
    state[req.row] = match req.op {
        UpdateOp::Add => bits::add_mod(cur, req.operand, q),
        UpdateOp::Sub => bits::sub_mod(cur, req.operand, q),
        UpdateOp::And => cur & req.operand & m,
        UpdateOp::Or => (cur | req.operand) & m,
        UpdateOp::Xor => (cur ^ req.operand) & m,
    };
}

/// ≥4 producer threads with *disjoint row sets* and mixed,
/// non-commutative op kinds. Because each row is owned by exactly one
/// producer, the sequential reference is well-defined per row — any
/// drop, duplication, or same-row reorder inside a shard changes the
/// final state (And/Or/Xor/Add sequences do not commute).
#[test]
fn concurrent_producers_match_sequential_reference() {
    let rows = 256;
    let q = 16;
    let shards = 4;
    let producers = 8; // two producers land on every shard
    let per_thread = 4000;

    let ops = [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor];
    // Deterministic per-thread request streams, generated up front so
    // the reference can replay them exactly.
    let streams: Vec<Vec<UpdateRequest>> = (0..producers)
        .map(|t| {
            let mut rng = Rng::new(9000 + t as u64);
            (0..per_thread)
                .map(|_| {
                    // Row ≡ t (mod producers): disjoint ownership.
                    let slot = rng.below((rows / producers) as u64) as usize;
                    let row = slot * producers + t;
                    UpdateRequest {
                        row,
                        op: ops[rng.below(ops.len() as u64) as usize],
                        operand: rng.below(1 << q) as u32,
                    }
                })
                .collect()
        })
        .collect();

    let mut reference = vec![0u32; rows];
    for stream in &streams {
        for req in stream {
            apply_reference(&mut reference, req, q);
        }
    }

    let engine = sharded_engine(rows, q, shards);
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            scope.spawn(move || {
                for req in stream {
                    engine.submit_blocking(*req).unwrap();
                }
            });
        }
    });
    engine.flush().unwrap();

    assert_eq!(engine.snapshot().unwrap(), reference);
    let s = engine.stats();
    let total = (producers * per_thread) as u64;
    assert_eq!(s.submitted, total);
    assert_eq!(s.completed, total, "coalescing must not drop requests");
    assert_eq!(s.rejected, 0, "blocking submits never reject");
    // Every shard carried traffic and the per-shard books add up.
    assert_eq!(s.shards.len(), shards);
    assert!(s.shards.iter().all(|sc| sc.requests > 0));
    assert_eq!(s.shards.iter().map(|sc| sc.requests).sum::<u64>(), total);
    assert_eq!(s.shards.iter().map(|sc| sc.batches_sealed).sum::<u64>(), s.batches);
    assert_eq!(s.shards.iter().map(|sc| sc.rows_updated).sum::<u64>(), s.rows_updated);
    engine.shutdown().unwrap();
}

/// All producers hammer the SAME hot rows with adds (commutative, so
/// any interleaving yields one expected sum). Lost updates — e.g. a
/// coalesce overwriting instead of merging under contention — would
/// break the total.
#[test]
fn contended_hot_rows_lose_no_updates() {
    let rows = 256;
    let q = 16;
    let producers = 4;
    let per_thread = 5000;
    let hot_rows = 64;

    let streams: Vec<Vec<UpdateRequest>> = (0..producers)
        .map(|t| {
            let mut rng = Rng::new(31 + t as u64);
            (0..per_thread)
                .map(|_| {
                    UpdateRequest::add(
                        rng.below(hot_rows as u64) as usize,
                        1 + rng.below(999) as u32,
                    )
                })
                .collect()
        })
        .collect();

    let mut expected = vec![0u32; rows];
    for stream in &streams {
        for req in stream {
            apply_reference(&mut expected, req, q);
        }
    }

    let engine = sharded_engine(rows, q, 4);
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            scope.spawn(move || {
                // Mix the bulk and single submit paths.
                for chunk in stream.chunks(128) {
                    engine.submit_many(chunk.to_vec()).unwrap();
                }
            });
        }
    });
    engine.flush().unwrap();

    assert_eq!(engine.snapshot().unwrap(), expected);
    let s = engine.stats();
    assert_eq!(s.completed, (producers * per_thread) as u64);
    // 20k updates over 64 rows must coalesce heavily.
    assert!(
        s.shards.iter().map(|sc| sc.coalesce_hits).sum::<u64>() > 0,
        "hot-row traffic must produce coalesce hits"
    );
    engine.shutdown().unwrap();
}

/// Same-row deltas within one shard must apply in program order:
/// non-commutative kind sequences (And after Add ≠ Add after And)
/// detect any reorder, and the request accounting detects any drop.
#[test]
fn same_row_deltas_keep_program_order_within_shard() {
    let rows = 128;
    let q = 16;
    let engine = sharded_engine(rows, q, 2);
    let mut reference = vec![0u32; rows];
    let mut rng = Rng::new(4242);
    let ops = [UpdateOp::Add, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor, UpdateOp::Sub];
    let mut submitted = 0u64;
    for _ in 0..6000 {
        // Concentrate on few rows so kind changes hit the same row
        // repeatedly within a shard.
        let row = rng.below(8) as usize * 16;
        let req = UpdateRequest {
            row,
            op: ops[rng.below(ops.len() as u64) as usize],
            operand: rng.below(1 << q) as u32,
        };
        apply_reference(&mut reference, &req, q);
        engine.submit_blocking(req).unwrap();
        submitted += 1;
    }
    engine.flush().unwrap();
    assert_eq!(engine.snapshot().unwrap(), reference);
    let s = engine.stats();
    assert_eq!(s.completed, submitted);
    // Kind changes must have sealed batches (the order-preservation
    // mechanism under mixed kinds).
    assert!(
        s.shards.iter().map(|sc| sc.sealed_kind_change).sum::<u64>() > 0,
        "mixed-kind traffic must seal on kind change"
    );
    engine.shutdown().unwrap();
}

/// The group-commit deadline seals throughput-starved shards: with a
/// huge size seal and a short deadline, a sparse trickle still lands.
#[test]
fn deadline_seals_under_trickle_load() {
    let mut cfg = EngineConfig::sharded(256, 16, 4);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_millis(2);
    let engine = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();
    for row in 0..4 {
        engine.submit_blocking(UpdateRequest::add(row, 7)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(60));
    let s = engine.stats();
    assert_eq!(s.completed, 4, "deadline must flush without an explicit flush");
    assert!(
        s.shards.iter().map(|sc| sc.sealed_deadline).sum::<u64>() >= 1,
        "at least one shard must have sealed on deadline"
    );
    for row in 0..4 {
        assert_eq!(engine.read(row).unwrap(), 7);
    }
    engine.shutdown().unwrap();
}
