//! Concurrency integration tests for the sharded update engine: many
//! producer threads firing interleaved row updates must match a
//! sequential reference apply, coalescing must never drop or reorder
//! same-row deltas within a shard, and the per-shard accounting must
//! stay consistent under contention.

use std::time::Duration;

use fast_sram::coordinator::{
    EngineConfig, FastBackend, UpdateEngine, UpdateOp, UpdateRequest,
};
use fast_sram::fastmem::Fidelity;
use fast_sram::util::bits;
use fast_sram::util::rng::Rng;

fn sharded_engine(rows: usize, q: usize, shards: usize) -> UpdateEngine {
    let cfg = EngineConfig::sharded(rows, q, shards);
    UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap()
}

/// Host-side oracle applying requests one by one.
fn apply_reference(state: &mut [u32], req: &UpdateRequest, q: usize) {
    let m = bits::mask(q);
    let cur = state[req.row];
    state[req.row] = match req.op {
        UpdateOp::Add => bits::add_mod(cur, req.operand, q),
        UpdateOp::Sub => bits::sub_mod(cur, req.operand, q),
        UpdateOp::And => cur & req.operand & m,
        UpdateOp::Or => (cur | req.operand) & m,
        UpdateOp::Xor => (cur ^ req.operand) & m,
    };
}

/// ≥4 producer threads with *disjoint row sets* and mixed,
/// non-commutative op kinds. Because each row is owned by exactly one
/// producer, the sequential reference is well-defined per row — any
/// drop, duplication, or same-row reorder inside a shard changes the
/// final state (And/Or/Xor/Add sequences do not commute).
#[test]
fn concurrent_producers_match_sequential_reference() {
    let rows = 256;
    let q = 16;
    let shards = 4;
    let producers = 8; // two producers land on every shard
    let per_thread = 4000;

    let ops = [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor];
    // Deterministic per-thread request streams, generated up front so
    // the reference can replay them exactly.
    let streams: Vec<Vec<UpdateRequest>> = (0..producers)
        .map(|t| {
            let mut rng = Rng::new(9000 + t as u64);
            (0..per_thread)
                .map(|_| {
                    // Row ≡ t (mod producers): disjoint ownership.
                    let slot = rng.below((rows / producers) as u64) as usize;
                    let row = slot * producers + t;
                    UpdateRequest {
                        row,
                        op: ops[rng.below(ops.len() as u64) as usize],
                        operand: rng.below(1 << q) as u32,
                    }
                })
                .collect()
        })
        .collect();

    let mut reference = vec![0u32; rows];
    for stream in &streams {
        for req in stream {
            apply_reference(&mut reference, req, q);
        }
    }

    let engine = sharded_engine(rows, q, shards);
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            scope.spawn(move || {
                for req in stream {
                    engine.submit_blocking(*req).unwrap();
                }
            });
        }
    });
    // snapshot() is a barrier: each shard force-seals before reporting
    // (there is no whole-engine flush() anymore).
    assert_eq!(engine.snapshot().unwrap(), reference);
    let s = engine.stats();
    let total = (producers * per_thread) as u64;
    assert_eq!(s.submitted, total);
    assert_eq!(s.completed, total, "coalescing must not drop requests");
    assert_eq!(s.rejected, 0, "blocking submits never reject");
    // Every shard carried traffic and the per-shard books add up.
    assert_eq!(s.shards.len(), shards);
    assert!(s.shards.iter().all(|sc| sc.requests > 0));
    assert_eq!(s.shards.iter().map(|sc| sc.requests).sum::<u64>(), total);
    assert_eq!(s.shards.iter().map(|sc| sc.batches_sealed).sum::<u64>(), s.batches);
    assert_eq!(s.shards.iter().map(|sc| sc.rows_updated).sum::<u64>(), s.rows_updated);
    engine.shutdown().unwrap();
}

/// All producers hammer the SAME hot rows with adds (commutative, so
/// any interleaving yields one expected sum). Lost updates — e.g. a
/// coalesce overwriting instead of merging under contention — would
/// break the total.
#[test]
fn contended_hot_rows_lose_no_updates() {
    let rows = 256;
    let q = 16;
    let producers = 4;
    let per_thread = 5000;
    let hot_rows = 64;

    let streams: Vec<Vec<UpdateRequest>> = (0..producers)
        .map(|t| {
            let mut rng = Rng::new(31 + t as u64);
            (0..per_thread)
                .map(|_| {
                    UpdateRequest::add(
                        rng.below(hot_rows as u64) as usize,
                        1 + rng.below(999) as u32,
                    )
                })
                .collect()
        })
        .collect();

    let mut expected = vec![0u32; rows];
    for stream in &streams {
        for req in stream {
            apply_reference(&mut expected, req, q);
        }
    }

    let engine = sharded_engine(rows, q, 4);
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            scope.spawn(move || {
                // Mix the bulk and single submit paths.
                for chunk in stream.chunks(128) {
                    engine.submit_many(chunk.to_vec()).unwrap();
                }
            });
        }
    });
    assert_eq!(engine.snapshot().unwrap(), expected);
    let s = engine.stats();
    assert_eq!(s.completed, (producers * per_thread) as u64);
    // 20k updates over 64 rows must coalesce heavily.
    assert!(
        s.shards.iter().map(|sc| sc.coalesce_hits).sum::<u64>() > 0,
        "hot-row traffic must produce coalesce hits"
    );
    engine.shutdown().unwrap();
}

/// Same-row deltas within one shard must apply in program order:
/// non-commutative kind sequences (And after Add ≠ Add after And)
/// detect any reorder, and the request accounting detects any drop.
#[test]
fn same_row_deltas_keep_program_order_within_shard() {
    let rows = 128;
    let q = 16;
    let engine = sharded_engine(rows, q, 2);
    let mut reference = vec![0u32; rows];
    let mut rng = Rng::new(4242);
    let ops = [UpdateOp::Add, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor, UpdateOp::Sub];
    let mut submitted = 0u64;
    for _ in 0..6000 {
        // Concentrate on few rows so kind changes hit the same row
        // repeatedly within a shard.
        let row = rng.below(8) as usize * 16;
        let req = UpdateRequest {
            row,
            op: ops[rng.below(ops.len() as u64) as usize],
            operand: rng.below(1 << q) as u32,
        };
        apply_reference(&mut reference, &req, q);
        engine.submit_blocking(req).unwrap();
        submitted += 1;
    }
    assert_eq!(engine.snapshot().unwrap(), reference);
    let s = engine.stats();
    assert_eq!(s.completed, submitted);
    // Kind changes must have sealed batches (the order-preservation
    // mechanism under mixed kinds).
    assert!(
        s.shards.iter().map(|sc| sc.sealed_kind_change).sum::<u64>() > 0,
        "mixed-kind traffic must seal on kind change"
    );
    engine.shutdown().unwrap();
}

/// Deterministic randomized stress sweep: every trial draws a shard
/// count, seal policy (deadline and/or size seal), queue depth, row
/// space, op mix, and per-producer submission strategy (blocking
/// singles vs bulk chunks of random size) from a seeded meta-RNG, then
/// runs ≥ 4 producers with disjoint row ownership against a sequential
/// reference. Disjoint ownership keeps the per-row reference exact
/// under non-commutative op mixes no matter how threads interleave;
/// the seeded draws make every trial replayable from its printed seed.
/// After the flush the engine must match the reference exactly and the
/// books must balance; a post-flush tail of updates is then read back
/// through the read-your-writes path (forcing the final seals) before
/// shutdown, so a shutdown that dropped sealed batches would surface
/// as a failed read or a failed join.
#[test]
fn randomized_stress_matches_reference_across_configs() {
    // The CI fidelity matrix points this test's backends at each tier;
    // phase-accurate is ~100× word-fast per batch, so trim the load.
    let tier = Fidelity::from_env_or(Fidelity::WordFast);
    let per_thread = if tier == Fidelity::PhaseAccurate { 250 } else { 2000 };

    for trial in 0..6u64 {
        let seed = 0x5EED_0000 + trial;
        let mut meta = Rng::new(seed);
        let shards = 1usize << meta.below(4); // 1 | 2 | 4 | 8
        let producers = 8; // ≥ 4, and every shard sees ≥ 1 producer
        let rows = [64usize, 128, 256][meta.below(3) as usize]; // all divide by 8
        let q = [8usize, 16][meta.below(2) as usize];
        let ops = [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor];

        let mut cfg = EngineConfig::sharded(rows, q, shards);
        cfg.seal_deadline = Duration::from_micros(1 + meta.below(400));
        cfg.seal_at_rows = if meta.chance(0.5) {
            None
        } else {
            Some(1 + meta.below(rows as u64) as usize)
        };
        cfg.queue_cap = 64 << meta.below(5); // 64 .. 1024

        // (stream, bulk chunk size or None) per producer.
        let streams: Vec<(Vec<UpdateRequest>, Option<usize>)> = (0..producers)
            .map(|t| {
                let mut rng = Rng::new(seed ^ (0xA0 + t as u64));
                let stream = (0..per_thread)
                    .map(|_| {
                        let slot = rng.below((rows / producers) as u64) as usize;
                        UpdateRequest {
                            row: slot * producers + t,
                            op: ops[rng.below(ops.len() as u64) as usize],
                            operand: rng.below(1 << q) as u32,
                        }
                    })
                    .collect();
                let chunking = if rng.chance(0.5) {
                    Some(1 + rng.below(256) as usize)
                } else {
                    None
                };
                (stream, chunking)
            })
            .collect();

        let mut reference = vec![0u32; rows];
        for (stream, _) in &streams {
            for req in stream {
                apply_reference(&mut reference, req, q);
            }
        }

        let engine = UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, tier)))
        })
        .unwrap();
        std::thread::scope(|scope| {
            for (stream, chunking) in &streams {
                let engine = &engine;
                scope.spawn(move || match chunking {
                    Some(n) => {
                        for chunk in stream.chunks(*n) {
                            engine.submit_many(chunk.to_vec()).unwrap();
                        }
                    }
                    None => {
                        for req in stream {
                            engine.submit_blocking(*req).unwrap();
                        }
                    }
                });
            }
        });
        // Commit everything via the explicit barrier (per-shard drains
        // under the hood), exercising it under the randomized configs.
        engine.drain_all().unwrap();

        let ctx = format!(
            "trial {trial} (seed {seed:#x}): rows={rows} q={q} shards={shards} tier={tier}"
        );
        assert_eq!(engine.snapshot().unwrap(), reference, "{ctx}");
        let s = engine.stats();
        let total = (producers * per_thread) as u64;
        assert_eq!(s.submitted, total, "{ctx}");
        assert_eq!(s.completed, total, "{ctx}: flush must drain every request");
        assert_eq!(s.rejected, 0, "{ctx}: blocking paths never reject");
        assert_eq!(s.queue_depth, 0, "{ctx}: queues must drain");
        assert_eq!(s.shards.len(), shards, "{ctx}");
        assert_eq!(s.shards.iter().map(|sc| sc.requests).sum::<u64>(), total, "{ctx}");
        assert_eq!(
            s.shards.iter().map(|sc| sc.batches_sealed).sum::<u64>(),
            s.batches,
            "{ctx}"
        );

        // Tail: updates submitted after the big flush must survive the
        // seal-on-read path right up to shutdown (no dropped batches).
        let mut tail_reference = reference;
        for i in 0..16usize {
            let row = (i * 7) % rows;
            let req = UpdateRequest::add(row, 3);
            apply_reference(&mut tail_reference, &req, q);
            engine.submit_blocking(req).unwrap();
        }
        for i in 0..16usize {
            let row = (i * 7) % rows;
            assert_eq!(engine.read(row).unwrap(), tail_reference[row], "{ctx} tail row {row}");
        }
        engine.shutdown().unwrap();
    }
}

/// The group-commit deadline seals throughput-starved shards: with a
/// huge size seal and a short deadline, a sparse trickle still lands.
#[test]
fn deadline_seals_under_trickle_load() {
    let mut cfg = EngineConfig::sharded(256, 16, 4);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_millis(2);
    let engine = UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap();
    for row in 0..4 {
        engine.submit_blocking(UpdateRequest::add(row, 7)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(60));
    let s = engine.stats();
    assert_eq!(s.completed, 4, "deadline must flush without an explicit flush");
    assert!(
        s.shards.iter().map(|sc| sc.sealed_deadline).sum::<u64>() >= 1,
        "at least one shard must have sealed on deadline"
    );
    for row in 0..4 {
        assert_eq!(engine.read(row).unwrap(), 7);
    }
    engine.shutdown().unwrap();
}
