//! Cross-tenant differential net (PR-8 tentpole): a multi-tenant
//! registry must be *indistinguishable* — in state AND in energy
//! accounting — from N independent single-tenant engines.
//!
//! - *Differential equivalence*: N mixed-precision tenants (q = 4, 8,
//!   16) driven by interleaved producers are bit-identical to N
//!   reference engines fed the same per-tenant streams, over 1/2/4/8
//!   shards × the fidelity tier from `FAST_TEST_FIDELITY`
//!   (phase|word|bitplane; default word) — snapshots, digests,
//!   modeled time/energy (compared at the bit level), per-shard
//!   commit seqs, and per-tenant query results.
//! - *Crash recovery*: a durable registry reopened after a
//!   SIGKILL-style torn append in EVERY tenant's WAL subdirectory
//!   restores every tenant bit-identically, and each tenant's
//!   WAL→trace export replays to the same state (the q=16 tenant
//!   carries >8-bit values to prove width survives the round trip).
//! - *Isolation/fairness*: a hot tenant saturating its own queues
//!   cannot stall a cold tenant's ticketed commits beyond a bounded
//!   factor; quota overflow is a typed, retryable rejection that
//!   never reaches the engine; dropping a tenant never perturbs the
//!   survivors' digests.
//! - *Precision closed forms*: 4- and 16-bit plane stacks report
//!   `cycles == q`, `alu_evals == q·rows` and the exact telescoped
//!   `cell_toggles` sum; on the bitplane tier a 4-bit tenant's
//!   modeled batch time is measurably below an 8-bit tenant's for the
//!   same workload (the paper's q-cycle batch law, per tenant).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use fast_sram::apps::trace::state_digest;
use fast_sram::coordinator::{
    BitPlaneBackend, EngineConfig, FastBackend, UpdateEngine, UpdateRequest,
};
use fast_sram::durability::{self, segment, DurabilityConfig, FsyncPolicy};
use fast_sram::fastmem::{AluOp, BitPlaneArray, Fidelity};
use fast_sram::query::{QuerySpec, Reduction};
use fast_sram::tenant::{tenant_dir, QuotaExceeded, TenantRegistry, TenantSpec};
use fast_sram::util::bits;
use fast_sram::util::rng::Rng;
use fast_sram::Result;

/// The mixed-precision tenant set every test drives: one tenant per
/// allowed q, rows divisible by the largest shard count (8).
const SPECS: [(&str, usize, usize); 3] = [("a4", 64, 4), ("b8", 64, 8), ("c16", 32, 16)];

fn fidelity() -> Fidelity {
    Fidelity::from_env_or(Fidelity::WordFast)
}

fn tmpdir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!("fast-tenants-{tag}-{}-{nanos}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic engine: only explicit drains seal, so the N-tenant
/// and single-tenant sides see identical batch boundaries and the
/// energy accounting can be compared bit for bit.
fn quiet_cfg(rows: usize, q: usize, shards: usize) -> EngineConfig {
    let mut cfg = EngineConfig::sharded(rows, q, shards);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_secs(3600);
    cfg.queue_cap = 4096;
    cfg
}

fn start_tier(cfg: EngineConfig, tier: Fidelity) -> Result<UpdateEngine> {
    match tier {
        Fidelity::BitPlane => UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
        }),
        f => UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, f)))
        }),
    }
}

/// One tenant's producer: a seeded update/write/read mix with drains
/// at fixed points, applied identically to the registry handle and
/// (when given) a reference engine. Returns the host row model.
fn drive(
    tier: Fidelity,
    handle: &fast_sram::tenant::TenantHandle,
    reference: Option<&UpdateEngine>,
    rows: usize,
    q: usize,
    seed: u64,
    ctx: &str,
) -> Vec<u32> {
    let per = if tier == Fidelity::PhaseAccurate { 80 } else { 350 };
    let mut rng = Rng::new(seed);
    let mut model = vec![0u32; rows];
    for i in 0..per {
        let row = rng.below(rows as u64) as usize;
        let v = rng.below(bits::mask(q) as u64 + 1) as u32;
        if rng.chance(0.08) {
            // Read-your-writes, per tenant: a single producer owns the
            // whole tenant, so every read must see its own stream.
            let got = handle.engine().read(row).unwrap();
            assert_eq!(got, model[row], "{ctx} i={i}: read-your-writes at row {row}");
            if let Some(r) = reference {
                assert_eq!(r.read(row).unwrap(), got, "{ctx} i={i}: reference diverged");
            }
        } else if rng.chance(0.1) {
            handle.write(row, v).unwrap();
            if let Some(r) = reference {
                r.write(row, v).unwrap();
            }
            model[row] = v;
        } else if rng.chance(0.3) {
            handle.submit(UpdateRequest::sub(row, v)).unwrap();
            if let Some(r) = reference {
                r.submit(UpdateRequest::sub(row, v)).unwrap();
            }
            model[row] = bits::sub_mod(model[row], v, q);
        } else {
            handle.submit(UpdateRequest::add(row, v)).unwrap();
            if let Some(r) = reference {
                r.submit(UpdateRequest::add(row, v)).unwrap();
            }
            model[row] = bits::add_mod(model[row], v, q);
        }
        if (i + 1) % 40 == 0 {
            handle.engine().drain_all().unwrap();
            if let Some(r) = reference {
                r.drain_all().unwrap();
            }
        }
    }
    handle.engine().drain_all().unwrap();
    if let Some(r) = reference {
        r.drain_all().unwrap();
    }
    model
}

/// The tentpole property: N tenants on one registry are bit-identical
/// — state AND accounting — to N independent single-tenant engines,
/// across shard counts, at the fidelity tier under test.
#[test]
fn n_tenants_are_bit_identical_to_n_single_tenant_engines() {
    let tier = fidelity();
    for shards in [1usize, 2, 4, 8] {
        let reg = TenantRegistry::volatile(move |spec: &TenantSpec| {
            start_tier(quiet_cfg(spec.rows, spec.q, shards), tier)
        });
        let refs: Vec<UpdateEngine> = SPECS
            .iter()
            .map(|&(_, rows, q)| start_tier(quiet_cfg(rows, q, shards), tier).unwrap())
            .collect();
        for &(name, rows, q) in &SPECS {
            reg.create(TenantSpec::new(name, rows, q).unwrap()).unwrap();
        }

        // Interleaved producers: one thread per tenant, all live on
        // the registry concurrently; each thread replays its stream
        // onto its private reference engine at the same points.
        let models: Vec<Vec<u32>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (i, &(name, rows, q)) in SPECS.iter().enumerate() {
                let tenant = reg.get(name).unwrap();
                let reference = &refs[i];
                let ctx = format!("shards={shards} tier={tier:?} tenant={name}");
                handles.push(scope.spawn(move || {
                    drive(tier, &tenant, Some(reference), rows, q, 0xFA57 + 131 * i as u64, &ctx)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for (i, &(name, rows, _q)) in SPECS.iter().enumerate() {
            let tenant = reg.get(name).unwrap();
            let ctx = format!("shards={shards} tier={tier:?} tenant={name}");

            // State: registry == reference == host model, bit for bit.
            let snap_reg = tenant.engine().snapshot().unwrap();
            let snap_ref = refs[i].snapshot().unwrap();
            assert_eq!(snap_reg.len(), rows, "{ctx}");
            assert_eq!(snap_reg, snap_ref, "{ctx}: state diverged");
            assert_eq!(snap_reg, models[i], "{ctx}: state != host model");
            assert_eq!(tenant.digest().unwrap(), state_digest(&snap_ref), "{ctx}: digest");

            // Energy/time accounting: identical batch structure must
            // yield identical books, down to the last float bit.
            let s_reg = tenant.engine().stats();
            let s_ref = refs[i].stats();
            assert_eq!(s_reg.submitted, s_ref.submitted, "{ctx}: submitted");
            assert_eq!(s_reg.completed, s_ref.completed, "{ctx}: completed");
            assert_eq!(s_reg.batches, s_ref.batches, "{ctx}: batches");
            assert_eq!(s_reg.rows_updated, s_ref.rows_updated, "{ctx}: rows_updated");
            assert_eq!(
                s_reg.modeled_ns.to_bits(),
                s_ref.modeled_ns.to_bits(),
                "{ctx}: modeled time must be bit-identical ({} vs {})",
                s_reg.modeled_ns,
                s_ref.modeled_ns
            );
            assert_eq!(
                s_reg.modeled_energy_pj.to_bits(),
                s_ref.modeled_energy_pj.to_bits(),
                "{ctx}: modeled energy must be bit-identical ({} vs {})",
                s_reg.modeled_energy_pj,
                s_ref.modeled_energy_pj
            );
            for (sh, (a, b)) in s_reg.shards.iter().zip(&s_ref.shards).enumerate() {
                assert_eq!(a.commit_seq, b.commit_seq, "{ctx}: shard {sh} commit_seq");
            }

            // Per-tenant scoped query: same value, same plane-wise
            // accounting, and the value matches the host model.
            let spec = QuerySpec::all(Reduction::Sum);
            let r_reg = tenant.engine().query(&spec).unwrap();
            let r_ref = refs[i].query(&spec).unwrap();
            assert_eq!(r_reg, r_ref, "{ctx}: query result diverged");
            let want: u64 = models[i].iter().map(|&v| u64::from(v)).sum();
            assert_eq!(r_reg.value, want, "{ctx}: query read-your-writes");
        }

        for r in refs {
            r.shutdown().unwrap();
        }
        reg.shutdown().unwrap();
    }
}

/// Crash recovery: reopen a durable registry after a SIGKILL-style
/// torn append in EVERY tenant's WAL subdirectory — each tenant must
/// come back bit-identical, its WAL→trace export must replay to the
/// same state (q=16 values included), and a drop must survive the
/// next reopen.
#[test]
fn recovery_restores_every_tenant_and_repairs_per_tenant_torn_tails() {
    let tier = fidelity();
    let root = tmpdir("crash");
    let mk_factory = |root: PathBuf| {
        move |spec: &TenantSpec| {
            let mut cfg = quiet_cfg(spec.rows, spec.q, 2);
            let mut d = DurabilityConfig::new(tenant_dir(&root, &spec.name));
            // Every record durable: the torn garbage below is the only
            // unacknowledged suffix, so recovery must change nothing.
            d.fsync = FsyncPolicy::Always;
            cfg.durability = Some(d);
            start_tier(cfg, tier)
        }
    };

    // Phase 1: create the mixed-q tenants, stream traffic, remember
    // every digest and snapshot, shut down cleanly.
    let mut recorded: Vec<(&str, usize, usize, u64, Vec<u32>)> = Vec::new();
    {
        let reg = TenantRegistry::open(root.clone(), mk_factory(root.clone())).unwrap();
        for (i, &(name, rows, q)) in SPECS.iter().enumerate() {
            let tenant = reg.create(TenantSpec::new(name, rows, q).unwrap()).unwrap();
            let ctx = format!("crash tier={tier:?} tenant={name}");
            drive(tier, &tenant, None, rows, q, 0xC2A5 + 131 * i as u64, &ctx);
            if q == 16 {
                // Width witness: a value no 8-bit tenant could hold
                // must survive WAL → recovery → trace export → replay.
                tenant.write(0, 0xBEE5).unwrap();
            }
            let snap = tenant.engine().snapshot().unwrap();
            recorded.push((name, rows, q, state_digest(&snap), snap));
        }
        reg.shutdown().unwrap();
    }
    let wide = recorded.iter().find(|r| r.2 == 16).unwrap();
    assert!(
        wide.4.iter().any(|&v| v > 0xFF),
        "the q=16 tenant must carry >8-bit values for the width round trip"
    );

    // SIGKILL emulation: every tenant's newest shard-0 segment gets a
    // torn (partial, never-acknowledged) append.
    for &(name, ..) in &SPECS {
        let dir = tenant_dir(&root, name);
        let segs = segment::list_segments(&dir, 0).unwrap();
        let seg = segs.last().unwrap_or_else(|| panic!("tenant {name} wrote no shard-0 segment"));
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&seg.path).unwrap();
        f.write_all(&[0xA5u8; 41]).unwrap();
    }

    // Phase 2: reopen — recovery runs per tenant inside the factory,
    // truncating each torn tail; acknowledged state is untouched.
    let reg = TenantRegistry::open(root.clone(), mk_factory(root.clone())).unwrap();
    assert_eq!(reg.len(), SPECS.len());
    for &(name, rows, q, digest, ref snap) in &recorded {
        let tenant = reg.get(name).unwrap();
        assert_eq!(tenant.spec().rows, rows, "tenant {name}: spec rows");
        assert_eq!(tenant.spec().q, q, "tenant {name}: spec q");
        assert_eq!(tenant.digest().unwrap(), digest, "tenant {name}: digest after recovery");
        assert_eq!(&tenant.engine().snapshot().unwrap(), snap, "tenant {name}: state");

        // Independent audit: the tenant's WAL exports to a trace whose
        // replay reproduces the recovered state bit for bit.
        let trace = durability::export_trace(&tenant_dir(&root, name), name).unwrap();
        assert_eq!((trace.rows, trace.q), (rows, q), "tenant {name}: export shape");
        let e = start_tier(quiet_cfg(rows, q, 1), tier).unwrap();
        let rep = trace.replay(&e).unwrap();
        assert_eq!(&rep.final_state, snap, "tenant {name}: export→replay round trip");
        assert_eq!(state_digest(&rep.final_state), digest, "tenant {name}");
        e.shutdown().unwrap();
    }

    // Phase 3: drop one tenant; the removal must survive a reopen and
    // the survivors must still be bit-identical.
    reg.drop_tenant("a4").unwrap();
    assert!(!tenant_dir(&root, "a4").exists(), "drop must delete the WAL subdirectory");
    reg.shutdown().unwrap();
    let reg = TenantRegistry::open(root.clone(), mk_factory(root.clone())).unwrap();
    assert_eq!(reg.len(), SPECS.len() - 1);
    assert!(reg.get("a4").is_err());
    for &(name, _, _, digest, _) in recorded.iter().filter(|r| r.0 != "a4") {
        assert_eq!(reg.get(name).unwrap().digest().unwrap(), digest, "survivor {name}");
    }
    reg.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

/// Fairness: isolation is structural, so a hot tenant flooding its own
/// bounded queues (and eating `ERR busy`) cannot delay a cold tenant's
/// ticketed commits beyond a bounded factor of its seal deadline.
#[test]
fn a_hot_tenant_cannot_starve_a_cold_tenants_ticketed_commits() {
    let tier = fidelity();
    let reg = TenantRegistry::volatile(move |spec: &TenantSpec| {
        let mut cfg = EngineConfig::sharded(spec.rows, spec.q, 2);
        // Small queues + a live deadline: the hot tenant saturates
        // fast, the cold tenant's commits ride the group-commit seal.
        cfg.queue_cap = 256;
        cfg.seal_deadline = Duration::from_micros(300);
        start_tier(cfg, tier)
    });
    let hot = reg.create(TenantSpec::new("hot", 64, 8).unwrap()).unwrap();
    let cold = reg.create(TenantSpec::new("cold", 64, 8).unwrap()).unwrap();

    let stop = AtomicBool::new(false);
    let (attempts, worst) = std::thread::scope(|scope| {
        let flood = scope.spawn(|| {
            let mut rng = Rng::new(0x407);
            let mut attempts = 0u64;
            while !stop.load(Ordering::Relaxed) {
                // Saturate: ignore busy — that is the hot tenant's own
                // backpressure, not anyone else's problem.
                let _ = hot.submit(UpdateRequest::add(rng.below(64) as usize, 1));
                attempts += 1;
            }
            attempts
        });

        let mut worst = Duration::ZERO;
        for k in 0..20usize {
            let t0 = Instant::now();
            let ticket = cold.submit_ticketed(UpdateRequest::add(k % 64, 1)).unwrap();
            let commit = ticket.wait().unwrap();
            worst = worst.max(t0.elapsed());
            assert!(commit.commit_seq >= 1);
        }
        stop.store(true, Ordering::Relaxed);
        (flood.join().unwrap(), worst)
    });

    // Bounded-factor bar: a 300 µs group-commit deadline must not
    // stretch into seconds just because a sibling tenant is molten.
    assert!(
        worst < Duration::from_secs(2),
        "cold tenant commit stalled {worst:?} behind a hot tenant"
    );
    assert!(attempts > 256, "the hot tenant never actually saturated ({attempts} attempts)");

    cold.engine().drain_all().unwrap();
    // 20 ticketed adds of +1 landed on rows 0..20, one each — the cold
    // tenant's state is exactly its own stream, untouched by the flood.
    for k in 0..20usize {
        assert_eq!(cold.engine().read(k).unwrap(), 1, "cold row {k}");
    }
    drop(hot);
    drop(cold);
    reg.shutdown().unwrap();
}

/// Quota overflow is typed and retryable (the handle keeps working),
/// never reaches the engine, and dropping a tenant perturbs no
/// survivor's digest — the name is immediately reusable, fresh.
#[test]
fn quota_is_typed_retryable_and_drop_never_perturbs_survivors() {
    let tier = fidelity();
    let reg = TenantRegistry::volatile(move |spec: &TenantSpec| {
        start_tier(quiet_cfg(spec.rows, spec.q, 2), tier)
    });
    let a = reg.create(TenantSpec::with_quota("a4", 64, 4, 32).unwrap()).unwrap();
    let b = reg.create(TenantSpec::new("b8", 64, 8).unwrap()).unwrap();
    let c = reg.create(TenantSpec::new("c16", 32, 16).unwrap()).unwrap();
    for (h, rows, q, seed) in [(&a, 32usize, 4usize, 1u64), (&b, 64, 8, 2), (&c, 32, 16, 3)] {
        let mut rng = Rng::new(seed);
        for _ in 0..60 {
            h.submit(UpdateRequest::add(
                rng.below(rows as u64) as usize,
                rng.below(bits::mask(q) as u64 + 1) as u32,
            ))
            .unwrap();
        }
        h.engine().drain_all().unwrap();
    }

    // Typed, pre-engine, retryable.
    let before = a.engine().stats().submitted;
    for row in [32usize, 48, 63] {
        let e = a.submit(UpdateRequest::add(row, 1)).unwrap_err();
        assert!(
            e.root_cause().downcast_ref::<QuotaExceeded>().is_some(),
            "row {row}: {e:#}"
        );
    }
    assert_eq!(a.engine().stats().submitted, before, "rejections must not reach the engine");
    a.submit(UpdateRequest::add(31, 1)).unwrap(); // retryable: handle still live
    a.engine().drain_all().unwrap();

    // Drop b8: survivors' digests must not move.
    let da = a.digest().unwrap();
    let dc = c.digest().unwrap();
    drop(b);
    reg.drop_tenant("b8").unwrap();
    assert!(reg.get("b8").is_err());
    assert_eq!(a.digest().unwrap(), da, "a4 perturbed by dropping b8");
    assert_eq!(c.digest().unwrap(), dc, "c16 perturbed by dropping b8");

    // The name is reusable immediately — with a different shape — and
    // comes back empty.
    let b2 = reg.create(TenantSpec::new("b8", 32, 16).unwrap()).unwrap();
    assert_eq!(b2.engine().snapshot().unwrap(), vec![0u32; 32]);
    // Survivors still accept traffic after the drop.
    a.submit(UpdateRequest::add(0, 1)).unwrap();
    c.submit(UpdateRequest::add(0, 1)).unwrap();
    drop((a, b2, c));
    reg.shutdown().unwrap();
}

/// Host oracle for one row's shift-register toggles: q cycles of
/// `w' = (w >> 1) | (out_t << (q-1))`, 2·popcount(w' ⊕ w) per cycle —
/// the word-level form the bitplane tier's telescoped closed form
/// (module docs of `fastmem::bitplane`) must reproduce exactly.
fn host_shift_toggles(pre: u32, post: u32, q: usize) -> u64 {
    let mut w = pre;
    let mut toggles = 0u64;
    for t in 0..q {
        let next = (w >> 1) | (((post >> t) & 1) << (q - 1));
        toggles += 2 * u64::from((next ^ w).count_ones());
        w = next;
    }
    assert_eq!(w, post, "the rotation must land on the result word");
    toggles
}

/// Precision round trip, satellite 3a: a 4-bit and a 16-bit tenant's
/// plane stacks report exactly the per-q closed form — plane count,
/// plane words, cycles, alu_evals, cell_toggles.
#[test]
fn per_q_closed_form_accounting_is_exact_for_narrow_and_wide_tenants() {
    for q in [4usize, 16] {
        let rows = 96usize;
        let mut a = BitPlaneArray::new(rows, &[q]);
        let mut rng = Rng::new(0xACC7 + q as u64);
        let mut pre = vec![0u32; rows];
        for (r, p) in pre.iter_mut().enumerate() {
            *p = rng.below(1u64 << q) as u32;
            a.write_word(r, 0, *p);
        }
        let operands: Vec<u32> = (0..rows).map(|_| rng.below(1u64 << q) as u32).collect();
        let report = a.apply(AluOp::Add, &operands);
        let post: Vec<u32> = (0..rows).map(|r| a.read_word(r, 0)).collect();
        for r in 0..rows {
            assert_eq!(post[r], bits::add_mod(pre[r], operands[r], q), "q={q} row {r}");
        }
        assert_eq!(report.cycles, q as u64, "q={q}: q-cycle batch law");
        assert_eq!(report.rows_active, rows as u64, "q={q}");
        assert_eq!(report.alu_evals, (q * rows) as u64, "q={q}: alu_evals == q·rows");
        let want: u64 = (0..rows).map(|r| host_shift_toggles(pre[r], post[r], q)).sum();
        assert_eq!(report.cell_toggles, want, "q={q}: telescoped toggle closed form");
        assert_eq!(a.plane_count(), q, "q={q}");
        assert_eq!(a.plane_words(), q * rows.div_ceil(64), "q={q}: O(q·rows/64)");
    }
}

/// Satellite 3b (the acceptance bar): on the bitplane tier, a 4-bit
/// tenant's modeled batch time is measurably below an 8-bit tenant's
/// (and 8 below 16) for the same workload — narrower plane stacks,
/// fewer shift cycles.
#[test]
fn narrow_precision_tenants_pay_fewer_modeled_cycles_on_the_bitplane_tier() {
    let reg = TenantRegistry::volatile(|spec: &TenantSpec| {
        let cfg = quiet_cfg(spec.rows, spec.q, 2);
        UpdateEngine::start(cfg, move |plan| {
            Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
        })
    });
    // Same rows, same stream (operands fit the narrowest q), drains at
    // the same points → identical batch structure, different q.
    for q in [4usize, 8, 16] {
        let t = reg.create(TenantSpec::new(&format!("t{q}"), 128, q).unwrap()).unwrap();
        let mut rng = Rng::new(0x9C7);
        for i in 0..200 {
            t.submit(UpdateRequest::add(
                rng.below(128) as usize,
                rng.below(bits::mask(4) as u64 + 1) as u32,
            ))
            .unwrap();
            if (i + 1) % 40 == 0 {
                t.engine().drain_all().unwrap();
            }
        }
        t.engine().drain_all().unwrap();
    }
    let s4 = reg.get("t4").unwrap().engine().stats();
    let s8 = reg.get("t8").unwrap().engine().stats();
    let s16 = reg.get("t16").unwrap().engine().stats();
    assert_eq!(s4.batches, s8.batches, "identical batch structure is the premise");
    assert_eq!(s8.batches, s16.batches, "identical batch structure is the premise");
    assert!(
        s4.modeled_ns < 0.75 * s8.modeled_ns,
        "4-bit batches must be measurably cheaper: {} vs {} ns",
        s4.modeled_ns,
        s8.modeled_ns
    );
    assert!(
        s8.modeled_ns < 0.75 * s16.modeled_ns,
        "8-bit batches must be measurably cheaper than 16: {} vs {} ns",
        s8.modeled_ns,
        s16.modeled_ns
    );
    reg.shutdown().unwrap();
}
