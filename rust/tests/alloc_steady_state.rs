//! Allocation-count proof for the zero-copy admission path: parsing a
//! canonical `fast-trace-v1` event line must not allocate.
//!
//! The whole test binary runs under a counting wrapper around the
//! system allocator (a `#[global_allocator]` is process-wide, which is
//! why this test lives alone in its own binary — the count would
//! otherwise be polluted by unrelated tests on other threads). Lines
//! are materialized and the parser warmed up *before* the measured
//! window, then a steady-state loop over every event shape asserts the
//! allocation counter did not move.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fast_sram::apps::TraceEvent;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn canonical_line_parse_is_allocation_free_in_steady_state() {
    const ROWS: usize = 64;
    const Q: usize = 8;
    // One line per event shape, built before the measured window.
    let lines: Vec<String> = vec![
        "{\"t\":\"u\",\"o\":\"add\",\"r\":5,\"v\":3}".to_string(),
        "{\"t\":\"u\",\"o\":\"sub\",\"r\":63,\"v\":255}".to_string(),
        "{\"t\":\"u\",\"o\":\"xor\",\"r\":0,\"v\":0}".to_string(),
        "{\"t\":\"w\",\"r\":17,\"v\":170}".to_string(),
        "{\"t\":\"f\"}".to_string(),
    ];
    // Warm up: fault in lazy runtime state (TLS, panic machinery
    // shims) outside the measured window.
    let mut acc = 0u64;
    for line in &lines {
        for _ in 0..16 {
            let ev = TraceEvent::parse_line_fast(line, ROWS, Q).unwrap();
            acc += fold_marker(ev);
        }
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..2_000 {
        for line in &lines {
            let ev = TraceEvent::parse_line_fast(line, ROWS, Q).unwrap();
            acc += fold_marker(ev);
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);

    assert!(acc > 0, "events must actually be produced");
    assert_eq!(
        after - before,
        0,
        "canonical-line admission allocated {} times in steady state",
        after - before
    );
}

/// Keep the parsed event observably alive so the loop cannot be
/// optimized away.
fn fold_marker(ev: TraceEvent) -> u64 {
    match ev {
        TraceEvent::Update(req) => req.row as u64 + u64::from(req.operand),
        TraceEvent::Write { row, value } => row as u64 + u64::from(value),
        TraceEvent::Flush => 1,
    }
}

#[test]
fn span_sampling_and_ring_traffic_are_allocation_free_in_steady_state() {
    use fast_sram::telemetry::{now_ns, SpanEvent, Telemetry, TelemetryConfig};

    // Everything that allocates — the shard state (its ring slots),
    // the `now_ns` epoch — is faulted in before the measured window.
    let cfg = TelemetryConfig { enabled: true, sample_rate: 4, ..TelemetryConfig::default() };
    let tel = Telemetry::new(cfg, 1);
    let state = tel.shard(0);
    let _ = now_ns();
    let mut acc = 0u64;
    for _ in 0..64 {
        let stamp = state.submit_stamp();
        if stamp != 0 {
            state.record(SpanEvent {
                t_submit: stamp,
                t_enqueue: now_ns(),
                t_resolve: now_ns(),
                ..SpanEvent::default()
            });
        }
        if let Some(ev) = state.ring.pop() {
            acc += ev.t_submit;
        }
    }

    // Steady state: the admission decision (stamp mint), a completed
    // span pushed into the SPSC ring, and the consumer-side pop — the
    // entire hot-path telemetry surface — must never touch the
    // allocator. This is the "always-on" claim as a proof, not a
    // benchmark.
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..2_000 {
        let stamp = state.submit_stamp();
        if stamp != 0 {
            state.record(SpanEvent {
                t_submit: stamp,
                t_enqueue: now_ns(),
                t_seal: now_ns(),
                t_apply: now_ns(),
                t_resolve: now_ns(),
                ..SpanEvent::default()
            });
        }
        if let Some(ev) = state.ring.pop() {
            acc += ev.t_submit + ev.t_resolve;
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);

    assert!(acc > 0, "spans must actually flow through the ring");
    assert!(
        state.sampled.load(Ordering::Relaxed) > 0,
        "rate 1/4 over 2064 admissions must sample spans"
    );
    assert_eq!(
        after - before,
        0,
        "span submit/record/pop allocated {} times in steady state",
        after - before
    );
}
