//! Allocation-count proof for the zero-copy admission path: parsing a
//! canonical `fast-trace-v1` event line must not allocate.
//!
//! The whole test binary runs under a counting wrapper around the
//! system allocator (a `#[global_allocator]` is process-wide, which is
//! why this test lives alone in its own binary — the count would
//! otherwise be polluted by unrelated tests on other threads). Lines
//! are materialized and the parser warmed up *before* the measured
//! window, then a steady-state loop over every event shape asserts the
//! allocation counter did not move.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fast_sram::apps::TraceEvent;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn canonical_line_parse_is_allocation_free_in_steady_state() {
    const ROWS: usize = 64;
    const Q: usize = 8;
    // One line per event shape, built before the measured window.
    let lines: Vec<String> = vec![
        "{\"t\":\"u\",\"o\":\"add\",\"r\":5,\"v\":3}".to_string(),
        "{\"t\":\"u\",\"o\":\"sub\",\"r\":63,\"v\":255}".to_string(),
        "{\"t\":\"u\",\"o\":\"xor\",\"r\":0,\"v\":0}".to_string(),
        "{\"t\":\"w\",\"r\":17,\"v\":170}".to_string(),
        "{\"t\":\"f\"}".to_string(),
    ];
    // Warm up: fault in lazy runtime state (TLS, panic machinery
    // shims) outside the measured window.
    let mut acc = 0u64;
    for line in &lines {
        for _ in 0..16 {
            let ev = TraceEvent::parse_line_fast(line, ROWS, Q).unwrap();
            acc += fold_marker(ev);
        }
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..2_000 {
        for line in &lines {
            let ev = TraceEvent::parse_line_fast(line, ROWS, Q).unwrap();
            acc += fold_marker(ev);
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);

    assert!(acc > 0, "events must actually be produced");
    assert_eq!(
        after - before,
        0,
        "canonical-line admission allocated {} times in steady state",
        after - before
    );
}

/// Keep the parsed event observably alive so the loop cannot be
/// optimized away.
fn fold_marker(ev: TraceEvent) -> u64 {
    match ev {
        TraceEvent::Update(req) => req.row as u64 + u64::from(req.operand),
        TraceEvent::Write { row, value } => row as u64 + u64::from(value),
        TraceEvent::Flush => 1,
    }
}
