//! End-to-end integration: full application workloads through the
//! coordinator on multiple backends, checked against host-semantics
//! replays; plus whole-experiment smoke checks (every table/figure
//! driver runs and asserts its own paper anchors).

use std::collections::HashMap;

use fast_sram::apps::{reference_round, CsrGraph, DeltaTable, GraphEngine, Histogram};
use fast_sram::coordinator::{DigitalBackend, EngineConfig, FastBackend, UpdateEngine};
use fast_sram::experiments::{fig10, fig11, fig12, fig13, fig14, table1, waveforms};
use fast_sram::util::rng::Rng;

fn fast_engine(rows: usize, q: usize) -> UpdateEngine {
    let cfg = EngineConfig::new(rows, q);
    UpdateEngine::start(cfg, move |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })
    .unwrap()
}

#[test]
fn database_workload_matches_hashmap_reference() {
    let mut table = DeltaTable::new(fast_engine(256, 16));
    let mut reference: HashMap<u64, u32> = HashMap::new();
    let mut rng = Rng::new(42);
    for _ in 0..20_000 {
        let key = rng.below(200);
        let delta = rng.below(100) as u32;
        if rng.chance(0.3) {
            table.decrement(key, delta).unwrap();
            let e = reference.entry(key).or_insert(0);
            *e = e.wrapping_sub(delta) & 0xFFFF;
        } else {
            table.increment(key, delta).unwrap();
            let e = reference.entry(key).or_insert(0);
            *e = e.wrapping_add(delta) & 0xFFFF;
        }
    }
    let mut want: Vec<(u64, u32)> = reference.into_iter().collect();
    want.sort_unstable();
    assert_eq!(table.scan().unwrap(), want);
    let s = table.stats();
    assert!(
        s.rows_per_batch > 10.0,
        "20k updates over 200 keys must coalesce heavily, got {:.1} rows/batch",
        s.rows_per_batch
    );
    table.close().unwrap();
}

#[test]
fn graph_engine_on_digital_backend_matches_fast() {
    let g = CsrGraph::random(120, 5, 7);
    let feats: Vec<u32> = (0..120).map(|i| (i * 31 + 5) as u32).collect();

    let run = |engine: UpdateEngine| {
        let mut ge = GraphEngine::new(g.clone(), engine).unwrap();
        ge.set_features(&feats).unwrap();
        ge.run(4, 1).unwrap();
        let out = ge.features().unwrap();
        let stats = ge.stats();
        ge.close().unwrap();
        (out, stats)
    };

    let (fast_out, fast_stats) = run(fast_engine(128, 16));
    let digital_cfg = EngineConfig::new(128, 16);
    let digital_engine = UpdateEngine::start(digital_cfg, |plan| {
        Ok(Box::new(DigitalBackend::new(plan.rows, plan.q)))
    })
    .unwrap();
    let (dig_out, dig_stats) = run(digital_engine);

    // Same results, asymmetric modeled cost.
    assert_eq!(fast_out, dig_out);
    assert!(fast_stats.modeled_ns < dig_stats.modeled_ns / 3.0);

    // And both match the pure reference.
    let mut want = feats.clone();
    for _ in 0..4 {
        want = reference_round(&g, &want, 16, |f| f >> 1);
    }
    assert_eq!(fast_out, want);
}

#[test]
fn histogram_of_normal_samples() {
    let mut h = Histogram::new(fast_engine(128, 16), -4.0, 4.0, 64).unwrap();
    let mut rng = Rng::new(11);
    for _ in 0..20_000 {
        h.record(rng.normal()).unwrap();
    }
    let counts = h.counts().unwrap();
    assert_eq!(counts.iter().map(|&c| c as u64).sum::<u64>(), 20_000);
    // Bell shape: the middle bins outweigh the tails.
    let mid: u64 = counts[24..40].iter().map(|&c| c as u64).sum();
    let tails: u64 =
        counts[..8].iter().map(|&c| c as u64).sum::<u64>()
            + counts[56..].iter().map(|&c| c as u64).sum::<u64>();
    assert!(mid > 50 * tails.max(1) / 10, "mid {mid} vs tails {tails}");
    h.close().unwrap();
}

// --- experiment smoke checks: every driver runs and self-validates ---

#[test]
fn all_figure_drivers_run() {
    let t1 = table1::run(128, 16);
    assert!((t1.energy_ratio - 5.5).abs() < 0.3);
    assert!((t1.speed_ratio - 27.2).abs() < 1.5);

    let f10 = fig10::run();
    assert!(!f10.is_empty());

    let f11 = fig11::run();
    assert!(!f11.is_empty());

    let f12 = fig12::run(50, 42);
    assert!((0.2..0.5).contains(&f12.mc.worst_margin()));

    let f13 = fig13::run();
    assert!(f13.max_pass_freq(1.0).is_some());

    let f14 = fig14::run(128, 16);
    assert!((f14.macro_overhead - 0.417).abs() < 0.02);

    let f7 = waveforms::run_fig7(1.25);
    assert_eq!(f7.initial, f7.after_full_rotation);
    let f8 = waveforms::run_fig8(1.25, 9, 8);
    assert_eq!(f8.result, 1); // (9+8) mod 16
}

#[test]
fn multi_bank_scaling_preserves_semantics() {
    // 1024 logical rows over 8 banks with a high-churn workload.
    let rows = 1024;
    let engine = fast_engine(rows, 16);
    let mut rng = Rng::new(3);
    let mut reference = vec![0u32; rows];
    for _ in 0..10_000 {
        let row = rng.below(rows as u64) as usize;
        let v = rng.below(1 << 16) as u32;
        engine
            .submit_blocking(fast_sram::coordinator::UpdateRequest::add(row, v))
            .unwrap();
        reference[row] = (reference[row].wrapping_add(v)) & 0xFFFF;
    }
    assert_eq!(engine.snapshot().unwrap(), reference);
    let s = engine.stats();
    assert!(s.batches > 0);
    // Amortization: many requests per fully-concurrent batch. The exact
    // figure depends on drain timing; require a healthy floor.
    assert!(s.rows_per_batch > 5.0, "rows/batch {:.1}", s.rows_per_batch);
    engine.shutdown().unwrap();
}
