//! Replication integration tests: WAL shipping through a seeded
//! fault-injection proxy must end in one of exactly two states —
//! a follower bit-identical to the primary, or an explicit fail-stop.
//!
//! - *Chaos matrix*: seeded drop/duplicate/corrupt/cut/delay plans at
//!   1/2/4/8 shards × phase/word/bitplane; after reconnects and
//!   catch-up the follower's state digest equals the trace's host
//!   reference digest (the same oracle `fast trace replay
//!   --digest-only` prints).
//! - *Scripted single faults*: each fault class at a pinned record
//!   index, with the counters (reconnects, dup skips, wire errors)
//!   proving the follower took the intended recovery path.
//! - *Forgery*: an internally-consistent forged frame (CRC fixed up)
//!   must fail-stop via the FNV chain — never apply, never serve a
//!   wrong answer.
//! - *Failover*: primary dies mid-trace, the follower promotes under
//!   a fenced epoch, serves the rest, and the final digest matches a
//!   full-trace replay; the fenced-off old primary then refuses a
//!   newer-epoch follower.
//! - *Restart resume*: a follower restarted from its own WAL resumes
//!   shipping at `recovered watermark + 1` with no side-channel state.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fast_sram::apps::trace::{state_digest, uniform_trace, TraceEvent};
use fast_sram::coordinator::{
    Backend, BitPlaneBackend, EngineConfig, FastBackend, ShardPlan, UpdateEngine,
};
use fast_sram::durability::{DurabilityConfig, FsyncPolicy};
use fast_sram::fastmem::Fidelity;
use fast_sram::replication::{
    load_epoch, spawn_follower, store_epoch, FaultAction, FaultPlan, FaultProbs, FaultProxy,
    FollowerHandle, FollowerOpts, ReplListener, ReplListenerCfg, ReplStats,
};

fn tmpdir(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let d = std::env::temp_dir().join(format!("fast-repl-{tag}-{}-{nanos}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Deterministic durable config: only explicit drains seal, every
/// record fsynced, tiny segments so rotation (and therefore the 'D'
/// digest exchange) happens even on short traces.
fn durable_cfg(rows: usize, q: usize, shards: usize, dir: &Path, read_only: bool) -> EngineConfig {
    let mut cfg = EngineConfig::sharded(rows, q, shards);
    cfg.seal_at_rows = None;
    cfg.seal_deadline = Duration::from_secs(3600);
    cfg.read_only = read_only;
    let mut d = DurabilityConfig::new(dir.to_path_buf());
    d.fsync = FsyncPolicy::Always;
    d.segment_bytes = 2048;
    cfg.durability = Some(d);
    cfg
}

#[derive(Debug, Clone, Copy)]
enum Tier {
    Phase,
    Word,
    BitPlane,
}

fn start_tier(cfg: EngineConfig, tier: Tier) -> UpdateEngine {
    match tier {
        Tier::Phase => UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows_fidelity(p.rows, p.q, Fidelity::PhaseAccurate))
                as Box<dyn Backend>)
        }),
        Tier::Word => UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
        }),
        Tier::BitPlane => UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(BitPlaneBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
        }),
    }
    .unwrap()
}

/// Apply a slice of trace events and drain (drain = group-commit seal
/// = durable WAL frames the primary's cursors can ship).
fn apply_events(engine: &UpdateEngine, events: &[TraceEvent]) {
    for e in events {
        match e {
            TraceEvent::Update(req) => engine.submit_blocking(*req).unwrap(),
            TraceEvent::Write { row, value } => engine.write(*row, *value).unwrap(),
            TraceEvent::Flush => {
                engine.drain_all().unwrap();
            }
        }
    }
    engine.drain_all().unwrap();
}

fn digest_of(engine: &UpdateEngine) -> u64 {
    state_digest(&engine.snapshot().unwrap())
}

/// Poll until the engine's state digest matches, or the deadline
/// passes. Recovery from dropped tails rides the heartbeat stall
/// detector, so convergence needs no extra traffic — just time.
fn wait_digest(engine: &UpdateEngine, want: u64, deadline: Duration) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if digest_of(engine) == want {
            return true;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    false
}

fn wait_failed(handle: &FollowerHandle, deadline: Duration) -> Option<String> {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if let Some(msg) = handle.failed() {
            return Some(msg);
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    None
}

fn fast_opts() -> FollowerOpts {
    FollowerOpts {
        backoff_min: Duration::from_millis(10),
        backoff_max: Duration::from_millis(200),
        ..FollowerOpts::default()
    }
}

/// Everything one primary/follower pair needs, wired through a fault
/// proxy. The follower engine is shared (`Arc`) with the handle.
struct Pair {
    primary: UpdateEngine,
    follower: Arc<UpdateEngine>,
    handle: Arc<FollowerHandle>,
    _listener: ReplListener,
    _proxy: FaultProxy,
    fdir: PathBuf,
}

/// `backlog` is applied to the primary BEFORE the follower attaches,
/// so those frames ship from a cold cursor over existing segments
/// rather than a live tail.
fn start_pair(
    rows: usize,
    q: usize,
    shards: usize,
    tier: Tier,
    tag: &str,
    plan: FaultPlan,
    backlog: &[TraceEvent],
) -> Pair {
    let pdir = tmpdir(&format!("{tag}-p"));
    let fdir = tmpdir(&format!("{tag}-f"));
    let primary = start_tier(durable_cfg(rows, q, shards, &pdir, false), tier);
    if !backlog.is_empty() {
        apply_events(&primary, backlog);
    }
    let stats = ReplStats::new("primary", shards);
    let listener = ReplListener::start(
        "127.0.0.1:0",
        ReplListenerCfg { wal_dir: pdir, rows, q, shards, stats },
    )
    .unwrap();
    let proxy = FaultProxy::start(listener.addr(), plan).unwrap();
    let follower = Arc::new(start_tier(durable_cfg(rows, q, shards, &fdir, true), tier));
    let handle = spawn_follower(
        Arc::clone(&follower),
        fdir.clone(),
        proxy.addr().to_string(),
        fast_opts(),
    )
    .unwrap();
    Pair { primary, follower, handle, _listener: listener, _proxy: proxy, fdir }
}

impl Pair {
    /// Stop replication and shut both engines down cleanly.
    fn teardown(self) {
        self.handle.stop();
        let Pair { primary, follower, handle, _listener, _proxy, .. } = self;
        drop(_proxy);
        drop(_listener);
        drop(handle);
        Arc::try_unwrap(follower)
            .unwrap_or_else(|_| panic!("follower engine still shared"))
            .shutdown()
            .unwrap();
        primary.shutdown().unwrap();
    }
}

const CATCH_UP: Duration = Duration::from_secs(30);

// -------------------------------------------------------------------------
// Chaos matrix: seeded recoverable faults × shards × fidelity tiers
// -------------------------------------------------------------------------

#[test]
fn chaos_faults_always_end_in_bit_identical_catch_up() {
    let mut digest_exchanges = 0u64;
    for (i, &shards) in [1usize, 2, 4, 8].iter().enumerate() {
        for (j, tier) in [Tier::Phase, Tier::Word, Tier::BitPlane].iter().enumerate() {
            let seed = 0xFA57_0000 + (i as u64) * 16 + j as u64;
            let trace = uniform_trace(64, 8, 240, seed);
            let want = state_digest(&trace.reference_state());
            let half = trace.events.len() / 2;

            // Half the trace is backlog (shipped from cold cursors),
            // half arrives while the follower live-tails.
            let pair = start_pair(
                64,
                8,
                shards,
                *tier,
                &format!("chaos-s{shards}t{j}"),
                FaultPlan::chaos(seed, FaultProbs::mild()),
                &trace.events[..half],
            );
            apply_events(&pair.primary, &trace.events[half..]);
            assert_eq!(digest_of(&pair.primary), want, "primary itself must match the oracle");

            assert!(
                wait_digest(&pair.follower, want, CATCH_UP),
                "shards={shards} tier={tier:?} seed={seed:#x}: follower digest {:016x} never \
                 reached {want:016x} (applied={:?}, failed={:?})",
                digest_of(&pair.follower),
                pair.handle.applied_lsns(),
                pair.handle.failed()
            );
            assert!(
                pair.handle.failed().is_none(),
                "recoverable chaos must never fail-stop: {:?}",
                pair.handle.failed()
            );
            let snap = pair.handle.stats.snapshot();
            digest_exchanges += snap.digests_verified;
            pair.teardown();
        }
    }
    // 2 KiB segments over 12 runs: segment boundaries must have
    // produced (and verified) at least some 'D' digest exchanges.
    assert!(digest_exchanges > 0, "no segment digest was ever exchanged");
}

// -------------------------------------------------------------------------
// Scripted single-fault plans: each class takes its intended path
// -------------------------------------------------------------------------

#[test]
fn each_scripted_fault_class_recovers_to_the_same_digest() {
    let cases: &[(&str, FaultAction, u64)] = &[
        ("drop", FaultAction::Drop, 2),
        ("dup", FaultAction::Duplicate, 2),
        ("corrupt", FaultAction::CorruptWire, 1),
        ("swap", FaultAction::Swap, 1),
        ("truncate", FaultAction::Truncate, 2),
        ("cut", FaultAction::Cut, 0),
        ("delay", FaultAction::Delay(30), 1),
    ];
    for &(name, action, idx) in cases {
        let trace = uniform_trace(48, 8, 160, 0xD00D);
        let want = state_digest(&trace.reference_state());
        let pair =
            start_pair(48, 8, 1, Tier::Word, name, FaultPlan::scripted([(idx, action)]), &[]);
        // Four separate seals guarantee at least four shipped frames,
        // so every scripted index lands on a real record.
        for chunk in trace.events.chunks(40) {
            apply_events(&pair.primary, chunk);
        }
        assert!(
            wait_digest(&pair.follower, want, CATCH_UP),
            "{name}: follower stuck at {:016x}, want {want:016x} (failed={:?})",
            digest_of(&pair.follower),
            pair.handle.failed()
        );
        assert!(pair.handle.failed().is_none(), "{name} must be recoverable");
        let snap = pair.handle.stats.snapshot();
        match action {
            FaultAction::Duplicate => {
                assert!(snap.dup_frames >= 1, "{name}: dup skip counter never moved")
            }
            FaultAction::Delay(_) => {}
            _ => assert!(
                snap.wire_errors >= 1 && snap.reconnects >= 1,
                "{name}: expected a reconnect, saw wire_errors={} reconnects={}",
                snap.wire_errors,
                snap.reconnects
            ),
        }
        pair.teardown();
    }
}

// -------------------------------------------------------------------------
// Forgery: internally-consistent wrong bytes must fail-stop
// -------------------------------------------------------------------------

#[test]
fn forged_frame_fail_stops_instead_of_serving_wrong_state() {
    let trace = uniform_trace(48, 8, 160, 0xBAD);
    let pair = start_pair(
        48,
        8,
        1,
        Tier::Word,
        "forge",
        FaultPlan::scripted([(2, FaultAction::Forge)]),
        &[],
    );
    for chunk in trace.events.chunks(40) {
        apply_events(&pair.primary, chunk);
    }
    let msg = wait_failed(&pair.handle, CATCH_UP).expect("a forged frame must fail-stop");
    assert!(
        msg.contains("fork") || msg.contains("divergence") || msg.contains("chain"),
        "fail-stop reason should name the chain divergence: {msg}"
    );
    // The stats snapshot carries the same reason (what --stats-json
    // reports), and the engine still answers reads — it fail-stopped,
    // it did not crash or serve the forged bytes.
    let snap = pair.handle.stats.snapshot();
    assert_eq!(snap.failed.as_deref(), Some(msg.as_str()));
    assert!(!pair.follower.is_writable());
    let state = pair.follower.snapshot().unwrap();
    // Everything applied before the fail-stop is a true prefix of the
    // primary's history: replaying the trace up to any watermark can
    // only produce row values the primary also held. Cheap proxy for
    // "never a wrong answer": the follower applied at most the frames
    // before the forgery.
    assert_eq!(state.len(), 48);
    pair.teardown();
}

// -------------------------------------------------------------------------
// Failover: promote mid-trace, finish on the new primary
// -------------------------------------------------------------------------

#[test]
fn promoted_follower_finishes_the_trace_bit_identically() {
    let trace = uniform_trace(64, 8, 200, 0xF01);
    let want_full = state_digest(&trace.reference_state());
    let half = trace.events.len() / 2;

    let pair = start_pair(64, 8, 2, Tier::Word, "failover", FaultPlan::clean(), &[]);
    apply_events(&pair.primary, &trace.events[..half]);
    let want_half = digest_of(&pair.primary);
    assert!(
        wait_digest(&pair.follower, want_half, CATCH_UP),
        "follower never caught up to the pre-failover watermark"
    );

    // "SIGKILL" the primary: sever the stream, discard the engine.
    let Pair { primary, follower, handle, _listener, _proxy, fdir } = pair;
    drop(_proxy);
    drop(_listener);
    primary.shutdown().unwrap();

    // Promote: epoch 0 → 1, persisted BEFORE writes open, idempotent.
    let epoch = handle.promote().unwrap();
    assert_eq!(epoch, 1);
    assert_eq!(load_epoch(&fdir).unwrap(), 1, "the fenced epoch must be durable");
    assert_eq!(handle.promote().unwrap(), 1, "re-promoting is a no-op");
    assert!(follower.is_writable());
    assert_eq!(handle.stats.role(), "primary");

    // The promoted primary serves the remainder of the trace.
    apply_events(&follower, &trace.events[half..]);
    assert_eq!(
        digest_of(&follower),
        want_full,
        "post-failover state must equal a full-trace replay"
    );

    // (The old primary refusing newer-epoch followers is covered by
    // `stale_primary_refuses_a_newer_epoch_follower` below.)
    drop(handle);
    let follower = Arc::try_unwrap(follower).unwrap_or_else(|_| panic!("shared"));
    follower.shutdown().unwrap();
}

#[test]
fn stale_primary_refuses_a_newer_epoch_follower() {
    let trace = uniform_trace(32, 8, 60, 0xE0);
    let pdir = tmpdir("stale-p");
    let fdir = tmpdir("stale-f");
    let primary = start_tier(durable_cfg(32, 8, 1, &pdir, false), Tier::Word);
    apply_events(&primary, &trace.events);
    let stats = ReplStats::new("primary", 1);
    let listener = ReplListener::start(
        "127.0.0.1:0",
        ReplListenerCfg { wal_dir: pdir, rows: 32, q: 8, shards: 1, stats },
    )
    .unwrap();

    // The follower carries epoch 3 (it lived through promotions the
    // old primary never saw). The handshake must be refused and the
    // refusal must fail-stop — replicating from a fenced primary
    // would silently fork history.
    store_epoch(&fdir, 3).unwrap();
    let follower = Arc::new(start_tier(durable_cfg(32, 8, 1, &fdir, true), Tier::Word));
    let handle = spawn_follower(
        Arc::clone(&follower),
        fdir,
        listener.addr().to_string(),
        fast_opts(),
    )
    .unwrap();
    let msg = wait_failed(&handle, CATCH_UP).expect("stale primary must cause a fail-stop");
    assert!(msg.contains("refused") || msg.contains("stale"), "{msg}");
    assert_eq!(digest_of(&follower), state_digest(&[0u32; 32]), "nothing was replicated");

    handle.stop();
    drop(handle);
    drop(listener);
    Arc::try_unwrap(follower).unwrap_or_else(|_| panic!("shared")).shutdown().unwrap();
    primary.shutdown().unwrap();
}

// -------------------------------------------------------------------------
// Restart: the follower's WAL is its cursor
// -------------------------------------------------------------------------

#[test]
fn follower_restart_resumes_from_its_recovered_watermark() {
    let trace = uniform_trace(48, 8, 180, 0x5E);
    let want_full = state_digest(&trace.reference_state());
    let third = trace.events.len() / 3;

    let pair = start_pair(48, 8, 1, Tier::Word, "restart", FaultPlan::clean(), &[]);
    apply_events(&pair.primary, &trace.events[..third]);
    let want_third = digest_of(&pair.primary);
    assert!(wait_digest(&pair.follower, want_third, CATCH_UP));

    // Stop and fully discard the follower (process death).
    let Pair { primary, follower, handle, _listener, _proxy, fdir } = pair;
    handle.stop();
    let frames_before = handle.stats.snapshot().frames_applied;
    assert!(frames_before > 0);
    drop(handle);
    Arc::try_unwrap(follower).unwrap_or_else(|_| panic!("shared")).shutdown().unwrap();

    // More history lands while the follower is down.
    apply_events(&primary, &trace.events[third..]);

    // Restart: recovery replays the follower's own WAL bit-identically
    // and replication resumes at the recovered watermark — the dup
    // counter staying 0 proves the primary resumed exactly past what
    // the follower already had, rather than re-shipping from LSN 1.
    let follower = Arc::new(start_tier(durable_cfg(48, 8, 1, &fdir, true), Tier::Word));
    assert_eq!(digest_of(&follower), want_third, "recovery must reproduce the pre-kill state");
    let handle = spawn_follower(
        Arc::clone(&follower),
        fdir,
        _proxy.addr().to_string(),
        fast_opts(),
    )
    .unwrap();
    assert!(
        wait_digest(&follower, want_full, CATCH_UP),
        "restarted follower never caught up (failed={:?})",
        handle.failed()
    );
    assert_eq!(handle.stats.snapshot().dup_frames, 0, "resume must not re-ship applied frames");
    assert!(handle.failed().is_none());

    handle.stop();
    drop(handle);
    drop(_proxy);
    drop(_listener);
    Arc::try_unwrap(follower).unwrap_or_else(|_| panic!("shared")).shutdown().unwrap();
    primary.shutdown().unwrap();
}
