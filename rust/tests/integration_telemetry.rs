//! End-to-end telemetry net (PR-10 tentpole): the sampled span
//! pipeline, the Prometheus exposition endpoint, and the
//! schema-versioned stats surface, exercised through real sockets.
//!
//! - *HTTP round trip*: a live engine under traffic + a real
//!   `MetricsServer` on an ephemeral port; a hand-rolled HTTP/1.1 GET
//!   of `/metrics` must parse through the crate's own exposition
//!   parser and contain EVERY documented metric family — the same
//!   assertion CI's telemetry-smoke job makes from the shell.
//! - *Spans end-to-end*: at sample rate 1 every ticketed submit
//!   becomes a span; the per-stage histograms must account for every
//!   one of them, with sane stage ordering (enqueue ≤ total) and a
//!   live WAL stage on a durable engine.
//! - *Scrape deltas are monotone*: two scrapes around a second burst
//!   of traffic must show strictly increasing completed counters —
//!   the property `fast stats --watch` renders as rates.
//! - *Schema surface*: the `METRICS` wire verb and the stats JSON are
//!   checked end to end in `serve.rs` unit tests; here the exposition
//!   carries the schema contract (`# EOF` terminator, typed families).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use fast_sram::coordinator::{EngineConfig, FastBackend, ShardPlan, UpdateEngine, UpdateRequest};
use fast_sram::durability::{DurabilityConfig, FsyncPolicy};
use fast_sram::serve;
use fast_sram::telemetry::expo::{self, DOCUMENTED_FAMILIES};
use fast_sram::telemetry::server::MetricsServer;

fn engine_with(rows: usize, q: usize, shards: usize, sample_rate: u64) -> Arc<UpdateEngine> {
    let mut cfg = EngineConfig::sharded(rows, q, shards);
    cfg.telemetry.sample_rate = sample_rate;
    Arc::new(
        UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap(),
    )
}

fn drive(engine: &UpdateEngine, rows: usize, n: usize) {
    let tickets: Vec<_> = (0..n)
        .map(|i| engine.submit_ticketed(UpdateRequest::add(i % rows, 1)).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
}

/// Plain HTTP/1.1 GET against the metrics endpoint, no client crate.
fn http_get_metrics(addr: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(stream, "GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").unwrap();
    let mut reader = BufReader::new(stream);
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    let mut headers = String::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        if line.trim_end().is_empty() {
            break;
        }
        headers.push_str(&line);
    }
    let mut body = String::new();
    reader.read_to_string(&mut body).unwrap();
    (format!("{status}{headers}"), body)
}

#[test]
fn metrics_endpoint_serves_every_documented_family_over_http() {
    let engine = engine_with(64, 8, 2, 1);
    drive(&engine, 64, 50);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let render = serve::metrics_render_engine(Arc::clone(&engine), None);
    let server = MetricsServer::start(listener, render).unwrap();

    let (head, body) = http_get_metrics(&addr);
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("text/plain"), "exposition content type: {head}");
    assert!(body.trim_end().ends_with("# EOF"), "exposition must end with # EOF");

    let scrape = expo::parse_text(&body).unwrap();
    for family in DOCUMENTED_FAMILIES {
        assert!(scrape.has_family(family), "missing documented family {family}");
    }
    assert!(
        scrape.total("fast_requests_completed_total") >= 50.0,
        "counters must reflect the traffic that actually ran"
    );

    // Second scrape around more traffic: every counter is monotone —
    // the delta `fast stats --watch` turns into a rate.
    drive(&engine, 64, 30);
    let (_, body2) = http_get_metrics(&addr);
    let scrape2 = expo::parse_text(&body2).unwrap();
    let d = scrape2.total("fast_requests_completed_total")
        - scrape.total("fast_requests_completed_total");
    assert!(d >= 30.0, "scrape delta must cover the second burst, got {d}");

    // Stop the endpoint BEFORE tearing down the engine: stop joins the
    // accept thread and drops the render closure's engine Arc.
    server.stop();
    Arc::try_unwrap(engine)
        .unwrap_or_else(|_| panic!("metrics server must have released its engine handle"))
        .shutdown()
        .unwrap();
}

#[test]
fn rate_one_sampling_accounts_for_every_ticketed_commit() {
    let engine = engine_with(64, 8, 2, 1);
    drive(&engine, 64, 80);
    // The drain thread ticks every 5ms; give it a couple of cycles to
    // sweep the rings into the stage histograms.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let snap = engine.telemetry().snapshot();
        let total = snap.stages.iter().find(|(n, _)| *n == "total").unwrap().1;
        if total.count > 0 || std::time::Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }

    let snap = engine.telemetry().snapshot();
    assert!(snap.enabled);
    assert_eq!(snap.sample_rate, 1);
    assert!(
        snap.spans_sampled >= 80,
        "rate 1 must stamp every admission, got {}",
        snap.spans_sampled
    );
    let stage = |name: &str| {
        snap.stages
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("stage {name} missing"))
            .1
    };
    let total = stage("total");
    assert!(total.count > 0, "sampled spans must land in the stage histograms");
    // A volatile engine never reaches the WAL or fsync stages.
    assert_eq!(stage("wal").count, 0);
    assert_eq!(stage("fsync_lag").count, 0);
    // Stage containment: the enqueue leg can never exceed the span.
    assert!(
        stage("enqueue").p99_ns <= total.max_ns,
        "enqueue p99 {} must sit inside the span max {}",
        stage("enqueue").p99_ns,
        total.max_ns
    );

    Arc::try_unwrap(engine).unwrap_or_else(|_| panic!("sole owner")).shutdown().unwrap();
}

#[test]
fn durable_engine_spans_cover_the_wal_and_fsync_stages() {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    let dir = std::env::temp_dir()
        .join(format!("fast-telemetry-wal-{}-{nanos}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut cfg = EngineConfig::sharded(64, 8, 2);
    cfg.telemetry.sample_rate = 1;
    let mut d = DurabilityConfig::new(dir.clone());
    d.fsync = FsyncPolicy::Always;
    cfg.durability = Some(d);
    let engine = UpdateEngine::start(cfg, |p: &ShardPlan| {
        Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
    })
    .unwrap();
    drive(&engine, 64, 40);

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let snap = loop {
        let snap = engine.telemetry().snapshot();
        let wal = snap.stages.iter().find(|(n, _)| *n == "wal").unwrap().1;
        if wal.count > 0 || std::time::Instant::now() > deadline {
            break snap;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    let stage = |name: &str| snap.stages.iter().find(|(n, _)| *n == name).unwrap().1;
    assert!(stage("wal").count > 0, "durable spans must time the WAL stage");
    assert!(
        stage("fsync_lag").count > 0,
        "fsync=always must surface the fsync-lag stage on sampled spans"
    );

    engine.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
