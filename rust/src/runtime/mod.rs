//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! This is the only place the process touches XLA. Artifacts are HLO
//! *text* (see python/compile/aot.py for why text, not serialized
//! protos), compiled once at load time on the CPU PJRT client, and
//! executed from the hot path with u32 word vectors.
//!
//! The manifest (artifacts/manifest.json, authored by aot.py) describes
//! every artifact's shapes and semantics so the coordinator can pick
//! executables without parsing HLO.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

pub mod validate;

// Without the `pjrt` feature, `xla::` resolves to the in-repo stub
// (fails cleanly at client construction); with it, to the real
// bindings crate — which is NOT in the offline vendor set, so the
// feature is guarded until the dependency is wired in. See
// xla_stub.rs for the rationale.
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
mod xla;

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the `xla` PJRT bindings crate, which is not \
     in the offline vendor set: add `xla` to rust/Cargo.toml [dependencies] \
     and remove this guard"
);

/// Metadata for one AOT artifact, parsed from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// Semantic op: "add" | "sub" | "and" | "or" | "xor" | "scan_add".
    pub op: String,
    /// Row count R (multiple of the 128-row macro height).
    pub rows: usize,
    /// Bit width q of each word (1..=32).
    pub q: usize,
    /// For scan artifacts: number of accumulate rounds T.
    pub rounds: Option<usize>,
    /// HLO text file name within the artifact directory.
    pub file: String,
    /// sha256 of the HLO text, for integrity checking.
    pub sha256: String,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let field = |k: &str| {
            v.get(k)
                .ok_or_else(|| anyhow!("manifest artifact missing field {k:?}"))
        };
        Ok(ArtifactMeta {
            name: field("name")?
                .as_str()
                .ok_or_else(|| anyhow!("name not a string"))?
                .to_string(),
            op: field("op")?
                .as_str()
                .ok_or_else(|| anyhow!("op not a string"))?
                .to_string(),
            rows: field("rows")?
                .as_usize()
                .ok_or_else(|| anyhow!("rows not a non-negative integer"))?,
            q: field("q")?
                .as_usize()
                .ok_or_else(|| anyhow!("q not a non-negative integer"))?,
            rounds: v.get("rounds").and_then(Json::as_usize),
            file: field("file")?
                .as_str()
                .ok_or_else(|| anyhow!("file not a string"))?
                .to_string(),
            sha256: field("sha256")?
                .as_str()
                .ok_or_else(|| anyhow!("sha256 not a string"))?
                .to_string(),
        })
    }
}

/// One compiled artifact: metadata + a PJRT loaded executable.
pub struct LoadedArtifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute a two-input artifact (add/sub/logic): `table` and
    /// `operand` must each have exactly `meta.rows` words.
    pub fn exec2(&self, table: &[u32], operand: &[u32]) -> Result<Vec<u32>> {
        if table.len() != self.meta.rows || operand.len() != self.meta.rows {
            bail!(
                "artifact {} expects {} rows, got table={} operand={}",
                self.meta.name,
                self.meta.rows,
                table.len(),
                operand.len()
            );
        }
        let a = xla::Literal::vec1(table);
        let b = xla::Literal::vec1(operand);
        self.run(&[a, b])
    }

    /// Execute a scan artifact: `table` is [rows], `rounds_flat` is
    /// row-major [t, rows].
    pub fn exec_scan(&self, table: &[u32], rounds_flat: &[u32]) -> Result<Vec<u32>> {
        let t = self
            .meta
            .rounds
            .ok_or_else(|| anyhow!("artifact {} is not a scan artifact", self.meta.name))?;
        if table.len() != self.meta.rows {
            bail!(
                "artifact {} expects {} rows, got {}",
                self.meta.name,
                self.meta.rows,
                table.len()
            );
        }
        if rounds_flat.len() != t * self.meta.rows {
            bail!(
                "artifact {} expects {}x{} round deltas, got {}",
                self.meta.name,
                t,
                self.meta.rows,
                rounds_flat.len()
            );
        }
        let a = xla::Literal::vec1(table);
        let b = xla::Literal::vec1(rounds_flat)
            .reshape(&[t as i64, self.meta.rows as i64])
            .context("reshaping scan rounds")?;
        self.run(&[a, b])
    }

    fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<u32>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact {}", self.meta.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
        let out = lit.to_tuple1().context("unwrapping result tuple")?;
        Ok(out.to_vec::<u32>()?)
    }
}

/// Runtime holding the PJRT client and every compiled artifact.
pub struct Runtime {
    platform: String,
    dir: PathBuf,
    artifacts: HashMap<String, LoadedArtifact>,
}

impl Runtime {
    /// Load and compile every artifact listed in `dir/manifest.json`.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Runtime> {
        Self::load_filtered(dir, |_| true)
    }

    /// Load a subset (predicate over artifact names) — faster startup
    /// when the caller needs only one executable.
    pub fn load_filtered(
        dir: impl AsRef<Path>,
        keep: impl Fn(&str) -> bool,
    ) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        if manifest.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        let mut artifacts = HashMap::new();
        for entry in manifest
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?
        {
            let meta = ArtifactMeta::from_json(entry)?;
            if !keep(&meta.name) {
                continue;
            }
            let hlo_path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", meta.name))?;
            artifacts.insert(meta.name.clone(), LoadedArtifact { meta, exe });
        }
        Ok(Runtime { platform, dir, artifacts })
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, name: &str) -> Result<&LoadedArtifact> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not loaded (have: {:?})",
                self.names()
            )
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }
}

/// Default artifact directory: `$FAST_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("FAST_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
