//! Build-time stub of the `xla` (PJRT) bindings.
//!
//! The real `xla` crate is not in the offline vendor set, so by default
//! the runtime module compiles against this stub, which type-checks the
//! same API surface and fails cleanly at client construction. Building
//! with `--features pjrt` (plus adding the `xla` dependency in an
//! environment that has it) swaps the real bindings back in; no other
//! code changes.
//!
//! Every entry point the runtime uses is represented: client/compile,
//! HLO-text parsing, literal construction and readback.
#![allow(dead_code)]

use std::fmt;

/// Error type standing in for the real bindings' error.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built without the `pjrt` feature \
         (the xla bindings crate is not in the offline vendor set)"
            .to_string(),
    )
}

/// Host literal (dense array value).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable())
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable())
    }
}

/// PJRT client. Construction is the stub's single failure point: it
/// errors before any artifact is compiled, so callers degrade exactly
/// like a machine without a PJRT plugin.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable())
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
