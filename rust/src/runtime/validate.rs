//! Cross-validation of the XLA artifacts against pure-Rust semantics.
//!
//! The reproduction has three implementations of the FAST batch-op
//! semantics: the Pallas kernel (checked against ref.py by pytest), the
//! Rust behavioural array model, and the host-side word arithmetic in
//! `util::bits`. This module checks a loaded artifact against the host
//! arithmetic on random vectors — run at coordinator startup (optional)
//! and by `cargo test` integration tests.

use anyhow::{bail, Result};

use super::LoadedArtifact;
use crate::util::bits;
use crate::util::rng::Rng;

/// Expected result of a two-input artifact according to `meta.op`.
pub fn expected2(op: &str, a: u32, b: u32, q: usize) -> Result<u32> {
    Ok(match op {
        "add" => bits::add_mod(a, b, q),
        "sub" => bits::sub_mod(a, b, q),
        "and" => a & b & bits::mask(q),
        "or" => (a | b) & bits::mask(q),
        "xor" => (a ^ b) & bits::mask(q),
        other => bail!("unknown artifact op {other:?}"),
    })
}

/// Run `trials` random vectors through a two-input artifact and compare
/// element-wise with the host arithmetic. Returns the number of words
/// checked.
pub fn validate2(art: &LoadedArtifact, trials: usize, seed: u64) -> Result<usize> {
    let rows = art.meta.rows;
    let q = art.meta.q;
    let m = bits::mask(q) as u64 + 1;
    let mut rng = Rng::new(seed);
    let mut checked = 0;
    for trial in 0..trials {
        let a: Vec<u32> = (0..rows).map(|_| rng.below(m) as u32).collect();
        let b: Vec<u32> = (0..rows).map(|_| rng.below(m) as u32).collect();
        let got = art.exec2(&a, &b)?;
        if got.len() != rows {
            bail!(
                "artifact {} returned {} words, expected {rows}",
                art.meta.name,
                got.len()
            );
        }
        for r in 0..rows {
            let want = expected2(&art.meta.op, a[r], b[r], q)?;
            if got[r] != want {
                bail!(
                    "artifact {} mismatch (trial {trial}, row {r}): \
                     {} {} {} -> got {:#x}, want {:#x}",
                    art.meta.name,
                    a[r],
                    art.meta.op,
                    b[r],
                    got[r],
                    want
                );
            }
            checked += 1;
        }
    }
    Ok(checked)
}

/// Validate a scan artifact: T successive batch adds.
pub fn validate_scan(art: &LoadedArtifact, trials: usize, seed: u64) -> Result<usize> {
    let rows = art.meta.rows;
    let q = art.meta.q;
    let t = match art.meta.rounds {
        Some(t) => t,
        None => bail!("artifact {} is not a scan artifact", art.meta.name),
    };
    let m = bits::mask(q) as u64 + 1;
    let mut rng = Rng::new(seed);
    let mut checked = 0;
    for trial in 0..trials {
        let table: Vec<u32> = (0..rows).map(|_| rng.below(m) as u32).collect();
        let rounds: Vec<u32> = (0..t * rows).map(|_| rng.below(m) as u32).collect();
        let got = art.exec_scan(&table, &rounds)?;
        let mut want = table.clone();
        for ti in 0..t {
            for r in 0..rows {
                want[r] = bits::add_mod(want[r], rounds[ti * rows + r], q);
            }
        }
        if got != want {
            bail!(
                "scan artifact {} mismatch on trial {trial}",
                art.meta.name
            );
        }
        checked += rows;
    }
    Ok(checked)
}
