//! Deterministic fault injection for the replication stream: a TCP
//! proxy that sits between a follower and its primary, parses the
//! post-handshake `fast-repl-v1` record stream, and mangles it
//! according to a seeded or scripted [`FaultPlan`].
//!
//! The proxy is protocol-aware on the primary→follower leg (faults
//! land on whole records, so each injected failure is a *specific*
//! failure mode, not random line noise) and a verbatim byte pipe on
//! the follower→primary leg. The plan's state is shared across
//! reconnects: record indices keep counting when the follower comes
//! back, so a script like "forge record 7" fires exactly once no
//! matter how many connections it takes to get there.
//!
//! Fault vocabulary and what the follower must do about each:
//!
//! | action        | wire effect                          | required reaction |
//! |---------------|--------------------------------------|-------------------|
//! | `Drop`        | frame never arrives → LSN gap        | reconnect, resume |
//! | `Duplicate`   | frame arrives twice                  | skip the dup      |
//! | `CorruptWire` | frame bytes flipped, CRC now wrong   | reconnect, resume |
//! | `Truncate`    | partial record, connection dies      | reconnect, resume |
//! | `Delay`       | frame arrives late                   | nothing (lag)     |
//! | `Cut`         | connection dies mid-stream           | reconnect, resume |
//! | `Swap`        | two frames reordered → LSN gap       | reconnect, resume |
//! | `Forge`       | payload flipped, CRC *recomputed*    | **fail-stop**     |
//!
//! `Forge` is the divergence case: the frame is internally consistent
//! but is not what the primary logged, which only the chained FNV can
//! catch. Everything above it must end in transparent catch-up.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::Context;

use crate::util::crc32::crc32;
use crate::util::rng::Rng;
use crate::Result;

use super::protocol::{
    read_record, write_digest_record, write_frame_record, write_heartbeat, ReplRecord, GO_LINE,
};

/// What to do with one shipped frame record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Pass through untouched.
    Deliver,
    /// Swallow the record (follower sees an LSN gap next).
    Drop,
    /// Deliver the record twice back to back.
    Duplicate,
    /// Flip a frame byte WITHOUT fixing the CRC — detectable wire
    /// damage; the follower must reconnect, never apply.
    CorruptWire,
    /// Flip a payload byte and RECOMPUTE the frame CRC — an internally
    /// consistent forgery only the chained digest can catch. The
    /// follower must fail-stop.
    Forge,
    /// Deliver a byte-truncated record, then kill the connection.
    Truncate,
    /// Sleep this many milliseconds, then deliver.
    Delay(u64),
    /// Kill the connection without delivering.
    Cut,
    /// Hold this record back and deliver it AFTER the next one
    /// (reorder → LSN gap on the early frame).
    Swap,
}

/// Seeded chaos probabilities (recoverable faults only — divergence
/// faults are scripted so tests know exactly where they fire).
#[derive(Debug, Clone, Copy)]
pub struct FaultProbs {
    pub drop: f64,
    pub duplicate: f64,
    pub corrupt: f64,
    pub cut: f64,
    /// Probability of a delay, and how long it is.
    pub delay: f64,
    pub delay_ms: u64,
}

impl FaultProbs {
    /// A mild mix of every recoverable fault.
    pub fn mild() -> FaultProbs {
        FaultProbs { drop: 0.04, duplicate: 0.04, corrupt: 0.03, cut: 0.02, delay: 0.05, delay_ms: 3 }
    }
}

enum PlanKind {
    Scripted(BTreeMap<u64, FaultAction>),
    Chaos { rng: Rng, probs: FaultProbs },
}

/// A deterministic schedule of [`FaultAction`]s over the stream's
/// frame records (0-indexed, counted across reconnects).
pub struct FaultPlan {
    kind: PlanKind,
    next_idx: u64,
}

impl FaultPlan {
    /// Deliver everything (control runs).
    pub fn clean() -> FaultPlan {
        FaultPlan::scripted([])
    }

    /// Explicit `(frame_index, action)` pairs; unlisted frames deliver.
    pub fn scripted(actions: impl IntoIterator<Item = (u64, FaultAction)>) -> FaultPlan {
        FaultPlan { kind: PlanKind::Scripted(actions.into_iter().collect()), next_idx: 0 }
    }

    /// Seeded recoverable chaos: same seed + probs → same schedule.
    pub fn chaos(seed: u64, probs: FaultProbs) -> FaultPlan {
        FaultPlan { kind: PlanKind::Chaos { rng: Rng::new(seed), probs }, next_idx: 0 }
    }

    /// The action for the next frame record (advances the index).
    fn next_action(&mut self) -> FaultAction {
        let idx = self.next_idx;
        self.next_idx += 1;
        match &mut self.kind {
            PlanKind::Scripted(map) => map.get(&idx).copied().unwrap_or(FaultAction::Deliver),
            PlanKind::Chaos { rng, probs } => {
                // One RNG draw per category per frame keeps the
                // schedule independent of which categories fire.
                let drop = rng.chance(probs.drop);
                let dup = rng.chance(probs.duplicate);
                let corrupt = rng.chance(probs.corrupt);
                let cut = rng.chance(probs.cut);
                let delay = rng.chance(probs.delay);
                if drop {
                    FaultAction::Drop
                } else if corrupt {
                    FaultAction::CorruptWire
                } else if dup {
                    FaultAction::Duplicate
                } else if cut {
                    FaultAction::Cut
                } else if delay {
                    FaultAction::Delay(probs.delay_ms)
                } else {
                    FaultAction::Deliver
                }
            }
        }
    }
}

/// Man-in-the-middle proxy applying a [`FaultPlan`] to the
/// primary→follower record stream. Point the follower at
/// [`FaultProxy::addr`] instead of the primary.
pub struct FaultProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl FaultProxy {
    pub fn start(primary: SocketAddr, plan: FaultPlan) -> Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding fault proxy")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let plan = Arc::new(Mutex::new(plan));
        let accept_stop = Arc::clone(&stop);
        let accept_thread = thread::Builder::new()
            .name("fault-proxy".into())
            .spawn(move || {
                while !accept_stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((conn, _)) => {
                            let plan = Arc::clone(&plan);
                            let _ = thread::Builder::new().name("fault-conn".into()).spawn(
                                move || {
                                    let _ = relay(conn, primary, &plan);
                                },
                            );
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawning fault proxy")?;
        Ok(FaultProxy { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// Where the follower should connect.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Handle one follower connection end to end.
fn relay(follower: TcpStream, primary: SocketAddr, plan: &Mutex<FaultPlan>) -> Result<()> {
    let upstream = TcpStream::connect(primary).context("fault proxy dialing primary")?;
    // Follower→primary: verbatim byte pipe (handshake lines + nothing
    // else in v1). Dies when either side closes.
    let mut up_rx = follower.try_clone()?;
    let mut up_tx = upstream.try_clone()?;
    let up = thread::Builder::new().name("fault-up".into()).spawn(move || {
        let _ = std::io::copy(&mut up_rx, &mut up_tx);
        let _ = up_tx.shutdown(Shutdown::Write);
    })?;
    let res = pump_down(&upstream, &follower, plan);
    // Ensure both directions die so the copy thread unblocks.
    let _ = upstream.shutdown(Shutdown::Both);
    let _ = follower.shutdown(Shutdown::Both);
    let _ = up.join();
    res
}

/// Primary→follower: relay the handshake verbatim, then parse records
/// and apply the plan to frame records.
fn pump_down(upstream: &TcpStream, follower: &TcpStream, plan: &Mutex<FaultPlan>) -> Result<()> {
    let mut r = BufReader::new(upstream.try_clone()?);
    let mut w = BufWriter::new(follower.try_clone()?);
    loop {
        let mut line = String::new();
        if r.read_line(&mut line).unwrap_or(0) == 0 {
            return Ok(()); // primary closed during handshake
        }
        w.write_all(line.as_bytes())?;
        w.flush()?;
        let t = line.trim_end();
        if t == GO_LINE {
            break;
        }
        if t.starts_with("RERR") {
            return Ok(());
        }
    }
    // Held-back record for Swap: delivered right after the next
    // delivered record.
    let mut held: Option<Vec<u8>> = None;
    loop {
        let rec = match read_record(&mut r) {
            Ok(rec) => rec,
            Err(_) => return Ok(()), // primary closed / killed
        };
        let mut bytes = Vec::new();
        let action = match &rec {
            ReplRecord::Frame { chain, frame } => {
                write_frame_record(&mut bytes, *chain, frame)?;
                plan.lock().expect("fault plan lock").next_action()
            }
            ReplRecord::Digest(d) => {
                write_digest_record(&mut bytes, d)?;
                FaultAction::Deliver
            }
            ReplRecord::Heartbeat(tails) => {
                write_heartbeat(&mut bytes, tails)?;
                FaultAction::Deliver
            }
        };
        match action {
            FaultAction::Deliver => deliver(&mut w, bytes, &mut held)?,
            FaultAction::Drop => {}
            FaultAction::Duplicate => {
                deliver(&mut w, bytes.clone(), &mut held)?;
                deliver(&mut w, bytes, &mut held)?;
            }
            FaultAction::CorruptWire => {
                // Flip the frame's final byte; the 8-byte record
                // prefix (tag absent here: tag+len+chain = 13 bytes)
                // stays intact so the follower reads a well-formed
                // record whose FRAME fails its CRC check.
                let last = bytes.len() - 1;
                bytes[last] ^= 0xFF;
                deliver(&mut w, bytes, &mut held)?;
            }
            FaultAction::Forge => {
                // Record layout: tag(1) len(4) chain(8) | frame:
                // flen(4) fcrc(4) payload. Flip the final payload byte
                // and recompute fcrc so the frame stays internally
                // consistent — only the chain can catch it.
                let last = bytes.len() - 1;
                bytes[last] ^= 0x01;
                let fixed = crc32(&bytes[21..]);
                bytes[17..21].copy_from_slice(&fixed.to_le_bytes());
                deliver(&mut w, bytes, &mut held)?;
            }
            FaultAction::Truncate => {
                let keep = bytes.len().saturating_sub(5).max(1);
                w.write_all(&bytes[..keep])?;
                w.flush()?;
                return Ok(()); // connection dies mid-record
            }
            FaultAction::Delay(ms) => {
                w.flush()?;
                thread::sleep(Duration::from_millis(ms));
                deliver(&mut w, bytes, &mut held)?;
            }
            FaultAction::Cut => return Ok(()),
            FaultAction::Swap => {
                held = Some(bytes); // rides out after the next delivery
            }
        }
        w.flush()?;
    }
}

fn deliver(w: &mut impl Write, bytes: Vec<u8>, held: &mut Option<Vec<u8>>) -> Result<()> {
    w.write_all(&bytes)?;
    if let Some(h) = held.take() {
        w.write_all(&h)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_plans_fire_at_exact_indices() {
        let mut p = FaultPlan::scripted([(1, FaultAction::Drop), (3, FaultAction::Forge)]);
        assert_eq!(p.next_action(), FaultAction::Deliver);
        assert_eq!(p.next_action(), FaultAction::Drop);
        assert_eq!(p.next_action(), FaultAction::Deliver);
        assert_eq!(p.next_action(), FaultAction::Forge);
        assert_eq!(p.next_action(), FaultAction::Deliver);
    }

    #[test]
    fn chaos_plans_are_seed_deterministic() {
        let probs = FaultProbs::mild();
        let mut a = FaultPlan::chaos(42, probs);
        let mut b = FaultPlan::chaos(42, probs);
        let mut c = FaultPlan::chaos(43, probs);
        let sa: Vec<_> = (0..256).map(|_| a.next_action()).collect();
        let sb: Vec<_> = (0..256).map(|_| b.next_action()).collect();
        let sc: Vec<_> = (0..256).map(|_| c.next_action()).collect();
        assert_eq!(sa, sb, "same seed, same schedule");
        assert_ne!(sa, sc, "different seed, different schedule");
        assert!(
            sa.iter().any(|x| *x != FaultAction::Deliver),
            "mild chaos over 256 frames should fire at least once"
        );
        // Chaos never emits the divergence fault — that is scripted only.
        assert!(sa.iter().all(|x| *x != FaultAction::Forge));
    }
}
