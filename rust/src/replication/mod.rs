//! Layer-5 replication: WAL shipping from a durable primary to live
//! read-only followers, with divergence fail-stop and epoch-fenced
//! promotion (`fast serve --follower`, `fast promote`).
//!
//! The design rides PR 5's durability subsystem end to end: the
//! per-shard CRC32-framed WAL *is* the replication log. A primary
//! tails its own segments with read-only [`WalCursor`]s
//! ([`crate::durability::cursor`]) and ships raw frame bytes; a
//! follower verifies each frame (CRC + chained FNV), re-logs it
//! byte-identically through its own WAL, and applies it through the
//! same sealed-batch path recovery uses — so a follower's directory is
//! at all times a valid crash-recoverable WAL dir, and promotion is
//! just "stop tailing, bump the epoch, accept writes".
//!
//! - [`protocol`] — `fast-repl-v1` handshake + binary record codec,
//!   [`protocol::ShardChain`] digests, `repl.json` epoch persistence
//! - [`primary`] — repl listener: accepts followers, pumps cursors
//! - [`follower`] — reconnect loop with capped backoff + jitter,
//!   verify/apply, divergence fail-stop, promotion
//! - [`fault`] — deterministic fault-injection proxy for tests
//!   (drop/duplicate/corrupt/truncate/delay/reorder, seeded)
//!
//! ## Invariants
//!
//! - **Cursor**: a follower requests `applied watermark + 1` per shard
//!   on (re)connect; the primary replays from its segments, so any
//!   retained history is resumable. Duplicates below the watermark are
//!   skipped; gaps above it are wire errors (reconnect), never applied.
//! - **Watermark**: a shard's applied LSN advances only after the
//!   frame is re-logged AND applied on the follower — reads served at
//!   the watermark are reads of replicated, durable state.
//! - **Divergence = fail-stop**: a frame whose CRC passes but whose
//!   chain/digest disagrees, a commit-seq mismatch, or an epoch from
//!   the past makes the follower exit with a typed [`Divergence`]
//!   error. A follower never serves state it cannot prove matches the
//!   primary's log.

pub mod fault;
pub mod follower;
pub mod primary;
pub mod protocol;

pub use fault::{FaultAction, FaultPlan, FaultProbs, FaultProxy};
pub use follower::{spawn_follower, FollowerHandle, FollowerOpts};
pub use primary::{ReplListener, ReplListenerCfg};
pub use protocol::{load_epoch, store_epoch, HelloAck, SegmentDigest, ShardChain};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Typed marker for replica-state divergence: the streams disagree in
/// a way reconnecting cannot heal (chain/digest mismatch, commit-seq
/// mismatch, stale epoch, geometry mismatch). Followers fail-stop on
/// it; everything else is a wire error and retries.
#[derive(Debug)]
pub struct Divergence(pub String);

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "replica divergence: {}", self.0)
    }
}

impl std::error::Error for Divergence {}

/// Build a fail-stop divergence error.
pub fn diverged(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(Divergence(msg.into()))
}

/// True when `err`'s root cause is a [`Divergence`] (fail-stop) rather
/// than a retryable wire problem.
pub fn is_divergence(err: &anyhow::Error) -> bool {
    err.root_cause().downcast_ref::<Divergence>().is_some()
}

/// Per-shard replication lag state (shared, lock-free on the hot path).
pub struct ReplShardLag {
    /// Highest LSN re-logged AND applied locally.
    pub applied_lsn: AtomicU64,
    /// Primary's durable tail LSN as last heard (frames + heartbeats).
    pub primary_lsn: AtomicU64,
    /// When `applied_lsn` last advanced (drives wall-clock lag).
    last_advance: Mutex<Instant>,
}

/// Shared replication counters surfaced through `--stats-json` and the
/// serve `STATS` verb. One instance per process role.
pub struct ReplStats {
    role: Mutex<&'static str>,
    pub epoch: AtomicU64,
    pub connected: AtomicBool,
    pub reconnects: AtomicU64,
    pub frames_applied: AtomicU64,
    pub dup_frames: AtomicU64,
    pub wire_errors: AtomicU64,
    pub digests_verified: AtomicU64,
    failed: Mutex<Option<String>>,
    shards: Vec<ReplShardLag>,
}

impl ReplStats {
    pub fn new(role: &'static str, shards: usize) -> Arc<ReplStats> {
        Arc::new(ReplStats {
            role: Mutex::new(role),
            epoch: AtomicU64::new(0),
            connected: AtomicBool::new(false),
            reconnects: AtomicU64::new(0),
            frames_applied: AtomicU64::new(0),
            dup_frames: AtomicU64::new(0),
            wire_errors: AtomicU64::new(0),
            digests_verified: AtomicU64::new(0),
            failed: Mutex::new(None),
            shards: (0..shards)
                .map(|_| ReplShardLag {
                    applied_lsn: AtomicU64::new(0),
                    primary_lsn: AtomicU64::new(0),
                    last_advance: Mutex::new(Instant::now()),
                })
                .collect(),
        })
    }

    pub fn role(&self) -> &'static str {
        *self.role.lock().expect("repl role lock")
    }

    pub fn set_role(&self, role: &'static str) {
        *self.role.lock().expect("repl role lock") = role;
    }

    pub fn record_applied(&self, shard: usize, lsn: u64) {
        let s = &self.shards[shard];
        s.applied_lsn.store(lsn, Ordering::Release);
        *s.last_advance.lock().expect("lag lock") = Instant::now();
    }

    pub fn record_primary_tail(&self, shard: usize, lsn: u64) {
        let s = &self.shards[shard];
        s.primary_lsn.fetch_max(lsn, Ordering::AcqRel);
    }

    pub fn applied_lsn(&self, shard: usize) -> u64 {
        self.shards[shard].applied_lsn.load(Ordering::Acquire)
    }

    /// Total logical lag across all shards (Σ primary tail − applied),
    /// saturating per shard — the gauge the telemetry rate series
    /// samples (`Telemetry::set_lag_source`).
    pub fn total_lag_lsn(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.primary_lsn
                    .load(Ordering::Acquire)
                    .saturating_sub(s.applied_lsn.load(Ordering::Acquire))
            })
            .sum()
    }

    /// Record a fail-stop reason (first one wins).
    pub fn fail(&self, msg: String) {
        let mut f = self.failed.lock().expect("repl failed lock");
        if f.is_none() {
            *f = Some(msg);
        }
    }

    pub fn failed(&self) -> Option<String> {
        self.failed.lock().expect("repl failed lock").clone()
    }

    pub fn snapshot(&self) -> ReplSnapshot {
        let now = Instant::now();
        ReplSnapshot {
            role: self.role(),
            epoch: self.epoch.load(Ordering::Acquire),
            connected: self.connected.load(Ordering::Acquire),
            reconnects: self.reconnects.load(Ordering::Relaxed),
            frames_applied: self.frames_applied.load(Ordering::Relaxed),
            dup_frames: self.dup_frames.load(Ordering::Relaxed),
            wire_errors: self.wire_errors.load(Ordering::Relaxed),
            digests_verified: self.digests_verified.load(Ordering::Relaxed),
            failed: self.failed(),
            shards: self
                .shards
                .iter()
                .enumerate()
                .map(|(shard, s)| {
                    let applied = s.applied_lsn.load(Ordering::Acquire);
                    let primary = s.primary_lsn.load(Ordering::Acquire);
                    let lag_wall_ms = if primary > applied {
                        now.duration_since(*s.last_advance.lock().expect("lag lock"))
                            .as_millis() as u64
                    } else {
                        0
                    };
                    ReplShardLagSnap {
                        shard,
                        applied_lsn: applied,
                        primary_lsn: primary,
                        lag_lsn: primary.saturating_sub(applied),
                        lag_wall_ms,
                    }
                })
                .collect(),
        }
    }
}

/// Point-in-time view of one shard's lag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplShardLagSnap {
    pub shard: usize,
    pub applied_lsn: u64,
    pub primary_lsn: u64,
    /// `primary_lsn - applied_lsn` (0 when caught up).
    pub lag_lsn: u64,
    /// Milliseconds since the applied watermark last advanced, 0 when
    /// caught up.
    pub lag_wall_ms: u64,
}

/// Point-in-time view of the whole replication state, serialized into
/// `--stats-json` under the `"repl"` key.
#[derive(Debug, Clone)]
pub struct ReplSnapshot {
    pub role: &'static str,
    pub epoch: u64,
    pub connected: bool,
    pub reconnects: u64,
    pub frames_applied: u64,
    pub dup_frames: u64,
    pub wire_errors: u64,
    pub digests_verified: u64,
    /// Fail-stop reason, if the follower stopped on divergence.
    pub failed: Option<String>,
    pub shards: Vec<ReplShardLagSnap>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divergence_classification_survives_context() {
        use anyhow::Context;
        let e = diverged("chain mismatch at lsn 9");
        assert!(is_divergence(&e));
        let wrapped = Err::<(), _>(e).context("while applying shard 2").unwrap_err();
        assert!(is_divergence(&wrapped), "downcast must see through context layers");
        assert!(!is_divergence(&anyhow::anyhow!("connection reset")));
    }

    #[test]
    fn lag_snapshot_tracks_watermarks() {
        let stats = ReplStats::new("follower", 2);
        stats.record_primary_tail(0, 10);
        stats.record_applied(0, 7);
        stats.record_primary_tail(1, 4);
        stats.record_applied(1, 4);
        // fetch_max never regresses the tail.
        stats.record_primary_tail(0, 9);
        let snap = stats.snapshot();
        assert_eq!(snap.shards[0].lag_lsn, 3);
        assert_eq!(snap.shards[0].primary_lsn, 10);
        assert_eq!(snap.shards[1].lag_lsn, 0);
        assert_eq!(snap.shards[1].lag_wall_ms, 0, "caught up means zero wall lag");
        assert_eq!(snap.role, "follower");
        stats.fail("boom".into());
        stats.fail("later".into());
        assert_eq!(stats.failed().as_deref(), Some("boom"), "first fail-stop reason wins");
    }
}
