//! Primary side of WAL shipping: a listener that accepts follower
//! connections, replays history from the shard segments with
//! read-only [`WalCursor`]s, and live-tails new frames as the engine
//! appends them.
//!
//! The listener never touches the engine: the durable log is the
//! source of truth, so a frame is shipped if and only if it is on
//! disk — a follower can never get ahead of what a primary crash
//! would preserve. Each connection runs its own cursors and
//! [`ShardChain`]s seeded from the follower's requested LSNs, pumps
//! shards round-robin (bounded burst per shard per round so one hot
//! shard cannot starve the rest), emits a `'D'` digest at every
//! segment boundary, and heartbeats durable tail LSNs while idle.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::Context;

use crate::durability::cursor::{CursorEvent, WalCursor};
use crate::durability::segment::list_segments;
use crate::Result;

use super::protocol::{
    err_line, load_epoch, ok_line, parse_hello, parse_start, write_digest_record,
    write_frame_record, write_heartbeat, GO_LINE,
};
use super::{ReplStats, ShardChain};

/// Max frames pumped per shard per round-robin pass.
const BURST: usize = 64;
/// Idle poll interval when fully caught up.
const IDLE_POLL: Duration = Duration::from_millis(20);
/// Heartbeat every N idle polls (~100 ms at the default interval).
const HEARTBEAT_EVERY: u32 = 5;

/// What a connection needs to serve a follower.
#[derive(Clone)]
pub struct ReplListenerCfg {
    pub wal_dir: PathBuf,
    pub rows: usize,
    pub q: usize,
    pub shards: usize,
    pub stats: Arc<ReplStats>,
}

/// The primary's replication listener (`fast serve --repl-listen`).
/// Dropping it stops the accept loop; in-flight connections notice the
/// stop flag within one idle poll.
pub struct ReplListener {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl ReplListener {
    pub fn start(listen: &str, cfg: ReplListenerCfg) -> Result<ReplListener> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding repl listener on {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept_stop = Arc::clone(&stop);
        let conns = Arc::new(AtomicU64::new(0));
        let accept_thread = thread::Builder::new()
            .name("repl-listen".into())
            .spawn(move || {
                while !accept_stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((conn, peer)) => {
                            let cfg = cfg.clone();
                            let stop = Arc::clone(&accept_stop);
                            let conns = Arc::clone(&conns);
                            let _ = thread::Builder::new().name("repl-conn".into()).spawn(
                                move || {
                                    conns.fetch_add(1, Ordering::AcqRel);
                                    cfg.stats.connected.store(true, Ordering::Release);
                                    if let Err(e) = serve_follower(conn, &cfg, &stop) {
                                        eprintln!(
                                            "fast serve: repl connection from {peer} ended: {e:#}"
                                        );
                                    }
                                    if conns.fetch_sub(1, Ordering::AcqRel) == 1 {
                                        cfg.stats.connected.store(false, Ordering::Release);
                                    }
                                },
                            );
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(IDLE_POLL);
                        }
                        Err(e) => {
                            eprintln!("fast serve: repl accept failed: {e}");
                            break;
                        }
                    }
                }
            })
            .context("spawning repl listener")?;
        Ok(ReplListener { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (useful with port 0 in tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ReplListener {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Handshake + pump loop for one follower connection.
fn serve_follower(conn: TcpStream, cfg: &ReplListenerCfg, stop: &AtomicBool) -> Result<()> {
    conn.set_nodelay(true)?;
    let mut r = BufReader::new(conn.try_clone()?);
    let mut w = BufWriter::new(conn);

    let mut line = String::new();
    r.read_line(&mut line).context("reading RHELLO")?;
    let follower_epoch = match parse_hello(line.trim_end()) {
        Ok(e) => e,
        Err(e) => {
            writeln!(w, "{}", err_line(&format!("{e:#}")))?;
            w.flush()?;
            return Err(e);
        }
    };
    let my_epoch = load_epoch(&cfg.wal_dir)?;
    cfg.stats.epoch.store(my_epoch, Ordering::Release);
    if follower_epoch > my_epoch {
        let msg = format!(
            "follower epoch {follower_epoch} is ahead of this primary's epoch {my_epoch} — \
             this primary is stale (a follower was promoted past it); do not replicate from it"
        );
        writeln!(w, "{}", err_line(&msg))?;
        w.flush()?;
        anyhow::bail!("{msg}");
    }
    writeln!(w, "{}", ok_line(cfg.rows, cfg.q, cfg.shards, my_epoch))?;
    w.flush()?;

    line.clear();
    r.read_line(&mut line).context("reading RSTART")?;
    let (echo_epoch, lsns) = match parse_start(line.trim_end()) {
        Ok(v) => v,
        Err(e) => {
            writeln!(w, "{}", err_line(&format!("{e:#}")))?;
            w.flush()?;
            return Err(e);
        }
    };
    if echo_epoch != my_epoch || lsns.len() != cfg.shards {
        let msg = if echo_epoch != my_epoch {
            format!("RSTART echoes epoch {echo_epoch}, primary is at {my_epoch}")
        } else {
            format!("RSTART carries {} lsns for {} shards", lsns.len(), cfg.shards)
        };
        writeln!(w, "{}", err_line(&msg))?;
        w.flush()?;
        anyhow::bail!("{msg}");
    }
    // Pre-validate coverage so a compacted-away cursor is an
    // actionable handshake refusal, not a mid-stream hangup.
    for (shard, &lsn) in lsns.iter().enumerate() {
        let segs = list_segments(&cfg.wal_dir, shard)?;
        if let Some(oldest) = segs.first() {
            if lsn < oldest.first_lsn {
                let msg = format!(
                    "shard {shard}: lsn {lsn} was compacted away (oldest retained {}) — \
                     re-seed the follower from a fresh copy of the primary's WAL dir",
                    oldest.first_lsn
                );
                writeln!(w, "{}", err_line(&msg))?;
                w.flush()?;
                anyhow::bail!("{msg}");
            }
        }
    }
    writeln!(w, "{GO_LINE}")?;
    w.flush()?;

    let mut cursors = Vec::with_capacity(cfg.shards);
    let mut chains = Vec::with_capacity(cfg.shards);
    for (shard, &lsn) in lsns.iter().enumerate() {
        cursors.push(WalCursor::new(&cfg.wal_dir, shard, lsn)?);
        chains.push(ShardChain::new(shard as u32, lsn));
    }

    let mut idle_polls: u32 = 0;
    while !stop.load(Ordering::Acquire) {
        let mut shipped = false;
        for shard in 0..cfg.shards {
            for _ in 0..BURST {
                match cursors[shard].poll()? {
                    CursorEvent::Frame { record: _, frame } => {
                        let chain = chains[shard].absorb(&frame);
                        write_frame_record(&mut w, chain, &frame)?;
                        cfg.stats.frames_applied.fetch_add(1, Ordering::Relaxed);
                        shipped = true;
                    }
                    CursorEvent::SegmentSealed { upto_lsn } => {
                        write_digest_record(&mut w, &chains[shard].digest(shard as u32, upto_lsn))?;
                        cfg.stats.digests_verified.fetch_add(1, Ordering::Relaxed);
                        shipped = true;
                    }
                    CursorEvent::Idle => break,
                }
            }
            cfg.stats.record_primary_tail(shard, cursors[shard].tail_seen());
        }
        w.flush()?;
        if shipped {
            idle_polls = 0;
            continue;
        }
        idle_polls += 1;
        if idle_polls % HEARTBEAT_EVERY == 0 {
            let tails: Vec<u64> = cursors.iter().map(WalCursor::tail_seen).collect();
            write_heartbeat(&mut w, &tails)?;
            w.flush()?;
        }
        thread::sleep(IDLE_POLL);
    }
    Ok(())
}
