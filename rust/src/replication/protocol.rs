//! `fast-repl-v1` wire protocol: the frame-shipping stream between a
//! WAL-bearing primary and a read-only follower, plus the epoch file
//! that fences promoted followers against a returning old primary.
//!
//! ## Handshake (text, one line each, `\n`-terminated)
//!
//! ```text
//! F→P  RHELLO fast-repl-v1 epoch=<E>
//! P→F  ROK fast-repl-v1 rows=<R> q=<Q> shards=<S> epoch=<E>     (or RERR <msg>)
//! F→P  RSTART epoch=<E> lsns=<l0>,<l1>,...                      (one per shard,
//!                                                                first lsn wanted)
//! P→F  RGO                                                      (or RERR <msg>)
//! ```
//!
//! The follower echoes the primary's epoch in `RSTART` so both sides
//! agree on which history they are shipping before a single frame
//! moves. After `RGO` the stream switches to binary records, P→F only:
//!
//! ```text
//! 'F' | len:u32 | chain:u64 | frame[len]     one WAL frame (len|crc|payload
//!                                            exactly as on the primary's disk),
//!                                            chain = primary's running FNV after
//!                                            absorbing this frame
//! 'D' | shard:u32 | upto_lsn:u64 | frames:u64 | crc:u32 | fnv:u64
//!                                            segment-boundary digest: cumulative
//!                                            over every frame shipped for the
//!                                            shard on THIS connection
//! 'H' | nshards:u32 | nshards × tail:u64     heartbeat: primary's durable tail
//!                                            lsn per shard (lag measurement)
//! ```
//!
//! All integers little-endian. The per-frame `chain` value lets the
//! follower detect divergence on the very frame where histories split
//! (not just at the next segment boundary); the `'D'` digest
//! cross-checks the CRC32 accumulation as well, riding the same CRC
//! the `wal verify` machinery trusts.
//!
//! ## Epoch fencing (`repl.json`)
//!
//! A WAL dir carries a replication epoch (missing file = epoch 0).
//! `fast promote` bumps it durably before the engine accepts writes;
//! a primary refuses followers from a *newer* epoch (it has been
//! promoted past), and a follower fail-stops on a primary from an
//! *older* epoch (stale pre-failover primary came back).

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::durability::wal::MAX_PAYLOAD;
use crate::util::crc32::Crc32;
use crate::util::json::Json;
use crate::Result;

/// Protocol / epoch-file format tag.
pub const REPL_FORMAT: &str = "fast-repl-v1";
/// Epoch file name inside a WAL dir.
pub const REPL_FILE: &str = "repl.json";
/// The go-ahead line ending the handshake.
pub const GO_LINE: &str = "RGO";

/// Smallest shippable frame: 8-byte frame header + the WAL's fixed
/// payload fields.
const MIN_FRAME: u32 = 8 + 27;
/// Heartbeats size sanity cap (shard counts are small powers of two).
const MAX_HEARTBEAT_SHARDS: u32 = 4096;

// ---------------------------------------------------------------------------
// Epoch file

/// Read the replication epoch from `dir` (missing file = epoch 0).
pub fn load_epoch(dir: &Path) -> Result<u64> {
    let path = dir.join(REPL_FILE);
    if !path.exists() {
        return Ok(0);
    }
    let text = fs::read_to_string(&path)
        .with_context(|| format!("reading epoch file {}", path.display()))?;
    let j = Json::parse(text.trim()).context("parsing epoch file")?;
    ensure!(
        j.get("repl").and_then(Json::as_str) == Some(REPL_FORMAT),
        "{} is not a {REPL_FORMAT} epoch file",
        path.display()
    );
    let epoch = j
        .get("epoch")
        .and_then(Json::as_usize)
        .with_context(|| format!("{}: missing/invalid \"epoch\"", path.display()))?;
    Ok(epoch as u64)
}

/// Durably persist `epoch` into `dir` (write-temp + rename + dir
/// fsync, same discipline as the WAL manifest).
pub fn store_epoch(dir: &Path, epoch: u64) -> Result<()> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let path = dir.join(REPL_FILE);
    let tmp = dir.join(format!("{REPL_FILE}.tmp"));
    let body = format!("{{\"repl\":\"{REPL_FORMAT}\",\"epoch\":{epoch}}}\n");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(body.as_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, &path)
        .with_context(|| format!("renaming epoch file into {}", path.display()))?;
    if let Ok(d) = fs::File::open(dir) {
        let _ = d.sync_all(); // best-effort directory fsync (POSIX)
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Handshake lines

/// Geometry + epoch the primary advertises in `ROK`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HelloAck {
    pub rows: usize,
    pub q: usize,
    pub shards: usize,
    pub epoch: u64,
}

pub fn hello_line(epoch: u64) -> String {
    format!("RHELLO {REPL_FORMAT} epoch={epoch}")
}

pub fn parse_hello(line: &str) -> Result<u64> {
    let mut t = line.split_whitespace();
    ensure!(t.next() == Some("RHELLO"), "expected RHELLO, got {line:?}");
    ensure!(
        t.next() == Some(REPL_FORMAT),
        "unsupported repl protocol in {line:?} (this side speaks {REPL_FORMAT})"
    );
    let epoch = kv(t.next(), "epoch", line)?;
    Ok(epoch)
}

pub fn ok_line(rows: usize, q: usize, shards: usize, epoch: u64) -> String {
    format!("ROK {REPL_FORMAT} rows={rows} q={q} shards={shards} epoch={epoch}")
}

pub fn parse_ok(line: &str) -> Result<HelloAck> {
    if let Some(msg) = line.strip_prefix("RERR ") {
        bail!("primary refused the handshake: {msg}");
    }
    let mut t = line.split_whitespace();
    ensure!(t.next() == Some("ROK"), "expected ROK, got {line:?}");
    ensure!(
        t.next() == Some(REPL_FORMAT),
        "primary speaks a different repl protocol: {line:?}"
    );
    Ok(HelloAck {
        rows: kv(t.next(), "rows", line)? as usize,
        q: kv(t.next(), "q", line)? as usize,
        shards: kv(t.next(), "shards", line)? as usize,
        epoch: kv(t.next(), "epoch", line)?,
    })
}

pub fn start_line(epoch: u64, lsns: &[u64]) -> String {
    let lsns = lsns.iter().map(u64::to_string).collect::<Vec<_>>().join(",");
    format!("RSTART epoch={epoch} lsns={lsns}")
}

pub fn parse_start(line: &str) -> Result<(u64, Vec<u64>)> {
    let mut t = line.split_whitespace();
    ensure!(t.next() == Some("RSTART"), "expected RSTART, got {line:?}");
    let epoch = kv(t.next(), "epoch", line)?;
    let lsns_tok = t
        .next()
        .and_then(|s| s.strip_prefix("lsns="))
        .with_context(|| format!("missing lsns= in {line:?}"))?;
    let mut lsns = Vec::new();
    for part in lsns_tok.split(',') {
        let lsn: u64 = part
            .parse()
            .with_context(|| format!("bad lsn {part:?} in {line:?}"))?;
        ensure!(lsn >= 1, "lsn space starts at 1 (got {lsn} in {line:?})");
        lsns.push(lsn);
    }
    Ok((epoch, lsns))
}

pub fn err_line(msg: &str) -> String {
    // Keep the reply single-line whatever the error chain contains.
    format!("RERR {}", msg.replace('\n', "; "))
}

fn kv(tok: Option<&str>, key: &str, line: &str) -> Result<u64> {
    let tok = tok.with_context(|| format!("missing {key}= in {line:?}"))?;
    let val = tok
        .strip_prefix(key)
        .and_then(|s| s.strip_prefix('='))
        .with_context(|| format!("expected {key}=<n>, got {tok:?} in {line:?}"))?;
    val.parse::<u64>()
        .with_context(|| format!("bad {key} value {val:?} in {line:?}"))
}

// ---------------------------------------------------------------------------
// Binary stream records

/// Cumulative digest of every frame shipped for one shard on one
/// connection, emitted at segment boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentDigest {
    pub shard: u32,
    /// Highest LSN covered by this digest.
    pub upto_lsn: u64,
    /// Frames absorbed since the connection's start LSN.
    pub frames: u64,
    /// CRC32 over the concatenated frame bytes.
    pub crc: u32,
    /// FNV-1a chain value (seeded from shard + start LSN).
    pub fnv: u64,
}

/// One decoded post-handshake record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplRecord {
    /// A WAL frame plus the shipper's chain value after absorbing it.
    Frame { chain: u64, frame: Vec<u8> },
    /// Segment-boundary digest for one shard.
    Digest(SegmentDigest),
    /// Primary's durable tail LSN per shard.
    Heartbeat(Vec<u64>),
}

pub fn write_frame_record(w: &mut impl Write, chain: u64, frame: &[u8]) -> Result<()> {
    ensure!(
        frame.len() >= MIN_FRAME as usize && frame.len() <= 8 + MAX_PAYLOAD as usize,
        "refusing to ship an implausible {}-byte frame",
        frame.len()
    );
    w.write_all(&[b'F'])?;
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(&chain.to_le_bytes())?;
    w.write_all(frame)?;
    Ok(())
}

pub fn write_digest_record(w: &mut impl Write, d: &SegmentDigest) -> Result<()> {
    w.write_all(&[b'D'])?;
    w.write_all(&d.shard.to_le_bytes())?;
    w.write_all(&d.upto_lsn.to_le_bytes())?;
    w.write_all(&d.frames.to_le_bytes())?;
    w.write_all(&d.crc.to_le_bytes())?;
    w.write_all(&d.fnv.to_le_bytes())?;
    Ok(())
}

pub fn write_heartbeat(w: &mut impl Write, tails: &[u64]) -> Result<()> {
    ensure!(
        tails.len() <= MAX_HEARTBEAT_SHARDS as usize,
        "heartbeat for {} shards exceeds the sanity cap",
        tails.len()
    );
    w.write_all(&[b'H'])?;
    w.write_all(&(tails.len() as u32).to_le_bytes())?;
    for t in tails {
        w.write_all(&t.to_le_bytes())?;
    }
    Ok(())
}

/// Read one post-handshake record. Errors distinguish a clean read
/// failure (caller maps to a reconnect) from garbage tags/lengths
/// (unrecoverable stream corruption).
pub fn read_record(r: &mut impl Read) -> Result<ReplRecord> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag).context("reading repl record tag")?;
    match tag[0] {
        b'F' => {
            let len = read_u32(r)?;
            ensure!(
                (MIN_FRAME..=8 + MAX_PAYLOAD).contains(&len),
                "implausible shipped-frame length {len}"
            );
            let chain = read_u64(r)?;
            let mut frame = vec![0u8; len as usize];
            r.read_exact(&mut frame).context("reading shipped frame")?;
            Ok(ReplRecord::Frame { chain, frame })
        }
        b'D' => Ok(ReplRecord::Digest(SegmentDigest {
            shard: read_u32(r)?,
            upto_lsn: read_u64(r)?,
            frames: read_u64(r)?,
            crc: read_u32(r)?,
            fnv: read_u64(r)?,
        })),
        b'H' => {
            let n = read_u32(r)?;
            ensure!(
                n <= MAX_HEARTBEAT_SHARDS,
                "heartbeat claims {n} shards — stream corrupt"
            );
            let mut tails = Vec::with_capacity(n as usize);
            for _ in 0..n {
                tails.push(read_u64(r)?);
            }
            Ok(ReplRecord::Heartbeat(tails))
        }
        t => bail!("unknown repl record tag 0x{t:02x} — stream corrupt"),
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("reading repl record field")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("reading repl record field")?;
    Ok(u64::from_le_bytes(b))
}

// ---------------------------------------------------------------------------
// Shard chain digest

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Running digest over the exact frame bytes shipped for one shard.
/// Primary and follower each run one per shard per connection; the
/// FNV value travels with every frame, the CRC32 is cross-checked at
/// segment boundaries. Seeded from `(shard, start_lsn)` so resuming
/// from different cursors never aliases.
#[derive(Debug, Clone, Copy)]
pub struct ShardChain {
    fnv: u64,
    crc: Crc32,
    frames: u64,
}

impl ShardChain {
    pub fn new(shard: u32, start_lsn: u64) -> ShardChain {
        let mut fnv = FNV_OFFSET;
        for b in shard
            .to_le_bytes()
            .into_iter()
            .chain(start_lsn.to_le_bytes())
        {
            fnv = (fnv ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        ShardChain { fnv, crc: Crc32::new(), frames: 0 }
    }

    /// Fold one frame's bytes in; returns the new chain value.
    pub fn absorb(&mut self, frame: &[u8]) -> u64 {
        for &b in frame {
            self.fnv = (self.fnv ^ b as u64).wrapping_mul(FNV_PRIME);
        }
        self.crc = self.crc.update(frame);
        self.frames += 1;
        self.fnv
    }

    pub fn fnv(&self) -> u64 {
        self.fnv
    }

    pub fn crc(&self) -> u32 {
        self.crc.finish()
    }

    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Package the running state as a segment-boundary digest.
    pub fn digest(&self, shard: u32, upto_lsn: u64) -> SegmentDigest {
        SegmentDigest {
            shard,
            upto_lsn,
            frames: self.frames,
            crc: self.crc(),
            fnv: self.fnv,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handshake_lines_round_trip() {
        assert_eq!(parse_hello(&hello_line(7)).unwrap(), 7);
        let ack = parse_ok(&ok_line(1024, 8, 4, 3)).unwrap();
        assert_eq!(ack, HelloAck { rows: 1024, q: 8, shards: 4, epoch: 3 });
        let (epoch, lsns) = parse_start(&start_line(3, &[1, 17, 9])).unwrap();
        assert_eq!((epoch, lsns), (3, vec![1, 17, 9]));
        assert!(parse_ok(&err_line("no\nsuch luck")).unwrap_err().to_string().contains("no; such luck"));
        assert!(parse_hello("RHELLO fast-repl-v2 epoch=0").is_err());
        assert!(parse_start("RSTART epoch=0 lsns=0").is_err(), "lsn 0 is invalid");
    }

    #[test]
    fn binary_records_round_trip() {
        let frame = vec![0xAA; MIN_FRAME as usize];
        let digest =
            SegmentDigest { shard: 2, upto_lsn: 99, frames: 40, crc: 0xDEAD_BEEF, fnv: 12345 };
        let mut buf = Vec::new();
        write_frame_record(&mut buf, 777, &frame).unwrap();
        write_digest_record(&mut buf, &digest).unwrap();
        write_heartbeat(&mut buf, &[5, 6, 7]).unwrap();
        let mut r = &buf[..];
        assert_eq!(
            read_record(&mut r).unwrap(),
            ReplRecord::Frame { chain: 777, frame: frame.clone() }
        );
        assert_eq!(read_record(&mut r).unwrap(), ReplRecord::Digest(digest));
        assert_eq!(read_record(&mut r).unwrap(), ReplRecord::Heartbeat(vec![5, 6, 7]));
        assert!(r.is_empty());
        // A garbage tag is corruption, not EOF.
        let mut junk: &[u8] = &[0x42];
        assert!(read_record(&mut junk).unwrap_err().to_string().contains("tag"));
    }

    #[test]
    fn chains_are_deterministic_and_seed_sensitive() {
        let frames: Vec<Vec<u8>> = (0..4u8).map(|i| vec![i; 64]).collect();
        let mut a = ShardChain::new(1, 5);
        let mut b = ShardChain::new(1, 5);
        for f in &frames {
            let va = a.absorb(f);
            let vb = b.absorb(f);
            assert_eq!(va, vb);
        }
        assert_eq!(a.crc(), b.crc());
        assert_eq!(a.frames(), 4);
        // Same frames from a different start lsn or shard: different chain.
        let mut c = ShardChain::new(1, 6);
        let mut d = ShardChain::new(2, 5);
        for f in &frames {
            c.absorb(f);
            d.absorb(f);
        }
        assert_ne!(a.fnv(), c.fnv());
        assert_ne!(a.fnv(), d.fnv());
        // CRC ignores the seed by construction — that's WHY both travel.
        assert_eq!(a.crc(), c.crc());
        let dg = a.digest(1, 42);
        assert_eq!(dg.frames, 4);
        assert_eq!(dg.upto_lsn, 42);
        assert_eq!(dg.fnv, a.fnv());
    }

    #[test]
    fn epoch_file_round_trips_and_defaults_to_zero() {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let d = std::env::temp_dir().join(format!("fast-epoch-{}-{nanos}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        assert_eq!(load_epoch(&d).unwrap(), 0, "missing file means epoch 0");
        store_epoch(&d, 9).unwrap();
        assert_eq!(load_epoch(&d).unwrap(), 9);
        store_epoch(&d, 10).unwrap();
        assert_eq!(load_epoch(&d).unwrap(), 10);
        std::fs::write(d.join(REPL_FILE), "{\"repl\":\"other\",\"epoch\":1}\n").unwrap();
        assert!(load_epoch(&d).is_err());
        let _ = std::fs::remove_dir_all(&d);
    }
}
