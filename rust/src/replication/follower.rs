//! Follower side of WAL shipping: a background loop that keeps a
//! read-only engine caught up with a primary, survives wire faults by
//! reconnecting with capped exponential backoff + jitter, and
//! fail-stops the moment the histories provably diverge.
//!
//! The follower's own WAL dir is its durable cursor: every shipped
//! frame is verified (CRC + chained FNV), applied through the engine's
//! sealed-batch path, and thereby re-logged byte-identically by the
//! engine's WAL listener before the per-shard applied watermark
//! advances. A follower restart recovers that WAL like any crashed
//! primary would and resumes from `recovered watermark + 1` — no
//! side-channel state files.
//!
//! ## Error classification (the heart of the robustness story)
//!
//! - **Wire errors** — connect refusals, EOF, read timeouts, frame
//!   CRC failures, LSN gaps (dropped/reordered frames), truncated
//!   records, garbage tags, and the two stall proofs (a boundary
//!   digest past our watermark, or heartbeats showing durable frames
//!   past it with nothing arriving): nothing wrong was applied, so
//!   the loop reconnects and resumes from the durable watermark. Backoff
//!   doubles from `backoff_min` to `backoff_max` with uniform jitter,
//!   and resets after any successful apply.
//! - **Divergence** — a frame with a *valid* CRC whose FNV chain
//!   disagrees, a segment digest mismatch, a commit-seq mismatch
//!   during apply, a primary from an older epoch, a geometry
//!   mismatch, or a primary whose durable tail sits behind our
//!   applied watermark: reconnecting cannot heal a forked history.
//!   The loop records the reason, raises the fail-stop flag, and
//!   exits — a follower never serves state it cannot prove matches
//!   the primary's log.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{ensure, Context};

use crate::coordinator::UpdateEngine;
use crate::durability::wal::WalRecord;
use crate::util::crc32::crc32;
use crate::util::rng::Rng;
use crate::Result;

use super::protocol::{
    hello_line, load_epoch, parse_ok, read_record, start_line, store_epoch, ReplRecord, GO_LINE,
};
use super::{diverged, is_divergence, ReplStats, ShardChain};

/// Socket read timeout — bounds how long a stop request can go
/// unnoticed while blocked on the primary.
const READ_TIMEOUT: Duration = Duration::from_millis(200);
/// Consecutive heartbeats with the primary's tail behind our applied
/// watermark before we call it divergence (one transient heartbeat
/// can race a fresh cursor that has not scanned up to the tail yet).
const AHEAD_STRIKES: u8 = 2;
/// Consecutive heartbeats with durable frames past our watermark but
/// nothing arriving before we force a reconnect. Catches a tail-end
/// drop: when the *last* frame of a burst is lost on the wire, no
/// later frame ever exposes the LSN gap — only the heartbeat can.
const BEHIND_STRIKES: u8 = 3;

/// Reconnect/backoff tuning for [`spawn_follower`].
#[derive(Clone)]
pub struct FollowerOpts {
    pub backoff_min: Duration,
    pub backoff_max: Duration,
    /// Seeds the jitter RNG (determinism in tests).
    pub seed: u64,
    /// Raised when the follower fail-stops on divergence — serve wires
    /// its shutdown flag here so the process exits rather than keep
    /// answering reads for a replica it can no longer trust.
    pub on_fail_stop: Option<Arc<AtomicBool>>,
}

impl Default for FollowerOpts {
    fn default() -> FollowerOpts {
        FollowerOpts {
            backoff_min: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
            seed: 0x5EED,
            on_fail_stop: None,
        }
    }
}

/// A running follower loop. Reads are served by the engine at the
/// applied watermark; [`FollowerHandle::promote`] flips it to a
/// writable primary under a fresh fenced epoch.
pub struct FollowerHandle {
    pub stats: Arc<ReplStats>,
    engine: Arc<UpdateEngine>,
    wal_dir: PathBuf,
    stop: Arc<AtomicBool>,
    thread: Mutex<Option<thread::JoinHandle<()>>>,
}

/// Start replicating `engine` (which must be read-only and durable)
/// from the primary at `primary_addr`.
pub fn spawn_follower(
    engine: Arc<UpdateEngine>,
    wal_dir: PathBuf,
    primary_addr: String,
    opts: FollowerOpts,
) -> Result<Arc<FollowerHandle>> {
    ensure!(
        !engine.is_writable(),
        "follower mode requires a read-only engine (EngineConfig.read_only)"
    );
    let marks = engine
        .recovered_marks()
        .context("follower mode requires a durable engine (--wal-dir)")?
        .to_vec();
    let shards = engine.config().shards;
    ensure!(marks.len() == shards, "recovered {} marks for {shards} shards", marks.len());
    let stats = ReplStats::new("follower", shards);
    for (shard, mark) in marks.iter().enumerate() {
        stats.record_applied(shard, mark.lsn);
    }
    stats.epoch.store(load_epoch(&wal_dir)?, Ordering::Release);
    let handle = Arc::new(FollowerHandle {
        stats,
        engine,
        wal_dir,
        stop: Arc::new(AtomicBool::new(false)),
        thread: Mutex::new(None),
    });
    let looped = Arc::clone(&handle);
    let t = thread::Builder::new()
        .name("repl-follower".into())
        .spawn(move || follower_loop(&looped, &primary_addr, &opts))
        .context("spawning follower loop")?;
    *handle.thread.lock().expect("follower thread lock") = Some(t);
    Ok(handle)
}

impl FollowerHandle {
    /// Stop the loop and wait for it (idempotent).
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        let t = self.thread.lock().expect("follower thread lock").take();
        if let Some(t) = t {
            let _ = t.join();
        }
    }

    /// Fail-stop reason, if the follower detected divergence.
    pub fn failed(&self) -> Option<String> {
        self.stats.failed()
    }

    /// Highest applied LSN per shard (the read watermark).
    pub fn applied_lsns(&self) -> Vec<u64> {
        (0..self.engine.config().shards).map(|s| self.stats.applied_lsn(s)).collect()
    }

    /// Failover: stop tailing, force-seal, durably bump the epoch past
    /// the old primary's, and flip the engine writable. Returns the
    /// new epoch. Idempotent — promoting a promoted follower returns
    /// the current epoch.
    pub fn promote(&self) -> Result<u64> {
        self.stop();
        if self.engine.is_writable() {
            return load_epoch(&self.wal_dir);
        }
        // Nothing can be pending in read-only mode, but drain anyway:
        // it force-seals and proves every shard worker is alive before
        // we start taking writes.
        self.engine.drain_all().context("draining before promotion")?;
        let epoch = load_epoch(&self.wal_dir)? + 1;
        store_epoch(&self.wal_dir, epoch)
            .context("persisting the promotion epoch (refusing to accept writes unfenced)")?;
        self.engine.promote_writable();
        self.stats.set_role("primary");
        self.stats.epoch.store(epoch, Ordering::Release);
        self.stats.connected.store(false, Ordering::Release);
        eprintln!("fast serve: promoted to primary at epoch {epoch}");
        Ok(epoch)
    }
}

fn follower_loop(h: &FollowerHandle, primary: &str, opts: &FollowerOpts) {
    let mut rng = Rng::new(opts.seed);
    let mut backoff = opts.backoff_min;
    while !h.stop.load(Ordering::Acquire) {
        let applied_before = h.stats.frames_applied.load(Ordering::Relaxed);
        let res = run_once(h, primary);
        h.stats.connected.store(false, Ordering::Release);
        match res {
            Ok(()) => break, // stop requested
            Err(e) if is_divergence(&e) => {
                let msg = format!("{e:#}");
                eprintln!("fast serve: follower FAIL-STOP: {msg}");
                h.stats.fail(msg);
                if let Some(flag) = &opts.on_fail_stop {
                    flag.store(true, Ordering::Release);
                }
                break;
            }
            Err(_) => {
                h.stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                if h.stop.load(Ordering::Acquire) {
                    break;
                }
                if h.stats.frames_applied.load(Ordering::Relaxed) > applied_before {
                    backoff = opts.backoff_min; // progress resets backoff
                }
                let jitter_ms = rng.below(backoff.as_millis() as u64 / 2 + 1);
                thread::sleep(backoff + Duration::from_millis(jitter_ms));
                backoff = (backoff * 2).min(opts.backoff_max);
                h.stats.reconnects.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// One connection lifetime: handshake, then verify/apply until the
/// wire breaks (`Err`, wire), divergence (`Err`, typed), or stop
/// (`Ok`).
fn run_once(h: &FollowerHandle, primary: &str) -> Result<()> {
    let conn = TcpStream::connect(primary)
        .with_context(|| format!("connecting to primary {primary}"))?;
    conn.set_nodelay(true)?;
    conn.set_read_timeout(Some(READ_TIMEOUT))?;
    let mut r = BufReader::new(conn.try_clone()?);
    let mut w = BufWriter::new(conn);

    let local_epoch = load_epoch(&h.wal_dir)?;
    writeln!(w, "{}", hello_line(local_epoch))?;
    w.flush()?;
    let mut line = String::new();
    r.read_line(&mut line).context("reading handshake ack")?;
    ensure!(!line.is_empty(), "primary closed during handshake");
    // A refusal or a non-repl speaker on that address is actionable,
    // not retryable: surface it as a fail-stop.
    let ack = parse_ok(line.trim_end()).map_err(|e| diverged(format!("{e:#}")))?;
    let cfg = h.engine.config();
    if ack.rows != cfg.rows || ack.q != cfg.q || ack.shards != cfg.shards {
        return Err(diverged(format!(
            "geometry mismatch: primary is rows={} q={} shards={}, follower is rows={} q={} shards={}",
            ack.rows, ack.q, ack.shards, cfg.rows, cfg.q, cfg.shards
        )));
    }
    if ack.epoch < local_epoch {
        return Err(diverged(format!(
            "primary epoch {} is OLDER than ours ({local_epoch}) — that primary was fenced by a \
             promotion; point this follower at the promoted primary",
            ack.epoch
        )));
    }
    if ack.epoch > local_epoch {
        store_epoch(&h.wal_dir, ack.epoch).context("adopting the primary's epoch")?;
    }
    h.stats.epoch.store(ack.epoch, Ordering::Release);

    let shards = cfg.shards;
    // expected[s] = next LSN to apply, resumed from the durable
    // watermark (survives both reconnects and follower restarts).
    let mut expected: Vec<u64> = (0..shards).map(|s| h.stats.applied_lsn(s) + 1).collect();
    writeln!(w, "{}", start_line(ack.epoch, &expected))?;
    w.flush()?;
    line.clear();
    r.read_line(&mut line).context("reading stream go-ahead")?;
    if line.trim_end() != GO_LINE {
        return Err(diverged(format!("primary refused the cursor: {}", line.trim_end())));
    }
    h.stats.connected.store(true, Ordering::Release);

    let mut chains: Vec<ShardChain> =
        (0..shards).map(|s| ShardChain::new(s as u32, expected[s])).collect();
    let mut ahead_strikes = vec![0u8; shards];
    let mut behind_strikes = vec![0u8; shards];

    loop {
        if h.stop.load(Ordering::Acquire) {
            return Ok(());
        }
        let rec = match read_record(&mut r) {
            Ok(rec) => rec,
            Err(e) => {
                if let Some(io) = e.root_cause().downcast_ref::<std::io::Error>() {
                    if matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        continue; // quiet stream; re-check stop and wait on
                    }
                }
                return Err(e); // wire: EOF, reset, garbage tag/length
            }
        };
        match rec {
            ReplRecord::Frame { chain, frame } => {
                apply_frame(h, &mut expected, &mut chains, chain, &frame)?;
                ahead_strikes.fill(0);
                behind_strikes.fill(0);
            }
            ReplRecord::Digest(d) => {
                let shard = d.shard as usize;
                if shard >= shards {
                    return Err(diverged(format!("digest for shard {shard} of {shards}")));
                }
                // A boundary digest past our watermark means the
                // frames leading up to it never arrived — wire loss,
                // not divergence: reconnect and resume. (After the
                // resume both sides re-seed their chains from the new
                // cursor, so the next boundary compares cleanly.)
                ensure!(
                    d.upto_lsn <= expected[shard] - 1,
                    "shard {shard}: segment digest at lsn {} arrived with our watermark at {} — \
                     frames were lost on the wire",
                    d.upto_lsn,
                    expected[shard] - 1
                );
                let local = chains[shard].digest(d.shard, expected[shard] - 1);
                if local != d {
                    return Err(diverged(format!(
                        "segment digest mismatch on shard {shard}: primary upto_lsn={} \
                         frames={} crc={:#010x} fnv={:#018x}, follower upto_lsn={} frames={} \
                         crc={:#010x} fnv={:#018x} — the logs differ; re-seed this follower",
                        d.upto_lsn, d.frames, d.crc, d.fnv,
                        local.upto_lsn, local.frames, local.crc, local.fnv
                    )));
                }
                h.stats.digests_verified.fetch_add(1, Ordering::Relaxed);
            }
            ReplRecord::Heartbeat(tails) => {
                if tails.len() != shards {
                    return Err(diverged(format!(
                        "heartbeat covers {} shards, expected {shards}",
                        tails.len()
                    )));
                }
                for (shard, &tail) in tails.iter().enumerate() {
                    h.stats.record_primary_tail(shard, tail);
                    let applied = expected[shard] - 1;
                    if tail > applied {
                        // Durable frames exist past our watermark and
                        // the primary has gone idle (heartbeats only
                        // flow on an idle stream): the tail of the
                        // burst was dropped on the wire and no later
                        // frame will ever expose the gap. Reconnect
                        // and resume from the watermark.
                        ahead_strikes[shard] = 0;
                        behind_strikes[shard] += 1;
                        ensure!(
                            behind_strikes[shard] < BEHIND_STRIKES,
                            "shard {shard}: durable tail {tail} sits past our applied watermark \
                             {applied} with no frames arriving — the stream lost its tail; \
                             reconnecting"
                        );
                    } else if tail > 0 && tail < applied {
                        // tail == 0 means the cursor has not scanned
                        // data yet — not evidence of lost history.
                        behind_strikes[shard] = 0;
                        ahead_strikes[shard] += 1;
                        if ahead_strikes[shard] >= AHEAD_STRIKES {
                            return Err(diverged(format!(
                                "primary's durable tail {tail} is behind our applied watermark \
                                 {applied} on shard {shard} — the primary lost history (restored \
                                 from an older backup?); re-seed or re-point this follower"
                            )));
                        }
                    } else {
                        ahead_strikes[shard] = 0;
                        behind_strikes[shard] = 0;
                    }
                }
            }
        }
    }
}

/// Verify one shipped frame and apply it at the watermark.
fn apply_frame(
    h: &FollowerHandle,
    expected: &mut [u64],
    chains: &mut [ShardChain],
    chain: u64,
    frame: &[u8],
) -> Result<()> {
    // Wire-integrity first: a bad CRC is line damage, reconnect heals it.
    ensure!(frame.len() >= 8, "shipped frame shorter than its header");
    let len = u32::from_le_bytes(frame[..4].try_into().expect("4 bytes")) as usize;
    let crc = u32::from_le_bytes(frame[4..8].try_into().expect("4 bytes"));
    ensure!(
        frame.len() == 8 + len,
        "shipped frame length {} disagrees with its header ({len})",
        frame.len() - 8
    );
    ensure!(crc32(&frame[8..]) == crc, "shipped frame failed its CRC — wire corruption");
    // From here the bytes are *internally* consistent: any mismatch is
    // a forged/foreign history, not line noise.
    let rec = WalRecord::decode(&frame[8..])
        .map_err(|e| diverged(format!("valid-CRC frame failed to decode: {e:#}")))?;
    let shard = rec.shard as usize;
    if shard >= expected.len() {
        return Err(diverged(format!("frame for shard {shard} of {}", expected.len())));
    }
    if rec.lsn < expected[shard] {
        // Replay/duplicate below the watermark: already durable here.
        h.stats.dup_frames.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
    ensure!(
        rec.lsn == expected[shard],
        "shard {shard}: shipped lsn {} skips ahead of expected {} — dropped frames on the wire",
        rec.lsn,
        expected[shard]
    );
    let ours = chains[shard].absorb(frame);
    if ours != chain {
        return Err(diverged(format!(
            "FNV chain mismatch on shard {shard} at lsn {}: primary {chain:#018x}, follower \
             {ours:#018x} — the histories fork at this frame; re-seed this follower",
            rec.lsn
        )));
    }
    let lsn = rec.lsn;
    h.engine
        .apply_replicated(rec)
        .map_err(|e| diverged(format!("shard {shard} lsn {lsn}: apply failed: {e:#}")))?;
    expected[shard] += 1;
    h.stats.record_applied(shard, lsn);
    h.stats.record_primary_tail(shard, lsn);
    h.stats.frames_applied.fetch_add(1, Ordering::Relaxed);
    Ok(())
}
