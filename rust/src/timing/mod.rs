//! Clocking and timing analysis (paper Figs. 3b and 13).
//!
//! - [`clocks`] — two-phase non-overlapping clock + φ2d delayer
//! - [`shmoo`] — VDD × frequency pass/fail sweep of the shift protocol

pub mod clocks;
pub mod shmoo;

pub use clocks::{ClockConfig, ClockError, ClockGen, Edge, PhaseLevels, Signal};
pub use shmoo::{ShmooConfig, ShmooGrid, ShmooModel};
