//! Shmoo plot generation (paper Fig. 13): sweep supply voltage ×
//! clock frequency and mark pass/fail of the shift protocol.
//!
//! Pass criteria (both must hold):
//!  1. *Speed*: the requested clock period must exceed the critical
//!     path at that VDD — the alpha-power-law `f_max` calibrated to the
//!     two measured silicon points (800 MHz @ 1.0 V, 1.2 GHz @ 1.2 V).
//!  2. *Retention*: the dynamic node must hold its charge above the
//!     inverter trip point for the open-loop window (phase 1 + phase 2
//!     margins). At very low frequencies the φ1 window grows and the
//!     remnant charge leaks away — the classic dynamic-logic *minimum*
//!     frequency, taken from the analog leakage model.

use crate::analog::leak::RetentionModel;
use crate::energy::TechParams;

/// One shmoo sweep configuration.
#[derive(Debug, Clone)]
pub struct ShmooConfig {
    pub vdd_min: f64,
    pub vdd_max: f64,
    pub vdd_steps: usize,
    pub freq_min_ghz: f64,
    pub freq_max_ghz: f64,
    pub freq_steps: usize,
}

impl Default for ShmooConfig {
    fn default() -> Self {
        ShmooConfig {
            vdd_min: 0.7,
            vdd_max: 1.3,
            vdd_steps: 13,
            freq_min_ghz: 0.2,
            freq_max_ghz: 2.0,
            freq_steps: 19,
        }
    }
}

/// Result grid: `pass[vi][fi]` for voltage index vi, frequency index fi.
#[derive(Debug, Clone)]
pub struct ShmooGrid {
    pub vdds: Vec<f64>,
    pub freqs_ghz: Vec<f64>,
    pub pass: Vec<Vec<bool>>,
}

impl ShmooGrid {
    /// ASCII render, voltage rows (high at top), frequency columns.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("  VDD \\ f(GHz)  ");
        for f in &self.freqs_ghz {
            out.push_str(&format!("{f:>5.2}"));
        }
        out.push('\n');
        for (vi, v) in self.vdds.iter().enumerate().rev() {
            out.push_str(&format!("  {v:>6.2} V     "));
            for p in &self.pass[vi] {
                out.push_str(if *p { "    +" } else { "    ." });
            }
            out.push('\n');
        }
        out.push_str("  ('+' pass, '.' fail)\n");
        out
    }

    /// Max passing frequency at the given VDD (linear scan).
    pub fn max_pass_freq(&self, vdd: f64) -> Option<f64> {
        let vi = self
            .vdds
            .iter()
            .position(|v| (v - vdd).abs() < 1e-9)?;
        self.freqs_ghz
            .iter()
            .zip(&self.pass[vi])
            .filter(|(_, &p)| p)
            .map(|(f, _)| *f)
            .fold(None, |acc, f| Some(acc.map_or(f, |a: f64| a.max(f))))
    }
}

/// The shmoo model: speed limit from TechParams, retention limit from
/// the analog leakage model.
#[derive(Debug, Clone)]
pub struct ShmooModel {
    pub tech: TechParams,
    pub retention: RetentionModel,
}

impl Default for ShmooModel {
    fn default() -> Self {
        ShmooModel {
            tech: TechParams::default(),
            retention: RetentionModel::default(),
        }
    }
}

impl ShmooModel {
    /// Does the shift protocol pass at (vdd, freq)?
    pub fn passes(&self, vdd: f64, freq_ghz: f64) -> bool {
        if freq_ghz <= 0.0 {
            return false;
        }
        // Speed: requested frequency under the critical-path limit
        // (tiny tolerance so the calibrated silicon points sit exactly
        // on the boundary).
        if freq_ghz > self.tech.f_max_ghz(vdd) * (1.0 + 1e-9) {
            return false;
        }
        // Retention: open-loop window (≈ half period) must not exceed
        // the retention time at this supply.
        let half_period_ns = 0.5 / freq_ghz;
        let t_ret_ns = self.retention.retention_ns(vdd);
        half_period_ns < t_ret_ns
    }

    /// Sweep the full grid.
    pub fn sweep(&self, cfg: &ShmooConfig) -> ShmooGrid {
        let lin = |lo: f64, hi: f64, n: usize| -> Vec<f64> {
            if n == 1 {
                return vec![lo];
            }
            (0..n)
                .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
                .collect()
        };
        let vdds = lin(cfg.vdd_min, cfg.vdd_max, cfg.vdd_steps);
        let freqs = lin(cfg.freq_min_ghz, cfg.freq_max_ghz, cfg.freq_steps);
        let pass = vdds
            .iter()
            .map(|&v| freqs.iter().map(|&f| self.passes(v, f)).collect())
            .collect();
        ShmooGrid { vdds, freqs_ghz: freqs, pass }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silicon_points_pass() {
        let m = ShmooModel::default();
        // Measured: 800 MHz @ 1.0 V and 1.2 GHz @ 1.2 V.
        assert!(m.passes(1.0, 0.8));
        assert!(m.passes(1.2, 1.2));
    }

    #[test]
    fn beyond_silicon_points_fail() {
        let m = ShmooModel::default();
        assert!(!m.passes(1.0, 0.9));
        assert!(!m.passes(1.2, 1.3));
    }

    #[test]
    fn higher_vdd_passes_higher_freq() {
        let m = ShmooModel::default();
        let cfg = ShmooConfig::default();
        let grid = m.sweep(&cfg);
        let f10 = grid.max_pass_freq(1.0).unwrap();
        let f12 = grid.max_pass_freq(1.2).unwrap();
        assert!(f12 > f10, "f_max(1.2V)={f12} <= f_max(1.0V)={f10}");
    }

    #[test]
    fn pass_region_is_contiguous_in_freq() {
        // For each VDD row, passes form a contiguous band (no holes):
        // fail — pass — fail as frequency rises.
        let m = ShmooModel::default();
        let grid = m.sweep(&ShmooConfig::default());
        for row in &grid.pass {
            let mut transitions = 0;
            for w in row.windows(2) {
                if w[0] != w[1] {
                    transitions += 1;
                }
            }
            assert!(transitions <= 2, "non-contiguous pass band: {row:?}");
        }
    }

    #[test]
    fn render_contains_markers() {
        let m = ShmooModel::default();
        let grid = m.sweep(&ShmooConfig::default());
        let s = grid.render();
        assert!(s.contains('+') && s.contains('.'));
    }

    #[test]
    fn very_low_vdd_fails_everything() {
        let m = ShmooModel::default();
        for f in [0.2, 0.5, 1.0] {
            assert!(!m.passes(0.4, f));
        }
    }
}
