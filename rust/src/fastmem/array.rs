//! The FAST macro: R rows × C columns of shiftable cells with per-row
//! (per-segment) 1-bit ALUs — the paper's showcase is 128×16.
//!
//! The defining property: a *batch operation* applies one q-bit op with
//! write-back to **every enabled row simultaneously** in q shift cycles,
//! independent of the row count (Fig. 1b). Conventional access (read/
//! write through the bitlines) is still available row by row, exactly
//! like a normal SRAM.
//!
//! The model is phase-accurate: batch ops step all rows through the
//! φ1/φ2/φ2d protocol cell by cell, so protocol bugs (hazards, carry
//! timing) surface as errors rather than silently producing word-level
//! arithmetic. Tests cross-check results against `util::bits` word
//! semantics, and `cargo test` integration tests cross-check against
//! the XLA-executed Pallas artifacts.

use std::fmt;

use super::alu::AluOp;
use super::bitplane::BitPlaneArray;
use super::cell::CellError;
use super::route::{RouteError, RouteFabric};
use super::row::{CycleStats, Row};

/// Fidelity tier of the software datapath. All three tiers compute the
/// same values and the same [`BatchReport`] activity numbers (enforced
/// by differential tests); they trade modeling depth for speed:
///
/// - [`Fidelity::PhaseAccurate`] steps every cell through φ1/φ2/φ2d —
///   protocol bugs surface as hard errors. ~100× slower than word-fast.
/// - [`Fidelity::WordFast`] computes each row's shift loop with word
///   arithmetic but still walks rows one by one: O(rows · width).
/// - [`Fidelity::BitPlane`] stores the array transposed as bitplanes
///   (64 rows per machine word) and executes a batch in
///   O(width · rows/64) word ops — the software mirror of the
///   hardware's all-rows-at-once concurrency. Conventional-port
///   access lazily transposes in/out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    PhaseAccurate,
    WordFast,
    BitPlane,
}

impl Fidelity {
    /// Parse a CLI spelling (`phase`, `word`, `bitplane`).
    pub fn parse(s: &str) -> Option<Fidelity> {
        match s {
            "phase" | "phase-accurate" => Some(Fidelity::PhaseAccurate),
            "word" | "word-fast" => Some(Fidelity::WordFast),
            "bitplane" | "bit-plane" => Some(Fidelity::BitPlane),
            _ => None,
        }
    }

    /// Tier selected by the `FAST_TEST_FIDELITY` env var (the CI test
    /// matrix runs the suite once per tier), falling back to `default`
    /// when unset or unparseable. Tests that are not explicitly
    /// tier-parametric use this for their engines so the matrix leg
    /// exercises every tier end to end.
    pub fn from_env_or(default: Fidelity) -> Fidelity {
        std::env::var("FAST_TEST_FIDELITY")
            .ok()
            .and_then(|s| Self::parse(&s))
            .unwrap_or(default)
    }
}

impl fmt::Display for Fidelity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Fidelity::PhaseAccurate => "phase",
            Fidelity::WordFast => "word",
            Fidelity::BitPlane => "bitplane",
        })
    }
}

#[derive(Debug)]
pub enum ArrayError {
    /// Row index out of range (index, rows).
    RowOutOfRange(usize, usize),
    /// Segment index out of range (index, segments).
    SegmentOutOfRange(usize, usize),
    /// Operand count != enabled word count.
    OperandCount(usize, usize),
    /// A cell-level protocol violation surfaced through a batch op.
    Cell(CellError),
    /// A width-reconfiguration request was invalid.
    Route(RouteError),
}

impl fmt::Display for ArrayError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArrayError::RowOutOfRange(r, rows) => {
                write!(f, "row index {r} out of range (rows = {rows})")
            }
            ArrayError::SegmentOutOfRange(s, n) => {
                write!(f, "segment index {s} out of range (segments = {n})")
            }
            ArrayError::OperandCount(got, want) => {
                write!(f, "operand count {got} != enabled word count {want}")
            }
            ArrayError::Cell(e) => write!(f, "cell protocol error: {e}"),
            ArrayError::Route(e) => write!(f, "routing error: {e}"),
        }
    }
}

impl std::error::Error for ArrayError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArrayError::Cell(e) => Some(e),
            ArrayError::Route(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CellError> for ArrayError {
    fn from(e: CellError) -> Self {
        ArrayError::Cell(e)
    }
}

impl From<RouteError> for ArrayError {
    fn from(e: RouteError) -> Self {
        ArrayError::Route(e)
    }
}

/// Aggregate report for one batch operation (energy-model inputs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchReport {
    /// Shift cycles executed (== max segment width).
    pub cycles: u64,
    /// Rows that participated.
    pub rows_active: u64,
    /// Total cell toggles across the batch.
    pub cell_toggles: u64,
    /// Total 1-bit ALU evaluations across the batch.
    pub alu_evals: u64,
}

/// The FAST macro model.
#[derive(Debug, Clone)]
pub struct FastArray {
    rows: Vec<Row>,
    fabric: RouteFabric,
    /// Current uniform logical word width.
    word_width: usize,
    op: AluOp,
    /// Fidelity tier batch ops execute at (see [`Fidelity`]).
    fidelity: Fidelity,
    /// Bit-sliced mirror of the cell state (BitPlane tier), built
    /// lazily on the first batch op after a conventional-port access.
    plane: Option<BitPlaneArray>,
    /// True while the planes hold the current data and the cells are
    /// stale (the cells are refreshed on the next port access).
    plane_authoritative: bool,
    /// Cell toggles accounted by plane-path batches (the cells' own
    /// counters only see phase/word-path activity).
    plane_toggles: u64,
    /// Lifetime counters for conventional-port accesses (energy model).
    port_reads: u64,
    port_writes: u64,
    /// Lifetime batch-op counters.
    batch_ops: u64,
    batch_cycles: u64,
    // Scratch buffers owned by the array so the batch hot path never
    // allocates (operand expansion, multiply addends, transpose I/O).
    scratch_full: Vec<u32>,
    scratch_words: Vec<u32>,
    scratch_addends: Vec<u32>,
    scratch_multiplicands: Vec<u32>,
}

impl FastArray {
    /// A macro with `rows` rows of `width` cells, one word per row
    /// (the paper's configuration: 128 rows × 16 columns, Add ALU).
    pub fn new(rows: usize, width: usize) -> Self {
        Self::with_fabric(rows, RouteFabric::new(width, width), width, AluOp::Add)
            .expect("trivial fabric plan cannot fail")
    }

    /// Full control: routing fabric, initial word width and ALU op.
    pub fn with_fabric(
        rows: usize,
        fabric: RouteFabric,
        word_width: usize,
        op: AluOp,
    ) -> Result<Self, ArrayError> {
        assert!(rows >= 1, "array needs at least one row");
        let widths = fabric.plan(word_width)?;
        let rows_v = (0..rows)
            .map(|_| Row::with_segments(&widths, op))
            .collect();
        Ok(FastArray {
            rows: rows_v,
            fabric,
            word_width,
            op,
            fidelity: Fidelity::WordFast,
            plane: None,
            plane_authoritative: false,
            plane_toggles: 0,
            port_reads: 0,
            port_writes: 0,
            batch_ops: 0,
            batch_cycles: 0,
            scratch_full: Vec::new(),
            scratch_words: Vec::new(),
            scratch_addends: Vec::new(),
            scratch_multiplicands: Vec::new(),
        })
    }

    /// A `rows` × `width` macro running batch ops at the given
    /// [`Fidelity`] tier.
    pub fn with_fidelity(rows: usize, width: usize, fidelity: Fidelity) -> Self {
        let mut a = Self::new(rows, width);
        a.fidelity = fidelity;
        a
    }

    pub fn fidelity(&self) -> Fidelity {
        self.fidelity
    }

    /// Switch fidelity tiers in place. Data is preserved: leaving the
    /// bit-plane tier transposes the planes back into the cells.
    pub fn set_fidelity(&mut self, fidelity: Fidelity) {
        if fidelity != Fidelity::BitPlane {
            self.ensure_rows();
        }
        self.fidelity = fidelity;
    }

    /// Transpose plane state back into the cells if the planes are
    /// authoritative (no-op otherwise). Uses the same
    /// toggle-counter-neutral path as the word-fast model's
    /// `force_state`.
    fn ensure_rows(&mut self) {
        if !self.plane_authoritative {
            return;
        }
        let plane = self
            .plane
            .as_ref()
            .expect("plane_authoritative implies plane exists");
        let rows = &mut self.rows;
        plane.export_to(|r, s, w| rows[r].force_word(s, w));
        self.plane_authoritative = false;
    }

    /// Build (or refresh) the bit-plane mirror from the cells. Errors
    /// if any cell is mid-shift (a previously failed phase-accurate
    /// batch left the loop open).
    fn ensure_planes(&mut self) -> Result<(), ArrayError> {
        if self.plane_authoritative {
            return Ok(());
        }
        let widths = self.rows[0].segment_widths();
        let need_new = match &self.plane {
            Some(p) => p.rows() != self.rows.len() || p.segment_widths() != widths,
            None => true,
        };
        if need_new {
            self.plane = Some(BitPlaneArray::new(self.rows.len(), &widths));
        }
        let wpr = widths.len();
        let mut words = std::mem::take(&mut self.scratch_words);
        words.clear();
        let mut result = Ok(());
        'read: for row in &self.rows {
            for s in 0..wpr {
                match row.read_word(s) {
                    Ok(w) => words.push(w),
                    Err(e) => {
                        result = Err(ArrayError::Cell(e));
                        break 'read;
                    }
                }
            }
        }
        if result.is_ok() {
            let plane = self.plane.as_mut().expect("just ensured");
            plane.fill_from(|r, s| words[r * wpr + s]);
            self.plane_authoritative = true;
        }
        self.scratch_words = words;
        result
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    /// Physical columns per row.
    pub fn cols(&self) -> usize {
        self.fabric.row_width
    }

    /// Current logical word width q.
    pub fn word_width(&self) -> usize {
        self.word_width
    }

    /// Logical words per row at the current width.
    pub fn words_per_row(&self) -> usize {
        self.fabric.row_width / self.word_width
    }

    pub fn op(&self) -> AluOp {
        self.op
    }

    pub fn fabric(&self) -> RouteFabric {
        self.fabric
    }

    /// Reconfigure the ALU operation on every row (Section III.E).
    pub fn set_op(&mut self, op: AluOp) {
        self.op = op;
        for r in &mut self.rows {
            r.set_op(op);
        }
    }

    /// Reconfigure the logical word width via the routing unit
    /// (Fig. 5c). Data is preserved bit-wise. Returns control cycles
    /// spent re-latching routes.
    pub fn reconfigure_width(&mut self, width: usize) -> Result<u64, ArrayError> {
        let widths = self.fabric.plan(width)?;
        let cost = self.fabric.reconfig_cycles(self.word_width, width)?;
        // The routing unit reconnects shift lines between statically
        // held cells; the plane mirror's segment shape is invalidated.
        self.ensure_rows();
        self.plane = None;
        for r in &mut self.rows {
            r.reconfigure_segments(&widths, self.op)?;
        }
        self.word_width = width;
        Ok(cost)
    }

    fn check_row(&self, row: usize) -> Result<(), ArrayError> {
        if row >= self.rows.len() {
            return Err(ArrayError::RowOutOfRange(row, self.rows.len()));
        }
        Ok(())
    }

    fn check_seg(&self, seg: usize) -> Result<(), ArrayError> {
        let n = self.words_per_row();
        if seg >= n {
            return Err(ArrayError::SegmentOutOfRange(seg, n));
        }
        Ok(())
    }

    /// Conventional-port read of word `seg` in `row`.
    pub fn read_word(&mut self, row: usize, seg: usize) -> Result<u32, ArrayError> {
        self.check_row(row)?;
        self.check_seg(seg)?;
        self.ensure_rows();
        self.port_reads += 1;
        Ok(self.rows[row].read_word(seg)?)
    }

    /// Conventional-port write of word `seg` in `row`.
    pub fn write_word(&mut self, row: usize, seg: usize, word: u32) -> Result<(), ArrayError> {
        self.check_row(row)?;
        self.check_seg(seg)?;
        self.ensure_rows();
        self.port_writes += 1;
        Ok(self.rows[row].write_word(seg, word)?)
    }

    /// Non-counting write of word `seg` in `row`: the restore path of
    /// durability recovery, which replays pre-crash state into the
    /// array without pretending the workload issued conventional-port
    /// writes — port counters and cell toggle counters stay untouched
    /// (same contract as [`Self::peek_word`] on the read side; the
    /// cells are overwritten via the toggle-neutral `force_word`).
    pub fn poke_word(&mut self, row: usize, seg: usize, word: u32) -> Result<(), ArrayError> {
        self.check_row(row)?;
        self.check_seg(seg)?;
        self.ensure_rows();
        self.rows[row].force_word(seg, word);
        Ok(())
    }

    /// Non-counting read of word `seg` in `row`: a harness/verification
    /// accessor that leaves the conventional-port counters untouched,
    /// so energy accounting keeps modeling the workload rather than the
    /// test rig. Works in every fidelity tier without forcing a
    /// transpose.
    pub fn peek_word(&self, row: usize, seg: usize) -> Result<u32, ArrayError> {
        self.check_row(row)?;
        self.check_seg(seg)?;
        if self.plane_authoritative {
            Ok(self
                .plane
                .as_ref()
                .expect("plane_authoritative implies plane exists")
                .read_word(row, seg))
        } else {
            Ok(self.rows[row].read_word(seg)?)
        }
    }

    /// Non-counting snapshot of every row's word 0 (cf.
    /// [`Self::snapshot`], which models real conventional-port reads
    /// and counts them).
    pub fn peek_rows(&self) -> Vec<u32> {
        (0..self.rows())
            .map(|r| self.peek_word(r, 0).expect("row in range"))
            .collect()
    }

    /// Convenience single-word-per-row accessors (seg 0).
    pub fn read_row(&mut self, row: usize) -> u32 {
        self.read_word(row, 0).expect("row in range")
    }

    pub fn write_row(&mut self, row: usize, word: u32) {
        self.write_word(row, 0, word).expect("row in range")
    }

    /// Fully-concurrent batch op over **all** rows, one operand word per
    /// row (seg 0 of each row). The paper's headline operation.
    pub fn batch_add(&mut self, operands: &[u32]) -> BatchReport {
        self.set_op(AluOp::Add);
        self.batch_apply_all(operands).expect("uniform batch cannot fail")
    }

    pub fn batch_sub(&mut self, operands: &[u32]) -> BatchReport {
        self.set_op(AluOp::Sub);
        self.batch_apply_all(operands).expect("uniform batch cannot fail")
    }

    pub fn batch_logic(&mut self, op: AluOp, operands: &[u32]) -> BatchReport {
        assert!(matches!(op, AluOp::And | AluOp::Or | AluOp::Xor));
        self.set_op(op);
        self.batch_apply_all(operands).expect("uniform batch cannot fail")
    }

    /// Fully-concurrent batch multiply: `row[r] <- row[r] * m[r] mod 2^q`.
    ///
    /// The paper's Section III.E future work ("integer multiplier")
    /// realized with the *existing* datapath: shift-and-add. The stored
    /// value is first moved out as the multiplicand (one rotate-read),
    /// the accumulator is cleared, then q conditional batch adds feed
    /// `multiplicand << t` into rows whose multiplier bit t is set.
    /// Cost: q + 1 batch ops = q·(q+1) shift cycles — quadratic, as
    /// bit-serial multiply must be, but still row-parallel.
    pub fn batch_mul(&mut self, multipliers: &[u32]) -> Result<BatchReport, ArrayError> {
        if multipliers.len() != self.rows.len() {
            return Err(ArrayError::OperandCount(multipliers.len(), self.rows.len()));
        }
        let q = self.word_width;
        let m = crate::util::bits::mask(q);
        // Read out multiplicands (conventional port, counted). Both
        // working buffers are owned scratch — no per-call or
        // per-multiplier-bit allocation.
        let mut multiplicands = std::mem::take(&mut self.scratch_multiplicands);
        let mut addends = std::mem::take(&mut self.scratch_addends);
        multiplicands.clear();
        for r in 0..self.rows.len() {
            multiplicands.push(self.read_row(r));
        }
        let result = (|| -> Result<BatchReport, ArrayError> {
            // Clear accumulators: one XOR batch with the value itself
            // (x ^ x = 0) — stays on the shift datapath, no bitline
            // writes.
            self.set_op(AluOp::Xor);
            let mut total = self.batch_apply_all(&multiplicands)?;
            // q conditional adds of the shifted multiplicand.
            self.set_op(AluOp::Add);
            for t in 0..q {
                addends.clear();
                addends.extend(multiplicands.iter().zip(multipliers).map(
                    |(&mc, &mult)| {
                        if (mult >> t) & 1 == 1 {
                            (mc << t) & m
                        } else {
                            0
                        }
                    },
                ));
                let rep = self.batch_apply_all(&addends)?;
                total.cycles += rep.cycles;
                total.cell_toggles += rep.cell_toggles;
                total.alu_evals += rep.alu_evals;
            }
            total.rows_active = self.rows.len() as u64;
            Ok(total)
        })();
        self.scratch_multiplicands = multiplicands;
        self.scratch_addends = addends;
        result
    }

    /// Batch op where each row receives one operand per word segment:
    /// `operands[row * words_per_row + seg]`.
    ///
    /// Executes at the array's [`Fidelity`] tier; all tiers produce
    /// identical values and identical [`BatchReport`] activity numbers
    /// (differential-tested — see `batch_apply_segmented_exact` and
    /// `tests/integration_fidelity.rs`).
    pub fn batch_apply_segmented(&mut self, operands: &[u32]) -> Result<BatchReport, ArrayError> {
        let wpr = self.words_per_row();
        let expected = self.rows.len() * wpr;
        if operands.len() != expected {
            return Err(ArrayError::OperandCount(operands.len(), expected));
        }
        match self.fidelity {
            Fidelity::PhaseAccurate => self.batch_apply_segmented_exact(operands),
            Fidelity::WordFast => self.batch_apply_segmented_word(operands),
            Fidelity::BitPlane => self.batch_apply_segmented_planes(operands),
        }
    }

    /// Word-level fast path: per-row word arithmetic, O(rows · width).
    fn batch_apply_segmented_word(&mut self, operands: &[u32]) -> Result<BatchReport, ArrayError> {
        self.ensure_rows();
        let wpr = self.words_per_row();
        let mut report = BatchReport::default();
        // All rows advance in lockstep: the hardware drives one shared
        // 3-phase clock into every row. We iterate rows in the model,
        // but cycle counts reflect the concurrent schedule.
        for (ri, row) in self.rows.iter_mut().enumerate() {
            let ops = &operands[ri * wpr..(ri + 1) * wpr];
            let (cycles, toggles, evals) = row.apply_words_fast(ops);
            report.rows_active += 1;
            report.cycles = report.cycles.max(cycles);
            report.cell_toggles += toggles;
            report.alu_evals += evals;
        }
        self.batch_ops += 1;
        self.batch_cycles += report.cycles;
        Ok(report)
    }

    /// Bit-plane path: SIMD-within-a-register over transposed planes,
    /// O(width · rows/64) word ops — see [`super::bitplane`].
    fn batch_apply_segmented_planes(
        &mut self,
        operands: &[u32],
    ) -> Result<BatchReport, ArrayError> {
        self.ensure_planes()?;
        let report = self
            .plane
            .as_mut()
            .expect("planes ensured")
            .apply(self.op, operands);
        self.plane_toggles += report.cell_toggles;
        self.batch_ops += 1;
        self.batch_cycles += report.cycles;
        Ok(report)
    }

    /// Phase-accurate variant of [`Self::batch_apply_segmented`]: steps
    /// every cell through φ1/φ2/φ2d. ~100× slower; used for protocol
    /// validation and differential testing of the fast path.
    pub fn batch_apply_segmented_exact(
        &mut self,
        operands: &[u32],
    ) -> Result<BatchReport, ArrayError> {
        let wpr = self.words_per_row();
        let expected = self.rows.len() * wpr;
        if operands.len() != expected {
            return Err(ArrayError::OperandCount(operands.len(), expected));
        }
        self.ensure_rows();
        let mut report = BatchReport::default();
        for (ri, row) in self.rows.iter_mut().enumerate() {
            let ops = &operands[ri * wpr..(ri + 1) * wpr];
            let stats: Vec<CycleStats> = row.apply_words(ops)?;
            report.rows_active += 1;
            report.cycles = report.cycles.max(stats.len() as u64);
            for s in &stats {
                report.cell_toggles += s.cell_toggles;
                report.alu_evals += s.alu_evals;
            }
        }
        self.batch_ops += 1;
        self.batch_cycles += report.cycles;
        Ok(report)
    }

    fn batch_apply_all(&mut self, operands: &[u32]) -> Result<BatchReport, ArrayError> {
        let wpr = self.words_per_row();
        if wpr == 1 {
            return self.batch_apply_segmented(operands);
        }
        if operands.len() != self.rows.len() {
            return Err(ArrayError::OperandCount(operands.len(), self.rows.len()));
        }
        // One operand per row: apply to segment 0, identity on the rest.
        // Identity for Add/Sub/Xor is operand 0; for And it is all-ones;
        // for Or it is 0. The expansion buffer is owned by the array so
        // the hot path does not allocate per call.
        let ident = match self.op {
            AluOp::And => crate::util::bits::mask(self.word_width),
            _ => 0,
        };
        let mut full = std::mem::take(&mut self.scratch_full);
        full.clear();
        full.reserve(self.rows.len() * wpr);
        for &op in operands {
            full.push(op);
            for _ in 1..wpr {
                full.push(ident);
            }
        }
        let result = self.batch_apply_segmented(&full);
        self.scratch_full = full;
        result
    }

    /// Snapshot every row's word 0 (conventional reads, counted).
    pub fn snapshot(&mut self) -> Vec<u32> {
        (0..self.rows()).map(|r| self.read_row(r)).collect()
    }

    /// Load every row's word 0 (conventional writes, counted).
    pub fn load(&mut self, words: &[u32]) {
        assert_eq!(words.len(), self.rows());
        for (r, &w) in words.iter().enumerate() {
            self.write_row(r, w);
        }
    }

    // --- lifetime counters (energy accounting) ---

    pub fn port_reads(&self) -> u64 {
        self.port_reads
    }

    pub fn port_writes(&self) -> u64 {
        self.port_writes
    }

    pub fn batch_ops(&self) -> u64 {
        self.batch_ops
    }

    pub fn batch_cycles(&self) -> u64 {
        self.batch_cycles
    }

    /// Total cell toggles across the array (activity factor), summed
    /// over every fidelity tier's accounting.
    pub fn toggles(&self) -> u64 {
        self.plane_toggles + self.rows.iter().map(Row::toggles).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits;
    use crate::util::rng::Rng;

    #[test]
    fn showcase_dimensions() {
        let a = FastArray::new(128, 16);
        assert_eq!(a.rows(), 128);
        assert_eq!(a.cols(), 16);
        assert_eq!(a.word_width(), 16);
        assert_eq!(a.words_per_row(), 1);
    }

    #[test]
    fn batch_add_all_rows_concurrently() {
        let mut a = FastArray::new(128, 16);
        let mut rng = Rng::new(1);
        let init: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
        let deltas: Vec<u32> = (0..128).map(|_| rng.below(1 << 16) as u32).collect();
        a.load(&init);
        let report = a.batch_add(&deltas);
        // q cycles regardless of 128 rows — the paper's headline property.
        assert_eq!(report.cycles, 16);
        assert_eq!(report.rows_active, 128);
        for r in 0..128 {
            assert_eq!(a.read_row(r), bits::add_mod(init[r], deltas[r], 16));
        }
    }

    #[test]
    fn batch_sub_and_logic() {
        let mut a = FastArray::new(8, 16);
        let init: Vec<u32> = (0..8).map(|i| (i * 1000) as u32).collect();
        let ops: Vec<u32> = (0..8).map(|i| (i * 77 + 3) as u32).collect();

        a.load(&init);
        a.batch_sub(&ops);
        for r in 0..8 {
            assert_eq!(a.read_row(r), bits::sub_mod(init[r], ops[r], 16));
        }

        a.load(&init);
        a.batch_logic(AluOp::Xor, &ops);
        for r in 0..8 {
            assert_eq!(a.read_row(r), (init[r] ^ ops[r]) & 0xFFFF);
        }
    }

    #[test]
    fn segmented_batch_two_words_per_row() {
        let fabric = RouteFabric::new(16, 8);
        let mut a = FastArray::with_fabric(4, fabric, 8, AluOp::Add).unwrap();
        assert_eq!(a.words_per_row(), 2);
        for r in 0..4 {
            a.write_word(r, 0, r as u32).unwrap();
            a.write_word(r, 1, 100 + r as u32).unwrap();
        }
        let ops: Vec<u32> = (0..8).map(|i| i as u32).collect(); // row-major
        a.batch_apply_segmented(&ops).unwrap();
        for r in 0..4 {
            assert_eq!(a.read_word(r, 0).unwrap(), r as u32 + (2 * r) as u32);
            assert_eq!(a.read_word(r, 1).unwrap(), 100 + r as u32 + (2 * r + 1) as u32);
        }
    }

    #[test]
    fn width_reconfiguration_preserves_data() {
        let fabric = RouteFabric::new(16, 8);
        let mut a = FastArray::with_fabric(2, fabric, 8, AluOp::Add).unwrap();
        a.write_word(0, 0, 0xFF).unwrap();
        a.write_word(0, 1, 0x01).unwrap();
        a.reconfigure_width(16).unwrap();
        assert_eq!(a.read_word(0, 0).unwrap(), 0x01FF);
        a.batch_add(&[1, 0]);
        assert_eq!(a.read_word(0, 0).unwrap(), 0x0200);
    }

    #[test]
    fn one_operand_per_row_with_multiword_rows_is_identity_on_rest() {
        let fabric = RouteFabric::new(16, 8);
        let mut a = FastArray::with_fabric(2, fabric, 8, AluOp::Add).unwrap();
        a.write_word(0, 1, 42).unwrap();
        a.batch_add(&[5, 7]); // applies to word 0 of each row
        assert_eq!(a.read_word(0, 0).unwrap(), 5);
        assert_eq!(a.read_word(0, 1).unwrap(), 42); // untouched
    }

    #[test]
    fn poke_word_restores_state_without_counting() {
        // The durability-recovery preload path: state lands, the
        // workload-modeling port counters don't move.
        let mut a = FastArray::new(8, 8);
        a.write_row(0, 5);
        let writes_before = a.port_writes();
        a.poke_word(1, 0, 9).unwrap();
        a.poke_word(0, 0, 6).unwrap();
        assert_eq!(a.peek_word(1, 0).unwrap(), 9);
        assert_eq!(a.peek_word(0, 0).unwrap(), 6);
        assert_eq!(a.port_writes(), writes_before, "poke must not count");
        assert!(matches!(
            a.poke_word(8, 0, 1),
            Err(ArrayError::RowOutOfRange(8, 8))
        ));
    }

    #[test]
    fn operand_count_mismatch_rejected() {
        let mut a = FastArray::new(4, 16);
        let err = a.batch_apply_segmented(&[1, 2, 3]).unwrap_err();
        assert!(matches!(err, ArrayError::OperandCount(3, 4)));
    }

    #[test]
    fn out_of_range_access_rejected() {
        let mut a = FastArray::new(4, 16);
        assert!(matches!(
            a.read_word(4, 0),
            Err(ArrayError::RowOutOfRange(4, 4))
        ));
        assert!(matches!(
            a.read_word(0, 1),
            Err(ArrayError::SegmentOutOfRange(1, 1))
        ));
    }

    #[test]
    fn counters_track_usage() {
        let mut a = FastArray::new(4, 8);
        a.load(&[1, 2, 3, 4]);
        a.batch_add(&[1, 1, 1, 1]);
        a.snapshot();
        assert_eq!(a.port_writes(), 4);
        assert_eq!(a.port_reads(), 4);
        assert_eq!(a.batch_ops(), 1);
        assert_eq!(a.batch_cycles(), 8);
        assert!(a.toggles() > 0);
    }

    #[test]
    fn batch_mul_matches_host_math() {
        let mut rng = Rng::new(77);
        for q in [8usize, 16] {
            let mut a = FastArray::new(32, q);
            let init: Vec<u32> = (0..32).map(|_| rng.below(1u64 << q) as u32).collect();
            let mults: Vec<u32> = (0..32).map(|_| rng.below(1u64 << q) as u32).collect();
            a.load(&init);
            let rep = a.batch_mul(&mults).unwrap();
            // q+1 batch ops of q cycles each.
            assert_eq!(rep.cycles, ((q + 1) * q) as u64);
            for r in 0..32 {
                let want = (init[r] as u64 * mults[r] as u64) as u32 & bits::mask(q);
                assert_eq!(a.read_row(r), want, "q={q} row={r}");
            }
        }
    }

    #[test]
    fn batch_mul_edge_cases() {
        let mut a = FastArray::new(4, 16);
        a.load(&[0, 1, 0xFFFF, 1234]);
        a.batch_mul(&[5, 0xFFFF, 2, 1]).unwrap();
        assert_eq!(a.read_row(0), 0); // 0 * x
        assert_eq!(a.read_row(1), 0xFFFF); // 1 * x
        assert_eq!(a.read_row(2), (0xFFFFu32 * 2) & 0xFFFF);
        assert_eq!(a.read_row(3), 1234); // x * 1
    }

    #[test]
    fn fast_and_exact_batch_paths_agree() {
        let mut rng = Rng::new(41);
        let mut fast = FastArray::new(32, 16);
        let mut exact = FastArray::new(32, 16);
        let init: Vec<u32> = (0..32).map(|_| rng.below(1 << 16) as u32).collect();
        fast.load(&init);
        exact.load(&init);
        for _ in 0..4 {
            let deltas: Vec<u32> = (0..32).map(|_| rng.below(1 << 16) as u32).collect();
            let rf = fast.batch_apply_segmented(&deltas).unwrap();
            let re = exact.batch_apply_segmented_exact(&deltas).unwrap();
            assert_eq!(rf, re, "reports must match exactly");
        }
        // Verification reads are harness work — peek, don't count.
        assert_eq!(fast.peek_rows(), exact.peek_rows());
        assert_eq!(fast.toggles(), exact.toggles());
    }

    #[test]
    fn all_three_fidelity_tiers_agree() {
        let mut rng = Rng::new(4242);
        for op in [AluOp::Add, AluOp::Sub, AluOp::Xor, AluOp::And, AluOp::Or] {
            let rows = 70; // crosses a 64-row lane boundary
            let q = 16;
            let mut tiers = [
                FastArray::with_fidelity(rows, q, Fidelity::PhaseAccurate),
                FastArray::with_fidelity(rows, q, Fidelity::WordFast),
                FastArray::with_fidelity(rows, q, Fidelity::BitPlane),
            ];
            let init: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
            for a in &mut tiers {
                a.load(&init);
            }
            for _ in 0..3 {
                let deltas: Vec<u32> =
                    (0..rows).map(|_| rng.below(1 << q) as u32).collect();
                let reports: Vec<BatchReport> = tiers
                    .iter_mut()
                    .map(|a| {
                        a.set_op(op);
                        a.batch_apply_segmented(&deltas).unwrap()
                    })
                    .collect();
                assert_eq!(reports[0], reports[1], "{op:?}: phase vs word");
                assert_eq!(reports[1], reports[2], "{op:?}: word vs bitplane");
            }
            assert_eq!(tiers[0].peek_rows(), tiers[1].peek_rows(), "{op:?}");
            assert_eq!(tiers[1].peek_rows(), tiers[2].peek_rows(), "{op:?}");
            assert_eq!(tiers[0].toggles(), tiers[2].toggles(), "{op:?}");
        }
    }

    #[test]
    fn bitplane_lazy_transpose_roundtrips_through_port_access() {
        let mut a = FastArray::with_fidelity(100, 16, Fidelity::BitPlane);
        a.write_row(3, 41);
        let mut deltas = vec![0u32; 100];
        deltas[3] = 1;
        a.batch_add(&deltas); // transposes in, applies on planes
        assert_eq!(a.read_row(3), 42); // transposes back out
        a.write_row(3, 100); // cells authoritative again
        a.batch_add(&deltas); // re-transposes in
        assert_eq!(a.peek_word(3, 0).unwrap(), 101); // reads planes directly
        assert_eq!(a.batch_ops(), 2);
        assert_eq!(a.batch_cycles(), 32);
    }

    #[test]
    fn bitplane_mul_and_width_reconfig_work() {
        let mut a = FastArray::with_fidelity(32, 16, Fidelity::BitPlane);
        a.load(&[7; 32]);
        a.batch_mul(&[6; 32]).unwrap();
        assert_eq!(a.peek_rows(), vec![42u32; 32]);

        let fabric = RouteFabric::new(16, 8);
        let mut b =
            FastArray::with_fabric(2, fabric, 8, AluOp::Add).unwrap();
        b.set_fidelity(Fidelity::BitPlane);
        b.write_word(0, 0, 0xFF).unwrap();
        b.write_word(0, 1, 0x01).unwrap();
        b.batch_add(&[0, 0]); // builds planes at 2×8-bit segments
        b.reconfigure_width(16).unwrap(); // invalidates the plane shape
        b.batch_add(&[1, 0]);
        assert_eq!(b.peek_word(0, 0).unwrap(), 0x0200);
    }

    #[test]
    fn set_fidelity_preserves_data() {
        let mut a = FastArray::with_fidelity(65, 8, Fidelity::BitPlane);
        let init: Vec<u32> = (0..65).map(|r| (r as u32 * 3) & 0xFF).collect();
        a.load(&init);
        a.batch_add(&[1u32; 65]); // planes authoritative
        a.set_fidelity(Fidelity::WordFast); // transposes out
        a.batch_add(&[1u32; 65]);
        for (r, &v) in init.iter().enumerate() {
            assert_eq!(a.peek_word(r, 0).unwrap(), bits::add_mod(v, 2, 8), "row {r}");
        }
    }

    #[test]
    fn peek_does_not_count_port_reads() {
        let mut a = FastArray::new(4, 8);
        a.load(&[1, 2, 3, 4]);
        assert_eq!(a.peek_rows(), vec![1, 2, 3, 4]);
        assert_eq!(a.peek_word(2, 0).unwrap(), 3);
        assert_eq!(a.port_reads(), 0, "peek must not inflate port_reads");
        a.snapshot();
        assert_eq!(a.port_reads(), 4, "snapshot still models real reads");
        // Out-of-range peeks are clean errors.
        assert!(matches!(a.peek_word(4, 0), Err(ArrayError::RowOutOfRange(4, 4))));
        assert!(matches!(a.peek_word(0, 1), Err(ArrayError::SegmentOutOfRange(1, 1))));
    }

    #[test]
    fn random_cross_check_vs_word_semantics() {
        let mut rng = Rng::new(99);
        for q in [4usize, 8, 16] {
            let mut a = FastArray::new(16, q);
            let init: Vec<u32> = (0..16).map(|_| rng.below(1u64 << q) as u32).collect();
            let d1: Vec<u32> = (0..16).map(|_| rng.below(1u64 << q) as u32).collect();
            let d2: Vec<u32> = (0..16).map(|_| rng.below(1u64 << q) as u32).collect();
            a.load(&init);
            a.batch_add(&d1);
            a.batch_sub(&d2);
            for r in 0..16 {
                let want = bits::sub_mod(bits::add_mod(init[r], d1[r], q), d2[r], q);
                assert_eq!(a.read_row(r), want, "q={q} row={r}");
            }
        }
    }
}
