//! Phase-accurate behavioural model of the FAST SRAM (paper Section II).
//!
//! - [`cell`] — the 10T shiftable cell and its φ1/φ2/φ2d protocol
//! - [`alu`] — the per-row 1-bit ALU with dynamic carry latch
//! - [`row`] — a cell chain partitioned into word segments
//! - [`route`] — bit-width reconfiguration planning (Fig. 5c)
//! - [`array`] — the R×C macro with fully-concurrent batch operations
//! - [`bitplane`] — the bit-sliced (SIMD-within-a-register) fidelity
//!   tier: 64 rows per machine word, O(width · rows/64) batch ops

pub mod alu;
pub mod array;
pub mod bitplane;
pub mod cell;
pub mod route;
pub mod row;

pub use alu::{AluOp, RowAlu};
pub use array::{ArrayError, BatchReport, FastArray, Fidelity};
pub use bitplane::BitPlaneArray;
pub use cell::{CellError, Phase, ShiftCell};
pub use route::{RouteError, RouteFabric};
pub use row::{CycleStats, Row};
