//! One FAST row: a chain of shiftable cells partitioned into one or
//! more word *segments*, each closed into a cyclic shift loop through
//! its own 1-bit ALU (Figs. 4 and 5c).
//!
//! Cell index == bit significance within a segment: the cell at the
//! segment's low end holds the LSB and feeds the ALU; the ALU output
//! re-enters at the segment's high end (cyclic right shift toward the
//! ALU). The physical layout folds the row back on itself (Fig. 6b) so
//! the ALU-to-MSB wire stays short — layout is modelled in
//! [`crate::energy::area`]; here only the logical loop matters.

use super::alu::{AluOp, RowAlu};
use super::cell::{CellError, ShiftCell};

/// One word segment: `width` cells plus a dedicated 1-bit ALU.
#[derive(Debug, Clone)]
struct Segment {
    /// Index of the segment's LSB cell within the row.
    start: usize,
    /// Number of cells (== word bit width).
    width: usize,
    alu: RowAlu,
}

/// Statistics for one shift cycle across a row (energy-model inputs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleStats {
    /// Internal node toggles across all cells this cycle.
    pub cell_toggles: u64,
    /// ALU evaluations this cycle (one per segment).
    pub alu_evals: u64,
}

/// A row of shiftable cells with per-segment ALUs.
#[derive(Debug, Clone)]
pub struct Row {
    cells: Vec<ShiftCell>,
    segments: Vec<Segment>,
    /// Toggles accounted by the word-level fast path (the cells' own
    /// counters only see phase-path activity).
    fast_toggles: u64,
}

impl Row {
    /// A row of `width` cells as a single segment with the given ALU op.
    pub fn new(width: usize, op: AluOp) -> Self {
        Self::with_segments(&[width], op)
    }

    /// A row partitioned into word segments of the given widths
    /// (Fig. 5c multi-word configuration). Total cell count is the sum.
    pub fn with_segments(widths: &[usize], op: AluOp) -> Self {
        assert!(!widths.is_empty(), "row needs at least one segment");
        assert!(widths.iter().all(|&w| (1..=32).contains(&w)),
            "segment widths must be in [1,32], got {widths:?}");
        let total: usize = widths.iter().sum();
        let cells = (0..total).map(|_| ShiftCell::new(0)).collect();
        let mut segments = Vec::with_capacity(widths.len());
        let mut start = 0;
        for &w in widths {
            segments.push(Segment { start, width: w, alu: RowAlu::new(op) });
            start += w;
        }
        Row { cells, segments, fast_toggles: 0 }
    }

    /// Total cell count.
    pub fn width(&self) -> usize {
        self.cells.len()
    }

    /// Segment widths, LSB-side first.
    pub fn segment_widths(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.width).collect()
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Re-partition the row into new segment widths (the Fig. 5c routing
    /// unit reconnecting shift lines). Cell data is preserved bit-wise;
    /// total width must be unchanged. ALU latches reset.
    pub fn reconfigure_segments(&mut self, widths: &[usize], op: AluOp) -> Result<(), CellError> {
        assert!(!widths.is_empty());
        assert_eq!(
            widths.iter().sum::<usize>(),
            self.cells.len(),
            "new segment widths must cover the row exactly"
        );
        assert!(widths.iter().all(|&w| (1..=32).contains(&w)));
        // All cells must be statically held before rerouting.
        for c in &self.cells {
            c.read_static()?;
        }
        let mut segments = Vec::with_capacity(widths.len());
        let mut start = 0;
        for &w in widths {
            segments.push(Segment { start, width: w, alu: RowAlu::new(op) });
            start += w;
        }
        self.segments = segments;
        Ok(())
    }

    /// Reconfigure every segment's ALU operation (Section III.E).
    pub fn set_op(&mut self, op: AluOp) {
        for s in &mut self.segments {
            s.alu.reconfigure(op);
        }
    }

    /// Reset all ALU carry latches (start of a batch op).
    pub fn reset_alus(&mut self) {
        for s in &mut self.segments {
            s.alu.reset();
        }
    }

    /// Read segment `seg` as a word (LSB = segment's first cell).
    /// Errors if any cell is mid-shift.
    pub fn read_word(&self, seg: usize) -> Result<u32, CellError> {
        let s = &self.segments[seg];
        let mut w = 0u32;
        for i in 0..s.width {
            w |= (self.cells[s.start + i].read_static()? as u32) << i;
        }
        Ok(w)
    }

    /// Bitline write of segment `seg` (conventional SRAM port).
    pub fn write_word(&mut self, seg: usize, word: u32) -> Result<(), CellError> {
        let s = &self.segments[seg];
        let (start, width) = (s.start, s.width);
        for i in 0..width {
            self.cells[start + i].write_static(((word >> i) & 1) as u8)?;
        }
        Ok(())
    }

    /// Overwrite segment `seg` with `word` without touching the toggle
    /// counters — the transpose-out path of the bit-plane tier, which
    /// accounts toggles in aggregate (same contract as the cells'
    /// `force_state`). The cells end statically held.
    pub(crate) fn force_word(&mut self, seg: usize, word: u32) {
        let s = &self.segments[seg];
        let (start, width) = (s.start, s.width);
        for i in 0..width {
            self.cells[start + i].force_state(((word >> i) & 1) as u8);
        }
    }

    /// One shift cycle (phases 1–3), feeding each segment's ALU its
    /// external operand bit for this cycle.
    ///
    /// `operand_bits[k]` is `Some(bit)` for active segments and `None`
    /// for clock-gated ones: the controller gates the shift clock of a
    /// word group once its own width is reached in a mixed-width batch,
    /// so gated segments neither shift nor burn energy.
    pub fn shift_cycle(&mut self, operand_bits: &[Option<u8>]) -> Result<CycleStats, CellError> {
        assert_eq!(
            operand_bits.len(),
            self.segments.len(),
            "one operand bit per segment"
        );
        let toggles_before: u64 = self.cells.iter().map(|c| c.toggles()).sum();

        // ALU evaluation uses each segment's LSB-cell *output* (remnant
        // charge keeps presenting it during φ1).
        let mut alu_out = vec![0u8; self.segments.len()];
        let mut alu_evals = 0u64;
        for (k, (s, &b)) in self.segments.iter_mut().zip(operand_bits).enumerate() {
            if let Some(bit) = b {
                let a = self.cells[s.start].output();
                alu_out[k] = s.alu.eval(a, bit);
                alu_evals += 1;
            }
        }

        // Phase 1: every active cell's X node samples its upstream
        // neighbour; the segment's MSB slot samples the ALU output.
        // Upstream values are the *current* outputs (φ1 is simultaneous
        // across the row — remnant charge guarantees old data is
        // presented), so capture them before mutating.
        let outputs: Vec<u8> = self.cells.iter().map(|c| c.output()).collect();
        for (k, s) in self.segments.iter().enumerate() {
            if operand_bits[k].is_none() {
                continue; // clock-gated
            }
            for i in 0..s.width {
                let idx = s.start + i;
                let upstream = if i == s.width - 1 {
                    alu_out[k]
                } else {
                    outputs[idx + 1]
                };
                self.cells[idx].phase1(upstream)?;
            }
        }
        // Phase 2 / Phase 3 on active segments only.
        for (k, s) in self.segments.iter().enumerate() {
            if operand_bits[k].is_none() {
                continue;
            }
            for i in 0..s.width {
                self.cells[s.start + i].phase2()?;
            }
        }
        for (k, s) in self.segments.iter_mut().enumerate() {
            if operand_bits[k].is_none() {
                continue;
            }
            for i in 0..s.width {
                self.cells[s.start + i].phase3()?;
            }
            s.alu.commit_carry();
        }

        let toggles_after: u64 = self.cells.iter().map(|c| c.toggles()).sum();
        Ok(CycleStats {
            cell_toggles: toggles_after - toggles_before,
            alu_evals,
        })
    }

    /// Apply a full multi-bit operation to segment words: for each
    /// segment k, rotate `width_k` cycles feeding `operands[k]` LSB-first.
    /// All segments run in lockstep for `max(width)` cycles; shorter
    /// segments keep rotating with Pass semantics once done.
    ///
    /// In the showcase chip all segments share one width, so the common
    /// case is uniform. Returns per-cycle stats.
    pub fn apply_words(&mut self, operands: &[u32]) -> Result<Vec<CycleStats>, CellError> {
        assert_eq!(operands.len(), self.segments.len());
        self.reset_alus();
        let cycles = self
            .segments
            .iter()
            .map(|s| s.width)
            .max()
            .expect("row has segments");
        let mut stats = Vec::with_capacity(cycles);
        for t in 0..cycles {
            // Segments that already completed their own width are
            // clock-gated (None) — they neither shift nor burn energy.
            let bits: Vec<Option<u8>> = self
                .segments
                .iter()
                .enumerate()
                .map(|(k, s)| {
                    if t < s.width {
                        Some(((operands[k] >> t) & 1) as u8)
                    } else {
                        None
                    }
                })
                .collect();
            stats.push(self.shift_cycle(&bits)?);
        }
        Ok(stats)
    }

    /// Total cell toggles since construction.
    pub fn toggles(&self) -> u64 {
        self.fast_toggles + self.cells.iter().map(|c| c.toggles()).sum::<u64>()
    }

    /// Word-level fast path: same semantics, ALU usage and toggle
    /// accounting as [`Row::apply_words`], but computed with bitwise
    /// arithmetic instead of stepping every cell through the three
    /// phases. ~100× faster; differential-tested against the
    /// phase-accurate path (`fast_path_matches_phase_path` below and in
    /// the array tests).
    ///
    /// Returns (cycles, cell_toggles, alu_evals).
    pub fn apply_words_fast(&mut self, operands: &[u32]) -> (u64, u64, u64) {
        assert_eq!(operands.len(), self.segments.len());
        self.reset_alus();
        let mut max_cycles = 0u64;
        let mut toggles = 0u64;
        let mut alu_evals = 0u64;
        for (k, s) in self.segments.iter_mut().enumerate() {
            let width = s.width;
            let m = crate::util::bits::mask(width);
            // Pack the segment's current bits (LSB = cell at s.start).
            let mut w = 0u32;
            for i in 0..width {
                w |= (self.cells[s.start + i].output() as u32) << i;
            }
            for t in 0..width {
                let a = (w & 1) as u8;
                let b = ((operands[k] >> t) & 1) as u8;
                // Same ALU object as the phase path: identical carry
                // behaviour and eval counters.
                let out = s.alu.eval(a, b);
                s.alu.commit_carry();
                let incoming = ((w >> 1) | ((out as u32) << (width - 1))) & m;
                // Phase 1 toggles X where the incoming bit differs from
                // the held bit; phase 2 toggles Q under the same
                // condition — 2 node toggles per differing cell.
                toggles += 2 * (incoming ^ w).count_ones() as u64;
                w = incoming;
            }
            // Leave the cells in the exact post-cycle steady state.
            for i in 0..width {
                self.cells[s.start + i].force_state(((w >> i) & 1) as u8);
            }
            max_cycles = max_cycles.max(width as u64);
            alu_evals += width as u64;
        }
        self.fast_toggles += toggles;
        (max_cycles, toggles, alu_evals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits;

    #[test]
    fn single_segment_add() {
        let mut row = Row::new(16, AluOp::Add);
        row.write_word(0, 41).unwrap();
        row.apply_words(&[1]).unwrap();
        assert_eq!(row.read_word(0).unwrap(), 42);
    }

    #[test]
    fn add_wraps_mod_2q() {
        let mut row = Row::new(8, AluOp::Add);
        row.write_word(0, 200).unwrap();
        row.apply_words(&[100]).unwrap();
        assert_eq!(row.read_word(0).unwrap(), bits::add_mod(200, 100, 8));
    }

    #[test]
    fn full_carry_chain() {
        let mut row = Row::new(16, AluOp::Add);
        row.write_word(0, 0xFFFF).unwrap();
        row.apply_words(&[1]).unwrap();
        assert_eq!(row.read_word(0).unwrap(), 0);
    }

    #[test]
    fn sub_via_twos_complement() {
        let mut row = Row::new(16, AluOp::Sub);
        row.write_word(0, 10).unwrap();
        row.apply_words(&[25]).unwrap();
        assert_eq!(row.read_word(0).unwrap(), bits::sub_mod(10, 25, 16));
    }

    #[test]
    fn pass_rotates_identity_after_width_cycles() {
        let mut row = Row::new(8, AluOp::Pass);
        row.write_word(0, 0xA5).unwrap();
        row.apply_words(&[0]).unwrap(); // 8 pass cycles = full rotation
        assert_eq!(row.read_word(0).unwrap(), 0xA5);
    }

    #[test]
    fn logic_segment_ops() {
        for (op, a, b, want) in [
            (AluOp::And, 0xF0F0u32, 0xFF00u32, 0xF000u32),
            (AluOp::Or, 0xF0F0, 0xFF00, 0xFFF0),
            (AluOp::Xor, 0xF0F0, 0xFF00, 0x0FF0),
        ] {
            let mut row = Row::new(16, op);
            row.write_word(0, a).unwrap();
            row.apply_words(&[b]).unwrap();
            assert_eq!(row.read_word(0).unwrap(), want, "{op:?}");
        }
    }

    #[test]
    fn two_segment_row_independent_words() {
        let mut row = Row::with_segments(&[8, 8], AluOp::Add);
        row.write_word(0, 250).unwrap();
        row.write_word(1, 3).unwrap();
        row.apply_words(&[10, 20]).unwrap();
        assert_eq!(row.read_word(0).unwrap(), bits::add_mod(250, 10, 8));
        assert_eq!(row.read_word(1).unwrap(), 23);
    }

    #[test]
    fn reconfigure_merges_words() {
        // Two 8-bit words hold the halves of a 16-bit value; after the
        // routing unit merges them, a single 16-bit add crosses the
        // old word boundary (the cascaded-ALU case of Fig. 5c).
        let mut row = Row::with_segments(&[8, 8], AluOp::Add);
        let v: u32 = 0x01FF; // low byte 0xFF, high byte 0x01
        row.write_word(0, v & 0xFF).unwrap();
        row.write_word(1, v >> 8).unwrap();
        row.reconfigure_segments(&[16], AluOp::Add).unwrap();
        assert_eq!(row.read_word(0).unwrap(), v);
        row.apply_words(&[1]).unwrap();
        assert_eq!(row.read_word(0).unwrap(), 0x0200);
    }

    #[test]
    fn reconfigure_rejects_wrong_total() {
        let mut row = Row::with_segments(&[8, 8], AluOp::Add);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            row.reconfigure_segments(&[8, 4], AluOp::Add)
        }));
        assert!(res.is_err());
    }

    #[test]
    fn mixed_width_segments_lockstep() {
        let mut row = Row::with_segments(&[4, 12], AluOp::Add);
        row.write_word(0, 0xF).unwrap();
        row.write_word(1, 100).unwrap();
        row.apply_words(&[1, 200]).unwrap();
        // 4-bit word wraps: (15 + 1) mod 16 = 0. It must survive the
        // extra 8 lockstep cycles unchanged (pure rotation).
        assert_eq!(row.read_word(0).unwrap(), 0);
        assert_eq!(row.read_word(1).unwrap(), 300);
    }

    #[test]
    fn fast_path_matches_phase_path() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for widths in [vec![16usize], vec![8, 8], vec![4, 12]] {
            for op in [AluOp::Add, AluOp::Sub, AluOp::Xor, AluOp::And, AluOp::Or] {
                let mut slow = Row::with_segments(&widths, op);
                let mut fast = Row::with_segments(&widths, op);
                // Random init + three consecutive batches.
                for _ in 0..3 {
                    let ops: Vec<u32> = widths
                        .iter()
                        .map(|&w| rng.below(1u64 << w) as u32)
                        .collect();
                    let stats = slow.apply_words(&ops).unwrap();
                    let slow_toggles: u64 = stats.iter().map(|s| s.cell_toggles).sum();
                    let slow_evals: u64 = stats.iter().map(|s| s.alu_evals).sum();
                    let (cycles, fast_toggles, fast_evals) = fast.apply_words_fast(&ops);
                    assert_eq!(cycles as usize, *widths.iter().max().unwrap());
                    assert_eq!(fast_toggles, slow_toggles, "{widths:?} {op:?}");
                    assert_eq!(fast_evals, slow_evals);
                    for seg in 0..widths.len() {
                        assert_eq!(
                            slow.read_word(seg).unwrap(),
                            fast.read_word(seg).unwrap(),
                            "{widths:?} {op:?} seg {seg}"
                        );
                    }
                }
                assert_eq!(slow.toggles(), fast.toggles(), "{widths:?} {op:?}");
            }
        }
    }

    #[test]
    fn cycle_stats_counts_alu_evals() {
        let mut row = Row::with_segments(&[8, 8], AluOp::Add);
        let stats = row.apply_words(&[1, 2]).unwrap();
        assert_eq!(stats.len(), 8);
        assert!(stats.iter().all(|s| s.alu_evals == 2));
    }
}
