//! Bit-plane (bit-sliced) representation of a FAST array — the third
//! fidelity tier beneath the phase-accurate and word-fast paths.
//!
//! The paper's headline property is that one q-bit batch op commits to
//! **all enabled rows concurrently**; the phase-accurate and word-fast
//! software models still pay O(rows) scalar work per batch. This module
//! transposes the array: each word segment is stored as `width`
//! *bitplanes* of `ceil(rows/64)` u64 lanes, so bit `t` of row `j`
//! lives in bit `j % 64` of `planes[t][j / 64]`. A batch op then runs
//! as SIMD-within-a-register bitwise/ripple-carry arithmetic over
//! planes — O(width · rows/64) word ops — which is exactly the
//! transposed-layout trick bit-parallel SRAM CiM designs use to get
//! row-wise concurrency in the digital domain (Lee et al.,
//! arXiv:2008.03378; rCiM exploration, arXiv:2411.09546).
//!
//! Enabled-row sets are u64 lane masks, mirroring the hardware's
//! per-row shift-clock gates: disabled rows neither change state nor
//! burn modeled energy.
//!
//! ## Energy accounting survives the transposition
//!
//! [`BatchReport`] numbers must be *bit-identical* to the word-fast
//! path so the downstream [`crate::energy::model`] sees the same
//! activity factors. The word path counts, per shift cycle `t`,
//! `2 · popcount(w_{t+1} XOR w_t)` cell toggles where
//! `w_{t+1} = (w_t >> 1) | (out_t << (width-1))`. Writing `v` for the
//! pre-batch word and `r` for the result word (`out_t` is always
//! result bit `t` — ripple-carry adders and bitwise ALUs both emit the
//! final bit the cycle they consume it), the per-cycle XOR telescopes
//! into three families of plane differences:
//!
//! - `v_j XOR v_{j+1}` appears in cycles `t ≤ j` → weight `j+1`;
//! - the ALU boundary `v_{w-1} XOR r_0` appears every cycle → weight `w`;
//! - `r_k XOR r_{k+1}` appears in cycles `t > k` → weight `w-1-k`.
//!
//! So `cell_toggles = 2 · [Σ_j (j+1)·cnt(V_j ⊕ V_{j+1})
//! + w·cnt(V_{w-1} ⊕ R_0) + Σ_k (w-1-k)·cnt(R_k ⊕ R_{k+1})]` where
//! `cnt` is a masked popcount over the enabled-row lanes — derived
//! analytically from plane popcounts, no per-cycle state needed.
//! `alu_evals` is `width · enabled_rows` per segment, as in the word
//! path. The equivalence (values *and* reports) is enforced by
//! `rust/tests/integration_fidelity.rs` property tests.

use super::alu::AluOp;
use super::array::BatchReport;
use crate::util::bits::transpose64;

/// Bit-sliced storage for one segment: `width` planes × `lanes` u64s.
#[derive(Debug, Clone)]
struct SegPlanes {
    width: usize,
    /// `planes[t][l]`: bit `j` of lane word `l` is row `64·l + j`'s
    /// bit `t`.
    planes: Vec<Vec<u64>>,
}

/// A bit-sliced FAST array: the same logical state as a `rows`-high
/// stack of [`super::row::Row`]s, stored transposed for row-parallel
/// software execution.
#[derive(Debug, Clone)]
pub struct BitPlaneArray {
    rows: usize,
    lanes: usize,
    segs: Vec<SegPlanes>,
    /// Per-lane validity mask (all-ones except the partial last lane).
    valid: Vec<u64>,
    /// Total cell toggles accounted by plane ops (activity factor).
    toggles: u64,
    // Scratch reused across batch ops so the hot path never allocates.
    scratch_ops: Vec<Vec<u64>>,
    scratch_res: Vec<Vec<u64>>,
    scratch_carry: Vec<u64>,
}

impl BitPlaneArray {
    /// An all-zero array of `rows` rows where each row is partitioned into
    /// word segments of the given widths (LSB-side first), matching
    /// [`super::row::Row::with_segments`].
    pub fn new(rows: usize, seg_widths: &[usize]) -> Self {
        assert!(rows >= 1, "array needs at least one row");
        assert!(!seg_widths.is_empty(), "row needs at least one segment");
        assert!(
            seg_widths.iter().all(|&w| (1..=32).contains(&w)),
            "segment widths must be in [1,32], got {seg_widths:?}"
        );
        let lanes = rows.div_ceil(64);
        let mut valid = vec![u64::MAX; lanes];
        if rows % 64 != 0 {
            valid[lanes - 1] = (1u64 << (rows % 64)) - 1;
        }
        let max_w = *seg_widths.iter().max().expect("non-empty");
        BitPlaneArray {
            rows,
            lanes,
            segs: seg_widths
                .iter()
                .map(|&w| SegPlanes { width: w, planes: vec![vec![0u64; lanes]; w] })
                .collect(),
            valid,
            toggles: 0,
            scratch_ops: vec![vec![0u64; lanes]; max_w],
            scratch_res: vec![vec![0u64; lanes]; max_w],
            scratch_carry: vec![0u64; lanes],
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// u64 lanes per plane (`ceil(rows/64)`).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn segment_widths(&self) -> Vec<usize> {
        self.segs.iter().map(|s| s.width).collect()
    }

    pub fn segment_count(&self) -> usize {
        self.segs.len()
    }

    /// Total bitplanes across all segments (Σ widths). For the
    /// single-segment q-bit arrays the multi-tenant registry builds
    /// this is exactly `q` — the depth knob a narrow-precision tenant
    /// turns down (see [`crate::tenant`]): batches sweep
    /// [`Self::plane_words`] u64 lanes, so a 4-bit tenant pays half
    /// the plane traffic of an 8-bit one for the same row count.
    pub fn plane_count(&self) -> usize {
        self.segs.iter().map(|s| s.width).sum()
    }

    /// u64 plane words one full batch sweeps: `plane_count · lanes`
    /// (`q · ceil(rows/64)` for a single q-bit segment) — the
    /// O(q·rows/64) closed form behind the per-tenant cost accounting
    /// in [`crate::tenant`].
    pub fn plane_words(&self) -> usize {
        self.plane_count() * self.lanes
    }

    /// Lane mask with every row enabled (the full-batch case).
    pub fn full_mask(&self) -> Vec<u64> {
        self.valid.clone()
    }

    /// Borrow bit-plane `t` of segment `seg` (lane words, bit `j` of
    /// lane `l` = row `64·l + j`'s bit `t`). Bits beyond the row count
    /// in the partial last lane are always zero (every mutation masks
    /// with the validity lanes), so plane-wise consumers — the
    /// [`crate::query`] reduction kernels and their closed-form
    /// rotate-read cost accounting (`cell_toggles = 2·w·Σ circular
    /// transitions`, derived from plane popcounts; see that module's
    /// docs) — can popcount lanes directly.
    pub fn plane(&self, seg: usize, t: usize) -> &[u64] {
        &self.segs[seg].planes[t]
    }

    /// Total cell toggles accounted by plane batch ops.
    pub fn toggles(&self) -> u64 {
        self.toggles
    }

    /// Read segment `seg` of `row` as a word (LSB = plane 0).
    pub fn read_word(&self, row: usize, seg: usize) -> u32 {
        assert!(row < self.rows, "row {row} out of range ({})", self.rows);
        let (l, off) = (row / 64, row % 64);
        let s = &self.segs[seg];
        let mut w = 0u32;
        for (t, plane) in s.planes.iter().enumerate() {
            w |= (((plane[l] >> off) & 1) as u32) << t;
        }
        w
    }

    /// Write segment `seg` of `row` (masked to the segment width).
    pub fn write_word(&mut self, row: usize, seg: usize, word: u32) {
        assert!(row < self.rows, "row {row} out of range ({})", self.rows);
        let (l, off) = (row / 64, row % 64);
        let s = &mut self.segs[seg];
        for (t, plane) in s.planes.iter_mut().enumerate() {
            if (word >> t) & 1 == 1 {
                plane[l] |= 1u64 << off;
            } else {
                plane[l] &= !(1u64 << off);
            }
        }
    }

    /// Bulk transpose-in: overwrite the whole array from a word getter
    /// (`get(row, seg)`), 64 rows per [`transpose64`] call.
    pub fn fill_from(&mut self, mut get: impl FnMut(usize, usize) -> u32) {
        let mut buf = [0u64; 64];
        for (si, s) in self.segs.iter_mut().enumerate() {
            for l in 0..self.lanes {
                let base = l * 64;
                let take = 64.min(self.rows - base);
                for (k, slot) in buf.iter_mut().enumerate() {
                    *slot = if k < take { get(base + k, si) as u64 } else { 0 };
                }
                transpose64(&mut buf);
                for (t, plane) in s.planes.iter_mut().enumerate() {
                    plane[l] = buf[t] & self.valid[l];
                }
            }
        }
    }

    /// Bulk transpose-out: present every row word to `put(row, seg, w)`.
    pub fn export_to(&self, mut put: impl FnMut(usize, usize, u32)) {
        let mut buf = [0u64; 64];
        for (si, s) in self.segs.iter().enumerate() {
            for l in 0..self.lanes {
                let base = l * 64;
                let take = 64.min(self.rows - base);
                for (t, slot) in buf.iter_mut().enumerate() {
                    *slot = if t < s.width { s.planes[t][l] } else { 0 };
                }
                transpose64(&mut buf);
                for (k, &w) in buf.iter().enumerate().take(take) {
                    put(base + k, si, w as u32);
                }
            }
        }
    }

    /// Batch op over **all** rows: one operand per (row, segment),
    /// row-major (`operands[row * segments + seg]`). Semantics and
    /// [`BatchReport`] accounting are bit-identical to
    /// [`super::array::FastArray::batch_apply_segmented`] on the
    /// word-fast tier.
    pub fn apply(&mut self, op: AluOp, operands: &[u32]) -> BatchReport {
        self.apply_inner(op, operands, None)
    }

    /// Batch op restricted to an enabled-row set, given as a u64 lane
    /// mask (bit `j` of `enable[l]` enables row `64·l + j`). Disabled
    /// rows keep their state and contribute neither toggles nor ALU
    /// evaluations — the software mirror of per-row shift-clock gating.
    pub fn apply_masked(&mut self, op: AluOp, operands: &[u32], enable: &[u64]) -> BatchReport {
        assert_eq!(enable.len(), self.lanes, "one enable word per lane");
        self.apply_inner(op, operands, Some(enable))
    }

    fn apply_inner(
        &mut self,
        op: AluOp,
        operands: &[u32],
        enable: Option<&[u64]>,
    ) -> BatchReport {
        let nsegs = self.segs.len();
        assert_eq!(
            operands.len(),
            self.rows * nsegs,
            "one operand per (row, segment)"
        );
        // Effective per-lane mask: requested enables, clipped to rows
        // that exist (the partial last lane).
        let lane_mask = |l: usize| match enable {
            Some(e) => e[l] & self.valid[l],
            None => self.valid[l],
        };

        let mut report = BatchReport::default();
        let enabled_rows: u64 = (0..self.lanes)
            .map(|l| lane_mask(l).count_ones() as u64)
            .sum();
        report.rows_active = enabled_rows;

        let mut buf = [0u64; 64];
        for (si, seg) in self.segs.iter_mut().enumerate() {
            let w = seg.width;
            report.cycles = report.cycles.max(w as u64);
            report.alu_evals += w as u64 * enabled_rows;

            // 1. Transpose the operand column for this segment into
            //    the operand planes (scratch). Fully-gated lanes are
            //    skipped here and in steps 2/4 — their results are
            //    never read (step 3 skips them too), mirroring the
            //    clock-gated banks doing no work in hardware.
            for l in 0..self.lanes {
                if lane_mask(l) == 0 {
                    continue;
                }
                let base = l * 64;
                let take = 64.min(self.rows - base);
                for (k, slot) in buf.iter_mut().enumerate() {
                    *slot = if k < take {
                        operands[(base + k) * nsegs + si] as u64
                    } else {
                        0
                    };
                }
                transpose64(&mut buf);
                for (t, plane) in self.scratch_ops.iter_mut().enumerate().take(w) {
                    plane[l] = buf[t];
                }
            }

            // 2. Result planes, O(width · lanes) word ops.
            match op {
                AluOp::Add | AluOp::Sub => {
                    // Ripple carry across bit positions; every lane
                    // word carries 64 independent row adders. Sub is
                    // the same FA with the operand inverted and the
                    // carry latch seeded to 1 (two's complement).
                    let inv = op == AluOp::Sub;
                    let seed = if inv { u64::MAX } else { 0 };
                    self.scratch_carry.fill(seed);
                    for t in 0..w {
                        let vp = &seg.planes[t];
                        let bp = &self.scratch_ops[t];
                        let rp = &mut self.scratch_res[t];
                        for l in 0..self.lanes {
                            if lane_mask(l) == 0 {
                                continue; // gated lane: carry unused
                            }
                            let v = vp[l];
                            let b = if inv { !bp[l] } else { bp[l] };
                            let c = self.scratch_carry[l];
                            rp[l] = v ^ b ^ c;
                            self.scratch_carry[l] = (v & b) | (c & (v | b));
                        }
                    }
                }
                AluOp::And | AluOp::Or | AluOp::Xor => {
                    for t in 0..w {
                        let vp = &seg.planes[t];
                        let bp = &self.scratch_ops[t];
                        let rp = &mut self.scratch_res[t];
                        for l in 0..self.lanes {
                            if lane_mask(l) == 0 {
                                continue;
                            }
                            rp[l] = match op {
                                AluOp::And => vp[l] & bp[l],
                                AluOp::Or => vp[l] | bp[l],
                                _ => vp[l] ^ bp[l],
                            };
                        }
                    }
                }
                AluOp::Pass => {
                    // Pure rotation: the result equals the stored word.
                    for t in 0..w {
                        self.scratch_res[t].copy_from_slice(&seg.planes[t]);
                    }
                }
            }

            // 3. Analytic toggle count from plane popcounts (see the
            //    module docs for the derivation).
            let mut tog = 0u64;
            for l in 0..self.lanes {
                let m = lane_mask(l);
                if m == 0 {
                    continue;
                }
                for j in 0..w - 1 {
                    let d = (seg.planes[j][l] ^ seg.planes[j + 1][l]) & m;
                    tog += (j as u64 + 1) * d.count_ones() as u64;
                }
                let boundary = (seg.planes[w - 1][l] ^ self.scratch_res[0][l]) & m;
                tog += w as u64 * boundary.count_ones() as u64;
                for k in 0..w - 1 {
                    let d = (self.scratch_res[k][l] ^ self.scratch_res[k + 1][l]) & m;
                    tog += (w as u64 - 1 - k as u64) * d.count_ones() as u64;
                }
            }
            report.cell_toggles += 2 * tog;

            // 4. Commit result bits on enabled rows only.
            for t in 0..w {
                let rp = &self.scratch_res[t];
                let vp = &mut seg.planes[t];
                for l in 0..self.lanes {
                    let m = lane_mask(l);
                    if m == 0 {
                        continue;
                    }
                    vp[l] = (rp[l] & m) | (vp[l] & !m);
                }
            }
        }
        self.toggles += report.cell_toggles;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits;
    use crate::util::rng::Rng;

    #[test]
    fn word_roundtrip_and_partial_lane() {
        for rows in [1usize, 63, 64, 65, 130] {
            let mut a = BitPlaneArray::new(rows, &[16]);
            assert_eq!(a.lanes(), rows.div_ceil(64));
            for r in 0..rows {
                a.write_word(r, 0, (r as u32).wrapping_mul(2654435761) & 0xFFFF);
            }
            for r in 0..rows {
                let want = (r as u32).wrapping_mul(2654435761) & 0xFFFF;
                assert_eq!(a.read_word(r, 0), want, "rows={rows} r={r}");
            }
        }
    }

    #[test]
    fn plane_count_and_words_follow_the_per_q_closed_form() {
        // The tenant-facing cost surface: q planes, q·ceil(rows/64)
        // lane words for a single q-bit segment.
        for (rows, q) in [(64usize, 4usize), (128, 8), (130, 16)] {
            let a = BitPlaneArray::new(rows, &[q]);
            assert_eq!(a.plane_count(), q);
            assert_eq!(a.plane_words(), q * rows.div_ceil(64));
        }
        // Multi-segment arrays sum across segments.
        let a = BitPlaneArray::new(100, &[8, 8]);
        assert_eq!(a.plane_count(), 16);
        assert_eq!(a.plane_words(), 16 * 2);
    }

    #[test]
    fn fill_and_export_are_inverse() {
        let rows = 100;
        let mut a = BitPlaneArray::new(rows, &[8, 8]);
        let word = |r: usize, s: usize| ((r * 37 + s * 101 + 5) as u32) & 0xFF;
        a.fill_from(word);
        for r in 0..rows {
            assert_eq!(a.read_word(r, 0), word(r, 0));
            assert_eq!(a.read_word(r, 1), word(r, 1));
        }
        let mut seen = vec![0u32; rows * 2];
        a.export_to(|r, s, w| seen[r * 2 + s] = w);
        for r in 0..rows {
            assert_eq!(seen[r * 2], word(r, 0), "r={r}");
            assert_eq!(seen[r * 2 + 1], word(r, 1), "r={r}");
        }
    }

    #[test]
    fn apply_matches_host_word_semantics() {
        let mut rng = Rng::new(31);
        for rows in [5usize, 64, 129] {
            for op in [AluOp::Add, AluOp::Sub, AluOp::And, AluOp::Or, AluOp::Xor] {
                let q = 16;
                let mut a = BitPlaneArray::new(rows, &[q]);
                let init: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
                let ops: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
                a.fill_from(|r, _| init[r]);
                let rep = a.apply(op, &ops);
                assert_eq!(rep.cycles, q as u64);
                assert_eq!(rep.rows_active, rows as u64);
                assert_eq!(rep.alu_evals, (q * rows) as u64);
                for r in 0..rows {
                    let want = match op {
                        AluOp::Add => bits::add_mod(init[r], ops[r], q),
                        AluOp::Sub => bits::sub_mod(init[r], ops[r], q),
                        AluOp::And => init[r] & ops[r],
                        AluOp::Or => (init[r] | ops[r]) & bits::mask(q),
                        AluOp::Xor => (init[r] ^ ops[r]) & bits::mask(q),
                        AluOp::Pass => init[r],
                    };
                    assert_eq!(a.read_word(r, 0), want, "{op:?} rows={rows} r={r}");
                }
            }
        }
    }

    #[test]
    fn masked_apply_gates_rows() {
        let rows = 130;
        let q = 8;
        let mut a = BitPlaneArray::new(rows, &[q]);
        let init: Vec<u32> = (0..rows).map(|r| (r as u32 * 7) & 0xFF).collect();
        a.fill_from(|r, _| init[r]);
        // Enable only rows whose index bit 0 is set.
        let mut enable = vec![0u64; a.lanes()];
        for r in (1..rows).step_by(2) {
            enable[r / 64] |= 1u64 << (r % 64);
        }
        let ops: Vec<u32> = (0..rows).map(|r| (r as u32 + 3) & 0xFF).collect();
        let rep = a.apply_masked(AluOp::Add, &ops, &enable);
        assert_eq!(rep.rows_active, (rows / 2) as u64);
        assert_eq!(rep.alu_evals, (q * (rows / 2)) as u64);
        for r in 0..rows {
            let want = if r % 2 == 1 {
                bits::add_mod(init[r], ops[r], q)
            } else {
                init[r]
            };
            assert_eq!(a.read_word(r, 0), want, "r={r}");
        }
    }

    #[test]
    fn masked_toggles_sum_like_independent_runs() {
        // Toggles of a masked run over set S plus a masked run over the
        // complement of S equals one full run, because per-row activity
        // is independent.
        let rows = 96;
        let q = 16;
        let mut rng = Rng::new(77);
        let init: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
        let ops: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();

        let mut full = BitPlaneArray::new(rows, &[q]);
        full.fill_from(|r, _| init[r]);
        let rep_full = full.apply(AluOp::Add, &ops);

        let mut half = BitPlaneArray::new(rows, &[q]);
        half.fill_from(|r, _| init[r]);
        let mut lo = vec![0u64; half.lanes()];
        let mut hi = vec![0u64; half.lanes()];
        for r in 0..rows {
            if r < rows / 2 {
                lo[r / 64] |= 1u64 << (r % 64);
            } else {
                hi[r / 64] |= 1u64 << (r % 64);
            }
        }
        let rep_lo = half.apply_masked(AluOp::Add, &ops, &lo);
        let rep_hi = half.apply_masked(AluOp::Add, &ops, &hi);
        assert_eq!(rep_lo.cell_toggles + rep_hi.cell_toggles, rep_full.cell_toggles);
        assert_eq!(rep_lo.alu_evals + rep_hi.alu_evals, rep_full.alu_evals);
        for r in 0..rows {
            assert_eq!(half.read_word(r, 0), full.read_word(r, 0), "r={r}");
        }
    }

    #[test]
    fn segmented_apply_is_per_segment() {
        let rows = 10;
        let mut a = BitPlaneArray::new(rows, &[4, 12]);
        a.fill_from(|r, s| if s == 0 { r as u32 & 0xF } else { (100 + r as u32) & 0xFFF });
        let ops: Vec<u32> = (0..rows * 2)
            .map(|i| if i % 2 == 0 { 1 } else { 200 })
            .collect();
        let rep = a.apply(AluOp::Add, &ops);
        assert_eq!(rep.cycles, 12); // max segment width
        assert_eq!(rep.alu_evals, ((4 + 12) * rows) as u64);
        for r in 0..rows {
            assert_eq!(a.read_word(r, 0), (r as u32 + 1) & 0xF);
            assert_eq!(a.read_word(r, 1), (100 + r as u32 + 200) & 0xFFF);
        }
    }
}
