//! The 10T shiftable SRAM cell (paper Fig. 3a): a conventional 6T cell
//! plus one CMOS transmission gate (inter-cell switch, controlled by φ1)
//! and two NMOS switches (intra-cell switches, controlled by φ2 / φ2d).
//!
//! Shift protocol (Fig. 3c):
//!   Phase 1 (φ1):  intra-cell switches OFF, inter-cell switch ON. The
//!                  inverter loop is broken; the remnant charge at node X
//!                  keeps driving the pair, so the cell still presents its
//!                  old datum downstream while its X node is being charged
//!                  by the upstream neighbour.
//!   Phase 2 (φ2):  inter-cell OFF, first intra-cell switch ON — the
//!                  sampled value at X enters the inverter loop.
//!   Phase 3 (φ2d): second intra-cell switch ON (φ2 delayed) — the loop
//!                  closes fully and the datum is statically restored.
//!
//! φ1 and φ2 are non-overlapping; turning both on simultaneously shorts
//! the upstream driver into a half-open loop and loses data. The model
//! enforces this as a hard error ([`CellError::SwitchHazard`]).
//!
//! This is the *digital, phase-accurate* model used by the array/
//! coordinator layers; the charge/leakage physics of the same cell live
//! in [`crate::analog`].

use std::fmt;

/// The three shift phases of Fig. 3c.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// φ1 high: transfer upstream datum onto node X.
    P1,
    /// φ2 high: sample X into the inverter loop.
    P2,
    /// φ2d high: close the loop, restore statically.
    P3,
}

/// Errors raised by protocol violations in the cell model.
/// (thiserror is not in the offline vendor set — Display/Error are
/// hand-written, same messages.)
#[derive(Debug, PartialEq, Eq)]
pub enum CellError {
    /// φ1 and φ2/φ2d asserted together (non-overlap violation).
    SwitchHazard,
    /// Phase sequence violated (e.g. P2 without a preceding P1).
    PhaseOrder(Phase, Option<Phase>),
    /// Static read attempted while the loop is open (mid-shift).
    DynamicRead,
}

impl fmt::Display for CellError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CellError::SwitchHazard => {
                write!(f, "switch hazard: φ1 overlaps φ2/φ2d — data would be lost")
            }
            CellError::PhaseOrder(now, prev) => {
                write!(f, "phase order violation: {now:?} after {prev:?}")
            }
            CellError::DynamicRead => {
                write!(f, "read while inverter loop open (mid-shift datum is dynamic)")
            }
        }
    }
}

impl std::error::Error for CellError {}

/// Digital state of one 10T shiftable cell.
#[derive(Debug, Clone)]
pub struct ShiftCell {
    /// Datum on the inverter pair (node Q). 0 or 1.
    q: u8,
    /// Dynamic node X (input of the inverter pair, valid after P1).
    x: u8,
    /// True when the loop is closed (datum statically held).
    loop_closed: bool,
    /// Last phase applied, for order checking.
    last_phase: Option<Phase>,
    /// Toggle counter for activity-based energy accounting.
    toggles: u64,
}

impl ShiftCell {
    /// A new cell holding `bit`.
    pub fn new(bit: u8) -> Self {
        ShiftCell {
            q: bit & 1,
            x: bit & 1,
            loop_closed: true,
            last_phase: None,
            toggles: 0,
        }
    }

    /// Datum currently driven downstream. During a shift (loop open) the
    /// remnant charge keeps presenting the pre-shift datum — exactly the
    /// property the paper exploits in phase 1.
    #[inline]
    pub fn output(&self) -> u8 {
        self.q
    }

    /// Statically-held datum. Errors if the loop is open (dynamic state).
    pub fn read_static(&self) -> Result<u8, CellError> {
        if !self.loop_closed {
            return Err(CellError::DynamicRead);
        }
        Ok(self.q)
    }

    /// Direct (bitline) write, as in a conventional SRAM access. Only
    /// legal when the loop is closed.
    pub fn write_static(&mut self, bit: u8) -> Result<(), CellError> {
        if !self.loop_closed {
            return Err(CellError::DynamicRead);
        }
        let b = bit & 1;
        if b != self.q {
            self.toggles += 1;
        }
        self.q = b;
        self.x = b;
        Ok(())
    }

    /// Phase 1: the inter-cell switch is on; `upstream` is the datum
    /// presented by the left neighbour (or the row ALU for the MSB slot).
    pub fn phase1(&mut self, upstream: u8) -> Result<(), CellError> {
        // Legal predecessors: fresh cell, or a completed P3.
        match self.last_phase {
            None | Some(Phase::P3) => {}
            Some(p) => return Err(CellError::PhaseOrder(Phase::P1, Some(p))),
        }
        self.loop_closed = false; // intra switches off
        if (upstream & 1) != self.x {
            self.toggles += 1;
        }
        self.x = upstream & 1;
        self.last_phase = Some(Phase::P1);
        Ok(())
    }

    /// Phase 2: sample node X into the loop.
    pub fn phase2(&mut self) -> Result<(), CellError> {
        match self.last_phase {
            Some(Phase::P1) => {}
            p => return Err(CellError::PhaseOrder(Phase::P2, p)),
        }
        if self.x != self.q {
            self.toggles += 1;
        }
        self.q = self.x;
        self.last_phase = Some(Phase::P2);
        Ok(())
    }

    /// Phase 3: close the loop (φ2d). The datum becomes static.
    pub fn phase3(&mut self) -> Result<(), CellError> {
        match self.last_phase {
            Some(Phase::P2) => {}
            p => return Err(CellError::PhaseOrder(Phase::P3, p)),
        }
        self.loop_closed = true;
        self.last_phase = Some(Phase::P3);
        Ok(())
    }

    /// Total internal node toggles since construction (activity factor
    /// input for the energy model).
    pub fn toggles(&self) -> u64 {
        self.toggles
    }

    /// Set the cell to a post-cycle steady state without touching the
    /// toggle counter — used by the word-level fast path in
    /// [`super::row::Row`], which accounts toggles in aggregate. The
    /// resulting state is exactly what a completed φ1→φ2→φ2d cycle
    /// leaves behind.
    pub(crate) fn force_state(&mut self, bit: u8) {
        self.q = bit & 1;
        self.x = self.q;
        self.loop_closed = true;
        self.last_phase = Some(Phase::P3);
    }

    /// True when the datum is statically held.
    pub fn is_static(&self) -> bool {
        self.loop_closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_cell_is_static() {
        let c = ShiftCell::new(1);
        assert!(c.is_static());
        assert_eq!(c.read_static().unwrap(), 1);
        assert_eq!(c.output(), 1);
    }

    #[test]
    fn full_shift_cycle_moves_datum() {
        let mut c = ShiftCell::new(0);
        c.phase1(1).unwrap();
        // During P1 the old datum is still presented downstream.
        assert_eq!(c.output(), 0);
        assert!(!c.is_static());
        c.phase2().unwrap();
        assert_eq!(c.output(), 1); // sampled
        c.phase3().unwrap();
        assert!(c.is_static());
        assert_eq!(c.read_static().unwrap(), 1);
    }

    #[test]
    fn dynamic_read_rejected() {
        let mut c = ShiftCell::new(0);
        c.phase1(1).unwrap();
        assert_eq!(c.read_static(), Err(CellError::DynamicRead));
        assert_eq!(c.write_static(1), Err(CellError::DynamicRead));
    }

    #[test]
    fn phase_order_enforced() {
        let mut c = ShiftCell::new(0);
        assert!(matches!(c.phase2(), Err(CellError::PhaseOrder(_, _))));
        c.phase1(1).unwrap();
        assert!(matches!(c.phase3(), Err(CellError::PhaseOrder(_, _))));
        // P1 twice in a row is also a violation (φ1 re-asserted before φ2).
        assert!(matches!(c.phase1(0), Err(CellError::PhaseOrder(_, _))));
    }

    #[test]
    fn toggle_accounting() {
        let mut c = ShiftCell::new(0);
        c.phase1(1).unwrap(); // x: 0->1, toggle
        c.phase2().unwrap(); // q: 0->1, toggle
        c.phase3().unwrap();
        assert_eq!(c.toggles(), 2);
        // Shifting the same value in causes no toggles.
        c.phase1(1).unwrap();
        c.phase2().unwrap();
        c.phase3().unwrap();
        assert_eq!(c.toggles(), 2);
    }

    #[test]
    fn static_write() {
        let mut c = ShiftCell::new(0);
        c.write_static(1).unwrap();
        assert_eq!(c.read_static().unwrap(), 1);
        assert_eq!(c.toggles(), 1);
    }
}
