//! The per-row 1-bit ALU (paper Figs. 4–5) spliced between the row's
//! LSB cell output and MSB cell input.
//!
//! The showcase configuration is a full adder with a dynamic carry latch
//! (node T1, Fig. 5a): in phase 1 the FA evaluates and the carry-out is
//! parked on T1; in phase 3 the carry transmits through the φ2d switch
//! and becomes the carry-in of the *next* shift cycle. Section III.E
//! generalises the ALU to other 1-bit operators; we model AND/OR/XOR
//! (logic update), PASS (pure rotate) and the FA.

use crate::util::bits::full_adder;

/// 1-bit ALU operating mode — the paper's reconfigurable operation unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    /// Full adder with carry latch: multi-bit add over q cycles.
    Add,
    /// Full adder fed with inverted operand, carry-in seeded to 1:
    /// two's-complement subtract through the same FA path.
    Sub,
    /// Bitwise AND with the external operand bit.
    And,
    /// Bitwise OR.
    Or,
    /// Bitwise XOR.
    Xor,
    /// Pass-through: pure cyclic rotation (no external operand).
    Pass,
}

impl AluOp {
    /// Carry-in value the latch is seeded with at batch start.
    pub fn initial_carry(self) -> u8 {
        match self {
            AluOp::Sub => 1,
            _ => 0,
        }
    }

    /// Whether this op consumes the external operand bit.
    pub fn uses_operand(self) -> bool {
        !matches!(self, AluOp::Pass)
    }
}

/// One row's 1-bit ALU with its carry latch (node T1).
#[derive(Debug, Clone)]
pub struct RowAlu {
    op: AluOp,
    /// Dynamic carry latch (T1). Valid only for Add/Sub.
    carry: u8,
    /// Carry evaluated this cycle, parked during φ1, committed at φ3 —
    /// models the two-stage latch timing of Fig. 5(a)/(b).
    carry_next: u8,
    /// Evaluation counter (activity input for the energy model).
    evals: u64,
}

impl RowAlu {
    pub fn new(op: AluOp) -> Self {
        RowAlu { op, carry: op.initial_carry(), carry_next: op.initial_carry(), evals: 0 }
    }

    pub fn op(&self) -> AluOp {
        self.op
    }

    /// Reset the carry latch for a new batch operation.
    pub fn reset(&mut self) {
        self.carry = self.op.initial_carry();
        self.carry_next = self.carry;
    }

    /// Reconfigure the operation unit (Section III.E). Resets the latch.
    pub fn reconfigure(&mut self, op: AluOp) {
        self.op = op;
        self.reset();
    }

    /// Phase-1 evaluation: combine the LSB-cell output `a` with the
    /// external operand bit `b`; returns the sum/result bit that will be
    /// shifted into the MSB slot. Carry-out is parked on T1.
    pub fn eval(&mut self, a: u8, b: u8) -> u8 {
        self.evals += 1;
        let a = a & 1;
        let b = b & 1;
        match self.op {
            AluOp::Add => {
                let (s, c) = full_adder(a, b, self.carry);
                self.carry_next = c;
                s
            }
            AluOp::Sub => {
                // Invert the operand; carry latch was seeded with 1.
                let (s, c) = full_adder(a, b ^ 1, self.carry);
                self.carry_next = c;
                s
            }
            AluOp::And => a & b,
            AluOp::Or => a | b,
            AluOp::Xor => a ^ b,
            AluOp::Pass => a,
        }
    }

    /// Phase-3 commit: the parked carry transmits through the φ2d switch
    /// and becomes next cycle's carry-in (Fig. 5b).
    pub fn commit_carry(&mut self) {
        self.carry = self.carry_next;
    }

    /// Current latched carry (next cycle's carry-in).
    pub fn carry(&self) -> u8 {
        self.carry
    }

    pub fn evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_ripples_carry_across_cycles() {
        // 1 + 1 bit-serially over 2 cycles: LSBs 1+1 = 0 carry 1;
        // next bits 0+0+carry = 1.
        let mut alu = RowAlu::new(AluOp::Add);
        let s0 = alu.eval(1, 1);
        alu.commit_carry();
        assert_eq!(s0, 0);
        assert_eq!(alu.carry(), 1);
        let s1 = alu.eval(0, 0);
        alu.commit_carry();
        assert_eq!(s1, 1);
        assert_eq!(alu.carry(), 0);
    }

    #[test]
    fn carry_commits_only_at_phase3() {
        let mut alu = RowAlu::new(AluOp::Add);
        alu.eval(1, 1); // carry parked on T1, not yet committed
        assert_eq!(alu.carry(), 0);
        alu.commit_carry();
        assert_eq!(alu.carry(), 1);
    }

    #[test]
    fn sub_is_twos_complement() {
        // a - b computed bit-serially: 0 - 1 over 2 bits = 0b11 (-1 mod 4).
        let mut alu = RowAlu::new(AluOp::Sub);
        let s0 = alu.eval(0, 1);
        alu.commit_carry();
        let s1 = alu.eval(0, 0);
        alu.commit_carry();
        assert_eq!((s1 << 1) | s0, 0b11);
    }

    #[test]
    fn logic_ops() {
        assert_eq!(RowAlu::new(AluOp::And).eval(1, 1), 1);
        assert_eq!(RowAlu::new(AluOp::And).eval(1, 0), 0);
        assert_eq!(RowAlu::new(AluOp::Or).eval(0, 1), 1);
        assert_eq!(RowAlu::new(AluOp::Xor).eval(1, 1), 0);
        assert_eq!(RowAlu::new(AluOp::Pass).eval(1, 0), 1);
    }

    #[test]
    fn reconfigure_resets_latch() {
        let mut alu = RowAlu::new(AluOp::Add);
        alu.eval(1, 1);
        alu.commit_carry();
        assert_eq!(alu.carry(), 1);
        alu.reconfigure(AluOp::Sub);
        assert_eq!(alu.carry(), 1); // Sub seeds carry-in = 1
        alu.reconfigure(AluOp::Add);
        assert_eq!(alu.carry(), 0);
    }
}
