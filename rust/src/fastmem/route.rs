//! Bit-width reconfiguration planning (paper Fig. 5c).
//!
//! A physical row of C cells is built from base words of width `base`
//! (8 in the 16-cell example of Fig. 5c). The routing unit can connect
//! the shift lines of adjacent base words, cascading their ALUs, to
//! form wider logical words. This module computes valid segment layouts
//! and the reconfiguration cost the coordinator charges for switching.

use std::fmt;

#[derive(Debug, PartialEq, Eq)]
pub enum RouteError {
    /// Requested width is not a multiple of the base word width.
    NotMultipleOfBase(usize, usize),
    /// Requested width exceeds the row width.
    TooWide(usize, usize),
    /// Requested width outside the supported range [1, 32].
    Unsupported(usize),
    /// Row width is not a multiple of the requested width.
    DoesNotTile(usize, usize),
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::NotMultipleOfBase(w, base) => {
                write!(f, "requested width {w} is not a multiple of the base word width {base}")
            }
            RouteError::TooWide(w, row) => {
                write!(f, "requested width {w} exceeds the row width {row}")
            }
            RouteError::Unsupported(w) => {
                write!(f, "requested width {w} outside supported range [1, 32]")
            }
            RouteError::DoesNotTile(row, w) => {
                write!(f, "row width {row} is not a multiple of requested width {w}")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Static description of a macro's routing fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteFabric {
    /// Physical cells per row.
    pub row_width: usize,
    /// Base (hardware) word width; logical words are multiples of this.
    pub base_width: usize,
}

impl RouteFabric {
    pub fn new(row_width: usize, base_width: usize) -> Self {
        assert!(base_width >= 1 && row_width >= base_width);
        assert!(
            row_width % base_width == 0,
            "row width must be a multiple of the base word width"
        );
        RouteFabric { row_width, base_width }
    }

    /// Plan a uniform segment layout for logical words of `width` bits.
    /// Returns the per-row segment widths (all equal).
    pub fn plan(&self, width: usize) -> Result<Vec<usize>, RouteError> {
        if !(1..=32).contains(&width) {
            return Err(RouteError::Unsupported(width));
        }
        if width % self.base_width != 0 {
            return Err(RouteError::NotMultipleOfBase(width, self.base_width));
        }
        if width > self.row_width {
            return Err(RouteError::TooWide(width, self.row_width));
        }
        if self.row_width % width != 0 {
            return Err(RouteError::DoesNotTile(self.row_width, width));
        }
        Ok(vec![width; self.row_width / width])
    }

    /// Number of logical words per row at the given width.
    pub fn words_per_row(&self, width: usize) -> Result<usize, RouteError> {
        Ok(self.plan(width)?.len())
    }

    /// Widths this fabric supports.
    pub fn supported_widths(&self) -> Vec<usize> {
        (1..=self.row_width / self.base_width)
            .map(|k| k * self.base_width)
            .filter(|&w| w <= 32 && self.row_width % w == 0)
            .collect()
    }

    /// Reconfiguration cost in control cycles: one route-latch update per
    /// base-word boundary whose connectivity changes between layouts.
    pub fn reconfig_cycles(&self, from_width: usize, to_width: usize) -> Result<u64, RouteError> {
        let from = self.plan(from_width)?;
        let to = self.plan(to_width)?;
        // Boundary b (between base word b and b+1) is "connected" when it
        // falls inside a logical word.
        let boundaries = self.row_width / self.base_width - 1;
        let connected = |widths: &[usize]| -> Vec<bool> {
            let mut v = Vec::with_capacity(boundaries);
            let mut pos = 0;
            let mut seg_end = widths[0];
            let mut seg_idx = 0;
            for b in 0..boundaries {
                pos += self.base_width;
                while pos > seg_end {
                    seg_idx += 1;
                    seg_end += widths[seg_idx];
                }
                v.push(pos != seg_end || b == boundaries); // inside a word?
            }
            // simpler: boundary connected iff pos is not a segment edge
            v
        };
        let a = connected(&from);
        let b = connected(&to);
        Ok(a.iter().zip(&b).filter(|(x, y)| x != y).count() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_valid_widths() {
        let f = RouteFabric::new(16, 8);
        assert_eq!(f.plan(8).unwrap(), vec![8, 8]);
        assert_eq!(f.plan(16).unwrap(), vec![16]);
    }

    #[test]
    fn plan_rejects_bad_widths() {
        let f = RouteFabric::new(16, 8);
        assert_eq!(f.plan(12), Err(RouteError::NotMultipleOfBase(12, 8)));
        assert_eq!(f.plan(24), Err(RouteError::TooWide(24, 16)));
        assert_eq!(f.plan(0), Err(RouteError::Unsupported(0)));
    }

    #[test]
    fn supported_widths_enumerates() {
        let f = RouteFabric::new(32, 8);
        assert_eq!(f.supported_widths(), vec![8, 16, 32]);
        let g = RouteFabric::new(16, 4);
        assert_eq!(g.supported_widths(), vec![4, 8, 16]);
    }

    #[test]
    fn words_per_row() {
        let f = RouteFabric::new(32, 8);
        assert_eq!(f.words_per_row(8).unwrap(), 4);
        assert_eq!(f.words_per_row(32).unwrap(), 1);
    }

    #[test]
    fn reconfig_cost_zero_for_same_layout() {
        let f = RouteFabric::new(16, 8);
        assert_eq!(f.reconfig_cycles(8, 8).unwrap(), 0);
        assert!(f.reconfig_cycles(8, 16).unwrap() > 0);
    }

    #[test]
    #[should_panic]
    fn fabric_rejects_untiled_base() {
        RouteFabric::new(20, 8);
    }
}
