//! `fast` — leader entrypoint for the FAST SRAM reproduction.
//!
//! Experiment commands regenerate the paper's tables and figures;
//! system commands run the Layer-3 update engine (optionally on the
//! AOT-compiled XLA artifacts) and validate artifacts against host
//! semantics. See `fast help`.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::bail;

use fast_sram::apps::trace::{self, state_digest, BackendKind, Trace};
use fast_sram::apps::trainer::{self, TrainerConfig};
use fast_sram::bench;
use fast_sram::cli::{usage, Args};
use fast_sram::coordinator::{
    BitPlaneBackend, DigitalBackend, EngineConfig, FastBackend, UpdateEngine, XlaBackend,
};
use fast_sram::durability::{self, DurabilityConfig, FsyncPolicy};
use fast_sram::fastmem::Fidelity;
use fast_sram::experiments::{
    apps_bench, fig10, fig11, fig12, fig13, fig14, table1, waveforms, weight_update,
};
use fast_sram::metrics::render_table;
use fast_sram::query;
use fast_sram::replication::{
    spawn_follower, FollowerOpts, ReplListener, ReplListenerCfg, ReplStats,
};
use fast_sram::runtime::{default_artifact_dir, validate, Runtime};
use fast_sram::serve;
use fast_sram::telemetry::server::MetricsServer;
use fast_sram::tenant::{tenant_dir, TenantRegistry, TenantSpec};
use fast_sram::Result;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("table1") => cmd_table1(&args),
        Some("fig10") => cmd_fig10(),
        Some("fig11") => cmd_fig11(),
        Some("fig12") => cmd_fig12(&args),
        Some("fig13") => cmd_fig13(),
        Some("fig14") => cmd_fig14(&args),
        Some("waveforms") => cmd_waveforms(&args),
        Some("apps") => cmd_apps(&args),
        Some("train") => cmd_train(&args),
        Some("trace") => cmd_trace(&args),
        Some("serve") => cmd_serve(&args),
        Some("stats") => cmd_stats(&args),
        Some("promote") => cmd_promote(&args),
        Some("client") => cmd_client(&args),
        Some("tenant") => cmd_tenant(&args),
        Some("query") => cmd_query(&args),
        Some("bench") => cmd_bench(&args),
        Some("wal") => cmd_wal(&args),
        Some("validate") => cmd_validate(&args),
        Some("info") => cmd_info(&args),
        Some("help") | None => {
            print!("{}", usage());
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}; try `fast help`"),
    }
}

fn cmd_table1(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 128)?;
    let q = args.get_usize("q", 16)?;
    print!("{}", table1::render(&table1::run(rows, q)));
    Ok(())
}

fn cmd_fig10() -> Result<()> {
    print!("{}", fig10::render(&fig10::run()));
    Ok(())
}

fn cmd_fig11() -> Result<()> {
    print!("{}", fig11::render(&fig11::run()));
    Ok(())
}

fn cmd_fig12(args: &Args) -> Result<()> {
    let samples = args.get_usize("samples", 500)?;
    let seed = args.get_u64("seed", 42)?;
    print!("{}", fig12::render(&fig12::run(samples, seed)));
    Ok(())
}

fn cmd_fig13() -> Result<()> {
    print!("{}", fig13::render(&fig13::run()));
    Ok(())
}

fn cmd_fig14(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 128)?;
    let cols = args.get_usize("cols", 16)?;
    print!("{}", fig14::render(&fig14::run(rows, cols)));
    Ok(())
}

fn cmd_waveforms(args: &Args) -> Result<()> {
    let period = args.get_f64("period", 1.25)?;
    let f7 = waveforms::run_fig7(period);
    let f8 = waveforms::run_fig8(period, 0b0101, 0b0110);
    print!("{}", waveforms::render_fig7(&f7, 72));
    println!();
    print!("{}", waveforms::render_fig8(&f8, 72));
    if let Some(dir) = args.get("csv") {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/fig7.csv"), f7.set.to_csv())?;
        std::fs::write(format!("{dir}/fig8.csv"), f8.set.to_csv())?;
        println!("\nCSV traces written to {dir}/fig7.csv and {dir}/fig8.csv");
    }
    Ok(())
}

fn cmd_apps(args: &Args) -> Result<()> {
    let rows = args.get_usize("rows", 128)?;
    let q = args.get_usize("q", 16)?;
    let updates = args.get_usize("updates", 20_000)?;
    let seed = args.get_u64("seed", 7)?;
    let mut pairs = Vec::new();
    pairs.push(apps_bench::compare(
        rows,
        q,
        apps_bench::Workload::UniformDeltas { updates },
        seed,
    )?);
    pairs.push(apps_bench::compare(
        rows,
        q,
        apps_bench::Workload::SkewedDeltas { updates },
        seed,
    )?);
    pairs.push(apps_bench::compare(
        rows,
        q,
        apps_bench::Workload::GraphRounds { nodes: rows.min(128), avg_degree: 4, rounds: 4 },
        seed,
    )?);
    print!("{}", apps_bench::render(&pairs));
    Ok(())
}

/// Build a trainer config from the shared CLI flags.
fn trainer_config(args: &Args) -> Result<TrainerConfig> {
    let mut cfg = TrainerConfig::vgg7(args.get_usize("rows", 128)?, args.get_usize("q", 8)?);
    cfg.epochs = args.get_usize("epochs", cfg.epochs)?;
    cfg.steps_per_epoch = args.get_usize("steps", cfg.steps_per_epoch)?;
    cfg.shards = args.get_usize("shards", cfg.shards)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.density = args.get_f64("density", cfg.density)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = trainer_config(args)?;
    let report = weight_update::run(&cfg)?;
    print!("{}", weight_update::render(&report));
    if !args.get_bool("no-assert") && !report.passes_bars() {
        bail!(
            "paper-anchored bars not met: speed {:.1}x (need >= {}x), \
             energy {:.1}x (need >= {}x)",
            report.speedup,
            trainer::MIN_SPEEDUP_X,
            report.energy_eff,
            trainer::MIN_ENERGY_EFF_X
        );
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("record") => {
            let out = args
                .get("out")
                .ok_or_else(|| anyhow::anyhow!("trace record needs --out FILE"))?;
            let trace = match args.get_str("workload", "vgg7") {
                "vgg7" => trainer::record_trace(&trainer_config(args)?)?,
                "uniform" => {
                    let rows = args.get_usize("rows", 128)?;
                    let q = args.get_usize("q", 8)?;
                    anyhow::ensure!(rows >= 1, "--rows must be >= 1");
                    anyhow::ensure!((1..=32).contains(&q), "--q must be in 1..=32");
                    fast_sram::apps::trace::uniform_trace(
                        rows,
                        q,
                        args.get_usize("updates", 5000)?,
                        args.get_u64("seed", 66)?,
                    )
                }
                other => bail!("unknown workload {other:?} (vgg7|uniform)"),
            };
            trace.save(out)?;
            println!(
                "recorded {:?}: {} events ({} updates) over {} rows x {} bits -> {out}",
                trace.name,
                trace.events.len(),
                trace.updates(),
                trace.rows,
                trace.q
            );
            Ok(())
        }
        Some("replay") => {
            let path = args
                .get("in")
                .ok_or_else(|| anyhow::anyhow!("trace replay needs --in FILE"))?;
            let fidelity_str = args.get_str("fidelity", "word");
            let fidelity = Fidelity::parse(fidelity_str).ok_or_else(|| {
                anyhow::anyhow!("unknown fidelity {fidelity_str:?} (phase|word|bitplane)")
            })?;
            let kind = BackendKind::from_flags(args.get_str("backend", "fast"), fidelity)?;
            let shards = args.get_usize("shards", 1)?;
            let verify = args.get_bool("verify");
            // Streamed replay: events go straight from the BufReader
            // into the engine (a multi-million-event trace never sits
            // in memory); --verify folds the host oracle alongside and
            // replay_file errors on divergence.
            let fr = trace::replay_file(path, kind, shards, verify)?;
            let rep = &fr.report;
            let s = &rep.stats;
            let shape = format!("{} ({} rows x {} bits)", fr.name, fr.rows, fr.q);
            let digest = format!("{:016x}", state_digest(&rep.final_state));
            if args.get_bool("digest-only") {
                // Machine-readable mode for the CI smoke jobs: just
                // the digest (verification already ran if asked).
                println!("{digest}");
                return Ok(());
            }
            let mut rows_txt = vec![
                ("trace".to_string(), shape),
                ("backend".to_string(), s.backend.to_string()),
                ("shards".to_string(), format!("{shards}")),
                ("updates applied".to_string(), format!("{}", s.completed)),
                ("batches".to_string(), format!("{}", s.batches)),
                ("rows/batch".to_string(), format!("{:.1}", s.rows_per_batch)),
                ("modeled macro time".to_string(), format!("{:.3} µs", s.modeled_ns / 1000.0)),
                (
                    "modeled energy".to_string(),
                    format!("{:.3} nJ", s.modeled_energy_pj / 1000.0),
                ),
                ("wall time".to_string(), format!("{:.2} ms", rep.wall_us / 1000.0)),
                ("state digest".to_string(), digest),
            ];
            if verify {
                rows_txt.push((
                    "verified".to_string(),
                    "bit-identical to host semantics".to_string(),
                ));
            }
            print!("{}", render_table("trace replay", &rows_txt));
            Ok(())
        }
        _ => bail!("usage: fast trace record --out FILE | fast trace replay --in FILE"),
    }
}

/// Engine policy shared by every engine one `fast` process starts —
/// the backend/fidelity/seal/fsync flags, parsed once and **owned**,
/// so both `build_engine` (one engine) and the tenant factory of a
/// `--tenants` serve (a `'static` closure that outlives `args` and
/// builds one engine per tenant shape) start engines under an
/// identical policy.
struct EnginePolicy {
    shards: usize,
    backend: String,
    artifact_dir: String,
    fidelity: Fidelity,
    seal_deadline: Duration,
    seal_at_rows: Option<usize>,
    fsync: FsyncPolicy,
    segment_bytes: u64,
}

impl EnginePolicy {
    fn parse(args: &Args) -> Result<EnginePolicy> {
        let backend = args.get_str("backend", "fast").to_string();
        // `--flush-us` is the deprecated spelling of `--seal-deadline-us`
        // (kept as an alias; the new spelling wins when both are given).
        let (deadline_str, renamed) = args.get_renamed("seal-deadline-us", "flush-us");
        if renamed.deprecated() {
            eprintln!(
                "warning: --flush-us is deprecated; use --seal-deadline-us \
                 (legacy alias honoured{})",
                if deadline_str == args.get("flush-us") {
                    ""
                } else {
                    " — --seal-deadline-us takes precedence"
                }
            );
        }
        let deadline_us: u64 = match deadline_str {
            None => 100,
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--seal-deadline-us expects an integer, got {v:?}"))?,
        };
        let seal_at_rows = match args.get("seal-rows") {
            None => None,
            Some(n) => Some(
                n.parse()
                    .map_err(|_| anyhow::anyhow!("--seal-rows expects an integer, got {n:?}"))?,
            ),
        };
        let fidelity_str = args.get_str("fidelity", "word").to_string();
        let fidelity = Fidelity::parse(&fidelity_str).ok_or_else(|| {
            anyhow::anyhow!("unknown fidelity {fidelity_str:?} (phase|word|bitplane)")
        })?;
        if backend != "fast" && fidelity != Fidelity::WordFast {
            bail!("--fidelity applies to --backend fast only");
        }
        if args.get("wal-dir").is_none()
            && (args.get("fsync").is_some()
                || args.get("fsync-interval-us").is_some()
                || args.get("wal-segment-bytes").is_some())
        {
            bail!("--fsync/--fsync-interval-us/--wal-segment-bytes require --wal-dir");
        }
        let interval = Duration::from_micros(args.get_u64("fsync-interval-us", 2000)?);
        Ok(EnginePolicy {
            shards: args.get_usize("shards", 1)?,
            backend,
            artifact_dir: args.get_str("artifacts", "").to_string(),
            fidelity,
            seal_deadline: Duration::from_micros(deadline_us),
            seal_at_rows,
            fsync: FsyncPolicy::parse(args.get_str("fsync", "interval"), interval)?,
            segment_bytes: args.get_u64(
                "wal-segment-bytes",
                fast_sram::durability::DEFAULT_SEGMENT_BYTES,
            )?,
        })
    }

    /// Start one engine of the given shape under this policy. With a
    /// WAL directory the engine recovers it inside
    /// `UpdateEngine::start`, before any traffic.
    fn start(
        &self,
        rows: usize,
        q: usize,
        wal_dir: Option<PathBuf>,
        read_only: bool,
    ) -> Result<UpdateEngine> {
        let mut cfg = EngineConfig::sharded(rows, q, self.shards);
        cfg.seal_deadline = self.seal_deadline;
        if self.seal_at_rows.is_some() {
            cfg.seal_at_rows = self.seal_at_rows;
        }
        cfg.read_only = read_only;
        if let Some(dir) = wal_dir {
            let mut d = DurabilityConfig::new(dir);
            d.fsync = self.fsync.clone();
            d.segment_bytes = self.segment_bytes;
            cfg.durability = Some(d);
        }
        let engine = match self.backend.as_str() {
            "fast" => match self.fidelity {
                // The bit-plane tier transposes the shard's whole bank
                // set into one plane stack — the dedicated backend.
                Fidelity::BitPlane => UpdateEngine::start(cfg, move |plan| {
                    Ok(Box::new(BitPlaneBackend::with_rows(plan.rows, plan.q)))
                })?,
                f => UpdateEngine::start(cfg, move |plan| {
                    Ok(Box::new(FastBackend::with_rows_fidelity(plan.rows, plan.q, f)))
                })?,
            },
            "digital" => UpdateEngine::start(cfg, move |plan| {
                Ok(Box::new(DigitalBackend::new(plan.rows, plan.q)))
            })?,
            "xla" => {
                // AOT artifacts exist only for whole arrays (128/1024
                // rows) — sharding would need per-shard artifact
                // families.
                if self.shards > 1 {
                    bail!("--backend xla supports --shards 1 only (artifact shapes are fixed)");
                }
                let dir = if self.artifact_dir.is_empty() {
                    default_artifact_dir()
                } else {
                    PathBuf::from(&self.artifact_dir)
                };
                UpdateEngine::start(cfg, move |plan| {
                    Ok(Box::new(XlaBackend::new(&dir, plan.rows, plan.q)?))
                })?
            }
            other => bail!("unknown backend {other:?} (fast|digital|xla)"),
        };
        Ok(engine)
    }
}

/// Build the update engine `fast serve` fronts, from the shared CLI
/// flags (`--rows/--q/--shards/--backend/--fidelity/--seal-*`).
fn build_engine(args: &Args) -> Result<UpdateEngine> {
    let banks = args.get_usize("banks", 8)?;
    let rows = args.get_usize("rows", banks * 128)?;
    let q = args.get_usize("q", 16)?;
    let policy = EnginePolicy::parse(args)?;
    let wal_dir = args.get("wal-dir").map(PathBuf::from);
    // Replication roles: a follower starts read-only (writes answer
    // `ERR readonly` until `fast promote`), and both roles need the WAL
    // — it is the follower's durable cursor and the primary's shipped
    // history.
    let mut read_only = false;
    if args.get("follower").is_some() {
        anyhow::ensure!(
            wal_dir.is_some(),
            "--follower requires --wal-dir (the follower's WAL is its durable \
             replication cursor)"
        );
        anyhow::ensure!(
            args.get("repl-listen").is_none(),
            "--follower and --repl-listen are mutually exclusive roles"
        );
        read_only = true;
    } else if args.get("repl-listen").is_some() {
        anyhow::ensure!(
            wal_dir.is_some(),
            "--repl-listen requires --wal-dir (followers stream the durable WAL)"
        );
    }
    policy.start(rows, q, wal_dir, read_only)
}

/// `fast serve` — run the fast-serve-v1 front-end until a client sends
/// SHUTDOWN (TCP) or stdin closes (`--stdio`). Prints the final engine
/// stats on shutdown (a table, or one JSON line with `--stats-json`).
fn cmd_serve(args: &Args) -> Result<()> {
    if args.get_bool("tenants") {
        return cmd_serve_tenants(args);
    }
    let engine = std::sync::Arc::new(build_engine(args)?);
    let cfg = engine.config().clone();
    let stats_json = args.get_bool("stats-json");
    if let Some(d) = &cfg.durability {
        // Recovery already ran inside UpdateEngine::start — the engine
        // is serving the recovered state before the first connection.
        let seqs: Vec<String> = (0..cfg.shards)
            .map(|s| engine.committed_seq(s).map(|q| q.to_string()))
            .collect::<Result<_>>()?;
        eprintln!(
            "durability: WAL at {} (fsync={}, segment {} B); recovered commit seqs [{}]",
            d.dir.display(),
            d.fsync.name(),
            d.segment_bytes,
            seqs.join(",")
        );
    }

    // Replication role (validated by build_engine: both need --wal-dir).
    let repl = if let Some(primary) = args.get("follower") {
        let wal_dir = cfg.durability.as_ref().expect("follower has --wal-dir").dir.clone();
        let fail_stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let opts = FollowerOpts {
            on_fail_stop: Some(std::sync::Arc::clone(&fail_stop)),
            ..FollowerOpts::default()
        };
        let handle =
            spawn_follower(std::sync::Arc::clone(&engine), wal_dir, primary.to_string(), opts)?;
        eprintln!(
            "replication: follower of {primary} (reads served at the applied watermark; \
             writes answer ERR readonly until `fast promote`)"
        );
        Some(serve::ServeRepl {
            stats: std::sync::Arc::clone(&handle.stats),
            follower: Some(handle),
            repl_listener: None,
            fail_stop: Some(fail_stop),
        })
    } else if let Some(listen) = args.get("repl-listen") {
        let wal_dir = cfg.durability.as_ref().expect("primary has --wal-dir").dir.clone();
        let stats = ReplStats::new("primary", cfg.shards);
        let listener = ReplListener::start(
            listen,
            ReplListenerCfg {
                wal_dir,
                rows: cfg.rows,
                q: cfg.q,
                shards: cfg.shards,
                stats: std::sync::Arc::clone(&stats),
            },
        )?;
        eprintln!(
            "replication: shipping the WAL on {} (attach with \
             `fast serve --follower {}`)",
            listener.addr(),
            listener.addr()
        );
        Some(serve::ServeRepl {
            stats,
            follower: None,
            repl_listener: Some(listener),
            fail_stop: None,
        })
    } else {
        None
    };

    // Feed the replication lag gauge into the telemetry rate series
    // whenever the serve carries a role — the series (and /metrics)
    // then report live lag without touching the repl hot path.
    if let Some(r) = &repl {
        let stats = std::sync::Arc::clone(&r.stats);
        engine.telemetry().set_lag_source(move || stats.total_lag_lsn());
    }

    let report = if args.get_bool("stdio") {
        anyhow::ensure!(
            args.get("metrics-listen").is_none(),
            "--metrics-listen needs the TCP serve (drop --stdio)"
        );
        eprintln!(
            "fast-serve-v1 on stdio: {} rows x {} bits, {} shard(s), backend {}",
            cfg.rows,
            cfg.q,
            cfg.shards,
            engine.stats().backend
        );
        serve::serve_stdio_with(engine, repl)?
    } else {
        let listen = args.get_str("listen", "127.0.0.1:4750").to_string();
        let listener = std::net::TcpListener::bind(&listen)
            .map_err(|e| anyhow::anyhow!("binding {listen}: {e}"))?;
        let metrics = match args.get("metrics-listen") {
            Some(addr) => {
                let ml = std::net::TcpListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("binding metrics listener {addr}: {e}"))?;
                let render = serve::metrics_render_engine(
                    std::sync::Arc::clone(&engine),
                    repl.as_ref().map(|r| std::sync::Arc::clone(&r.stats)),
                );
                let server = MetricsServer::start(ml, render)?;
                eprintln!(
                    "telemetry: Prometheus exposition on http://{}/metrics",
                    server.local_addr()
                );
                Some(server)
            }
            None => None,
        };
        eprintln!(
            "fast-serve-v1 listening on {} ({} rows x {} bits, {} shard(s), backend {}) — \
             drive it with `fast client --connect {listen}` or any line client; \
             SHUTDOWN drains and exits",
            listener.local_addr()?,
            cfg.rows,
            cfg.q,
            cfg.shards,
            engine.stats().backend
        );
        serve::serve_tcp_observed(engine, listener, repl, metrics)?
    };

    // Clean drain happened inside serve_*; report it.
    let s = &report.stats;
    if stats_json {
        println!("{}", serve::stats_json_with_repl(s, report.repl.as_ref()));
    } else {
        let mut rows_txt = vec![
            ("backend".to_string(), s.backend.to_string()),
            ("submitted".to_string(), format!("{}", s.submitted)),
            ("completed".to_string(), format!("{}", s.completed)),
            ("rejected (backpressure)".to_string(), format!("{}", s.rejected)),
            ("tickets resolved".to_string(), format!("{}", s.tickets_resolved)),
            ("batches".to_string(), format!("{}", s.batches)),
            ("rows/batch".to_string(), format!("{:.1}", s.rows_per_batch)),
            ("modeled macro time".to_string(), format!("{:.2} µs", s.modeled_ns / 1000.0)),
            ("modeled energy".to_string(), format!("{:.2} nJ", s.modeled_energy_pj / 1000.0)),
            ("apply p99".to_string(), format!("{} ns", s.apply_wall.p99_ns)),
        ];
        for (i, sh) in s.shards.iter().enumerate() {
            rows_txt.push((
                format!("shard {i}"),
                format!(
                    "commit_seq {} | {} batches | commit wall p50/p95/p99 {}/{}/{} ns",
                    sh.commit_seq,
                    sh.batches_sealed,
                    sh.commit_wall.p50_ns,
                    sh.commit_wall.p95_ns,
                    sh.commit_wall.p99_ns,
                ),
            ));
        }
        if let Some(r) = &report.repl {
            rows_txt.push((
                "replication".to_string(),
                format!(
                    "role {} | epoch {} | {} frame(s) | {} reconnect(s) | {} digest(s)",
                    r.role, r.epoch, r.frames_applied, r.reconnects, r.digests_verified
                ),
            ));
        }
        print!("{}", render_table("serve (drained)", &rows_txt));
    }
    // A follower that fail-stopped on divergence must exit nonzero —
    // its state can no longer be trusted to match the primary.
    if let Some(r) = &report.repl {
        if let Some(msg) = &r.failed {
            bail!("replication fail-stop: {msg}");
        }
    }
    Ok(())
}

/// Build a tenant registry from the shared CLI flags: `--wal-dir`
/// becomes the registry root (manifest + per-tenant WAL
/// subdirectories at `<root>/tenants/<name>/`), and every tenant's
/// engine is started by the same owned [`EnginePolicy`].
fn build_registry(args: &Args) -> Result<TenantRegistry> {
    let policy = EnginePolicy::parse(args)?;
    match args.get("wal-dir") {
        Some(root) => {
            let root = PathBuf::from(root);
            let durable_root = root.clone();
            TenantRegistry::open(root, move |spec: &TenantSpec| {
                policy.start(
                    spec.rows,
                    spec.q,
                    Some(tenant_dir(&durable_root, &spec.name)),
                    false,
                )
            })
        }
        None => Ok(TenantRegistry::volatile(move |spec: &TenantSpec| {
            policy.start(spec.rows, spec.q, None, false)
        })),
    }
}

/// `fast serve --tenants` — the multi-tenant front-end: one registry
/// of named tenants, each with its own engine, precision q, quota and
/// (durable mode) WAL subdirectory. Sessions bind with `TENANT USE`
/// or route per line via the `"tenant"` event field; SHUTDOWN drains
/// every tenant.
fn cmd_serve_tenants(args: &Args) -> Result<()> {
    anyhow::ensure!(
        args.get("follower").is_none() && args.get("repl-listen").is_none(),
        "--tenants and replication roles are mutually exclusive \
         (replicate a tenant's WAL subdirectory with a dedicated serve instead)"
    );
    let stats_json = args.get_bool("stats-json");
    let reg = std::sync::Arc::new(build_registry(args)?);
    if let Some(root) = reg.root() {
        eprintln!(
            "tenant registry at {} ({} tenant(s) recovered before accepting connections)",
            root.display(),
            reg.len()
        );
    }
    let report = if args.get_bool("stdio") {
        anyhow::ensure!(
            args.get("metrics-listen").is_none(),
            "--metrics-listen needs the TCP serve (drop --stdio)"
        );
        eprintln!(
            "fast-serve-v1 (tenants) on stdio: {} tenant(s); bind with TENANT USE",
            reg.len()
        );
        serve::serve_stdio_tenants(reg)?
    } else {
        let listen = args.get_str("listen", "127.0.0.1:4750").to_string();
        let listener = std::net::TcpListener::bind(&listen)
            .map_err(|e| anyhow::anyhow!("binding {listen}: {e}"))?;
        let metrics = match args.get("metrics-listen") {
            Some(addr) => {
                let ml = std::net::TcpListener::bind(addr)
                    .map_err(|e| anyhow::anyhow!("binding metrics listener {addr}: {e}"))?;
                let render = serve::metrics_render_tenants(std::sync::Arc::clone(&reg));
                let server = MetricsServer::start(ml, render)?;
                eprintln!(
                    "telemetry: Prometheus exposition on http://{}/metrics \
                     (one labelled scope per tenant)",
                    server.local_addr()
                );
                Some(server)
            }
            None => None,
        };
        eprintln!(
            "fast-serve-v1 (tenants) listening on {} — {} tenant(s); \
             TENANT CREATE/USE/DROP/LIST administer the registry \
             (or `fast tenant … --connect {listen}`); SHUTDOWN drains every tenant",
            listener.local_addr()?,
            reg.len()
        );
        serve::serve_tcp_tenants_observed(reg, listener, metrics)?
    };
    if stats_json {
        println!("{}", serve::stats_json_tenants(&report.tenants));
    } else {
        let mut rows_txt = Vec::new();
        for (spec, s) in &report.tenants {
            rows_txt.push((
                format!("tenant {}", spec.name),
                format!(
                    "{} rows x {} bits (quota {}) | {} submitted | {} completed | \
                     {} batches | apply p99 {} ns",
                    spec.rows,
                    spec.q,
                    spec.quota_rows,
                    s.submitted,
                    s.completed,
                    s.batches,
                    s.apply_wall.p99_ns
                ),
            ));
        }
        if rows_txt.is_empty() {
            rows_txt.push(("tenants".to_string(), "none".to_string()));
        }
        print!("{}", render_table("serve (drained)", &rows_txt));
    }
    Ok(())
}

/// `fast stats --connect HOST:PORT [--watch]` — scrape a live serve's
/// `METRICS` verb and render the headline counters as a table; with
/// `--watch`, re-scrape on an interval and report scrape-to-scrape
/// deltas as live rates (ops/s, WAL B/s, batches/s).
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.get("connect").ok_or_else(|| {
        anyhow::anyhow!(
            "usage: fast stats --connect HOST:PORT [--watch] [--interval-ms N] [--count N]"
        )
    })?;
    let watch = args.get_bool("watch");
    let interval = Duration::from_millis(args.get_usize("interval-ms", 1000)? as u64);
    let count = args.get_usize("count", 0)?;
    // --watch with no --count runs until the connection drops;
    // a finite default keeps scripted runs bounded.
    let count = if count == 0 { if watch { usize::MAX } else { 1 } } else { count };
    serve::run_stats_client(addr, watch, interval, count)
}

/// `fast tenant create|drop|list` — tenant administration, over the
/// wire against a live `fast serve --tenants` (`--connect`) or
/// offline against a registry root (`--wal-dir`; takes each tenant's
/// single-writer lock, so a live serve on the same root blocks it).
fn cmd_tenant(args: &Args) -> Result<()> {
    let verb = args.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: fast tenant create NAME [--rows N] [--q 4|8|16] [--quota N] | \
             fast tenant drop NAME | fast tenant list \
             (--connect HOST:PORT or --wal-dir DIR)"
        )
    })?;
    let name = || {
        args.positional
            .get(1)
            .map(String::as_str)
            .ok_or_else(|| anyhow::anyhow!("fast tenant {verb} needs a tenant NAME"))
    };
    let rows = args.get_usize("rows", 128)?;
    let q = args.get_usize("q", 8)?;
    let quota = args.get_usize("quota", rows)?;
    if let Some(addr) = args.get("connect") {
        let line = match verb {
            "create" => format!("TENANT CREATE {} {rows} {q} {quota}", name()?),
            "drop" => format!("TENANT DROP {}", name()?),
            "list" => "TENANT LIST".to_string(),
            other => bail!("unknown tenant verb {other:?} (create|drop|list)"),
        };
        println!("{}", serve::run_tenant_cmd(addr, &line)?);
        return Ok(());
    }
    anyhow::ensure!(
        args.get("wal-dir").is_some(),
        "fast tenant needs --connect HOST:PORT (live serve) or --wal-dir DIR (offline)"
    );
    let reg = build_registry(args)?;
    match verb {
        "create" => {
            let spec = TenantSpec::with_quota(name()?, rows, q, quota)?;
            reg.create(spec.clone())?;
            println!(
                "created tenant {:?}: {} rows x {} bits, quota {}",
                spec.name, spec.rows, spec.q, spec.quota_rows
            );
        }
        "drop" => {
            let n = name()?;
            reg.drop_tenant(n)?;
            println!("dropped tenant {n:?}");
        }
        "list" => {
            if reg.is_empty() {
                println!("(no tenants)");
            }
            for s in reg.list() {
                println!("{} rows={} q={} quota={}", s.name, s.rows, s.q, s.quota_rows);
            }
        }
        other => bail!("unknown tenant verb {other:?} (create|drop|list)"),
    }
    reg.shutdown()?;
    Ok(())
}

/// `fast promote` — flip a replication follower into a writable
/// primary: it stops replicating, fences a new epoch (the old primary
/// is refused from then on), and starts accepting writes.
fn cmd_promote(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("usage: fast promote --connect HOST:PORT"))?;
    let epoch = serve::run_promote(addr)?;
    println!("promoted: {addr} now accepts writes at epoch {epoch}");
    Ok(())
}

/// `fast client` — protocol client for a running `fast serve`: streams
/// a fast-trace-v1 file through the wire, optionally prints the final
/// state digest, optionally shuts the server down.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_str("connect", "127.0.0.1:4750").to_string();
    let trace = match args.get("in") {
        Some(path) => Some(Trace::load(path)?),
        None => None,
    };
    let mode = match args.get_str("mode", "cmt") {
        "cmt" => serve::Mode::Cmt,
        "sub" => serve::Mode::Sub,
        other => bail!("unknown mode {other:?} (sub|cmt)"),
    };
    let want_digest = args.get_bool("digest");
    let query = args.get("query");
    let expect = match args.get("expect") {
        None => None,
        Some(v) => Some(
            v.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--expect expects an integer, got {v:?}"))?,
        ),
    };
    if expect.is_some() && query.is_none() {
        bail!("--expect requires --query");
    }
    let retry = serve::ClientRetry {
        retries: args.get_u64("retries", serve::ClientRetry::default().retries)?,
        backoff_us: args.get_u64("backoff-us", serve::ClientRetry::default().backoff_us)?,
    };
    let report = serve::run_client_retry(
        &addr,
        args.get("tenant"),
        trace.as_ref(),
        mode,
        want_digest,
        query,
        expect,
        args.get_bool("shutdown"),
        retry,
    )?;
    match report.digest {
        Some(digest) => println!("{digest}"),
        // run_client already errors when DIGEST fails; this guards the
        // contract so a half-failed stream can never exit 0 with an
        // empty stdout under --digest (the CI loopback diff relies on
        // a nonzero exit here).
        None if want_digest => bail!("server never returned the requested digest"),
        None => {}
    }
    if let Some(v) = report.query_value {
        // run_client already verified it against --expect or the trace
        // oracle; print the answer for scripted callers.
        eprintln!("query verified: value {v}");
    }
    eprintln!(
        "client done: {} event(s) acked, {} busy retr{}",
        report.acked,
        report.busy_retries,
        if report.busy_retries == 1 { "y" } else { "ies" }
    );
    Ok(())
}

/// `fast query` — stream a workload into the engine, then run one
/// in-array reduction over the committed state and print its value
/// with the plane-wise cost accounting. `--verify` re-runs the
/// reduction on a host-side scalar oracle over the trace's reference
/// state and fails on any value or accounting divergence.
fn cmd_query(args: &Args) -> Result<()> {
    let engine = build_engine(args)?;
    let cfg = engine.config().clone();
    // Workload: a recorded fast-trace-v1 file, or a seeded uniform
    // stream over the engine's shape.
    let trace = match args.get("in") {
        Some(path) => Trace::load(path)?,
        None => fast_sram::apps::trace::uniform_trace(
            cfg.rows,
            cfg.q,
            args.get_usize("updates", 5000)?,
            args.get_u64("seed", 66)?,
        ),
    };
    trace.replay(&engine)?;

    let tokens: Vec<&str> = args.positional.iter().map(String::as_str).collect();
    let spec = query::parse_spec(&tokens, cfg.rows, cfg.q)?;
    let r = engine.query(&spec)?;
    engine.shutdown()?;

    let verified = if args.get_bool("verify") {
        let (want, report) = query::scalar_reduce(&spec, &trace.reference_state(), cfg.q)?;
        anyhow::ensure!(
            r.value == want,
            "query mismatch: engine answered {}, host oracle says {want}",
            r.value
        );
        anyhow::ensure!(
            r.report == report,
            "accounting mismatch: engine reported {:?}, host oracle derived {report:?}",
            r.report
        );
        true
    } else {
        false
    };

    let seqs: Vec<String> = r.shard_seqs.iter().map(u64::to_string).collect();
    let mut rows_txt = vec![
        ("reduction".to_string(), spec.red.name().to_string()),
        ("value".to_string(), format!("{}", r.value)),
        ("rows reduced".to_string(), format!("{}", r.report.rows_active)),
        ("shift cycles".to_string(), format!("{}", r.report.cycles)),
        ("cell toggles".to_string(), format!("{}", r.report.cell_toggles)),
        ("ALU evaluations".to_string(), format!("{}", r.report.alu_evals)),
        ("banks active".to_string(), format!("{}", r.banks_active)),
        ("modeled energy".to_string(), format!("{:.3} pJ", r.cost.energy_fj / 1000.0)),
        ("modeled latency".to_string(), format!("{:.3} ns", r.cost.latency_ns)),
        ("observed commit seqs".to_string(), seqs.join(",")),
    ];
    if verified {
        rows_txt.push((
            "verified".to_string(),
            "value and accounting match the host scalar oracle".to_string(),
        ));
    }
    print!("{}", render_table("in-array query", &rows_txt));
    Ok(())
}

/// `fast bench engine|telemetry [--out PATH]` — the
/// measured-performance harnesses: the producers × shards scaling
/// grid (same implementation as `cargo bench --bench shard_scaling`,
/// writing `BENCH_shard_scaling.json`) and the telemetry-overhead A/B
/// (tracing on vs off under identical load, writing
/// `BENCH_telemetry_overhead.json`).
fn cmd_bench(args: &Args) -> Result<()> {
    let what = args.positional.first().map(String::as_str).unwrap_or("engine");
    match what {
        "engine" => {
            let cfg = bench::GridConfig::standard();
            let report = bench::run_engine_grid(&cfg)?;
            print!("{}", report.render_text());
            let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
                // Repo root, resolved at compile time — the measured
                // JSON replaces the committed placeholder in place.
                PathBuf::from(concat!(
                    env!("CARGO_MANIFEST_DIR"),
                    "/../BENCH_shard_scaling.json"
                ))
            });
            report.write_json(&out)?;
            println!("results written to {}", out.display());
            Ok(())
        }
        "telemetry" => {
            let cfg = bench::OverheadConfig::standard();
            let report = bench::run_telemetry_overhead(&cfg)?;
            print!("{}", report.render_text());
            let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
                PathBuf::from(concat!(
                    env!("CARGO_MANIFEST_DIR"),
                    "/../BENCH_telemetry_overhead.json"
                ))
            });
            report.write_json(&out)?;
            println!("results written to {}", out.display());
            Ok(())
        }
        other => {
            bail!("unknown bench target {other:?} (try: fast bench engine|telemetry [--out PATH])")
        }
    }
}

/// `fast wal <inspect|verify|compact|repair|export>` — offline
/// operations on a WAL directory. The mutating verbs (compact,
/// repair) take the directory's single-writer lock, so they refuse to
/// run while a live `fast serve` holds it.
fn cmd_wal(args: &Args) -> Result<()> {
    let verb = args.positional.first().map(String::as_str).ok_or_else(|| {
        anyhow::anyhow!("usage: fast wal inspect|verify|compact|repair|export --dir DIR")
    })?;
    let dir = std::path::PathBuf::from(
        args.get("dir")
            .ok_or_else(|| anyhow::anyhow!("fast wal {verb} needs --dir DIR"))?,
    );
    match verb {
        "inspect" => {
            let rep = durability::recover(&dir)?;
            let mut rows_txt = vec![
                ("shape".to_string(), format!("{} rows x {} bits, {} shard(s)", rep.rows, rep.q, rep.shards)),
                (
                    "snapshot".to_string(),
                    rep.snapshot
                        .as_ref()
                        .map(|p| p.display().to_string())
                        .unwrap_or_else(|| "none".to_string()),
                ),
                ("segments".to_string(), format!("{}", rep.segments)),
                ("tail records replayed".to_string(), format!("{}", rep.records_replayed)),
                ("state digest".to_string(), format!("{:016x}", rep.digest)),
            ];
            for (shard, mark) in rep.per_shard.iter().enumerate() {
                rows_txt.push((
                    format!("shard {shard}"),
                    format!("commit_seq {} | lsn {}", mark.commit_seq, mark.lsn),
                ));
            }
            for t in &rep.torn {
                rows_txt.push((
                    format!("torn tail (shard {})", t.shard),
                    format!("{} @ byte {} ({})", t.segment.display(), t.offset, t.reason),
                ));
            }
            // Per-segment write-coalescing stats from each shard's
            // sidecar; dirs written by pre-sidecar builds get an
            // explicit "(no sidecar)" row instead of silence.
            for shard in 0..rep.shards {
                rows_txt.extend(durability::coalesce_rows(&dir, shard));
            }
            print!("{}", render_table("wal inspect", &rows_txt));
            Ok(())
        }
        "verify" => {
            let rep = durability::recover(&dir)?;
            // A torn FINAL segment is the normal crash artifact —
            // recovery repairs it on the next durable start. Records
            // made unreachable by a mid-log tear are real corruption.
            for t in &rep.torn {
                if t.dropped_segments > 0 {
                    bail!(
                        "shard {}: bad frame in {} at byte {} makes {} later segment(s) \
                         unreachable ({}) — the log is corrupt beyond a torn tail; a \
                         durable engine will refuse this directory, and \
                         `fast wal repair --dir …` accepts the data loss explicitly",
                        t.shard,
                        t.segment.display(),
                        t.offset,
                        t.dropped_segments,
                        t.reason
                    );
                }
                eprintln!(
                    "note: shard {} has a torn tail at {} byte {} ({}) — \
                     recovery will truncate it",
                    t.shard,
                    t.segment.display(),
                    t.offset,
                    t.reason
                );
            }
            if args.get_bool("digest-only") {
                println!("{:016x}", rep.digest);
            } else {
                println!(
                    "wal ok: {} segment(s), {} tail record(s), state digest {:016x}",
                    rep.segments, rep.records_replayed, rep.digest
                );
            }
            Ok(())
        }
        "compact" => {
            let _lock = durability::DirLock::acquire(&dir)?;
            let rep = durability::compact(&dir)?;
            println!(
                "compacted: snapshot {} (digest {:016x}), {} segment(s) + {} old snapshot(s) \
                 removed, {} B reclaimed",
                rep.snapshot.display(),
                rep.digest,
                rep.segments_removed,
                rep.snapshots_removed,
                rep.bytes_reclaimed
            );
            Ok(())
        }
        "repair" => {
            // Destructive: truncates at the first bad frame wherever
            // it is and deletes stranded segments. This is the verb a
            // refused engine start points at.
            let _lock = durability::DirLock::acquire(&dir)?;
            let rep = durability::recover_force(&dir)?;
            if rep.torn.is_empty() {
                println!(
                    "nothing to repair: {} segment(s), state digest {:016x}",
                    rep.segments, rep.digest
                );
            } else {
                for t in &rep.torn {
                    println!(
                        "repaired shard {}: truncated {} at byte {} ({}), dropped {} \
                         unreachable segment(s)",
                        t.shard,
                        t.segment.display(),
                        t.offset,
                        t.reason,
                        t.dropped_segments
                    );
                }
                println!(
                    "post-repair state digest {:016x} ({} record(s) replayed)",
                    rep.digest, rep.records_replayed
                );
            }
            Ok(())
        }
        "export" => {
            let out = args
                .get("out")
                .ok_or_else(|| anyhow::anyhow!("fast wal export needs --out FILE"))?;
            let trace = durability::export_trace(&dir, args.get_str("name", "wal-export"))?;
            trace.save(out)?;
            println!(
                "exported {} event(s) over {} rows x {} bits -> {out} \
                 (digest-check with: fast trace replay --in {out} --digest-only)",
                trace.events.len(),
                trace.rows,
                trace.q
            );
            Ok(())
        }
        other => bail!("unknown wal verb {other:?} (inspect|verify|compact|repair|export)"),
    }
}

fn cmd_validate(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(default_artifact_dir);
    let trials = args.get_usize("trials", 3)?;
    let rt = Runtime::load_dir(&dir)?;
    println!("platform: {} | artifacts: {}", rt.platform(), rt.len());
    let mut total = 0usize;
    for name in rt.names() {
        let art = rt.get(name)?;
        let checked = if art.meta.op == "scan_add" {
            validate::validate_scan(art, trials, 0xFA57)?
        } else {
            validate::validate2(art, trials, 0xFA57)?
        };
        println!("  {name:<22} OK ({checked} words checked)");
        total += checked;
    }
    println!("all artifacts consistent with host semantics ({total} words)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(Into::into)
        .unwrap_or_else(default_artifact_dir);
    let rt = Runtime::load_dir(&dir)?;
    println!(
        "artifact dir: {} | platform: {}",
        rt.artifact_dir().display(),
        rt.platform()
    );
    for name in rt.names() {
        let a = rt.get(name)?;
        println!(
            "  {:<22} op={:<8} rows={:<5} q={:<2} rounds={:?}",
            name, a.meta.op, a.meta.rows, a.meta.q, a.meta.rounds
        );
    }
    Ok(())
}
