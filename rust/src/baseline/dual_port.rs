//! Dual-port SRAM strawman (paper Fig. 1a): read port and write port
//! operate concurrently, so a row update takes one access instead of
//! two — but rows are still visited serially and the ALU lives in the
//! periphery. This is the architecture the paper's introduction uses
//! to illustrate the row-by-row bottleneck.

use super::sram6t::Sram6T;
use crate::energy::{Cost, DualPortModel};
use crate::fastmem::AluOp;
use crate::util::bits;

/// A dual-port array: same storage, overlapped R/W scheduling.
#[derive(Debug, Clone)]
pub struct DualPortArray {
    sram: Sram6T,
    model: DualPortModel,
    q: usize,
}

impl DualPortArray {
    pub fn new(rows: usize, q: usize) -> Self {
        DualPortArray { sram: Sram6T::new(rows, q), model: DualPortModel::default(), q }
    }

    pub fn rows(&self) -> usize {
        self.sram.rows()
    }

    pub fn load(&mut self, words: &[u32]) {
        self.sram.load(words);
    }

    pub fn snapshot(&self) -> Vec<u32> {
        self.sram.snapshot()
    }

    /// Row-serial update with overlapped read/write: while row r writes
    /// back, row r+1 is being read (software pipeline of depth 2).
    pub fn batch_apply(&mut self, op: AluOp, operands: &[u32]) -> Cost {
        assert_eq!(operands.len(), self.sram.rows());
        let m = bits::mask(self.q);
        for (r, &operand) in operands.iter().enumerate() {
            let cur = self.sram.read(r).expect("in range");
            let next = match op {
                AluOp::Add => bits::add_mod(cur, operand, self.q),
                AluOp::Sub => bits::sub_mod(cur, operand, self.q),
                AluOp::And => cur & operand & m,
                AluOp::Or => (cur | operand) & m,
                AluOp::Xor => (cur ^ operand) & m,
                AluOp::Pass => cur,
            };
            self.sram.write(r, next).expect("in range");
        }
        self.model.batch_update(self.sram.rows(), self.q)
    }

    pub fn batch_add(&mut self, operands: &[u32]) -> Cost {
        self.batch_apply(AluOp::Add, operands)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn semantics_match_modular_add() {
        let mut a = DualPortArray::new(4, 8);
        a.load(&[250, 1, 2, 3]);
        a.batch_add(&[10, 10, 10, 10]);
        assert_eq!(a.snapshot(), vec![4, 11, 12, 13]);
    }

    #[test]
    fn latency_between_fast_and_nothing() {
        // One access per row — faster than 2 serialized accesses, but
        // still linear in rows (unlike FAST's q-cycle batch).
        let mut a = DualPortArray::new(128, 16);
        a.load(&vec![0; 128]);
        let c = a.batch_add(&vec![1; 128]);
        let per_row = c.latency_ns / 128.0;
        assert!((per_row - 0.94).abs() < 1e-9);
    }
}
