//! Conventional 6T SRAM array: word-oriented storage with a single
//! read/write port; all multi-row work is serialized through the port.

use std::fmt;

use crate::util::bits;

#[derive(Debug, PartialEq, Eq)]
pub enum SramError {
    /// Row index out of range (index, rows).
    RowOutOfRange(usize, usize),
    /// Word value exceeds the array's bit width (word, width).
    WordTooWide(u32, usize),
}

impl fmt::Display for SramError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SramError::RowOutOfRange(r, rows) => {
                write!(f, "row {r} out of range (rows = {rows})")
            }
            SramError::WordTooWide(w, q) => {
                write!(f, "word {w:#x} exceeds {q}-bit width")
            }
        }
    }
}

impl std::error::Error for SramError {}

/// A conventional 6T SRAM array of `rows` words of `q` bits.
#[derive(Debug, Clone)]
pub struct Sram6T {
    words: Vec<u32>,
    q: usize,
    reads: u64,
    writes: u64,
}

impl Sram6T {
    pub fn new(rows: usize, q: usize) -> Self {
        assert!(rows >= 1);
        let _ = bits::mask(q); // validates q
        Sram6T { words: vec![0; rows], q, reads: 0, writes: 0 }
    }

    pub fn rows(&self) -> usize {
        self.words.len()
    }

    pub fn width(&self) -> usize {
        self.q
    }

    pub fn read(&mut self, row: usize) -> Result<u32, SramError> {
        if row >= self.words.len() {
            return Err(SramError::RowOutOfRange(row, self.words.len()));
        }
        self.reads += 1;
        Ok(self.words[row])
    }

    pub fn write(&mut self, row: usize, word: u32) -> Result<(), SramError> {
        if row >= self.words.len() {
            return Err(SramError::RowOutOfRange(row, self.words.len()));
        }
        if word > bits::mask(self.q) {
            return Err(SramError::WordTooWide(word, self.q));
        }
        self.writes += 1;
        self.words[row] = word;
        Ok(())
    }

    /// Port access counters (inputs to the energy model).
    pub fn reads(&self) -> u64 {
        self.reads
    }

    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Bulk load without port accounting (test setup convenience).
    pub fn load(&mut self, words: &[u32]) {
        assert_eq!(words.len(), self.words.len());
        let m = bits::mask(self.q);
        for (dst, &w) in self.words.iter_mut().zip(words) {
            assert!(w <= m, "word {w:#x} exceeds width");
            *dst = w;
        }
    }

    pub fn snapshot(&self) -> Vec<u32> {
        self.words.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_roundtrip() {
        let mut s = Sram6T::new(8, 16);
        s.write(3, 0xBEEF).unwrap();
        assert_eq!(s.read(3).unwrap(), 0xBEEF);
        assert_eq!(s.reads(), 1);
        assert_eq!(s.writes(), 1);
    }

    #[test]
    fn bounds_and_width_checked() {
        let mut s = Sram6T::new(4, 8);
        assert_eq!(s.read(4), Err(SramError::RowOutOfRange(4, 4)));
        assert_eq!(s.write(0, 0x100), Err(SramError::WordTooWide(0x100, 8)));
    }

    #[test]
    fn load_and_snapshot() {
        let mut s = Sram6T::new(3, 8);
        s.load(&[1, 2, 3]);
        assert_eq!(s.snapshot(), vec![1, 2, 3]);
        assert_eq!(s.writes(), 0, "bulk load is not port traffic");
    }
}
