//! The fully-digital near-memory computing baseline (paper Fig. 9):
//! a general-purpose 6T SRAM assisted by standard-cell digital logic.
//! Batch updates sweep the array **row by row** through a read → ALU →
//! write-back pipeline — the serialization FAST eliminates.
//!
//! Functionally equivalent to `FastArray` batch ops (same q-bit modular
//! semantics) so results can be diffed word-for-word; the difference is
//! the cost profile, which `energy::DigitalModel` charges per row.

use super::sram6t::Sram6T;
use crate::energy::{Cost, DigitalModel};
use crate::fastmem::AluOp;
use crate::util::bits;

/// Outcome of one baseline batch update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepReport {
    /// Rows processed (pipeline iterations).
    pub rows: u64,
    /// Port reads / writes issued.
    pub reads: u64,
    pub writes: u64,
    /// Modeled cost of the sweep.
    pub cost: Cost,
}

/// The near-memory digital engine wrapping a 6T SRAM.
#[derive(Debug, Clone)]
pub struct DigitalEngine {
    sram: Sram6T,
    model: DigitalModel,
    q: usize,
}

impl DigitalEngine {
    pub fn new(rows: usize, q: usize) -> Self {
        DigitalEngine {
            sram: Sram6T::new(rows, q),
            model: DigitalModel::default(),
            q,
        }
    }

    pub fn rows(&self) -> usize {
        self.sram.rows()
    }

    pub fn width(&self) -> usize {
        self.q
    }

    pub fn load(&mut self, words: &[u32]) {
        self.sram.load(words);
    }

    pub fn snapshot(&self) -> Vec<u32> {
        self.sram.snapshot()
    }

    pub fn read_row(&mut self, row: usize) -> u32 {
        self.sram.read(row).expect("row in range")
    }

    pub fn write_row(&mut self, row: usize, word: u32) {
        self.sram.write(row, word).expect("row in range, word in width")
    }

    /// Row-by-row batch update: for every row, read, apply the ALU op
    /// with the row's operand, write back. One operand per row.
    pub fn batch_apply(&mut self, op: AluOp, operands: &[u32]) -> SweepReport {
        assert_eq!(operands.len(), self.sram.rows());
        let rows = self.sram.rows();
        let m = bits::mask(self.q);
        for (r, &operand) in operands.iter().enumerate() {
            let cur = self.sram.read(r).expect("in range");
            let next = match op {
                AluOp::Add => bits::add_mod(cur, operand, self.q),
                AluOp::Sub => bits::sub_mod(cur, operand, self.q),
                AluOp::And => cur & operand & m,
                AluOp::Or => (cur | operand) & m,
                AluOp::Xor => (cur ^ operand) & m,
                AluOp::Pass => cur,
            };
            self.sram.write(r, next).expect("in range");
        }
        SweepReport {
            rows: rows as u64,
            reads: rows as u64,
            writes: rows as u64,
            cost: self.model.batch_update(rows, self.q),
        }
    }

    pub fn batch_add(&mut self, operands: &[u32]) -> SweepReport {
        self.batch_apply(AluOp::Add, operands)
    }

    pub fn batch_sub(&mut self, operands: &[u32]) -> SweepReport {
        self.batch_apply(AluOp::Sub, operands)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fastmem::FastArray;
    use crate::util::rng::Rng;

    #[test]
    fn batch_add_semantics() {
        let mut e = DigitalEngine::new(8, 16);
        e.load(&[10, 20, 30, 40, 50, 60, 70, 0xFFFF]);
        let rep = e.batch_add(&[1, 2, 3, 4, 5, 6, 7, 1]);
        assert_eq!(rep.rows, 8);
        assert_eq!(
            e.snapshot(),
            vec![11, 22, 33, 44, 55, 66, 77, 0]
        );
    }

    #[test]
    fn same_function_as_fast_array() {
        // The paper's requirement: "This baseline is built with the same
        // function as the FAST SRAM."
        let mut rng = Rng::new(17);
        let init: Vec<u32> = (0..32).map(|_| rng.below(1 << 16) as u32).collect();
        let deltas: Vec<u32> = (0..32).map(|_| rng.below(1 << 16) as u32).collect();

        let mut fast = FastArray::new(32, 16);
        fast.load(&init);
        fast.batch_add(&deltas);

        let mut dig = DigitalEngine::new(32, 16);
        dig.load(&init);
        dig.batch_add(&deltas);

        assert_eq!(fast.snapshot(), dig.snapshot());
    }

    #[test]
    fn sweep_cost_scales_with_rows() {
        let mut small = DigitalEngine::new(32, 16);
        let mut large = DigitalEngine::new(256, 16);
        let r1 = small.batch_add(&vec![1; 32]);
        let r2 = large.batch_add(&vec![1; 256]);
        assert!(r2.cost.latency_ns > 7.0 * r1.cost.latency_ns);
        assert!(r2.cost.energy_fj > 8.0 * r1.cost.energy_fj);
    }

    #[test]
    fn logic_ops_match_host_semantics() {
        for op in [AluOp::And, AluOp::Or, AluOp::Xor] {
            let mut e = DigitalEngine::new(2, 8);
            e.load(&[0xF0, 0x0F]);
            e.batch_apply(op, &[0xAA, 0xAA]);
            let want = |a: u32| match op {
                AluOp::And => a & 0xAA,
                AluOp::Or => (a | 0xAA) & 0xFF,
                AluOp::Xor => (a ^ 0xAA) & 0xFF,
                _ => unreachable!(),
            };
            assert_eq!(e.snapshot(), vec![want(0xF0), want(0x0F)], "{op:?}");
        }
    }
}
