//! Baseline architectures the paper compares against (Fig. 1a, Fig. 9).
//!
//! - [`sram6t`] — conventional 6T SRAM array, row-by-row port access
//! - [`digital`] — the fully-digital near-memory computing engine:
//!   6T SRAM swept through a standard-cell ALU pipeline (Fig. 9)
//! - [`dual_port`] — dual-port strawman with overlapped read/write
//!
//! The behavioural baselines implement the *same* batch-update
//! semantics as [`crate::fastmem::FastArray`] so tests can diff results
//! word-for-word, while their cost models charge row-serial latency.

pub mod digital;
pub mod dual_port;
pub mod sram6t;

pub use digital::DigitalEngine;
pub use dual_port::DualPortArray;
pub use sram6t::Sram6T;
