//! The sharded concurrent update engine — the Layer-3 system around
//! the FAST macros: admission control, per-shard coalescing batchers,
//! group-commit seal policy, worker threads, metrics.
//!
//! ## Sharding
//!
//! The paper's hardware updates *all 128 rows of a macro concurrently*;
//! a single coordinator worker would serialize in software exactly what
//! the array parallelizes. The engine therefore stripes the logical row
//! space over `shards` independent shards (power of two). A row is
//! routed by its low bits — `shard = row & (shards - 1)`, `local_row =
//! row >> log2(shards)` — so contiguous and uniform workloads both
//! spread evenly. Each shard owns:
//!
//! - a bounded command queue (admission control / backpressure),
//! - a [`Batcher`] coalescing same-row deltas,
//! - a worker thread,
//! - a [`Backend`] instance over the shard's rows.
//!
//! Same-row requests always land on the same shard, so per-row order is
//! program order. Cross-row ordering between shards is relaxed — the
//! same contract a multi-bank memory gives the hardware.
//!
//! ## Group commit
//!
//! Each shard seals batches like a write-ahead log groups commits: a
//! batch is sealed when it is *full* (`seal_at_rows` distinct rows),
//! when a request of a different batch kind arrives, when the
//! *seal deadline* expires (bounded staleness), or when a read needs
//! read-your-writes consistency. One backend dispatch then applies the
//! whole batch, amortizing dispatch cost the way group commit
//! amortizes fsync.
//!
//! ## Commit sequencing and completion tickets
//!
//! The engine is a request/response pipeline. Every sealed batch gets
//! a per-shard **commit sequence number** at seal time (1, 2, 3, …);
//! after the backend applies, the worker resolves every completion
//! ticket riding the batch with a [`Commit`] (`{shard, commit_seq,
//! seal_reason, modeled_ns, …}`) and publishes the committed seq for
//! [`UpdateEngine::wait_seq`]. Read-your-writes is per shard *and per
//! row*: a read at row `r` seals the owning shard's open batch only
//! when that batch actually pends an update for `r` — no global
//! flush, and an untouched read leaves even the owning shard's batch
//! open. The only whole-engine barriers left are
//! [`UpdateEngine::snapshot`] and [`UpdateEngine::shutdown`]; callers
//! that need "my work landed" use tickets, `wait_seq`, or
//! [`UpdateEngine::drain_shard`].
//!
//! Lifecycle: `UpdateEngine::start(config, backend_factory)` spawns one
//! worker per shard; each worker *constructs its backend inside the
//! thread* (PJRT executables are not `Send`).
//!
//! ## Hot-path de-locking
//!
//! Tokio is not in the offline vendor set (DESIGN.md §7); admission
//! rides `std::thread` plus a bounded **lock-free MPSC ring**
//! ([`crate::util::ring`]) per shard — `mpsc::sync_channel` took a
//! mutex on every send/recv, serializing producers on the queue lock
//! before they ever reached the worker. Queue depth and the
//! high-water mark are now derived from the ring's own head/tail
//! distance, which is capped at `queue_cap` by construction (the old
//! raise-before-send gauge could transiently overcount past the cap
//! when a rejected submit raced an admitted one). Ticket resolution
//! is batch-wake: each shard publishes its commit epoch on one shared
//! [`WaitHub`] (`publish` + a single `notify_all` per seal) instead of
//! taking a `Mutex+Condvar` per ticket. Contention is observable
//! without a profiler via the `submit_spins` / `park_events` /
//! `wake_batch` shard counters.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure};

use crate::durability::{
    recover::recover_or_init,
    wal::{ShardWal, WalPayload, WalRecord},
    DirLock, DurabilityConfig, ShardMark,
};
use crate::energy::Cost;
use crate::fastmem::BatchReport;
use crate::metrics::{
    Counters, EnergyAccount, LatencyRecorder, LatencySummary, ShardCounters, ShardSnapshot,
};
use crate::query::{shard_specs, QueryOutcome, QuerySpec, Reduction};
use crate::telemetry::{
    now_ns, PendingSpan, SeriesSample, ShardSpanState, SpanEvent, Telemetry, TelemetryConfig,
};
use crate::util::ring::{self, RingReceiver, RingSender};
use crate::Result;

use super::backend::Backend;
use super::batcher::{Batch, Batcher, SealReason};
use super::request::{
    ticket_on, BatchKind, Commit, SeqWait, Ticket, TicketNotifier, UpdateRequest, WaitHub,
};

/// Engine configuration. All knobs have CLI flags on `fast serve`.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Logical rows across all shards (must match the summed backend
    /// rows). Unit: rows. Must be divisible by `shards`.
    pub rows: usize,
    /// Word width q. Unit: bits (1..=32).
    pub q: usize,
    /// Worker shards. Unit: count; must be a power of two and divide
    /// `rows`. Default 1 (single-worker, the pre-sharding behaviour).
    /// Each shard owns the rows whose low bits equal its index.
    pub shards: usize,
    /// Group-commit size seal: seal a shard's batch once this many
    /// distinct rows of the *logical* space are touched (each shard
    /// seals at `max(1, seal_at_rows / shards)` of its own rows).
    /// Unit: rows. `None` = seal only on kind change / deadline / read.
    /// Default: 75% of the row space.
    pub seal_at_rows: Option<usize>,
    /// Group-commit deadline seal: a non-empty open batch is flushed
    /// this long after its first pending request (bounded staleness).
    /// Unit: duration (CLI flag `--seal-deadline-us`). Default 100 µs.
    pub seal_deadline: Duration,
    /// Bounded per-shard command-queue depth (admission control).
    /// Unit: commands. Default 4096.
    pub queue_cap: usize,
    /// Durability knobs (CLI `fast serve --wal-dir`): when set, the
    /// engine recovers the WAL directory BEFORE accepting work
    /// (snapshot + per-shard tail replay, torn tails repaired), each
    /// shard worker appends every commit and conventional-port write
    /// to a segmented WAL aligned with the group-commit seals, and
    /// per-shard `commit_seq` continues from the recovered watermark.
    /// `None` (default) = volatile, the pre-durability behaviour.
    pub durability: Option<DurabilityConfig>,
    /// Start in read-only (replication follower) mode: every update
    /// submit path and the conventional-port write are rejected with a
    /// typed [`EngineReadOnly`] error; reads, waits, queries, drains
    /// and snapshots still work. Replicated WAL frames enter through
    /// [`UpdateEngine::apply_replicated`], and a later
    /// [`UpdateEngine::promote_writable`] (failover) flips the engine
    /// to accepting writes. Default `false`.
    pub read_only: bool,
    /// Span-tracing knobs ([`crate::telemetry`]): seeded-deterministic
    /// sampling of 1 in `sample_rate` admissions into per-shard SPSC
    /// span rings, drained into stage histograms by a background
    /// thread. Always-on by default at 1/64; the unsampled hot path
    /// pays one relaxed `fetch_add` plus one hash — no locks, no
    /// allocations, no clock read.
    pub telemetry: TelemetryConfig,
}

impl EngineConfig {
    /// A sensible default for an R-row, q-bit array: one shard, seal at
    /// 75% of the row space, 100 µs seal deadline, 4096-deep queue.
    pub fn new(rows: usize, q: usize) -> Self {
        EngineConfig {
            rows,
            q,
            shards: 1,
            seal_at_rows: Some((rows * 3 / 4).max(1)),
            seal_deadline: Duration::from_micros(100),
            queue_cap: 4096,
            durability: None,
            read_only: false,
            telemetry: TelemetryConfig::default(),
        }
    }

    /// Default config striped over `shards` worker shards.
    pub fn sharded(rows: usize, q: usize, shards: usize) -> Self {
        let mut cfg = Self::new(rows, q);
        cfg.shards = shards;
        cfg
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.rows >= 1, "rows must be >= 1");
        ensure!(self.shards >= 1, "shards must be >= 1, got {}", self.shards);
        ensure!(
            self.shards.is_power_of_two(),
            "shards must be a power of two, got {}",
            self.shards
        );
        ensure!(
            self.rows % self.shards == 0,
            "rows {} not divisible by shards {}",
            self.rows,
            self.shards
        );
        ensure!(self.queue_cap >= 1, "queue_cap must be >= 1");
        ensure!(
            self.telemetry.sample_rate.is_power_of_two(),
            "telemetry sample_rate must be a power of two, got {}",
            self.telemetry.sample_rate
        );
        Ok(())
    }

    /// log2(shards); valid after `validate`.
    fn shard_bits(&self) -> u32 {
        self.shards.trailing_zeros()
    }
}

/// Typed admission-rejection error: the target shard's bounded queue
/// is full (transient backpressure — retry later). Carried as the
/// root cause of the `anyhow` error the non-blocking submit paths
/// return, so protocol layers can distinguish retryable backpressure
/// from terminal errors:
/// `err.root_cause().downcast_ref::<EngineBusy>().is_some()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineBusy;

impl std::fmt::Display for EngineBusy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "queue full: request rejected (backpressure)")
    }
}

impl std::error::Error for EngineBusy {}

/// Typed read-only-rejection error: the engine is running as a
/// replication follower ([`EngineConfig::read_only`]) and refuses
/// every mutation until promoted. Carried as the root cause of the
/// `anyhow` error the submit/write paths return, so protocol layers
/// can reply with a typed `ERR readonly` instead of a generic failure:
/// `err.root_cause().downcast_ref::<EngineReadOnly>().is_some()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineReadOnly;

impl std::fmt::Display for EngineReadOnly {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "engine is read-only (replication follower): writes are rejected until promotion"
        )
    }
}

impl std::error::Error for EngineReadOnly {}

/// Identity of one engine shard, handed to the backend factory so it
/// can size the backend to the shard's slice of the row space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Shard index in `0..shards`.
    pub shard: usize,
    /// Total shard count (power of two).
    pub shards: usize,
    /// Rows owned by this shard (`config.rows / shards`).
    pub rows: usize,
    /// Word width q (bits).
    pub q: usize,
}

/// The factory that builds one backend per shard, invoked *on the
/// shard's worker thread* (PJRT executables are not `Send`).
pub type BackendFactory =
    dyn Fn(&ShardPlan) -> Result<Box<dyn Backend>> + Send + Sync + 'static;

/// Per-shard commit hook, invoked on the shard's worker thread AFTER
/// the backend applied a mutation and BEFORE any completion ticket
/// resolves — so a resolved ticket implies the listener saw the
/// commit (the durability layer rides this: ticket resolution order
/// is unchanged, but resolution now implies the commit is logged).
/// A listener error is fatal to the shard: the worker stops, pending
/// tickets error out, and the committed-seq latch closes — exactly
/// the established backend-fault path.
pub trait CommitListener: Send {
    /// One sealed batch committed. `operands` is the dense coalesced
    /// operand vector (identity-filled for untouched rows).
    fn on_commit(&mut self, commit: &Commit, kind: BatchKind, operands: &[u32]) -> Result<()>;

    /// One conventional-port absolute write landed. `committed_seq`
    /// is the shard's last committed batch seq (writes do not mint
    /// commit seqs).
    fn on_write(&mut self, row: usize, value: u32, committed_seq: u64) -> Result<()> {
        let _ = (row, value, committed_seq);
        Ok(())
    }

    /// A barrier (drain / snapshot / shutdown) passed: flush anything
    /// buffered (the WAL fsyncs here regardless of policy).
    fn on_barrier(&mut self) -> Result<()> {
        Ok(())
    }

    /// When must buffered durability state reach the disk even if no
    /// further traffic arrives? The shard worker forces
    /// [`Self::on_barrier`] once this instant passes, so an interval
    /// fsync policy bounds the persistence lag of a burst's LAST
    /// commits too — not just the ones that happen to be followed by
    /// another append. `None` = nothing pending (the default).
    fn flush_due(&self) -> Option<Instant> {
        None
    }

    /// The shard worker is about to block waiting for work (its
    /// command queue is empty): flush anything opportunistically
    /// buffered. The WAL's cross-seal write coalescing rides this —
    /// frames staged during a burst are written out the moment the
    /// burst ends, so staging never extends the durability lag beyond
    /// the active burst (fsync timing is still governed by the policy
    /// / [`Self::flush_due`]). Default: nothing to do.
    fn on_quiescent(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Per-shard worker bootstrap: the commit listener, recovered state to
/// preload into the backend, and the first commit seq to mint.
struct WorkerInit {
    listener: Option<Box<dyn CommitListener>>,
    /// Shard-local row values to restore before going live (recovered
    /// state; only non-zero rows are written).
    preload: Option<Vec<u32>>,
    /// First commit seq to assign (recovered watermark + 1; 1 on a
    /// fresh engine).
    first_seq: u64,
    /// This shard's span-tracing state (the SPSC ring the worker
    /// publishes completed spans into); installed by `start_inner`.
    span: Option<Arc<ShardSpanState>>,
}

impl Default for WorkerInit {
    fn default() -> Self {
        WorkerInit { listener: None, preload: None, first_seq: 1, span: None }
    }
}

enum Command {
    /// One request, with an optional completion ticket and the sampled
    /// submit stamp (`telemetry::now_ns` at admission; 0 = unsampled —
    /// the overwhelmingly common case).
    Submit(UpdateRequest, Option<TicketNotifier>, u64),
    /// Amortizes channel crossings for bulk producers (one message per
    /// chunk instead of per request). Rows are shard-local. The
    /// optional waiter acks the chunk's LAST request — per-shard FIFO
    /// means its commit implies every earlier request of the chunk on
    /// this shard committed too. The stamp samples the chunk as one
    /// admission (0 = unsampled).
    SubmitMany(Vec<UpdateRequest>, Option<TicketNotifier>, u64),
    Read(usize, SyncSender<Result<u32>>),
    Write(usize, u32, SyncSender<Result<()>>),
    /// One in-array reduction over this shard's (already shard-local)
    /// spec; replies with the partial outcome plus the commit seq the
    /// query observed.
    Query(QuerySpec, SyncSender<Result<ShardQueryPart>>),
    /// Force-seal the open batch (per-shard drain); replies with the
    /// shard's last committed sequence number once applied.
    Drain(SyncSender<u64>),
    Snapshot(SyncSender<Result<Vec<u32>>>),
    /// Apply one replicated WAL record (follower mode): the frame's
    /// commit_seq must be exactly the shard's next seq (batch) or its
    /// last committed seq (write) — any mismatch is log divergence and
    /// fail-stops the shard. Re-logged through the local WAL listener
    /// and published to the committed-seq latch like a native commit.
    ReplApply(WalRecord, SyncSender<Result<()>>),
    Shutdown,
}

// Per-shard committed-sequence latch: the [`WaitHub`] from
// `coordinator::request`. Workers publish after every apply (one
// `notify_all` that wakes sequence waiters AND the seal's ticket
// waiters — the batch-wake path), `wait_seq` blocks on it, shutdown
// closes it so waiters can never hang on a sequence that will no
// longer arrive.

/// Shared metrics handle.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub counters: Counters,
    pub energy: EnergyAccount,
    /// Wall-clock time spent applying batches (all shards).
    pub apply_wall: LatencyRecorder,
    /// Per-shard counters (group-commit seal reasons, queue depth,
    /// WAL counters, …). `Arc` so the durability appenders can record
    /// into their shard's counters without holding the whole metrics
    /// handle.
    pub shards: Vec<Arc<ShardCounters>>,
    /// Modeled macro time in femtoseconds (ns × 1e6, atomically summed).
    modeled_fs: AtomicU64,
}

impl EngineMetrics {
    fn new(shards: usize) -> Self {
        EngineMetrics {
            shards: (0..shards).map(|_| Arc::new(ShardCounters::default())).collect(),
            ..Default::default()
        }
    }

    pub fn add_modeled_ns(&self, ns: f64) {
        self.modeled_fs
            .fetch_add((ns * 1e6).round() as u64, Ordering::Relaxed);
    }

    pub fn modeled_ns(&self) -> f64 {
        self.modeled_fs.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Point-in-time engine statistics.
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub rows_updated: u64,
    pub rows_per_batch: f64,
    pub modeled_ns: f64,
    pub modeled_energy_pj: f64,
    pub apply_wall: LatencySummary,
    pub backend: &'static str,
    /// Requests admitted but not yet drained by workers (all shards).
    pub queue_depth: u64,
    /// Completion tickets resolved across all shards.
    pub tickets_resolved: u64,
    /// In-array queries answered across all shards (one engine-level
    /// query counts once per shard it fanned out to).
    pub queries: u64,
    /// Spin-loop probes blocking submits burned on full rings, all
    /// shards (admission contention gauge).
    pub submit_spins: u64,
    /// Times a blocking submit gave up spinning and parked, all
    /// shards.
    pub park_events: u64,
    /// WAL writes that carried ≥ 2 coalesced frames, all shards.
    pub wal_coalesced_writes: u64,
    /// Frames delivered by those coalesced writes, all shards.
    pub wal_coalesced_frames: u64,
    /// Per-shard breakdown (seal reasons, coalesce hits, queue depth,
    /// commit sequence, submit→commit latency histograms).
    pub shards: Vec<ShardSnapshot>,
}

/// One shard's query answer (the wire format of [`Command::Query`]).
struct ShardQueryPart {
    outcome: QueryOutcome,
    commit_seq: u64,
}

/// Pending engine query: one partial result per shard, combined by
/// [`QueryTicket::wait`]. Like a completion [`Ticket`], waiting never
/// hangs — a shard that stops before answering surfaces as an error.
pub struct QueryTicket {
    red: Reduction,
    q: usize,
    parts: Vec<Receiver<Result<ShardQueryPart>>>,
}

/// Combined engine-level query result.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResult {
    /// The reduction's value over the whole logical row space (see
    /// [`Reduction`] for the empty-selection conventions).
    pub value: u64,
    /// Combined rotate-read pass accounting: `cycles` maxed (shards
    /// rotate concurrently), the activity fields summed.
    pub report: BatchReport,
    /// Banks holding at least one enabled row, across all shards.
    pub banks_active: usize,
    /// Modeled cost: energy summed over shards, latency maxed.
    pub cost: Cost,
    /// Per-shard commit sequence the query observed: the value
    /// reflects every commit through `shard_seqs[s]` on shard `s` and
    /// none after — read-your-writes, extended to reductions.
    pub shard_seqs: Vec<u64>,
}

impl QueryTicket {
    /// Block until every shard answered, then combine the partials
    /// ([`Reduction::combine`] on values; energy summed, latency and
    /// cycles maxed).
    pub fn wait(self) -> Result<QueryResult> {
        let QueryTicket { red, q, parts } = self;
        let mut value = red.identity(q);
        let mut report = BatchReport::default();
        let mut banks_active = 0usize;
        let mut cost = Cost::default();
        let mut shard_seqs = Vec::with_capacity(parts.len());
        for (shard, rx) in parts.into_iter().enumerate() {
            let part = rx.recv().map_err(|_| {
                anyhow!("engine shard {shard} stopped before answering the query")
            })??;
            value = red.combine(value, part.outcome.value);
            report.cycles = report.cycles.max(part.outcome.report.cycles);
            report.rows_active += part.outcome.report.rows_active;
            report.cell_toggles += part.outcome.report.cell_toggles;
            report.alu_evals += part.outcome.report.alu_evals;
            banks_active += part.outcome.banks_active;
            cost.energy_fj += part.outcome.cost.energy_fj;
            cost.latency_ns = cost.latency_ns.max(part.outcome.cost.latency_ns);
            shard_seqs.push(part.commit_seq);
        }
        Ok(QueryResult { value, report, banks_active, cost, shard_seqs })
    }
}

struct ShardHandle {
    tx: RingSender<Command>,
    worker: Option<JoinHandle<Result<()>>>,
}

/// Handle to a running update engine. Shareable across producer
/// threads (`Arc<UpdateEngine>`): every submit path is `&self`.
pub struct UpdateEngine {
    shards: Vec<ShardHandle>,
    seqs: Vec<Arc<WaitHub>>,
    shard_bits: u32,
    metrics: Arc<EngineMetrics>,
    backend_name: std::sync::OnceLock<&'static str>,
    cfg: EngineConfig,
    /// Single-writer lock on the WAL directory, held for the engine's
    /// lifetime (durable engines only; released on shutdown/drop).
    _wal_lock: Option<DirLock>,
    /// `false` while running as a read-only replication follower;
    /// flipped once (and only once) by [`Self::promote_writable`].
    writable: AtomicBool,
    /// Per-shard `(commit_seq, lsn)` watermarks recovered at start
    /// (durable engines only) — the follower's replication cursors
    /// resume from here.
    recovered: Option<Vec<ShardMark>>,
    /// Span-tracing hub: per-shard sampling state + SPSC rings, the
    /// stage histograms and the rate-window series its drain thread
    /// maintains. Always present; a disabled config skips the drain
    /// thread and stamps nothing.
    telemetry: Arc<Telemetry>,
}

impl UpdateEngine {
    /// Start the engine: one worker thread per shard, each building its
    /// own backend via `backend_factory` (called on the worker thread
    /// with that shard's [`ShardPlan`]).
    ///
    /// With [`EngineConfig::durability`] set, this first recovers the
    /// WAL directory (newest valid snapshot + per-shard tail replay,
    /// torn tails repaired) and only then spawns workers — each
    /// preloading its recovered rows, resuming `commit_seq` at the
    /// recovered watermark, and appending every commit to the log.
    pub fn start<F>(cfg: EngineConfig, backend_factory: F) -> Result<Self>
    where
        F: Fn(&ShardPlan) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        cfg.validate()?;
        let metrics = Arc::new(EngineMetrics::new(cfg.shards));
        let mut wal_lock = None;
        let mut recovered = None;
        let inits: Vec<WorkerInit> = match &cfg.durability {
            None => (0..cfg.shards).map(|_| WorkerInit::default()).collect(),
            Some(d) => {
                // Single-writer exclusion BEFORE touching the log: a
                // second appender on the same directory interleaves
                // LSNs, which a later recovery reads as corruption.
                std::fs::create_dir_all(&d.dir)
                    .map_err(|e| anyhow!("creating WAL dir {}: {e}", d.dir.display()))?;
                wal_lock = Some(DirLock::acquire(&d.dir)?);
                let rec = recover_or_init(d, cfg.rows, cfg.q, cfg.shards)?;
                recovered = Some(rec.per_shard.clone());
                (0..cfg.shards)
                    .map(|shard| {
                        let mark = rec.per_shard[shard];
                        let wal = ShardWal::open(
                            &d.dir,
                            shard,
                            cfg.q,
                            mark.lsn + 1,
                            d.fsync,
                            d.segment_bytes,
                            Some(Arc::clone(&metrics.shards[shard])),
                        )?;
                        Ok(WorkerInit {
                            listener: Some(Box::new(wal) as Box<dyn CommitListener>),
                            preload: Some(rec.shard_state(shard)),
                            first_seq: mark.commit_seq + 1,
                            span: None,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?
            }
        };
        Self::start_inner(cfg, Arc::new(backend_factory), metrics, inits, wal_lock, recovered)
    }

    /// [`Self::start`] with an explicit per-shard [`CommitListener`]
    /// factory — the generic form of the durability hook (replication,
    /// change-data capture, test instrumentation). Listeners are
    /// constructed here (the caller's thread) and moved into the
    /// workers. Mutually exclusive with [`EngineConfig::durability`],
    /// which installs the WAL appender on the same hook.
    pub fn start_with_listener<F, L>(
        cfg: EngineConfig,
        backend_factory: F,
        listener_factory: L,
    ) -> Result<Self>
    where
        F: Fn(&ShardPlan) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
        L: Fn(&ShardPlan) -> Result<Option<Box<dyn CommitListener>>>,
    {
        cfg.validate()?;
        ensure!(
            cfg.durability.is_none(),
            "EngineConfig::durability installs its own commit listener; \
             use start() or clear the durability config"
        );
        let metrics = Arc::new(EngineMetrics::new(cfg.shards));
        let shard_rows = cfg.rows / cfg.shards;
        let inits = (0..cfg.shards)
            .map(|shard| {
                let plan =
                    ShardPlan { shard, shards: cfg.shards, rows: shard_rows, q: cfg.q };
                Ok(WorkerInit {
                    listener: listener_factory(&plan)?,
                    preload: None,
                    first_seq: 1,
                    span: None,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Self::start_inner(cfg, Arc::new(backend_factory), metrics, inits, None, None)
    }

    fn start_inner(
        cfg: EngineConfig,
        factory: Arc<BackendFactory>,
        metrics: Arc<EngineMetrics>,
        inits: Vec<WorkerInit>,
        wal_lock: Option<DirLock>,
        recovered: Option<Vec<ShardMark>>,
    ) -> Result<Self> {
        let shard_rows = cfg.rows / cfg.shards;
        // Per-shard seal threshold: the config knob is expressed over
        // the logical row space.
        let seal_at_rows = cfg.seal_at_rows.map(|n| (n / cfg.shards).max(1));
        let telemetry = Arc::new(Telemetry::new(cfg.telemetry, cfg.shards));

        let mut shards = Vec::with_capacity(cfg.shards);
        let mut seqs = Vec::with_capacity(cfg.shards);
        let mut name_rxs = Vec::with_capacity(cfg.shards);
        for (shard, mut init) in inits.into_iter().enumerate() {
            init.span = Some(telemetry.shard(shard));
            let (tx, rx) = ring::channel(cfg.queue_cap);
            let (name_tx, name_rx) = mpsc::sync_channel(1);
            let plan = ShardPlan { shard, shards: cfg.shards, rows: shard_rows, q: cfg.q };
            let scfg = ShardConfig { seal_at_rows, seal_deadline: cfg.seal_deadline };
            let seq = Arc::new(WaitHub::new());
            let worker_seq = Arc::clone(&seq);
            let worker_metrics = Arc::clone(&metrics);
            let worker_factory = Arc::clone(&factory);
            let worker = std::thread::Builder::new()
                .name(format!("fast-shard-{shard}"))
                .spawn(move || {
                    worker_loop(
                        plan,
                        scfg,
                        rx,
                        worker_metrics,
                        worker_factory,
                        worker_seq,
                        name_tx,
                        init,
                    )
                })
                .expect("spawning engine shard worker");
            shards.push(ShardHandle { tx, worker: Some(worker) });
            seqs.push(seq);
            name_rxs.push(name_rx);
        }

        let writable = AtomicBool::new(!cfg.read_only);
        let mut engine = UpdateEngine {
            shards,
            seqs,
            shard_bits: cfg.shard_bits(),
            metrics,
            backend_name: std::sync::OnceLock::new(),
            cfg,
            _wal_lock: wal_lock,
            writable,
            recovered,
            telemetry,
        };

        // Collect every shard's construction outcome before going live.
        for name_rx in name_rxs {
            let outcome = match name_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    Err(anyhow!("engine shard failed to start within 120 s"))
                }
                Err(RecvTimeoutError::Disconnected) => Err(anyhow!(
                    "engine shard worker panicked during backend construction"
                )),
            };
            match outcome {
                Ok(name) => {
                    let _ = engine.backend_name.set(name);
                }
                Err(e) => {
                    // Tear the other shards down before reporting.
                    let _ = engine.shutdown_inner();
                    return Err(e);
                }
            }
        }

        // Spawn the telemetry drain only once every worker is live —
        // the sampling closure reads the engine's cumulative gauges,
        // which exist from construction, so it needs no engine handle
        // (keeping `telemetry` free of coordinator types).
        if engine.cfg.telemetry.enabled {
            let m = Arc::clone(&engine.metrics);
            engine.telemetry.start_drain(move || SeriesSample {
                completed: m.counters.requests_completed.load(Ordering::Relaxed),
                wal_bytes: m
                    .shards
                    .iter()
                    .map(|s| s.wal_bytes.load(Ordering::Relaxed))
                    .sum(),
                queue_depth: m
                    .shards
                    .iter()
                    .map(|s| s.queue_depth.load(Ordering::Relaxed))
                    .sum(),
            });
        }
        Ok(engine)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The engine's telemetry hub: span-stage histograms, the rate
    /// series, and the scrape [`Telemetry::snapshot`] the exposition
    /// surfaces render.
    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.telemetry
    }

    /// Route a logical row to (shard, local row).
    #[inline]
    fn route(&self, row: usize) -> Result<(usize, usize)> {
        ensure!(
            row < self.cfg.rows,
            "row {row} out of range (rows = {})",
            self.cfg.rows
        );
        Ok((row & (self.cfg.shards - 1), row >> self.shard_bits))
    }

    /// Account an admitted send. The queue gauges are derived from the
    /// ring's own occupancy (`tail - head`), which the admission CAS
    /// bounds at `queue_cap` — so the high-water mark can never exceed
    /// the cap, even while rejected submits race admitted ones (the
    /// old raise-before-send counter could transiently overcount).
    #[inline]
    fn note_admitted(&self, shard: usize, n: u64) {
        let sc = &self.metrics.shards[shard];
        // An admitted send proves occupancy was >= 1 an instant ago,
        // even if the worker already drained it by this sample.
        sc.queue_high_water
            .fetch_max((self.shards[shard].tx.len() as u64).max(1), Ordering::Relaxed);
        Counters::inc(&sc.requests, n);
    }

    /// Account the slow-path work a blocking send reported.
    #[inline]
    fn note_contention(&self, shard: usize, report: ring::SendReport) {
        if report.spins > 0 || report.parks > 0 {
            let sc = &self.metrics.shards[shard];
            Counters::inc(&sc.submit_spins, report.spins);
            Counters::inc(&sc.park_events, report.parks);
        }
    }

    /// Refresh each shard's depth gauge from its ring occupancy (a
    /// dead shard's leftover commands are unreachable — report 0).
    fn refresh_queue_gauges(&self) {
        for (h, sc) in self.shards.iter().zip(&self.metrics.shards) {
            let depth = if h.tx.is_disconnected() { 0 } else { h.tx.len() as u64 };
            sc.queue_depth.store(depth, Ordering::Relaxed);
        }
    }

    /// Mutation admission gate: a read-only (follower) engine rejects
    /// `n` requests with the typed [`EngineReadOnly`] root cause.
    #[inline]
    fn check_writable(&self, n: u64) -> Result<()> {
        if self.writable.load(Ordering::Acquire) {
            return Ok(());
        }
        Counters::inc(&self.metrics.counters.requests_rejected, n);
        Err(anyhow::Error::new(EngineReadOnly))
    }

    /// Non-blocking submit. `Err` = queue full (backpressure), row out
    /// of range, or engine shut down; the request was NOT accepted.
    pub fn submit(&self, req: UpdateRequest) -> Result<()> {
        self.submit_inner(req, false).map(|_| ())
    }

    /// Non-blocking submit returning a completion [`Ticket`]. Same
    /// admission control as [`Self::submit`]: `Err` means the request
    /// was NOT accepted (backpressure maps to an error, never to an
    /// unresolved ticket).
    pub fn submit_ticketed(&self, req: UpdateRequest) -> Result<Ticket> {
        Ok(self
            .submit_inner(req, true)?
            .expect("ticketed submit returns a ticket"))
    }

    fn submit_inner(&self, req: UpdateRequest, ticketed: bool) -> Result<Option<Ticket>> {
        self.check_writable(1)?;
        let (shard, local) = self.route(req.row)?;
        Counters::inc(&self.metrics.counters.requests_submitted, 1);
        let mut req = req;
        req.row = local;
        // Tickets ride the shard's wait hub so one publish per seal
        // wakes the whole waiter batch.
        let (ticket, waiter) = if ticketed {
            let (t, w) = ticket_on(Arc::clone(&self.seqs[shard]));
            (Some(t), Some(w))
        } else {
            (None, None)
        };
        let stamp = self.telemetry.submit_stamp(shard);
        match self.shards[shard].tx.try_send(Command::Submit(req, waiter, stamp)) {
            Ok(()) => {
                self.note_admitted(shard, 1);
                Ok(ticket)
            }
            Err(ring::TrySendError::Full(_)) => {
                Counters::inc(&self.metrics.counters.requests_rejected, 1);
                Err(anyhow::Error::new(EngineBusy))
            }
            Err(ring::TrySendError::Disconnected(_)) => Err(anyhow!("engine is shut down")),
        }
    }

    /// Blocking submit: waits for queue space (no rejection).
    pub fn submit_blocking(&self, req: UpdateRequest) -> Result<()> {
        self.submit_blocking_inner(req, false).map(|_| ())
    }

    /// Blocking submit returning a completion [`Ticket`].
    pub fn submit_blocking_ticketed(&self, req: UpdateRequest) -> Result<Ticket> {
        Ok(self
            .submit_blocking_inner(req, true)?
            .expect("ticketed submit returns a ticket"))
    }

    fn submit_blocking_inner(&self, req: UpdateRequest, ticketed: bool) -> Result<Option<Ticket>> {
        self.check_writable(1)?;
        let (shard, local) = self.route(req.row)?;
        Counters::inc(&self.metrics.counters.requests_submitted, 1);
        let mut req = req;
        req.row = local;
        let (ticket, waiter) = if ticketed {
            let (t, w) = ticket_on(Arc::clone(&self.seqs[shard]));
            (Some(t), Some(w))
        } else {
            (None, None)
        };
        let stamp = self.telemetry.submit_stamp(shard);
        match self.shards[shard].tx.send(Command::Submit(req, waiter, stamp)) {
            Ok(report) => {
                self.note_contention(shard, report);
                self.note_admitted(shard, 1);
                Ok(ticket)
            }
            Err(_) => Err(anyhow!("engine is shut down")),
        }
    }

    /// Bulk blocking submit: requests are partitioned by shard and sent
    /// as one chunk per shard — the fast path for high-rate producers.
    ///
    /// Failure contract: if a shard has died (backend fault) while
    /// others are alive, chunks sent to healthy shards BEFORE the dead
    /// one are already admitted when this returns `Err`. Do NOT retry
    /// the same vector — that would double-apply the admitted updates;
    /// treat the engine as failed and drain via [`Self::shutdown`].
    pub fn submit_many(&self, reqs: Vec<UpdateRequest>) -> Result<()> {
        self.submit_many_inner(reqs, false).map(|_| ())
    }

    /// Bulk blocking submit with completion tickets: one [`Ticket`]
    /// per shard the chunk touches, resolving when that shard commits
    /// the chunk's LAST request (per-shard FIFO makes that an ack for
    /// every earlier request of the chunk on the shard). Same failure
    /// contract as [`Self::submit_many`].
    pub fn submit_many_ticketed(&self, reqs: Vec<UpdateRequest>) -> Result<Vec<Ticket>> {
        self.submit_many_inner(reqs, true)
    }

    fn submit_many_inner(&self, reqs: Vec<UpdateRequest>, ticketed: bool) -> Result<Vec<Ticket>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        self.check_writable(reqs.len() as u64)?;
        let total = reqs.len() as u64;
        let mut buckets: Vec<Vec<UpdateRequest>> = Vec::new();
        buckets.resize_with(self.cfg.shards, Vec::new);
        for mut req in reqs {
            let (shard, local) = self.route(req.row)?;
            req.row = local;
            buckets[shard].push(req);
        }
        Counters::inc(&self.metrics.counters.requests_submitted, total);
        let mut tickets = Vec::new();
        for (shard, bucket) in buckets.into_iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let n = bucket.len() as u64;
            let waiter = if ticketed {
                let (t, w) = ticket_on(Arc::clone(&self.seqs[shard]));
                tickets.push(t);
                Some(w)
            } else {
                None
            };
            let stamp = self.telemetry.submit_stamp(shard);
            match self.shards[shard].tx.send(Command::SubmitMany(bucket, waiter, stamp)) {
                Ok(report) => {
                    self.note_contention(shard, report);
                    self.note_admitted(shard, n);
                }
                Err(_) => {
                    return Err(anyhow!(
                        "engine shard {shard} is down (earlier chunks of this bulk \
                         submit may already be admitted — do not retry the batch)"
                    ));
                }
            }
        }
        Ok(tickets)
    }

    /// Read a row with read-your-writes consistency. Per-shard AND
    /// per-row: the owning shard seals its open batch only if that
    /// batch pends an update for this very row; other shards — and an
    /// owning shard with no pending write to the row — keep batching
    /// undisturbed.
    pub fn read(&self, row: usize) -> Result<u32> {
        let (shard, local) = self.route(row)?;
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard]
            .tx
            .send(Command::Read(local, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Direct row write (conventional port; seals the owning shard's
    /// open batch first, but only if it pends an update to this row —
    /// program order per row is preserved, unrelated batching is not).
    pub fn write(&self, row: usize, value: u32) -> Result<()> {
        self.check_writable(0)?;
        let (shard, local) = self.route(row)?;
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard]
            .tx
            .send(Command::Write(local, value, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Apply one replicated WAL record (follower mode only): routes
    /// the frame to its shard's worker, which validates the commit
    /// sequence against its own watermark, applies it through the
    /// backend, re-logs it through the local WAL listener, and
    /// publishes the committed seq — exactly the native commit path
    /// minus ticket waiters. Valid only while the engine is read-only;
    /// after promotion the engine mints its own commits and a stale
    /// replication stream must not interleave.
    pub fn apply_replicated(&self, rec: WalRecord) -> Result<()> {
        ensure!(
            !self.writable.load(Ordering::Acquire),
            "engine is writable: replicated applies are only valid in read-only \
             (follower) mode"
        );
        let shard = rec.shard as usize;
        ensure!(
            shard < self.shards.len(),
            "replicated record names shard {shard} (shards = {})",
            self.shards.len()
        );
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard]
            .tx
            .send(Command::ReplApply(rec, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Failover: flip a read-only follower engine to accepting writes.
    /// Idempotent; the flag only ever goes read-only → writable.
    pub fn promote_writable(&self) {
        self.writable.store(true, Ordering::Release);
    }

    /// Is the engine currently accepting mutations? `false` only for a
    /// not-yet-promoted follower.
    pub fn is_writable(&self) -> bool {
        self.writable.load(Ordering::Acquire)
    }

    /// The per-shard `(commit_seq, lsn)` watermarks recovered at start
    /// (`None` on volatile engines) — replication cursors resume from
    /// these.
    pub fn recovered_marks(&self) -> Option<&[ShardMark]> {
        self.recovered.as_deref()
    }

    /// Submit one in-array reduction, fanned out to every shard as a
    /// shard-local spec ([`crate::query::shard_specs`]). Each shard
    /// seals and applies its open batch before answering, so the
    /// result reflects exactly the requests admitted to each shard
    /// before the query — a query ticketed after a commit's ticket
    /// resolved is guaranteed to observe that commit. The observed
    /// per-shard `commit_seq`s ride the [`QueryResult`].
    pub fn submit_query(&self, spec: &QuerySpec) -> Result<QueryTicket> {
        spec.validate(self.cfg.rows, self.cfg.q)?;
        let locals = shard_specs(spec, self.cfg.rows, self.cfg.shards)?;
        let mut parts = Vec::with_capacity(self.cfg.shards);
        for (shard, local) in locals.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel(1);
            self.shards[shard]
                .tx
                .send(Command::Query(local, tx))
                .map_err(|_| anyhow!("engine is shut down"))?;
            parts.push(rx);
        }
        Ok(QueryTicket { red: spec.red.clone(), q: self.cfg.q, parts })
    }

    /// [`Self::submit_query`] + [`QueryTicket::wait`] in one call.
    pub fn query(&self, spec: &QuerySpec) -> Result<QueryResult> {
        self.submit_query(spec)?.wait()
    }

    /// Which shard owns a logical row (for targeting
    /// [`Self::drain_shard`] / [`Self::wait_seq`]).
    pub fn shard_of(&self, row: usize) -> Result<usize> {
        self.route(row).map(|(shard, _)| shard)
    }

    /// Drain ONE shard: force-seal its open batch (if any), wait until
    /// the backend applied it, and return the shard's last committed
    /// sequence number. This is the per-shard replacement for the old
    /// whole-engine `flush()` — other shards keep batching.
    pub fn drain_shard(&self, shard: usize) -> Result<u64> {
        ensure!(
            shard < self.shards.len(),
            "shard {shard} out of range (shards = {})",
            self.shards.len()
        );
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard]
            .tx
            .send(Command::Drain(tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))
    }

    /// Explicit whole-engine barrier, spelled as per-shard drains:
    /// force-seal and apply every shard's open batch, returning each
    /// shard's last committed seq. For *semantic* barriers only (a
    /// trace's Flush event, an app's round boundary, server shutdown)
    /// — data access never needs it: reads/writes are read-your-writes
    /// per shard and per row.
    pub fn drain_all(&self) -> Result<Vec<u64>> {
        (0..self.shards.len()).map(|s| self.drain_shard(s)).collect()
    }

    /// Block until `shard` has committed sequence number `seq` (or
    /// higher); returns the committed seq observed. Errors if the
    /// shard stops before reaching `seq` — it never hangs on a
    /// sequence that can no longer arrive. Note that an open batch
    /// seals only by policy (size/kind/deadline) or an explicit
    /// [`Self::drain_shard`]; pair `wait_seq` with one of those (or
    /// use [`Self::wait_seq_timeout`] to bound the wait).
    pub fn wait_seq(&self, shard: usize, seq: u64) -> Result<u64> {
        // An unbounded wait only returns on commit (or errors).
        Ok(self
            .wait_seq_until(shard, seq, None)?
            .expect("unbounded wait resolves"))
    }

    /// [`Self::wait_seq`] with a bounded wait: `Ok(Some(committed))`
    /// once `seq` is reached, `Ok(None)` if `timeout` elapses first,
    /// `Err` if the shard stops before reaching `seq`. Lets callers
    /// interleave the wait with their own cancellation checks (the
    /// serve protocol's `WAIT` does, so a waiting client cannot block
    /// server shutdown).
    pub fn wait_seq_timeout(
        &self,
        shard: usize,
        seq: u64,
        timeout: Duration,
    ) -> Result<Option<u64>> {
        self.wait_seq_until(shard, seq, Some(Instant::now() + timeout))
    }

    /// Shared seq-wait loop: `deadline = None` blocks until commit.
    fn wait_seq_until(
        &self,
        shard: usize,
        seq: u64,
        deadline: Option<Instant>,
    ) -> Result<Option<u64>> {
        ensure!(
            shard < self.seqs.len(),
            "shard {shard} out of range (shards = {})",
            self.seqs.len()
        );
        match self.seqs[shard].wait_seq_until(seq, deadline) {
            SeqWait::Reached(committed) => Ok(Some(committed)),
            SeqWait::TimedOut => Ok(None),
            SeqWait::Closed(committed) => Err(anyhow!(
                "engine shard {shard} stopped at commit_seq {committed} (< requested {seq})"
            )),
        }
    }

    /// The shard's last committed sequence number (non-blocking gauge).
    pub fn committed_seq(&self, shard: usize) -> Result<u64> {
        ensure!(
            shard < self.seqs.len(),
            "shard {shard} out of range (shards = {})",
            self.seqs.len()
        );
        Ok(self.seqs[shard].committed())
    }

    /// Consistent snapshot of all rows. This is one of the two
    /// remaining whole-engine barriers (the other is shutdown): every
    /// shard force-seals its open batch before reporting its rows.
    /// "Consistent" = contains every request admitted before the call;
    /// it does not serialize against concurrent producers.
    pub fn snapshot(&self) -> Result<Vec<u32>> {
        let mut waits = Vec::with_capacity(self.shards.len());
        for h in &self.shards {
            let (tx, rx) = mpsc::sync_channel(1);
            h.tx
                .send(Command::Snapshot(tx))
                .map_err(|_| anyhow!("engine is shut down"))?;
            waits.push(rx);
        }
        let mut out = vec![0u32; self.cfg.rows];
        for (shard, rx) in waits.into_iter().enumerate() {
            let snap = rx
                .recv()
                .map_err(|_| anyhow!("engine dropped the reply"))??;
            for (local, v) in snap.into_iter().enumerate() {
                out[(local << self.shard_bits) | shard] = v;
            }
        }
        Ok(out)
    }

    pub fn stats(&self) -> EngineStats {
        let c = self.metrics.counters.snapshot();
        self.refresh_queue_gauges();
        let shards: Vec<ShardSnapshot> =
            self.metrics.shards.iter().map(|s| s.snapshot()).collect();
        EngineStats {
            submitted: c.requests_submitted,
            completed: c.requests_completed,
            rejected: c.requests_rejected,
            batches: c.batches_flushed,
            rows_updated: c.rows_updated,
            rows_per_batch: c.rows_per_batch(),
            modeled_ns: self.metrics.modeled_ns(),
            modeled_energy_pj: self.metrics.energy.total_pj(),
            apply_wall: self.metrics.apply_wall.summary(),
            backend: self.backend_name.get().copied().unwrap_or("unknown"),
            queue_depth: shards.iter().map(|s| s.queue_depth).sum(),
            tickets_resolved: shards.iter().map(|s| s.tickets_resolved).sum(),
            queries: shards.iter().map(|s| s.queries).sum(),
            submit_spins: shards.iter().map(|s| s.submit_spins).sum(),
            park_events: shards.iter().map(|s| s.park_events).sum(),
            wal_coalesced_writes: shards.iter().map(|s| s.wal_coalesced_writes).sum(),
            wal_coalesced_frames: shards.iter().map(|s| s.wal_coalesced_frames).sum(),
            shards,
        }
    }

    /// Graceful shutdown: flush every shard, stop the workers, join.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        // Stop the telemetry drain FIRST: its final sweep drains every
        // span the workers are about to stop producing, and a stopped
        // drain thread cannot race the gauge closures below.
        self.telemetry.stop_drain();
        let mut first_err = None;
        for h in &self.shards {
            let _ = h.tx.send(Command::Shutdown);
        }
        for h in &mut self.shards {
            if let Some(worker) = h.worker.take() {
                match worker.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => first_err = first_err.or(Some(e)),
                    Err(_) => {
                        first_err =
                            first_err.or(Some(anyhow!("engine shard worker panicked")))
                    }
                }
            }
        }
        // All workers are joined and `&mut self` excludes concurrent
        // producers: any command still in a ring (a send that landed
        // between the worker's post-death drain and its receiver
        // drop) is unreachable — zero the depth gauges.
        for sc in &self.metrics.shards {
            sc.queue_depth.store(0, Ordering::Relaxed);
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for UpdateEngine {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

/// Per-shard slice of the engine config.
#[derive(Debug, Clone, Copy)]
struct ShardConfig {
    /// Shard-local size seal (already divided by the shard count).
    seal_at_rows: Option<usize>,
    seal_deadline: Duration,
}

/// Worker-side state of one shard: the backend, the coalescing
/// batcher, the deadline anchor, and the commit-sequence counter.
struct ShardWorker<'a> {
    plan: ShardPlan,
    cfg: ShardConfig,
    metrics: &'a EngineMetrics,
    seq: &'a WaitHub,
    backend: Box<dyn Backend>,
    batcher: Batcher,
    deadline: Option<Instant>,
    /// Next commit sequence number to assign at seal time (starts at
    /// 1, or at the recovered watermark + 1 on a durable engine;
    /// `next_seq - 1` is the last committed seq).
    next_seq: u64,
    /// Commit hook (the WAL appender on a durable engine): invoked
    /// after every backend apply, before any ticket resolves. A
    /// listener error kills the worker like a backend fault.
    listener: Option<Box<dyn CommitListener>>,
    /// This shard's span ring + sampling counters (never absent on an
    /// engine-started worker; `Option` keeps the struct constructible
    /// in isolation).
    span: Option<Arc<ShardSpanState>>,
    /// The sampled request currently riding the open batch (at most
    /// one — the first sampled admission wins; resolved by the seal
    /// that commits it).
    pending: Option<PendingSpan>,
}

impl ShardWorker<'_> {
    /// Apply one sealed batch: assign its commit_seq, run the backend,
    /// account metrics, resolve the riding tickets with the commit
    /// metadata, and publish the committed seq for `wait_seq`.
    fn apply_sealed(&mut self, batch: Batch, reason: SealReason) -> Result<()> {
        let m = self.metrics;
        let backend = &mut self.backend;
        // Span tracing: stamp the seal of the batch carrying the
        // sampled request (if any). Clock reads happen only on sampled
        // seals — the common path takes the `is_none` branch.
        let mut span_ev = self.pending.take().map(|p| SpanEvent {
            t_submit: p.t_submit,
            t_enqueue: p.t_enqueue,
            t_seal: now_ns(),
            ..SpanEvent::default()
        });
        let applied = m
            .apply_wall
            .time(|| backend.apply(batch.kind, &batch.operands))?;
        if let Some(ev) = &mut span_ev {
            ev.t_apply = now_ns();
        }
        let commit_seq = self.next_seq;
        self.next_seq += 1;
        Counters::inc(&m.counters.batches_flushed, 1);
        Counters::inc(&m.counters.rows_updated, batch.rows_touched as u64);
        Counters::inc(&m.counters.requests_completed, batch.requests as u64);
        Counters::inc(
            &m.counters.requests_coalesced,
            (batch.requests - batch.rows_touched) as u64,
        );
        Counters::inc(&m.counters.shift_cycles, applied.cycles);
        m.energy.add_fj(applied.cost.energy_fj);
        m.add_modeled_ns(applied.cost.latency_ns);
        let sc = &m.shards[self.plan.shard];
        sc.note_sealed(reason, batch.rows_touched as u64, batch.requests as u64);
        sc.commit_seq.store(commit_seq, Ordering::Relaxed);
        let commit = Commit {
            shard: self.plan.shard,
            commit_seq,
            seal_reason: reason,
            rows: batch.rows_touched,
            requests: batch.requests,
            rows_active: applied.rows_active,
            modeled_ns: applied.cost.latency_ns,
            cycles: applied.cycles,
            banks_active: applied.banks_active,
        };
        // Commit hook (WAL append on a durable engine): BEFORE any
        // ticket resolves, so a resolved ticket implies the commit is
        // logged. An error drops the waiters (they observe the fault)
        // and kills the worker — the established fail-stop path.
        if let Some(listener) = &mut self.listener {
            listener.on_commit(&commit, batch.kind, &batch.operands)?;
            if let Some(ev) = &mut span_ev {
                ev.t_wal = now_ns();
            }
        }
        let modeled_ns_u64 = applied.cost.latency_ns.max(0.0).round() as u64;
        // Batch-wake: store every waiter's commit with plain atomics
        // (`resolve_quiet`), then let the ONE `publish` below issue the
        // seal's single notify_all — the waiters share this shard's
        // wait hub, so sequence waiters and ticket waiters wake
        // together instead of paying O(waiters) lock/notify cycles.
        let waiters = batch.waiters.len() as u64;
        for mut waiter in batch.waiters {
            sc.commit_wall
                .record_ns(waiter.submitted_at().elapsed().as_nanos() as u64);
            sc.commit_modeled.record_ns(modeled_ns_u64);
            Counters::inc(&sc.tickets_resolved, 1);
            waiter.resolve_quiet(commit);
        }
        if waiters > 0 {
            sc.wake_batch.record_ns(waiters);
        }
        self.seq.publish(commit_seq);
        // Resolve the span AFTER the publish — `t_resolve` covers the
        // full request/response round trip the waiters observe. The
        // fsync gauge is whatever sync last completed on this shard
        // (coalesced fsync runs behind resolution by design; the
        // `fsync_lag` stage measures exactly that distance).
        if let (Some(mut ev), Some(span)) = (span_ev, self.span.as_ref()) {
            ev.t_fsync = sc.last_fsync_ns.load(Ordering::Relaxed);
            ev.t_resolve = now_ns();
            span.record(ev);
        }
        Ok(())
    }

    fn flush(&mut self, reason: SealReason) -> Result<()> {
        if let Some(batch) = self.batcher.force_flush() {
            self.apply_sealed(batch, reason)?;
        }
        Ok(())
    }

    /// Apply one replicated WAL record (follower mode). A batch frame
    /// replays the primary's sealed commit through the normal
    /// [`Self::apply_sealed`] path (densified back to the operand
    /// vector the WAL filtered), so metrics, the local WAL re-log and
    /// the committed-seq publication all behave like a native commit.
    /// A commit_seq that disagrees with the shard's own watermark is
    /// log divergence — fail-stop, never a silent skip.
    fn apply_replicated_record(&mut self, rec: WalRecord) -> Result<()> {
        ensure!(
            self.batcher.pending_rows() == 0,
            "replicated apply with a non-empty local batch (shard {})",
            self.plan.shard
        );
        match rec.payload {
            WalPayload::Batch { seal_reason, kind, ops } => {
                ensure!(
                    rec.commit_seq == self.next_seq,
                    "shard {} lsn {}: replicated commit_seq {} != expected {} — \
                     log divergence",
                    self.plan.shard,
                    rec.lsn,
                    rec.commit_seq,
                    self.next_seq
                );
                let ident = kind.identity(self.plan.q);
                let mut operands = vec![ident; self.plan.rows];
                let mut rows_touched = 0usize;
                for (row, operand) in ops {
                    let row = row as usize;
                    ensure!(
                        row < self.plan.rows,
                        "shard {} lsn {}: replicated local row {row} out of range \
                         ({} shard rows)",
                        self.plan.shard,
                        rec.lsn,
                        self.plan.rows
                    );
                    if operands[row] == ident && operand != ident {
                        rows_touched += 1;
                    }
                    operands[row] = operand;
                }
                let batch = Batch {
                    kind,
                    operands,
                    rows_touched,
                    requests: rows_touched,
                    waiters: Vec::new(),
                };
                self.apply_sealed(batch, seal_reason)
            }
            WalPayload::Write { row, value } => {
                ensure!(
                    rec.commit_seq == self.next_seq - 1,
                    "shard {} lsn {}: replicated write carries committed_seq {} != \
                     local {} — log divergence",
                    self.plan.shard,
                    rec.lsn,
                    rec.commit_seq,
                    self.next_seq - 1
                );
                let row = row as usize;
                ensure!(
                    row < self.plan.rows,
                    "shard {} lsn {}: replicated local row {row} out of range \
                     ({} shard rows)",
                    self.plan.shard,
                    rec.lsn,
                    self.plan.rows
                );
                self.backend.write_row(row, value)?;
                if let Some(listener) = &mut self.listener {
                    listener.on_write(row, value, self.next_seq - 1)?;
                }
                Ok(())
            }
        }
    }

    fn run(&mut self, rx: &RingReceiver<Command>) -> Result<()> {
        ensure!(
            self.backend.rows() == self.plan.rows,
            "backend rows {} != shard rows {} (shard {} of {})",
            self.backend.rows(),
            self.plan.rows,
            self.plan.shard,
            self.plan.shards
        );
        // Copy the `&'a EngineMetrics` out of self so this borrow is
        // independent of the `&mut self` calls below.
        let metrics: &EngineMetrics = self.metrics;
        let shard_counters = &metrics.shards[self.plan.shard];
        loop {
            // Group-commit deadline: seal the open batch once it
            // expires (checked every pass — timeouts `continue` here).
            if let Some(d) = self.deadline {
                if Instant::now() >= d {
                    self.flush(SealReason::Deadline)?;
                    self.deadline = None;
                }
            }
            // Idle-tail persistence: an interval-fsync WAL reports
            // when dirty bytes must hit the disk even with no further
            // traffic; force the sync so the policy's window bounds
            // the lag of a burst's LAST commits too.
            if let Some(listener) = &mut self.listener {
                if listener.flush_due().is_some_and(|due| Instant::now() >= due) {
                    listener.on_barrier()?;
                }
            }
            // Burst boundary: about to wait for work with an empty
            // queue — let the listener flush anything it staged
            // opportunistically (the WAL's coalesced write buffer), so
            // cross-seal coalescing never holds frames past the burst.
            if rx.is_empty() {
                if let Some(listener) = &mut self.listener {
                    listener.on_quiescent()?;
                }
            }
            let wake = match (
                self.deadline,
                self.listener.as_ref().and_then(|l| l.flush_due()),
            ) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
            let cmd = match wake {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        continue; // expired while a command was handled
                    }
                    match rx.recv_timeout(d - now) {
                        Ok(c) => c,
                        Err(ring::RecvTimeoutError::Timeout) => continue,
                        Err(ring::RecvTimeoutError::Disconnected) => break,
                    }
                }
                None => match rx.recv() {
                    Ok(c) => c,
                    Err(_) => break,
                },
            };

            match cmd {
                Command::Submit(req, waiter, stamp) => {
                    // A sampled admission (stamp != 0) arms the span
                    // the next seal resolves; first sampled wins.
                    if stamp != 0 && self.pending.is_none() {
                        self.pending =
                            Some(PendingSpan { t_submit: stamp, t_enqueue: now_ns() });
                    }
                    if self.batcher.pending_rows() == 0 {
                        self.deadline = Some(Instant::now() + self.cfg.seal_deadline);
                    }
                    if let Some((batch, reason)) = self.batcher.push_ticketed(req, waiter) {
                        self.apply_sealed(batch, reason)?;
                        self.deadline = if self.batcher.pending_rows() > 0 {
                            Some(Instant::now() + self.cfg.seal_deadline)
                        } else {
                            None
                        };
                    }
                }
                Command::SubmitMany(reqs, mut waiter, stamp) => {
                    if stamp != 0 && self.pending.is_none() {
                        self.pending =
                            Some(PendingSpan { t_submit: stamp, t_enqueue: now_ns() });
                    }
                    let last = reqs.len().saturating_sub(1);
                    for (i, req) in reqs.into_iter().enumerate() {
                        // The chunk waiter acks the LAST request.
                        let w = if i == last { waiter.take() } else { None };
                        if let Some((batch, reason)) = self.batcher.push_ticketed(req, w) {
                            self.apply_sealed(batch, reason)?;
                            self.deadline = None; // re-anchored below if still pending
                        }
                    }
                    // Anchor the deadline at the first pending request; do
                    // not extend it on later arrivals (bounded staleness).
                    if self.batcher.pending_rows() > 0 {
                        if self.deadline.is_none() {
                            self.deadline = Some(Instant::now() + self.cfg.seal_deadline);
                        }
                    } else {
                        self.deadline = None;
                    }
                }
                Command::Read(row, reply) => {
                    // Read-your-writes, per row: seal only if the open
                    // batch pends an update for THIS row; otherwise the
                    // backend already holds the row's current value and
                    // the batch stays open.
                    if self.batcher.touches(row) {
                        self.flush(SealReason::Forced)?;
                        self.deadline = None;
                    }
                    let _ = reply.send(self.backend.read_row(row));
                }
                Command::Write(row, value, reply) => {
                    // Pending updates to this row must land before the
                    // overwrite (program order per row); unrelated rows
                    // keep batching.
                    if self.batcher.touches(row) {
                        self.flush(SealReason::Forced)?;
                        self.deadline = None;
                    }
                    let mut res = self.backend.write_row(row, value);
                    let mut fatal = None;
                    if res.is_ok() {
                        // Log the write AFTER the backend applied it,
                        // sequenced by the shard's WAL lsn between
                        // batch commits. A log failure fails both the
                        // caller and (fail-stop) this worker.
                        if let Some(listener) = &mut self.listener {
                            if let Err(e) = listener.on_write(row, value, self.next_seq - 1)
                            {
                                res = Err(anyhow!("durable log append failed: {e:#}"));
                                fatal = Some(e);
                            }
                        }
                    }
                    let _ = reply.send(res);
                    if let Some(e) = fatal {
                        return Err(e);
                    }
                }
                Command::Query(spec, reply) => {
                    // A query is sequenced against the shard's commit
                    // stream: seal and apply the open batch (if any)
                    // so the answer reflects every request admitted
                    // before it, then stamp the observed commit_seq.
                    if self.batcher.pending_rows() > 0 {
                        self.flush(SealReason::Forced)?;
                        self.deadline = None;
                    }
                    let backend = &mut self.backend;
                    let out = shard_counters.query_wall.time(|| backend.query(&spec));
                    Counters::inc(&shard_counters.queries, 1);
                    // A query error (unsupported backend, bad local
                    // spec) fails the caller, not the shard.
                    let _ = reply.send(out.map(|outcome| ShardQueryPart {
                        outcome,
                        commit_seq: self.next_seq - 1,
                    }));
                }
                Command::Drain(reply) => {
                    self.flush(SealReason::Forced)?;
                    // A drain is a durability barrier too: whatever
                    // the fsync policy, a drained shard is on disk.
                    if let Some(listener) = &mut self.listener {
                        listener.on_barrier()?;
                    }
                    self.deadline = None;
                    let _ = reply.send(self.next_seq - 1);
                }
                Command::Snapshot(reply) => {
                    self.flush(SealReason::Forced)?;
                    if let Some(listener) = &mut self.listener {
                        listener.on_barrier()?;
                    }
                    self.deadline = None;
                    let _ = reply.send(self.backend.snapshot());
                }
                Command::ReplApply(rec, reply) => {
                    // A replicated apply failure is fatal to the shard
                    // (fail-stop): the caller gets the error AND the
                    // worker dies, so a diverged follower can never
                    // keep serving answers past the fault.
                    match self.apply_replicated_record(rec) {
                        Ok(()) => {
                            let _ = reply.send(Ok(()));
                        }
                        Err(e) => {
                            let _ = reply.send(Err(anyhow!("replicated apply failed: {e:#}")));
                            return Err(e);
                        }
                    }
                }
                Command::Shutdown => {
                    self.flush(SealReason::Forced)?;
                    if let Some(listener) = &mut self.listener {
                        listener.on_barrier()?;
                    }
                    break;
                }
            }
        }
        Ok(())
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    plan: ShardPlan,
    cfg: ShardConfig,
    rx: RingReceiver<Command>,
    metrics: Arc<EngineMetrics>,
    factory: Arc<BackendFactory>,
    seq: Arc<WaitHub>,
    name_tx: SyncSender<Result<&'static str>>,
    mut init: WorkerInit,
) -> Result<()> {
    // `&dyn Fn` is callable; `Arc<dyn Fn>` is not (no Fn impl on Arc).
    let factory = factory.as_ref();
    let backend = match factory(&plan) {
        Ok(mut b) => {
            // Restore recovered state BEFORE announcing readiness, so
            // a preload failure surfaces as a start() error rather
            // than a later mystery fault. Backend::preload is the
            // non-counting path — recovery must not inflate the
            // workload-modeling port/energy counters.
            let preload_err = match init.preload.take() {
                Some(state) => b.preload(&state).err(),
                None => None,
            };
            match preload_err {
                None => {
                    // Publish the recovered watermark BEFORE announcing
                    // readiness, so the moment start() returns,
                    // wait_seq / committed_seq / stats all see the
                    // pre-crash commits (no transient zero).
                    if init.first_seq > 1 {
                        metrics.shards[plan.shard]
                            .commit_seq
                            .store(init.first_seq - 1, Ordering::Relaxed);
                        seq.publish(init.first_seq - 1);
                    }
                    let _ = name_tx.send(Ok(b.name()));
                    b
                }
                Some(e) => {
                    let _ = name_tx
                        .send(Err(anyhow!("restoring recovered shard state: {e:#}")));
                    seq.close();
                    return Ok(());
                }
            }
        }
        Err(e) => {
            let _ = name_tx.send(Err(anyhow!("backend construction failed: {e:#}")));
            seq.close();
            return Ok(());
        }
    };
    let batcher = Batcher::new(plan.rows, plan.q, cfg.seal_at_rows);
    let mut worker = ShardWorker {
        plan,
        cfg,
        metrics: &*metrics,
        seq: &*seq,
        backend,
        batcher,
        deadline: None,
        next_seq: init.first_seq,
        listener: init.listener,
        span: init.span,
        pending: None,
    };

    // Every exit path (clean shutdown, backend fault) falls through to
    // the close + queue-gauge drain below.
    let result = worker.run(&rx);

    // Wake any `wait_seq` caller: no further commits will arrive.
    seq.close();

    // Drain whatever was queued when the worker died (backend fault,
    // rows mismatch): dropping a Submit/SubmitMany here drops its
    // ticket notifier, which wakes the waiter with an error, and
    // dropping a reply sender fails its caller's recv — nothing
    // hangs. The depth gauge is derived from ring occupancy, so the
    // drain itself brings it back to zero.
    while rx.try_recv().is_ok() {}
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FastBackend;
    use crate::util::bits;
    use crate::util::rng::Rng;

    fn engine(rows: usize, q: usize) -> UpdateEngine {
        sharded_engine(rows, q, 1)
    }

    fn sharded_engine(rows: usize, q: usize, shards: usize) -> UpdateEngine {
        let cfg = EngineConfig::sharded(rows, q, shards);
        UpdateEngine::start(cfg, move |plan: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap()
    }

    #[test]
    fn submit_read_roundtrip() {
        let e = engine(128, 16);
        e.submit_blocking(UpdateRequest::add(5, 100)).unwrap();
        e.submit_blocking(UpdateRequest::add(5, 23)).unwrap();
        e.submit_blocking(UpdateRequest::sub(5, 3)).unwrap();
        assert_eq!(e.read(5).unwrap(), 120);
        let stats = e.stats();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.completed, 3);
        assert!(stats.batches >= 1);
        e.shutdown().unwrap();
    }

    #[test]
    fn sampled_spans_flow_into_stage_histograms() {
        let mut cfg = EngineConfig::sharded(64, 8, 2);
        cfg.telemetry.sample_rate = 1; // sample every admission
        let e = UpdateEngine::start(cfg, |plan: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        for row in 0..64 {
            e.submit_blocking_ticketed(UpdateRequest::add(row, 1))
                .unwrap()
                .wait()
                .unwrap();
        }
        let snap = e.telemetry().snapshot();
        assert!(snap.enabled);
        assert_eq!(snap.sample_rate, 1);
        assert!(snap.spans_sampled >= 64, "rate 1 samples every admission");
        let stage = |name: &str| {
            snap.stages
                .iter()
                .find(|(n, _)| *n == name)
                .expect("stage present")
                .1
        };
        assert!(stage("total").count >= 1, "sealed spans reach the histograms");
        assert!(stage("enqueue").count >= 1);
        assert!(stage("apply").count >= 1);
        // Volatile engine: no WAL listener, so the wal stage and the
        // fsync-lag stage never get endpoints.
        assert_eq!(stage("wal").count, 0);
        assert_eq!(stage("fsync_lag").count, 0);
        e.shutdown().unwrap();
    }

    #[test]
    fn disabled_telemetry_stamps_and_records_nothing() {
        let mut cfg = EngineConfig::sharded(64, 8, 2);
        cfg.telemetry.enabled = false;
        let e = UpdateEngine::start(cfg, |plan: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap();
        for row in 0..64 {
            e.submit_blocking(UpdateRequest::add(row, 1)).unwrap();
        }
        e.drain_all().unwrap();
        let snap = e.telemetry().snapshot();
        assert!(!snap.enabled);
        assert_eq!(snap.spans_sampled, 0);
        assert!(snap.stages.iter().all(|(_, s)| s.count == 0));
        e.shutdown().unwrap();
    }

    #[test]
    fn config_rejects_non_power_of_two_sample_rate() {
        let mut cfg = EngineConfig::new(64, 8);
        cfg.telemetry.sample_rate = 48;
        let res = UpdateEngine::start(cfg, |plan: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        });
        assert!(res.is_err(), "sample_rate 48 must be rejected at validate");
    }

    #[test]
    fn random_stream_matches_host_semantics() {
        let rows = 128;
        let q = 16;
        let e = engine(rows, q);
        let mut rng = Rng::new(77);
        let mut expect = vec![0u32; rows];
        for _ in 0..2000 {
            let row = rng.below(rows as u64) as usize;
            let v = rng.below(1 << q) as u32;
            if rng.chance(0.3) {
                e.submit_blocking(UpdateRequest::sub(row, v)).unwrap();
                expect[row] = bits::sub_mod(expect[row], v, q);
            } else {
                e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
                expect[row] = bits::add_mod(expect[row], v, q);
            }
        }
        assert_eq!(e.snapshot().unwrap(), expect);
        let stats = e.stats();
        assert_eq!(stats.completed, 2000);
        assert!(stats.rows_per_batch > 1.0, "coalescing should batch rows");
        e.shutdown().unwrap();
    }

    #[test]
    fn sharded_stream_matches_host_semantics() {
        for shards in [2usize, 4, 8] {
            let rows = 256;
            let q = 16;
            let e = sharded_engine(rows, q, shards);
            let mut rng = Rng::new(1000 + shards as u64);
            let mut expect = vec![0u32; rows];
            for _ in 0..4000 {
                let row = rng.below(rows as u64) as usize;
                let v = rng.below(1 << q) as u32;
                if rng.chance(0.3) {
                    e.submit_blocking(UpdateRequest::sub(row, v)).unwrap();
                    expect[row] = bits::sub_mod(expect[row], v, q);
                } else {
                    e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
                    expect[row] = bits::add_mod(expect[row], v, q);
                }
            }
            assert_eq!(e.snapshot().unwrap(), expect, "shards = {shards}");
            let stats = e.stats();
            assert_eq!(stats.completed, 4000);
            assert_eq!(stats.shards.len(), shards);
            let per_shard_batches: u64 = stats.shards.iter().map(|s| s.batches_sealed).sum();
            assert_eq!(per_shard_batches, stats.batches);
            e.shutdown().unwrap();
        }
    }

    #[test]
    fn sharded_reads_and_writes_route_correctly() {
        let e = sharded_engine(256, 16, 4);
        for row in [0usize, 1, 2, 3, 4, 127, 128, 255] {
            e.write(row, (row as u32) + 7).unwrap();
        }
        for row in [0usize, 1, 2, 3, 4, 127, 128, 255] {
            assert_eq!(e.read(row).unwrap(), (row as u32) + 7, "row {row}");
        }
        e.shutdown().unwrap();
    }

    #[test]
    fn invalid_shard_configs_are_rejected() {
        let factory =
            |plan: &ShardPlan| -> Result<Box<dyn crate::coordinator::Backend>> {
                Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
            };
        // Not a power of two.
        assert!(UpdateEngine::start(EngineConfig::sharded(128, 16, 3), factory).is_err());
        // Does not divide the row space.
        assert!(UpdateEngine::start(EngineConfig::sharded(100, 16, 8), factory).is_err());
        // Zero shards.
        assert!(UpdateEngine::start(EngineConfig::sharded(128, 16, 0), factory).is_err());
    }

    #[test]
    fn submit_many_matches_individual_submits() {
        let rows = 128;
        let q = 16;
        let bulk = engine(rows, q);
        let single = engine(rows, q);
        let mut rng = Rng::new(9);
        let reqs: Vec<UpdateRequest> = (0..3000)
            .map(|_| {
                let row = rng.below(rows as u64) as usize;
                let v = rng.below(1 << q) as u32;
                if rng.chance(0.3) {
                    UpdateRequest::sub(row, v)
                } else {
                    UpdateRequest::add(row, v)
                }
            })
            .collect();
        for chunk in reqs.chunks(256) {
            bulk.submit_many(chunk.to_vec()).unwrap();
        }
        for r in &reqs {
            single.submit_blocking(*r).unwrap();
        }
        assert_eq!(bulk.snapshot().unwrap(), single.snapshot().unwrap());
        assert_eq!(bulk.stats().completed, 3000);
        bulk.shutdown().unwrap();
        single.shutdown().unwrap();
    }

    #[test]
    fn sharded_submit_many_partitions_by_shard() {
        let rows = 256;
        let q = 16;
        let sharded = sharded_engine(rows, q, 4);
        let single = engine(rows, q);
        let mut rng = Rng::new(21);
        let reqs: Vec<UpdateRequest> = (0..5000)
            .map(|_| UpdateRequest::add(rng.below(rows as u64) as usize, rng.below(1 << q) as u32))
            .collect();
        for chunk in reqs.chunks(512) {
            sharded.submit_many(chunk.to_vec()).unwrap();
            single.submit_many(chunk.to_vec()).unwrap();
        }
        assert_eq!(sharded.snapshot().unwrap(), single.snapshot().unwrap());
        sharded.shutdown().unwrap();
        single.shutdown().unwrap();
    }

    #[test]
    fn deadline_flushes_without_reads() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_deadline = Duration::from_millis(5);
        cfg.seal_at_rows = None; // only the deadline can flush
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        e.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let s = e.stats();
        assert_eq!(s.batches, 1, "deadline flush did not fire");
        assert_eq!(s.shards[0].sealed_deadline, 1, "seal reason must be Deadline");
        e.shutdown().unwrap();
    }

    #[test]
    fn write_is_consistent_with_pending_updates() {
        let e = engine(128, 16);
        e.submit_blocking(UpdateRequest::add(7, 5)).unwrap();
        e.write(7, 1000).unwrap(); // flushes the +5 first, then overwrites
        e.submit_blocking(UpdateRequest::add(7, 1)).unwrap();
        assert_eq!(e.read(7).unwrap(), 1001);
        e.shutdown().unwrap();
    }

    #[test]
    fn stats_report_energy_and_modeled_time() {
        let e = engine(128, 16);
        for r in 0..128 {
            e.submit_blocking(UpdateRequest::add(r, 1)).unwrap();
        }
        e.drain_shard(0).unwrap();
        let s = e.stats();
        assert!(s.modeled_energy_pj > 0.0);
        assert!(s.modeled_ns > 0.0);
        assert_eq!(s.backend, "fast-behavioural");
        e.shutdown().unwrap();
    }

    #[test]
    fn queue_depth_gauge_drains_to_zero() {
        let e = sharded_engine(256, 16, 2);
        for r in 0..256 {
            e.submit_blocking(UpdateRequest::add(r, 1)).unwrap();
        }
        e.drain_all().unwrap();
        let s = e.stats();
        assert_eq!(s.queue_depth, 0, "queue must drain after per-shard drains");
        assert!(s.shards.iter().any(|sc| sc.queue_high_water > 0));
        e.shutdown().unwrap();
    }

    #[test]
    fn ticketed_submit_resolves_with_commit_metadata() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600); // only the drain seals
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        let t1 = e.submit_blocking_ticketed(UpdateRequest::add(5, 7)).unwrap();
        let t2 = e.submit_blocking_ticketed(UpdateRequest::add(9, 1)).unwrap();
        let seq = e.drain_shard(0).unwrap();
        let c1 = t1.wait().unwrap();
        let c2 = t2.wait().unwrap();
        // Both requests rode the same batch → identical commit.
        assert_eq!(c1, c2);
        assert_eq!(c1.shard, 0);
        assert_eq!(c1.commit_seq, seq);
        assert_eq!(c1.rows, 2);
        assert_eq!(c1.requests, 2);
        assert_eq!(c1.rows_active, 2);
        assert_eq!(c1.seal_reason, SealReason::Forced);
        assert!(c1.modeled_ns > 0.0);
        assert!(c1.cycles > 0);
        let s = e.stats();
        assert_eq!(s.tickets_resolved, 2);
        assert!(s.shards[0].commit_wall.count == 2);
        assert!(s.shards[0].commit_modeled.count == 2);
        e.shutdown().unwrap();
    }

    #[test]
    fn commit_seqs_increase_per_shard_and_wait_seq_observes_them() {
        let e = sharded_engine(256, 16, 2);
        assert_eq!(e.committed_seq(0).unwrap(), 0);
        // Two sealed batches on shard 0 (rows with low bit 0).
        e.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
        let s1 = e.drain_shard(0).unwrap();
        e.submit_blocking(UpdateRequest::add(2, 1)).unwrap();
        let s2 = e.drain_shard(0).unwrap();
        assert_eq!((s1, s2), (1, 2));
        assert_eq!(e.wait_seq(0, 2).unwrap(), 2);
        assert_eq!(e.committed_seq(0).unwrap(), 2);
        // Shard 1 is untouched: its seq is still 0, and an empty drain
        // does not mint a commit.
        assert_eq!(e.committed_seq(1).unwrap(), 0);
        assert_eq!(e.drain_shard(1).unwrap(), 0);
        e.shutdown().unwrap();
    }

    #[test]
    fn wait_seq_blocks_until_a_concurrent_drain_commits() {
        let e = std::sync::Arc::new(engine(128, 16));
        e.submit_blocking(UpdateRequest::add(3, 9)).unwrap();
        let waiter = {
            let e = std::sync::Arc::clone(&e);
            std::thread::spawn(move || e.wait_seq(0, 1))
        };
        std::thread::sleep(Duration::from_millis(10));
        e.drain_shard(0).unwrap();
        assert_eq!(waiter.join().unwrap().unwrap(), 1);
        std::sync::Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn submit_many_ticketed_acks_once_per_touched_shard() {
        let e = sharded_engine(256, 16, 4);
        // Rows 0..8 touch all four shards, twice each.
        let reqs: Vec<UpdateRequest> =
            (0..8).map(|r| UpdateRequest::add(r, 1 + r as u32)).collect();
        let tickets = e.submit_many_ticketed(reqs).unwrap();
        assert_eq!(tickets.len(), 4, "one ticket per shard touched");
        for shard in 0..4 {
            e.drain_shard(shard).unwrap();
        }
        let mut shards_seen: Vec<usize> =
            tickets.iter().map(|t| t.wait().unwrap().shard).collect();
        shards_seen.sort_unstable();
        assert_eq!(shards_seen, vec![0, 1, 2, 3]);
        e.shutdown().unwrap();
    }

    #[test]
    fn read_of_untouched_row_leaves_the_open_batch_alone() {
        let mut cfg = EngineConfig::sharded(64, 16, 2);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600); // only forced seals
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        e.submit_blocking(UpdateRequest::add(0, 5)).unwrap(); // shard 0, pending
        // Row 2 is shard 0 but NOT pending: the read must not seal.
        assert_eq!(e.read(2).unwrap(), 0);
        assert_eq!(e.stats().batches, 0, "untouched read must not seal");
        // Reading the pending row seals (read-your-writes)…
        assert_eq!(e.read(0).unwrap(), 5);
        assert_eq!(e.stats().batches, 1);
        // …and a later drain finds nothing new.
        assert_eq!(e.drain_shard(0).unwrap(), 1);
        e.shutdown().unwrap();
    }

    #[test]
    fn dropped_tickets_error_when_engine_shuts_down_uncommitted() {
        // A worker that dies on a backend fault must fail pending
        // tickets rather than hang them.
        struct FailingBackend;
        impl crate::coordinator::Backend for FailingBackend {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn rows(&self) -> usize {
                128
            }
            fn q(&self) -> usize {
                16
            }
            fn apply(
                &mut self,
                _kind: crate::coordinator::BatchKind,
                _operands: &[u32],
            ) -> Result<crate::coordinator::AppliedBatch> {
                anyhow::bail!("injected apply fault")
            }
            fn read_row(&mut self, _row: usize) -> Result<u32> {
                Ok(0)
            }
            fn write_row(&mut self, _row: usize, _value: u32) -> Result<()> {
                Ok(())
            }
            fn snapshot(&mut self) -> Result<Vec<u32>> {
                Ok(vec![0; 128])
            }
        }
        let cfg = EngineConfig::new(128, 16);
        let e = UpdateEngine::start(cfg, |_p: &ShardPlan| Ok(Box::new(FailingBackend))).unwrap();
        let t = e.submit_blocking_ticketed(UpdateRequest::add(0, 1)).unwrap();
        // The drain trips the fault; the worker dies.
        assert!(e.drain_shard(0).is_err());
        assert!(t.wait().is_err(), "uncommitted ticket must error, not hang");
        assert!(e.wait_seq(0, 1).is_err(), "seq latch must close on worker death");
        let _ = e.shutdown();
    }

    #[test]
    fn commit_listener_sees_commits_and_writes_before_tickets_resolve() {
        use std::sync::Mutex;

        #[derive(Debug, Default)]
        struct Log {
            commits: Vec<(u64, usize)>, // (commit_seq, non-identity ops)
            writes: Vec<(usize, u32, u64)>,
            barriers: u64,
        }
        struct Recorder(Arc<Mutex<Log>>);
        impl CommitListener for Recorder {
            fn on_commit(
                &mut self,
                commit: &Commit,
                kind: BatchKind,
                operands: &[u32],
            ) -> Result<()> {
                let ident = kind.identity(16);
                let ops = operands.iter().filter(|&&o| o != ident).count();
                self.0.lock().unwrap().commits.push((commit.commit_seq, ops));
                Ok(())
            }
            fn on_write(&mut self, row: usize, value: u32, committed_seq: u64) -> Result<()> {
                self.0.lock().unwrap().writes.push((row, value, committed_seq));
                Ok(())
            }
            fn on_barrier(&mut self) -> Result<()> {
                self.0.lock().unwrap().barriers += 1;
                Ok(())
            }
        }

        let log = Arc::new(Mutex::new(Log::default()));
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        let log2 = Arc::clone(&log);
        let e = UpdateEngine::start_with_listener(
            cfg,
            |p: &ShardPlan| Ok(Box::new(FastBackend::with_rows(p.rows, p.q))),
            move |_plan| Ok(Some(Box::new(Recorder(Arc::clone(&log2))) as Box<_>)),
        )
        .unwrap();
        let t = e.submit_blocking_ticketed(UpdateRequest::add(3, 7)).unwrap();
        e.submit_blocking(UpdateRequest::add(9, 1)).unwrap();
        assert_eq!(e.drain_shard(0).unwrap(), 1);
        let c = t.wait().unwrap();
        // The ticket resolved, so the listener must already have seen
        // the commit (hook runs before resolution).
        {
            let g = log.lock().unwrap();
            assert_eq!(g.commits, vec![(c.commit_seq, 2)]);
            assert!(g.barriers >= 1, "drain is a listener barrier");
        }
        e.write(5, 1000).unwrap();
        assert_eq!(log.lock().unwrap().writes, vec![(5, 1000, 1)]);
        e.shutdown().unwrap();
        assert!(log.lock().unwrap().barriers >= 2, "shutdown is a barrier too");
    }

    #[test]
    fn failing_listener_fails_tickets_like_a_backend_fault() {
        struct Failing;
        impl CommitListener for Failing {
            fn on_commit(&mut self, _: &Commit, _: BatchKind, _: &[u32]) -> Result<()> {
                anyhow::bail!("injected listener fault")
            }
        }
        let cfg = EngineConfig::new(128, 16);
        let e = UpdateEngine::start_with_listener(
            cfg,
            |p: &ShardPlan| Ok(Box::new(FastBackend::with_rows(p.rows, p.q))),
            |_plan| Ok(Some(Box::new(Failing) as Box<_>)),
        )
        .unwrap();
        let t = e.submit_blocking_ticketed(UpdateRequest::add(0, 1)).unwrap();
        assert!(e.drain_shard(0).is_err(), "listener fault kills the drain");
        assert!(t.wait().is_err(), "ticket must error, not report a lost commit");
        let _ = e.shutdown();
    }

    #[test]
    fn durability_config_conflicts_with_explicit_listener() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.durability = Some(crate::durability::DurabilityConfig::new(
            std::env::temp_dir().join("fast-never-created"),
        ));
        let r = UpdateEngine::start_with_listener(
            cfg,
            |p: &ShardPlan| Ok(Box::new(FastBackend::with_rows(p.rows, p.q))),
            |_plan| Ok(None),
        );
        assert!(r.is_err());
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600); // never by deadline
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        e.submit_blocking(UpdateRequest::add(0, 42)).unwrap();
        // give the worker a moment to drain the queue
        std::thread::sleep(Duration::from_millis(20));
        e.shutdown().unwrap();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.queue_cap = 2;
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        // A slow backend would be needed to reliably fill the queue; we
        // simulate by pausing the worker with a flood from this thread.
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        let mut rejected = 0;
        for i in 0..10_000 {
            if let Err(err) = e.submit(UpdateRequest::add((i % 128) as usize, 1)) {
                assert!(
                    err.root_cause().downcast_ref::<EngineBusy>().is_some(),
                    "rejections must carry the typed EngineBusy cause: {err:#}"
                );
                rejected += 1;
            }
        }
        // With a 2-deep queue and a busy worker some rejections are
        // overwhelmingly likely, but not guaranteed — accept either,
        // the accounting must be consistent.
        let s = e.stats();
        assert_eq!(s.rejected, rejected);
        assert_eq!(s.submitted, 10_000);
        e.shutdown().unwrap();
    }

    #[test]
    fn query_observes_pending_updates_and_stamps_seqs() {
        use crate::query::Reduction;
        let mut cfg = EngineConfig::sharded(64, 16, 2);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600); // only forced seals
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        e.submit_blocking(UpdateRequest::add(0, 5)).unwrap(); // shard 0
        e.submit_blocking(UpdateRequest::add(1, 7)).unwrap(); // shard 1
        let r = e.query(&QuerySpec::all(Reduction::Sum)).unwrap();
        // The query sealed both open batches: the sum reflects both
        // pending updates and each shard stamps commit_seq 1.
        assert_eq!(r.value, 12);
        assert_eq!(r.shard_seqs, vec![1, 1]);
        assert_eq!(r.report.rows_active, 64);
        assert!(r.cost.energy_fj > 0.0);
        // A second identical query finds nothing new to seal.
        let r2 = e.query(&QuerySpec::all(Reduction::Sum)).unwrap();
        assert_eq!(r2.value, 12);
        assert_eq!(r2.shard_seqs, vec![1, 1]);
        let s = e.stats();
        assert_eq!(s.queries, 4, "two engine queries × two shards");
        assert!(s.shards.iter().all(|sc| sc.queries == 2));
        assert!(s.shards.iter().all(|sc| sc.query_wall.count == 2));
        // Queries mint no commits and fold nothing into the update
        // energy account beyond the two seals they forced.
        assert_eq!(s.batches, 2);
        e.shutdown().unwrap();
    }

    #[test]
    fn query_matches_scalar_oracle_across_shard_counts() {
        use crate::query::{seeded_mask, Reduction};
        let rows = 256;
        let q = 16;
        let mut rng = Rng::new(4242);
        let updates: Vec<(usize, u32)> = (0..2000)
            .map(|_| (rng.below(rows as u64) as usize, rng.below(1 << q) as u32))
            .collect();
        let mut expect = vec![0u32; rows];
        for &(row, v) in &updates {
            expect[row] = bits::add_mod(expect[row], v, q);
        }
        let spec = QuerySpec::masked(
            Reduction::RangeCount { lo: 1, hi: 40_000 },
            seeded_mask(3, 70, rows),
        );
        let (want, _) = crate::query::scalar_reduce(&spec, &expect, q).unwrap();
        let mut results = Vec::new();
        for shards in [1usize, 2, 4, 8] {
            let e = sharded_engine(rows, q, shards);
            for &(row, v) in &updates {
                e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
            }
            let r = e.query(&spec).unwrap();
            assert_eq!(r.value, want, "shards = {shards}");
            results.push(r);
            e.shutdown().unwrap();
        }
        // Sharding must not move the combined pass accounting (the
        // modeled cost legitimately differs: shard slices bank
        // differently — e.g. 64-row banks at 4 shards).
        for r in &results[1..] {
            assert_eq!(r.report, results[0].report);
        }
    }

    #[test]
    fn out_of_range_submit_is_a_clean_error() {
        let e = sharded_engine(256, 16, 4);
        // Row 300 is out of range but would alias into shard space if
        // unvalidated — must be rejected at admission instead.
        assert!(e.submit(UpdateRequest::add(300, 1)).is_err());
        assert!(e.submit_blocking(UpdateRequest::add(300, 1)).is_err());
        assert!(e.submit_many(vec![UpdateRequest::add(300, 1)]).is_err());
        // Engine still healthy.
        e.submit_blocking(UpdateRequest::add(255, 2)).unwrap();
        assert_eq!(e.read(255).unwrap(), 2);
        e.shutdown().unwrap();
    }

    /// A [`FastBackend`] whose applies sleep, so admission queues
    /// reliably fill under test load.
    struct SlowBackend {
        inner: FastBackend,
        apply_delay: Duration,
    }

    impl crate::coordinator::Backend for SlowBackend {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn rows(&self) -> usize {
            self.inner.rows()
        }
        fn q(&self) -> usize {
            self.inner.q()
        }
        fn apply(
            &mut self,
            kind: BatchKind,
            operands: &[u32],
        ) -> Result<crate::coordinator::AppliedBatch> {
            std::thread::sleep(self.apply_delay);
            self.inner.apply(kind, operands)
        }
        fn read_row(&mut self, row: usize) -> Result<u32> {
            self.inner.read_row(row)
        }
        fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
            self.inner.write_row(row, value)
        }
        fn snapshot(&mut self) -> Result<Vec<u32>> {
            self.inner.snapshot()
        }
    }

    /// Regression for the queue-gauge overcount race: the old gauge
    /// was raised BEFORE the send, so a rejected non-blocking submit
    /// racing an admitted one could push `queue_high_water` past
    /// `queue_cap`. The gauge is now derived from ring occupancy,
    /// which the admission CAS bounds at the cap — hammer the queue
    /// with racing producers and pin `high_water <= queue_cap`.
    #[test]
    fn queue_high_water_never_exceeds_cap() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.queue_cap = 4;
        cfg.seal_at_rows = Some(1); // every request seals → slow applies back up the queue
        let e = Arc::new(
            UpdateEngine::start(cfg, |p: &ShardPlan| {
                Ok(Box::new(SlowBackend {
                    inner: FastBackend::with_rows(p.rows, p.q),
                    apply_delay: Duration::from_micros(200),
                }) as Box<dyn Backend>)
            })
            .unwrap(),
        );
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let e = Arc::clone(&e);
                std::thread::spawn(move || {
                    let mut rejected = 0u64;
                    for i in 0..500usize {
                        if e.submit(UpdateRequest::add((p * 31 + i) % 128, 1)).is_err() {
                            rejected += 1;
                        }
                    }
                    rejected
                })
            })
            .collect();
        let rejected: u64 = producers.into_iter().map(|h| h.join().unwrap()).sum();
        let s = e.stats();
        for sc in &s.shards {
            assert!(
                sc.queue_high_water <= 4,
                "high_water {} exceeded queue_cap 4",
                sc.queue_high_water
            );
        }
        // With a 4-deep queue and 200 µs applies, rejections are
        // effectively certain; the accounting must agree either way.
        assert_eq!(s.rejected, rejected);
        Arc::try_unwrap(e).ok().expect("sole owner").shutdown().unwrap();
    }

    /// Blocking submits against a full ring must do observable
    /// slow-path work (spin and/or park) and report it through the
    /// contention counters.
    #[test]
    fn blocking_submit_records_contention_counters() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.queue_cap = 1;
        cfg.seal_at_rows = Some(1);
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(SlowBackend {
                inner: FastBackend::with_rows(p.rows, p.q),
                apply_delay: Duration::from_millis(1),
            }) as Box<dyn Backend>)
        })
        .unwrap();
        for i in 0..20usize {
            e.submit_blocking(UpdateRequest::add(i % 128, 1)).unwrap();
        }
        let s = e.stats();
        assert!(
            s.submit_spins + s.park_events > 0,
            "a 1-deep ring with 1 ms applies must force spins or parks"
        );
        e.shutdown().unwrap();
    }

    /// The wake-batch histogram records how many ticket waiters each
    /// seal resolved with its single notify_all.
    #[test]
    fn wake_batch_histogram_counts_waiters_per_seal() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)) as Box<dyn Backend>)
        })
        .unwrap();
        let tickets: Vec<_> = (0..4)
            .map(|r| e.submit_blocking_ticketed(UpdateRequest::add(r, 1)).unwrap())
            .collect();
        e.drain_shard(0).unwrap();
        for t in &tickets {
            t.wait().unwrap();
        }
        let s = e.stats();
        assert_eq!(s.shards[0].wake_batch.count, 1, "one seal, one wake batch");
        assert_eq!(s.shards[0].wake_batch.max_ns, 4, "the seal woke all 4 waiters");
        e.shutdown().unwrap();
    }
}
