//! The concurrent update engine — the Layer-3 system around the FAST
//! macro: admission control, coalescing batcher, flush policy, worker
//! thread, metrics.
//!
//! Lifecycle: `UpdateEngine::start(config, backend_factory)` spawns a
//! worker thread that *constructs the backend inside the thread* (PJRT
//! executables are not `Send`), then consumes commands from a bounded
//! channel. Updates flow through the [`Batcher`]; batches flush when
//! full (`seal_at_rows`), on a kind change, on the flush deadline, or
//! when a read needs read-your-writes consistency.
//!
//! Tokio is not in the offline vendor set (DESIGN.md §7) —
//! `std::thread` + `mpsc::sync_channel` provide the same bounded-queue
//! backpressure semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::metrics::{Counters, EnergyAccount, LatencyRecorder, LatencySummary};
use crate::Result;

use super::backend::Backend;
use super::batcher::Batcher;
use super::request::UpdateRequest;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Logical rows (must match the backend).
    pub rows: usize,
    /// Word width q.
    pub q: usize,
    /// Seal a batch once this many distinct rows are touched.
    /// `None` = seal only on kind change / deadline / read.
    pub seal_at_rows: Option<usize>,
    /// Flush deadline for a non-empty open batch.
    pub flush_interval: Duration,
    /// Bounded command-queue depth (admission control).
    pub queue_cap: usize,
}

impl EngineConfig {
    /// A sensible default for an R-row, q-bit array: seal at 75% of the
    /// row space, 100 µs deadline, 4096-deep queue.
    pub fn new(rows: usize, q: usize) -> Self {
        EngineConfig {
            rows,
            q,
            seal_at_rows: Some((rows * 3 / 4).max(1)),
            flush_interval: Duration::from_micros(100),
            queue_cap: 4096,
        }
    }
}

enum Command {
    Submit(UpdateRequest),
    /// Amortizes channel crossings for bulk producers (one message per
    /// chunk instead of per request).
    SubmitMany(Vec<UpdateRequest>),
    Read(usize, SyncSender<Result<u32>>),
    Write(usize, u32, SyncSender<Result<()>>),
    Flush(SyncSender<()>),
    Snapshot(SyncSender<Result<Vec<u32>>>),
    Shutdown,
}

/// Shared metrics handle.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub counters: Counters,
    pub energy: EnergyAccount,
    /// Wall-clock time spent applying batches.
    pub apply_wall: LatencyRecorder,
    /// Modeled macro time in femtoseconds (ns × 1e6, atomically summed).
    modeled_fs: AtomicU64,
}

impl EngineMetrics {
    pub fn add_modeled_ns(&self, ns: f64) {
        self.modeled_fs
            .fetch_add((ns * 1e6).round() as u64, Ordering::Relaxed);
    }

    pub fn modeled_ns(&self) -> f64 {
        self.modeled_fs.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Point-in-time engine statistics.
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub rows_updated: u64,
    pub rows_per_batch: f64,
    pub modeled_ns: f64,
    pub modeled_energy_pj: f64,
    pub apply_wall: LatencySummary,
    pub backend: &'static str,
}

/// Handle to a running update engine.
pub struct UpdateEngine {
    tx: SyncSender<Command>,
    worker: Option<JoinHandle<Result<()>>>,
    metrics: Arc<EngineMetrics>,
    backend_name: std::sync::OnceLock<&'static str>,
    cfg: EngineConfig,
}

impl UpdateEngine {
    /// Start the engine. `backend_factory` runs on the worker thread.
    pub fn start<F>(cfg: EngineConfig, backend_factory: F) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn Backend>> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(cfg.queue_cap);
        let metrics = Arc::new(EngineMetrics::default());
        let worker_metrics = Arc::clone(&metrics);
        let worker_cfg = cfg.clone();
        // Report the backend name back once it is constructed.
        let (name_tx, name_rx) = mpsc::sync_channel(1);
        let worker = std::thread::Builder::new()
            .name("fast-update-engine".into())
            .spawn(move || worker_loop(worker_cfg, rx, worker_metrics, backend_factory, name_tx))
            .expect("spawning engine worker");
        let backend_name = std::sync::OnceLock::new();
        match name_rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Ok(name)) => {
                let _ = backend_name.set(name);
            }
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(e);
            }
            Err(_) => bail!("engine worker failed to start within 120 s"),
        }
        Ok(UpdateEngine { tx, worker: Some(worker), metrics, backend_name, cfg })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Non-blocking submit. `Err` = queue full (backpressure) or engine
    /// shut down; the request was NOT accepted.
    pub fn submit(&self, req: UpdateRequest) -> Result<()> {
        Counters::inc(&self.metrics.counters.requests_submitted, 1);
        match self.tx.try_send(Command::Submit(req)) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                Counters::inc(&self.metrics.counters.requests_rejected, 1);
                Err(anyhow!("queue full: request rejected (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("engine is shut down")),
        }
    }

    /// Blocking submit: waits for queue space (no rejection).
    pub fn submit_blocking(&self, req: UpdateRequest) -> Result<()> {
        Counters::inc(&self.metrics.counters.requests_submitted, 1);
        self.tx
            .send(Command::Submit(req))
            .map_err(|_| anyhow!("engine is shut down"))
    }

    /// Bulk blocking submit: one channel crossing for the whole chunk —
    /// the fast path for high-rate producers (apps, benches).
    pub fn submit_many(&self, reqs: Vec<UpdateRequest>) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        Counters::inc(&self.metrics.counters.requests_submitted, reqs.len() as u64);
        self.tx
            .send(Command::SubmitMany(reqs))
            .map_err(|_| anyhow!("engine is shut down"))
    }

    /// Read a row with read-your-writes consistency (flushes first).
    pub fn read(&self, row: usize) -> Result<u32> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Command::Read(row, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Direct row write (conventional port; flushes pending batch first).
    pub fn write(&self, row: usize, value: u32) -> Result<()> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Command::Write(row, value, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Force a flush and wait for it.
    pub fn flush(&self) -> Result<()> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Command::Flush(tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))
    }

    /// Consistent snapshot of all rows (flushes first).
    pub fn snapshot(&self) -> Result<Vec<u32>> {
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Command::Snapshot(tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    pub fn stats(&self) -> EngineStats {
        let c = self.metrics.counters.snapshot();
        EngineStats {
            submitted: c.requests_submitted,
            completed: c.requests_completed,
            rejected: c.requests_rejected,
            batches: c.batches_flushed,
            rows_updated: c.rows_updated,
            rows_per_batch: c.rows_per_batch(),
            modeled_ns: self.metrics.modeled_ns(),
            modeled_energy_pj: self.metrics.energy.total_pj(),
            apply_wall: self.metrics.apply_wall.summary(),
            backend: self.backend_name.get().copied().unwrap_or("unknown"),
        }
    }

    /// Graceful shutdown: flush, stop the worker, join.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        if let Some(worker) = self.worker.take() {
            let _ = self.tx.send(Command::Shutdown);
            match worker.join() {
                Ok(r) => r?,
                Err(_) => bail!("engine worker panicked"),
            }
        }
        Ok(())
    }
}

impl Drop for UpdateEngine {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

fn worker_loop<F>(
    cfg: EngineConfig,
    rx: Receiver<Command>,
    metrics: Arc<EngineMetrics>,
    backend_factory: F,
    name_tx: SyncSender<Result<&'static str>>,
) -> Result<()>
where
    F: FnOnce() -> Result<Box<dyn Backend>>,
{
    let mut backend = match backend_factory() {
        Ok(b) => {
            let _ = name_tx.send(Ok(b.name()));
            b
        }
        Err(e) => {
            let _ = name_tx.send(Err(anyhow!("backend construction failed: {e:#}")));
            return Ok(());
        }
    };
    anyhow::ensure!(
        backend.rows() == cfg.rows,
        "backend rows {} != config rows {}",
        backend.rows(),
        cfg.rows
    );
    let mut batcher = Batcher::new(cfg.rows, cfg.q, cfg.seal_at_rows);
    let mut deadline: Option<Instant> = None;

    let apply_sealed = |batch: super::batcher::Batch,
                        backend: &mut Box<dyn Backend>|
     -> Result<()> {
        let applied = metrics
            .apply_wall
            .time(|| backend.apply(batch.kind, &batch.operands))?;
        Counters::inc(&metrics.counters.batches_flushed, 1);
        Counters::inc(&metrics.counters.rows_updated, batch.rows_touched as u64);
        Counters::inc(&metrics.counters.requests_completed, batch.requests as u64);
        Counters::inc(
            &metrics.counters.requests_coalesced,
            (batch.requests - batch.rows_touched) as u64,
        );
        Counters::inc(&metrics.counters.shift_cycles, applied.cycles);
        metrics.energy.add_fj(applied.cost.energy_fj);
        metrics.add_modeled_ns(applied.cost.latency_ns);
        Ok(())
    };
    let flush =
        |batcher: &mut Batcher, backend: &mut Box<dyn Backend>| -> Result<()> {
            if let Some(batch) = batcher.force_flush() {
                apply_sealed(batch, backend)?;
            }
            Ok(())
        };

    loop {
        let cmd = match deadline {
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    flush(&mut batcher, &mut backend)?;
                    deadline = None;
                    continue;
                }
                match rx.recv_timeout(d - now) {
                    Ok(c) => c,
                    Err(RecvTimeoutError::Timeout) => {
                        flush(&mut batcher, &mut backend)?;
                        deadline = None;
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(c) => c,
                Err(_) => break,
            },
        };

        match cmd {
            Command::Submit(req) => {
                if batcher.pending_rows() == 0 {
                    deadline = Some(Instant::now() + cfg.flush_interval);
                }
                if let Some((batch, _reason)) = batcher.push(req) {
                    apply_sealed(batch, &mut backend)?;
                    deadline = if batcher.pending_rows() > 0 {
                        Some(Instant::now() + cfg.flush_interval)
                    } else {
                        None
                    };
                }
            }
            Command::SubmitMany(reqs) => {
                for req in reqs {
                    if let Some((batch, _reason)) = batcher.push(req) {
                        apply_sealed(batch, &mut backend)?;
                        deadline = None; // re-anchored below if still pending
                    }
                }
                // Anchor the deadline at the first pending request; do
                // not extend it on later arrivals (bounded staleness).
                if batcher.pending_rows() > 0 {
                    if deadline.is_none() {
                        deadline = Some(Instant::now() + cfg.flush_interval);
                    }
                } else {
                    deadline = None;
                }
            }
            Command::Read(row, reply) => {
                flush(&mut batcher, &mut backend)?;
                deadline = None;
                let _ = reply.send(backend.read_row(row));
            }
            Command::Write(row, value, reply) => {
                flush(&mut batcher, &mut backend)?;
                deadline = None;
                let _ = reply.send(backend.write_row(row, value));
            }
            Command::Flush(reply) => {
                flush(&mut batcher, &mut backend)?;
                deadline = None;
                let _ = reply.send(());
            }
            Command::Snapshot(reply) => {
                flush(&mut batcher, &mut backend)?;
                deadline = None;
                let _ = reply.send(backend.snapshot());
            }
            Command::Shutdown => {
                flush(&mut batcher, &mut backend)?;
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FastBackend;
    use crate::util::bits;
    use crate::util::rng::Rng;

    fn engine(rows: usize, q: usize) -> UpdateEngine {
        let cfg = EngineConfig::new(rows, q);
        UpdateEngine::start(cfg, move || {
            Ok(Box::new(FastBackend::new(rows.div_ceil(128).max(1), rows.min(128), q)))
        })
        .unwrap()
    }

    #[test]
    fn submit_read_roundtrip() {
        let e = engine(128, 16);
        e.submit_blocking(UpdateRequest::add(5, 100)).unwrap();
        e.submit_blocking(UpdateRequest::add(5, 23)).unwrap();
        e.submit_blocking(UpdateRequest::sub(5, 3)).unwrap();
        assert_eq!(e.read(5).unwrap(), 120);
        let stats = e.stats();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.completed, 3);
        assert!(stats.batches >= 1);
        e.shutdown().unwrap();
    }

    #[test]
    fn random_stream_matches_host_semantics() {
        let rows = 128;
        let q = 16;
        let e = engine(rows, q);
        let mut rng = Rng::new(77);
        let mut expect = vec![0u32; rows];
        for _ in 0..2000 {
            let row = rng.below(rows as u64) as usize;
            let v = rng.below(1 << q) as u32;
            if rng.chance(0.3) {
                e.submit_blocking(UpdateRequest::sub(row, v)).unwrap();
                expect[row] = bits::sub_mod(expect[row], v, q);
            } else {
                e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
                expect[row] = bits::add_mod(expect[row], v, q);
            }
        }
        assert_eq!(e.snapshot().unwrap(), expect);
        let stats = e.stats();
        assert_eq!(stats.completed, 2000);
        assert!(stats.rows_per_batch > 1.0, "coalescing should batch rows");
        e.shutdown().unwrap();
    }

    #[test]
    fn submit_many_matches_individual_submits() {
        let rows = 128;
        let q = 16;
        let bulk = engine(rows, q);
        let single = engine(rows, q);
        let mut rng = Rng::new(9);
        let reqs: Vec<UpdateRequest> = (0..3000)
            .map(|_| {
                let row = rng.below(rows as u64) as usize;
                let v = rng.below(1 << q) as u32;
                if rng.chance(0.3) {
                    UpdateRequest::sub(row, v)
                } else {
                    UpdateRequest::add(row, v)
                }
            })
            .collect();
        for chunk in reqs.chunks(256) {
            bulk.submit_many(chunk.to_vec()).unwrap();
        }
        for r in &reqs {
            single.submit_blocking(*r).unwrap();
        }
        assert_eq!(bulk.snapshot().unwrap(), single.snapshot().unwrap());
        assert_eq!(bulk.stats().completed, 3000);
        bulk.shutdown().unwrap();
        single.shutdown().unwrap();
    }

    #[test]
    fn deadline_flushes_without_reads() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.flush_interval = Duration::from_millis(5);
        cfg.seal_at_rows = None; // only the deadline can flush
        let e = UpdateEngine::start(cfg, || Ok(Box::new(FastBackend::new(1, 128, 16)))).unwrap();
        e.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(e.stats().batches, 1, "deadline flush did not fire");
        e.shutdown().unwrap();
    }

    #[test]
    fn write_is_consistent_with_pending_updates() {
        let e = engine(128, 16);
        e.submit_blocking(UpdateRequest::add(7, 5)).unwrap();
        e.write(7, 1000).unwrap(); // flushes the +5 first, then overwrites
        e.submit_blocking(UpdateRequest::add(7, 1)).unwrap();
        assert_eq!(e.read(7).unwrap(), 1001);
        e.shutdown().unwrap();
    }

    #[test]
    fn stats_report_energy_and_modeled_time() {
        let e = engine(128, 16);
        for r in 0..128 {
            e.submit_blocking(UpdateRequest::add(r, 1)).unwrap();
        }
        e.flush().unwrap();
        let s = e.stats();
        assert!(s.modeled_energy_pj > 0.0);
        assert!(s.modeled_ns > 0.0);
        assert_eq!(s.backend, "fast-behavioural");
        e.shutdown().unwrap();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_at_rows = None;
        cfg.flush_interval = Duration::from_secs(3600); // never by deadline
        let e = UpdateEngine::start(cfg, || Ok(Box::new(FastBackend::new(1, 128, 16)))).unwrap();
        e.submit_blocking(UpdateRequest::add(0, 42)).unwrap();
        // give the worker a moment to drain the queue
        std::thread::sleep(Duration::from_millis(20));
        e.shutdown().unwrap();
        // Batch applied at shutdown — verified via a fresh engine not
        // possible (state dropped); instead assert via stats path in
        // the deadline test. Here we just assert clean shutdown.
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.queue_cap = 2;
        cfg.seal_at_rows = None;
        cfg.flush_interval = Duration::from_secs(3600);
        // A slow backend would be needed to reliably fill the queue; we
        // simulate by pausing the worker with a flood from this thread.
        let e = UpdateEngine::start(cfg, || Ok(Box::new(FastBackend::new(1, 128, 16)))).unwrap();
        let mut rejected = 0;
        for i in 0..10_000 {
            if e.submit(UpdateRequest::add((i % 128) as usize, 1)).is_err() {
                rejected += 1;
            }
        }
        // With a 2-deep queue and a busy worker some rejections are
        // overwhelmingly likely, but not guaranteed — accept either,
        // the accounting must be consistent.
        let s = e.stats();
        assert_eq!(s.rejected, rejected);
        assert_eq!(s.submitted, 10_000);
        e.shutdown().unwrap();
    }
}
