//! The sharded concurrent update engine — the Layer-3 system around
//! the FAST macros: admission control, per-shard coalescing batchers,
//! group-commit seal policy, worker threads, metrics.
//!
//! ## Sharding
//!
//! The paper's hardware updates *all 128 rows of a macro concurrently*;
//! a single coordinator worker would serialize in software exactly what
//! the array parallelizes. The engine therefore stripes the logical row
//! space over `shards` independent shards (power of two). A row is
//! routed by its low bits — `shard = row & (shards - 1)`, `local_row =
//! row >> log2(shards)` — so contiguous and uniform workloads both
//! spread evenly. Each shard owns:
//!
//! - a bounded command queue (admission control / backpressure),
//! - a [`Batcher`] coalescing same-row deltas,
//! - a worker thread,
//! - a [`Backend`] instance over the shard's rows.
//!
//! Same-row requests always land on the same shard, so per-row order is
//! program order. Cross-row ordering between shards is relaxed — the
//! same contract a multi-bank memory gives the hardware.
//!
//! ## Group commit
//!
//! Each shard seals batches like a write-ahead log groups commits: a
//! batch is sealed when it is *full* (`seal_at_rows` distinct rows),
//! when a request of a different batch kind arrives, when the
//! *seal deadline* expires (bounded staleness), or when a read needs
//! read-your-writes consistency. One backend dispatch then applies the
//! whole batch, amortizing dispatch cost the way group commit
//! amortizes fsync.
//!
//! Lifecycle: `UpdateEngine::start(config, backend_factory)` spawns one
//! worker per shard; each worker *constructs its backend inside the
//! thread* (PJRT executables are not `Send`).
//!
//! Tokio is not in the offline vendor set (DESIGN.md §7) —
//! `std::thread` + `mpsc::sync_channel` provide the same bounded-queue
//! backpressure semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure};

use crate::metrics::{
    Counters, EnergyAccount, LatencyRecorder, LatencySummary, ShardCounters, ShardSnapshot,
};
use crate::Result;

use super::backend::Backend;
use super::batcher::{Batcher, SealReason};
use super::request::UpdateRequest;

/// Engine configuration. All knobs have CLI flags on `fast serve`.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Logical rows across all shards (must match the summed backend
    /// rows). Unit: rows. Must be divisible by `shards`.
    pub rows: usize,
    /// Word width q. Unit: bits (1..=32).
    pub q: usize,
    /// Worker shards. Unit: count; must be a power of two and divide
    /// `rows`. Default 1 (single-worker, the pre-sharding behaviour).
    /// Each shard owns the rows whose low bits equal its index.
    pub shards: usize,
    /// Group-commit size seal: seal a shard's batch once this many
    /// distinct rows of the *logical* space are touched (each shard
    /// seals at `max(1, seal_at_rows / shards)` of its own rows).
    /// Unit: rows. `None` = seal only on kind change / deadline / read.
    /// Default: 75% of the row space.
    pub seal_at_rows: Option<usize>,
    /// Group-commit deadline seal: a non-empty open batch is flushed
    /// this long after its first pending request (bounded staleness).
    /// Unit: duration (CLI flag `--seal-deadline-us`). Default 100 µs.
    pub seal_deadline: Duration,
    /// Bounded per-shard command-queue depth (admission control).
    /// Unit: commands. Default 4096.
    pub queue_cap: usize,
}

impl EngineConfig {
    /// A sensible default for an R-row, q-bit array: one shard, seal at
    /// 75% of the row space, 100 µs seal deadline, 4096-deep queue.
    pub fn new(rows: usize, q: usize) -> Self {
        EngineConfig {
            rows,
            q,
            shards: 1,
            seal_at_rows: Some((rows * 3 / 4).max(1)),
            seal_deadline: Duration::from_micros(100),
            queue_cap: 4096,
        }
    }

    /// Default config striped over `shards` worker shards.
    pub fn sharded(rows: usize, q: usize, shards: usize) -> Self {
        let mut cfg = Self::new(rows, q);
        cfg.shards = shards;
        cfg
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.rows >= 1, "rows must be >= 1");
        ensure!(self.shards >= 1, "shards must be >= 1, got {}", self.shards);
        ensure!(
            self.shards.is_power_of_two(),
            "shards must be a power of two, got {}",
            self.shards
        );
        ensure!(
            self.rows % self.shards == 0,
            "rows {} not divisible by shards {}",
            self.rows,
            self.shards
        );
        ensure!(self.queue_cap >= 1, "queue_cap must be >= 1");
        Ok(())
    }

    /// log2(shards); valid after `validate`.
    fn shard_bits(&self) -> u32 {
        self.shards.trailing_zeros()
    }
}

/// Identity of one engine shard, handed to the backend factory so it
/// can size the backend to the shard's slice of the row space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPlan {
    /// Shard index in `0..shards`.
    pub shard: usize,
    /// Total shard count (power of two).
    pub shards: usize,
    /// Rows owned by this shard (`config.rows / shards`).
    pub rows: usize,
    /// Word width q (bits).
    pub q: usize,
}

/// The factory that builds one backend per shard, invoked *on the
/// shard's worker thread* (PJRT executables are not `Send`).
pub type BackendFactory =
    dyn Fn(&ShardPlan) -> Result<Box<dyn Backend>> + Send + Sync + 'static;

enum Command {
    Submit(UpdateRequest),
    /// Amortizes channel crossings for bulk producers (one message per
    /// chunk instead of per request). Rows are shard-local.
    SubmitMany(Vec<UpdateRequest>),
    Read(usize, SyncSender<Result<u32>>),
    Write(usize, u32, SyncSender<Result<()>>),
    Flush(SyncSender<()>),
    Snapshot(SyncSender<Result<Vec<u32>>>),
    Shutdown,
}

/// Shared metrics handle.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    pub counters: Counters,
    pub energy: EnergyAccount,
    /// Wall-clock time spent applying batches (all shards).
    pub apply_wall: LatencyRecorder,
    /// Per-shard counters (group-commit seal reasons, queue depth, …).
    pub shards: Vec<ShardCounters>,
    /// Modeled macro time in femtoseconds (ns × 1e6, atomically summed).
    modeled_fs: AtomicU64,
}

impl EngineMetrics {
    fn new(shards: usize) -> Self {
        EngineMetrics {
            shards: (0..shards).map(|_| ShardCounters::default()).collect(),
            ..Default::default()
        }
    }

    pub fn add_modeled_ns(&self, ns: f64) {
        self.modeled_fs
            .fetch_add((ns * 1e6).round() as u64, Ordering::Relaxed);
    }

    pub fn modeled_ns(&self) -> f64 {
        self.modeled_fs.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Point-in-time engine statistics.
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batches: u64,
    pub rows_updated: u64,
    pub rows_per_batch: f64,
    pub modeled_ns: f64,
    pub modeled_energy_pj: f64,
    pub apply_wall: LatencySummary,
    pub backend: &'static str,
    /// Requests admitted but not yet drained by workers (all shards).
    pub queue_depth: u64,
    /// Per-shard breakdown (seal reasons, coalesce hits, queue depth).
    pub shards: Vec<ShardSnapshot>,
}

struct ShardHandle {
    tx: SyncSender<Command>,
    worker: Option<JoinHandle<Result<()>>>,
}

/// Handle to a running update engine. Shareable across producer
/// threads (`Arc<UpdateEngine>`): every submit path is `&self`.
pub struct UpdateEngine {
    shards: Vec<ShardHandle>,
    shard_bits: u32,
    metrics: Arc<EngineMetrics>,
    backend_name: std::sync::OnceLock<&'static str>,
    cfg: EngineConfig,
}

impl UpdateEngine {
    /// Start the engine: one worker thread per shard, each building its
    /// own backend via `backend_factory` (called on the worker thread
    /// with that shard's [`ShardPlan`]).
    pub fn start<F>(cfg: EngineConfig, backend_factory: F) -> Result<Self>
    where
        F: Fn(&ShardPlan) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    {
        cfg.validate()?;
        let factory: Arc<BackendFactory> = Arc::new(backend_factory);
        let metrics = Arc::new(EngineMetrics::new(cfg.shards));
        let shard_rows = cfg.rows / cfg.shards;
        // Per-shard seal threshold: the config knob is expressed over
        // the logical row space.
        let seal_at_rows = cfg.seal_at_rows.map(|n| (n / cfg.shards).max(1));

        let mut shards = Vec::with_capacity(cfg.shards);
        let mut name_rxs = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = mpsc::sync_channel(cfg.queue_cap);
            let (name_tx, name_rx) = mpsc::sync_channel(1);
            let plan = ShardPlan { shard, shards: cfg.shards, rows: shard_rows, q: cfg.q };
            let scfg = ShardConfig { seal_at_rows, seal_deadline: cfg.seal_deadline };
            let worker_metrics = Arc::clone(&metrics);
            let worker_factory = Arc::clone(&factory);
            let worker = std::thread::Builder::new()
                .name(format!("fast-shard-{shard}"))
                .spawn(move || {
                    worker_loop(plan, scfg, rx, worker_metrics, worker_factory, name_tx)
                })
                .expect("spawning engine shard worker");
            shards.push(ShardHandle { tx, worker: Some(worker) });
            name_rxs.push(name_rx);
        }

        let mut engine = UpdateEngine {
            shards,
            shard_bits: cfg.shard_bits(),
            metrics,
            backend_name: std::sync::OnceLock::new(),
            cfg,
        };

        // Collect every shard's construction outcome before going live.
        for name_rx in name_rxs {
            let outcome = match name_rx.recv_timeout(Duration::from_secs(120)) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    Err(anyhow!("engine shard failed to start within 120 s"))
                }
                Err(RecvTimeoutError::Disconnected) => Err(anyhow!(
                    "engine shard worker panicked during backend construction"
                )),
            };
            match outcome {
                Ok(name) => {
                    let _ = engine.backend_name.set(name);
                }
                Err(e) => {
                    // Tear the other shards down before reporting.
                    let _ = engine.shutdown_inner();
                    return Err(e);
                }
            }
        }
        Ok(engine)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Route a logical row to (shard, local row).
    #[inline]
    fn route(&self, row: usize) -> Result<(usize, usize)> {
        ensure!(
            row < self.cfg.rows,
            "row {row} out of range (rows = {})",
            self.cfg.rows
        );
        Ok((row & (self.cfg.shards - 1), row >> self.shard_bits))
    }

    /// Raise the queue gauge BEFORE sending, so the worker's decrement
    /// (which may race ahead of us) can never underflow the counter.
    /// Returns the raised depth; record it as a high-water mark only
    /// once the send is admitted (rejected requests must not inflate
    /// the mark past `queue_cap`).
    #[inline]
    fn gauge_add(&self, shard: usize, n: u64) -> u64 {
        self.metrics.shards[shard]
            .queue_depth
            .fetch_add(n, Ordering::Relaxed)
            + n
    }

    #[inline]
    fn note_admitted(&self, shard: usize, n: u64, depth: u64) {
        let sc = &self.metrics.shards[shard];
        sc.queue_high_water.fetch_max(depth, Ordering::Relaxed);
        Counters::inc(&sc.requests, n);
    }

    /// Roll the gauge back after a failed send.
    #[inline]
    fn gauge_sub(&self, shard: usize, n: u64) {
        self.metrics.shards[shard]
            .queue_depth
            .fetch_sub(n, Ordering::Relaxed);
    }

    /// Non-blocking submit. `Err` = queue full (backpressure), row out
    /// of range, or engine shut down; the request was NOT accepted.
    pub fn submit(&self, req: UpdateRequest) -> Result<()> {
        let (shard, local) = self.route(req.row)?;
        Counters::inc(&self.metrics.counters.requests_submitted, 1);
        let mut req = req;
        req.row = local;
        let depth = self.gauge_add(shard, 1);
        match self.shards[shard].tx.try_send(Command::Submit(req)) {
            Ok(()) => {
                self.note_admitted(shard, 1, depth);
                Ok(())
            }
            Err(TrySendError::Full(_)) => {
                self.gauge_sub(shard, 1);
                Counters::inc(&self.metrics.counters.requests_rejected, 1);
                Err(anyhow!("queue full: request rejected (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => {
                self.gauge_sub(shard, 1);
                Err(anyhow!("engine is shut down"))
            }
        }
    }

    /// Blocking submit: waits for queue space (no rejection).
    pub fn submit_blocking(&self, req: UpdateRequest) -> Result<()> {
        let (shard, local) = self.route(req.row)?;
        Counters::inc(&self.metrics.counters.requests_submitted, 1);
        let mut req = req;
        req.row = local;
        let depth = self.gauge_add(shard, 1);
        if self.shards[shard].tx.send(Command::Submit(req)).is_err() {
            self.gauge_sub(shard, 1);
            return Err(anyhow!("engine is shut down"));
        }
        self.note_admitted(shard, 1, depth);
        Ok(())
    }

    /// Bulk blocking submit: requests are partitioned by shard and sent
    /// as one chunk per shard — the fast path for high-rate producers.
    ///
    /// Failure contract: if a shard has died (backend fault) while
    /// others are alive, chunks sent to healthy shards BEFORE the dead
    /// one are already admitted when this returns `Err`. Do NOT retry
    /// the same vector — that would double-apply the admitted updates;
    /// treat the engine as failed and drain via [`Self::shutdown`].
    pub fn submit_many(&self, reqs: Vec<UpdateRequest>) -> Result<()> {
        if reqs.is_empty() {
            return Ok(());
        }
        let total = reqs.len() as u64;
        let mut buckets: Vec<Vec<UpdateRequest>> = Vec::new();
        buckets.resize_with(self.cfg.shards, Vec::new);
        for mut req in reqs {
            let (shard, local) = self.route(req.row)?;
            req.row = local;
            buckets[shard].push(req);
        }
        Counters::inc(&self.metrics.counters.requests_submitted, total);
        for (shard, bucket) in buckets.into_iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let n = bucket.len() as u64;
            let depth = self.gauge_add(shard, n);
            if self.shards[shard].tx.send(Command::SubmitMany(bucket)).is_err() {
                self.gauge_sub(shard, n);
                return Err(anyhow!(
                    "engine shard {shard} is down (earlier chunks of this bulk \
                     submit may already be admitted — do not retry the batch)"
                ));
            }
            self.note_admitted(shard, n, depth);
        }
        Ok(())
    }

    /// Read a row with read-your-writes consistency (flushes the
    /// owning shard first; other shards keep batching).
    pub fn read(&self, row: usize) -> Result<u32> {
        let (shard, local) = self.route(row)?;
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard]
            .tx
            .send(Command::Read(local, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Direct row write (conventional port; flushes the owning shard's
    /// pending batch first).
    pub fn write(&self, row: usize, value: u32) -> Result<()> {
        let (shard, local) = self.route(row)?;
        let (tx, rx) = mpsc::sync_channel(1);
        self.shards[shard]
            .tx
            .send(Command::Write(local, value, tx))
            .map_err(|_| anyhow!("engine is shut down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Force a flush on every shard and wait for all of them.
    pub fn flush(&self) -> Result<()> {
        let mut waits = Vec::with_capacity(self.shards.len());
        for h in &self.shards {
            let (tx, rx) = mpsc::sync_channel(1);
            h.tx
                .send(Command::Flush(tx))
                .map_err(|_| anyhow!("engine is shut down"))?;
            waits.push(rx);
        }
        for rx in waits {
            rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?;
        }
        Ok(())
    }

    /// Consistent snapshot of all rows (flushes every shard first).
    /// "Consistent" = contains every request admitted before the call;
    /// it does not serialize against concurrent producers.
    pub fn snapshot(&self) -> Result<Vec<u32>> {
        let mut waits = Vec::with_capacity(self.shards.len());
        for h in &self.shards {
            let (tx, rx) = mpsc::sync_channel(1);
            h.tx
                .send(Command::Snapshot(tx))
                .map_err(|_| anyhow!("engine is shut down"))?;
            waits.push(rx);
        }
        let mut out = vec![0u32; self.cfg.rows];
        for (shard, rx) in waits.into_iter().enumerate() {
            let snap = rx
                .recv()
                .map_err(|_| anyhow!("engine dropped the reply"))??;
            for (local, v) in snap.into_iter().enumerate() {
                out[(local << self.shard_bits) | shard] = v;
            }
        }
        Ok(out)
    }

    pub fn stats(&self) -> EngineStats {
        let c = self.metrics.counters.snapshot();
        let shards: Vec<ShardSnapshot> =
            self.metrics.shards.iter().map(ShardCounters::snapshot).collect();
        EngineStats {
            submitted: c.requests_submitted,
            completed: c.requests_completed,
            rejected: c.requests_rejected,
            batches: c.batches_flushed,
            rows_updated: c.rows_updated,
            rows_per_batch: c.rows_per_batch(),
            modeled_ns: self.metrics.modeled_ns(),
            modeled_energy_pj: self.metrics.energy.total_pj(),
            apply_wall: self.metrics.apply_wall.summary(),
            backend: self.backend_name.get().copied().unwrap_or("unknown"),
            queue_depth: shards.iter().map(|s| s.queue_depth).sum(),
            shards,
        }
    }

    /// Graceful shutdown: flush every shard, stop the workers, join.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        let mut first_err = None;
        for h in &self.shards {
            let _ = h.tx.send(Command::Shutdown);
        }
        for h in &mut self.shards {
            if let Some(worker) = h.worker.take() {
                match worker.join() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => first_err = first_err.or(Some(e)),
                    Err(_) => {
                        first_err =
                            first_err.or(Some(anyhow!("engine shard worker panicked")))
                    }
                }
            }
        }
        // All workers are joined and `&mut self` excludes concurrent
        // producers, so any depth left over from the worker-death race
        // (a send landing between a dead worker's drain and its
        // receiver drop) is now provably stale — zero the gauges.
        for sc in &self.metrics.shards {
            sc.queue_depth.store(0, Ordering::Relaxed);
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for UpdateEngine {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

/// Per-shard slice of the engine config.
#[derive(Debug, Clone, Copy)]
struct ShardConfig {
    /// Shard-local size seal (already divided by the shard count).
    seal_at_rows: Option<usize>,
    seal_deadline: Duration,
}

fn worker_loop(
    plan: ShardPlan,
    cfg: ShardConfig,
    rx: Receiver<Command>,
    metrics: Arc<EngineMetrics>,
    factory: Arc<BackendFactory>,
    name_tx: SyncSender<Result<&'static str>>,
) -> Result<()> {
    // `&dyn Fn` is callable; `Arc<dyn Fn>` is not (no Fn impl on Arc).
    let factory = factory.as_ref();
    let mut backend = match factory(&plan) {
        Ok(b) => {
            let _ = name_tx.send(Ok(b.name()));
            b
        }
        Err(e) => {
            let _ = name_tx.send(Err(anyhow!("backend construction failed: {e:#}")));
            return Ok(());
        }
    };
    let mut batcher = Batcher::new(plan.rows, plan.q, cfg.seal_at_rows);
    let mut deadline: Option<Instant> = None;
    let shard_counters = &metrics.shards[plan.shard];

    let apply_sealed = |batch: super::batcher::Batch,
                        reason: SealReason,
                        backend: &mut Box<dyn Backend>|
     -> Result<()> {
        let applied = metrics
            .apply_wall
            .time(|| backend.apply(batch.kind, &batch.operands))?;
        Counters::inc(&metrics.counters.batches_flushed, 1);
        Counters::inc(&metrics.counters.rows_updated, batch.rows_touched as u64);
        Counters::inc(&metrics.counters.requests_completed, batch.requests as u64);
        Counters::inc(
            &metrics.counters.requests_coalesced,
            (batch.requests - batch.rows_touched) as u64,
        );
        Counters::inc(&metrics.counters.shift_cycles, applied.cycles);
        metrics.energy.add_fj(applied.cost.energy_fj);
        metrics.add_modeled_ns(applied.cost.latency_ns);
        shard_counters.note_sealed(reason, batch.rows_touched as u64, batch.requests as u64);
        Ok(())
    };
    let flush = |batcher: &mut Batcher,
                 reason: SealReason,
                 backend: &mut Box<dyn Backend>|
     -> Result<()> {
        if let Some(batch) = batcher.force_flush() {
            apply_sealed(batch, reason, backend)?;
        }
        Ok(())
    };

    // The command loop runs inside a closure so that every exit path
    // (clean shutdown, backend fault) falls through to the queue-gauge
    // drain below.
    let result = (|| -> Result<()> {
    ensure!(
        backend.rows() == plan.rows,
        "backend rows {} != shard rows {} (shard {} of {})",
        backend.rows(),
        plan.rows,
        plan.shard,
        plan.shards
    );
    loop {
        let cmd = match deadline {
            Some(d) => {
                let now = Instant::now();
                if now >= d {
                    flush(&mut batcher, SealReason::Deadline, &mut backend)?;
                    deadline = None;
                    continue;
                }
                match rx.recv_timeout(d - now) {
                    Ok(c) => c,
                    Err(RecvTimeoutError::Timeout) => {
                        flush(&mut batcher, SealReason::Deadline, &mut backend)?;
                        deadline = None;
                        continue;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(c) => c,
                Err(_) => break,
            },
        };

        match cmd {
            Command::Submit(req) => {
                shard_counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
                if batcher.pending_rows() == 0 {
                    deadline = Some(Instant::now() + cfg.seal_deadline);
                }
                if let Some((batch, reason)) = batcher.push(req) {
                    apply_sealed(batch, reason, &mut backend)?;
                    deadline = if batcher.pending_rows() > 0 {
                        Some(Instant::now() + cfg.seal_deadline)
                    } else {
                        None
                    };
                }
            }
            Command::SubmitMany(reqs) => {
                shard_counters
                    .queue_depth
                    .fetch_sub(reqs.len() as u64, Ordering::Relaxed);
                for req in reqs {
                    if let Some((batch, reason)) = batcher.push(req) {
                        apply_sealed(batch, reason, &mut backend)?;
                        deadline = None; // re-anchored below if still pending
                    }
                }
                // Anchor the deadline at the first pending request; do
                // not extend it on later arrivals (bounded staleness).
                if batcher.pending_rows() > 0 {
                    if deadline.is_none() {
                        deadline = Some(Instant::now() + cfg.seal_deadline);
                    }
                } else {
                    deadline = None;
                }
            }
            Command::Read(row, reply) => {
                flush(&mut batcher, SealReason::Forced, &mut backend)?;
                deadline = None;
                let _ = reply.send(backend.read_row(row));
            }
            Command::Write(row, value, reply) => {
                flush(&mut batcher, SealReason::Forced, &mut backend)?;
                deadline = None;
                let _ = reply.send(backend.write_row(row, value));
            }
            Command::Flush(reply) => {
                flush(&mut batcher, SealReason::Forced, &mut backend)?;
                deadline = None;
                let _ = reply.send(());
            }
            Command::Snapshot(reply) => {
                flush(&mut batcher, SealReason::Forced, &mut backend)?;
                deadline = None;
                let _ = reply.send(backend.snapshot());
            }
            Command::Shutdown => {
                flush(&mut batcher, SealReason::Forced, &mut backend)?;
                break;
            }
        }
    }
    Ok(())
    })();

    // Narrow the depth-gauge error window when the worker dies early
    // (backend fault, rows mismatch): decrement for every queued
    // submit this worker will never process. Producers whose send
    // fails after the receiver drops roll their own increment back; a
    // send that lands between this drain and the receiver drop leaks
    // transiently and is zeroed by `shutdown_inner` after joins.
    while let Ok(cmd) = rx.try_recv() {
        match cmd {
            Command::Submit(_) => {
                shard_counters.queue_depth.fetch_sub(1, Ordering::Relaxed);
            }
            Command::SubmitMany(reqs) => {
                shard_counters
                    .queue_depth
                    .fetch_sub(reqs.len() as u64, Ordering::Relaxed);
            }
            _ => {}
        }
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::FastBackend;
    use crate::util::bits;
    use crate::util::rng::Rng;

    fn engine(rows: usize, q: usize) -> UpdateEngine {
        sharded_engine(rows, q, 1)
    }

    fn sharded_engine(rows: usize, q: usize, shards: usize) -> UpdateEngine {
        let cfg = EngineConfig::sharded(rows, q, shards);
        UpdateEngine::start(cfg, move |plan: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
        })
        .unwrap()
    }

    #[test]
    fn submit_read_roundtrip() {
        let e = engine(128, 16);
        e.submit_blocking(UpdateRequest::add(5, 100)).unwrap();
        e.submit_blocking(UpdateRequest::add(5, 23)).unwrap();
        e.submit_blocking(UpdateRequest::sub(5, 3)).unwrap();
        assert_eq!(e.read(5).unwrap(), 120);
        let stats = e.stats();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.completed, 3);
        assert!(stats.batches >= 1);
        e.shutdown().unwrap();
    }

    #[test]
    fn random_stream_matches_host_semantics() {
        let rows = 128;
        let q = 16;
        let e = engine(rows, q);
        let mut rng = Rng::new(77);
        let mut expect = vec![0u32; rows];
        for _ in 0..2000 {
            let row = rng.below(rows as u64) as usize;
            let v = rng.below(1 << q) as u32;
            if rng.chance(0.3) {
                e.submit_blocking(UpdateRequest::sub(row, v)).unwrap();
                expect[row] = bits::sub_mod(expect[row], v, q);
            } else {
                e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
                expect[row] = bits::add_mod(expect[row], v, q);
            }
        }
        assert_eq!(e.snapshot().unwrap(), expect);
        let stats = e.stats();
        assert_eq!(stats.completed, 2000);
        assert!(stats.rows_per_batch > 1.0, "coalescing should batch rows");
        e.shutdown().unwrap();
    }

    #[test]
    fn sharded_stream_matches_host_semantics() {
        for shards in [2usize, 4, 8] {
            let rows = 256;
            let q = 16;
            let e = sharded_engine(rows, q, shards);
            let mut rng = Rng::new(1000 + shards as u64);
            let mut expect = vec![0u32; rows];
            for _ in 0..4000 {
                let row = rng.below(rows as u64) as usize;
                let v = rng.below(1 << q) as u32;
                if rng.chance(0.3) {
                    e.submit_blocking(UpdateRequest::sub(row, v)).unwrap();
                    expect[row] = bits::sub_mod(expect[row], v, q);
                } else {
                    e.submit_blocking(UpdateRequest::add(row, v)).unwrap();
                    expect[row] = bits::add_mod(expect[row], v, q);
                }
            }
            assert_eq!(e.snapshot().unwrap(), expect, "shards = {shards}");
            let stats = e.stats();
            assert_eq!(stats.completed, 4000);
            assert_eq!(stats.shards.len(), shards);
            let per_shard_batches: u64 = stats.shards.iter().map(|s| s.batches_sealed).sum();
            assert_eq!(per_shard_batches, stats.batches);
            e.shutdown().unwrap();
        }
    }

    #[test]
    fn sharded_reads_and_writes_route_correctly() {
        let e = sharded_engine(256, 16, 4);
        for row in [0usize, 1, 2, 3, 4, 127, 128, 255] {
            e.write(row, (row as u32) + 7).unwrap();
        }
        for row in [0usize, 1, 2, 3, 4, 127, 128, 255] {
            assert_eq!(e.read(row).unwrap(), (row as u32) + 7, "row {row}");
        }
        e.shutdown().unwrap();
    }

    #[test]
    fn invalid_shard_configs_are_rejected() {
        let factory =
            |plan: &ShardPlan| -> Result<Box<dyn crate::coordinator::Backend>> {
                Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
            };
        // Not a power of two.
        assert!(UpdateEngine::start(EngineConfig::sharded(128, 16, 3), factory).is_err());
        // Does not divide the row space.
        assert!(UpdateEngine::start(EngineConfig::sharded(100, 16, 8), factory).is_err());
        // Zero shards.
        assert!(UpdateEngine::start(EngineConfig::sharded(128, 16, 0), factory).is_err());
    }

    #[test]
    fn submit_many_matches_individual_submits() {
        let rows = 128;
        let q = 16;
        let bulk = engine(rows, q);
        let single = engine(rows, q);
        let mut rng = Rng::new(9);
        let reqs: Vec<UpdateRequest> = (0..3000)
            .map(|_| {
                let row = rng.below(rows as u64) as usize;
                let v = rng.below(1 << q) as u32;
                if rng.chance(0.3) {
                    UpdateRequest::sub(row, v)
                } else {
                    UpdateRequest::add(row, v)
                }
            })
            .collect();
        for chunk in reqs.chunks(256) {
            bulk.submit_many(chunk.to_vec()).unwrap();
        }
        for r in &reqs {
            single.submit_blocking(*r).unwrap();
        }
        assert_eq!(bulk.snapshot().unwrap(), single.snapshot().unwrap());
        assert_eq!(bulk.stats().completed, 3000);
        bulk.shutdown().unwrap();
        single.shutdown().unwrap();
    }

    #[test]
    fn sharded_submit_many_partitions_by_shard() {
        let rows = 256;
        let q = 16;
        let sharded = sharded_engine(rows, q, 4);
        let single = engine(rows, q);
        let mut rng = Rng::new(21);
        let reqs: Vec<UpdateRequest> = (0..5000)
            .map(|_| UpdateRequest::add(rng.below(rows as u64) as usize, rng.below(1 << q) as u32))
            .collect();
        for chunk in reqs.chunks(512) {
            sharded.submit_many(chunk.to_vec()).unwrap();
            single.submit_many(chunk.to_vec()).unwrap();
        }
        assert_eq!(sharded.snapshot().unwrap(), single.snapshot().unwrap());
        sharded.shutdown().unwrap();
        single.shutdown().unwrap();
    }

    #[test]
    fn deadline_flushes_without_reads() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_deadline = Duration::from_millis(5);
        cfg.seal_at_rows = None; // only the deadline can flush
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        e.submit_blocking(UpdateRequest::add(0, 1)).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let s = e.stats();
        assert_eq!(s.batches, 1, "deadline flush did not fire");
        assert_eq!(s.shards[0].sealed_deadline, 1, "seal reason must be Deadline");
        e.shutdown().unwrap();
    }

    #[test]
    fn write_is_consistent_with_pending_updates() {
        let e = engine(128, 16);
        e.submit_blocking(UpdateRequest::add(7, 5)).unwrap();
        e.write(7, 1000).unwrap(); // flushes the +5 first, then overwrites
        e.submit_blocking(UpdateRequest::add(7, 1)).unwrap();
        assert_eq!(e.read(7).unwrap(), 1001);
        e.shutdown().unwrap();
    }

    #[test]
    fn stats_report_energy_and_modeled_time() {
        let e = engine(128, 16);
        for r in 0..128 {
            e.submit_blocking(UpdateRequest::add(r, 1)).unwrap();
        }
        e.flush().unwrap();
        let s = e.stats();
        assert!(s.modeled_energy_pj > 0.0);
        assert!(s.modeled_ns > 0.0);
        assert_eq!(s.backend, "fast-behavioural");
        e.shutdown().unwrap();
    }

    #[test]
    fn queue_depth_gauge_drains_to_zero() {
        let e = sharded_engine(256, 16, 2);
        for r in 0..256 {
            e.submit_blocking(UpdateRequest::add(r, 1)).unwrap();
        }
        e.flush().unwrap();
        let s = e.stats();
        assert_eq!(s.queue_depth, 0, "queue must drain after flush");
        assert!(s.shards.iter().any(|sc| sc.queue_high_water > 0));
        e.shutdown().unwrap();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600); // never by deadline
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        e.submit_blocking(UpdateRequest::add(0, 42)).unwrap();
        // give the worker a moment to drain the queue
        std::thread::sleep(Duration::from_millis(20));
        e.shutdown().unwrap();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let mut cfg = EngineConfig::new(128, 16);
        cfg.queue_cap = 2;
        cfg.seal_at_rows = None;
        cfg.seal_deadline = Duration::from_secs(3600);
        // A slow backend would be needed to reliably fill the queue; we
        // simulate by pausing the worker with a flood from this thread.
        let e = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        let mut rejected = 0;
        for i in 0..10_000 {
            if e.submit(UpdateRequest::add((i % 128) as usize, 1)).is_err() {
                rejected += 1;
            }
        }
        // With a 2-deep queue and a busy worker some rejections are
        // overwhelmingly likely, but not guaranteed — accept either,
        // the accounting must be consistent.
        let s = e.stats();
        assert_eq!(s.rejected, rejected);
        assert_eq!(s.submitted, 10_000);
        e.shutdown().unwrap();
    }

    #[test]
    fn out_of_range_submit_is_a_clean_error() {
        let e = sharded_engine(256, 16, 4);
        // Row 300 is out of range but would alias into shard space if
        // unvalidated — must be rejected at admission instead.
        assert!(e.submit(UpdateRequest::add(300, 1)).is_err());
        assert!(e.submit_blocking(UpdateRequest::add(300, 1)).is_err());
        assert!(e.submit_many(vec![UpdateRequest::add(300, 1)]).is_err());
        // Engine still healthy.
        e.submit_blocking(UpdateRequest::add(255, 2)).unwrap();
        assert_eq!(e.read(255).unwrap(), 2);
        e.shutdown().unwrap();
    }
}
