//! Request types and completion tickets for the concurrent update
//! engine.
//!
//! The paper motivates FAST with streams of small row updates (database
//! delta updates, graph feature updates). A request is one q-bit update
//! to one logical row; the coordinator's job is to pack many of them
//! into fully-concurrent FAST batch ops.
//!
//! ## Completion tickets
//!
//! The engine is a request/response pipeline, not fire-and-forget: a
//! ticketed submit hands back a [`Ticket`] that resolves to a
//! [`Commit`] once the backend has applied the batch carrying the
//! request. Coalescing merges waiter lists — every ticket attached to
//! a batch (whatever row it landed on, coalesced or not) resolves with
//! that batch's commit metadata. The two halves:
//!
//! - [`Ticket`] — held by the submitter; [`Ticket::wait`] blocks until
//!   the commit (or errors if the engine dropped the batch).
//! - [`TicketNotifier`] — threaded through the batcher into the sealed
//!   batch; the shard worker resolves it after the backend apply.
//!   Dropping an unresolved notifier (worker death, rejected command)
//!   wakes the waiter with an error — a ticket can never hang.
//!
//! ## Batch-wake via a shared epoch hub
//!
//! Tickets used to own a private `Mutex+Condvar` pair each, so a seal
//! resolving N waiters paid N lock/notify cycles. Now a ticket is a
//! lock-free `(state: AtomicU8, commit: UnsafeCell<Commit>)` cell
//! whose *wake medium* is a shared [`WaitHub`] — the same per-shard
//! hub that publishes the commit epoch (`commit_seq` watermark). The
//! worker resolves all of a seal's waiters with plain atomic stores
//! ([`TicketNotifier::resolve_quiet`]) and then issues **one**
//! `publish + notify_all` on the hub, waking sequence waiters and
//! ticket waiters together. Hot-path waits don't touch the hub mutex
//! at all: `wait`/`wait_timeout` first poll the ticket's atomic state
//! and only park on the hub condvar when the commit hasn't landed. A
//! standalone [`ticket`] pair (no engine involved) carries its own
//! private hub, so the public API is unchanged.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail};

use super::batcher::SealReason;
use crate::fastmem::AluOp;
use crate::util::bits;
use crate::Result;

/// The update operation carried by a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UpdateOp {
    /// row += operand (mod 2^q)
    Add,
    /// row -= operand (mod 2^q)
    Sub,
    And,
    Or,
    Xor,
}

impl UpdateOp {
    /// The ALU configuration implementing this op. `Sub` is executed as
    /// `Add` of the negated operand so Add/Sub share batches.
    pub fn alu_op(self) -> AluOp {
        match self {
            UpdateOp::Add | UpdateOp::Sub => AluOp::Add,
            UpdateOp::And => AluOp::And,
            UpdateOp::Or => AluOp::Or,
            UpdateOp::Xor => AluOp::Xor,
        }
    }

    /// Batch *kind*: requests of the same kind can share one FAST batch.
    pub fn kind(self) -> BatchKind {
        match self {
            UpdateOp::Add | UpdateOp::Sub => BatchKind::Add,
            UpdateOp::And => BatchKind::And,
            UpdateOp::Or => BatchKind::Or,
            UpdateOp::Xor => BatchKind::Xor,
        }
    }

    /// Normalize the operand for batching: Sub becomes Add of the
    /// two's complement.
    pub fn normalized_operand(self, operand: u32, q: usize) -> u32 {
        match self {
            UpdateOp::Sub => bits::sub_mod(0, operand, q),
            _ => operand & bits::mask(q),
        }
    }

    /// Stable wire spelling (used by the trace format and reports).
    pub fn name(self) -> &'static str {
        match self {
            UpdateOp::Add => "add",
            UpdateOp::Sub => "sub",
            UpdateOp::And => "and",
            UpdateOp::Or => "or",
            UpdateOp::Xor => "xor",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn parse(s: &str) -> Option<UpdateOp> {
        match s {
            "add" => Some(UpdateOp::Add),
            "sub" => Some(UpdateOp::Sub),
            "and" => Some(UpdateOp::And),
            "or" => Some(UpdateOp::Or),
            "xor" => Some(UpdateOp::Xor),
            _ => None,
        }
    }
}

/// Kind of a coalesced batch (one kind per FAST batch op).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchKind {
    Add,
    And,
    Or,
    Xor,
}

impl BatchKind {
    pub fn alu_op(self) -> AluOp {
        match self {
            BatchKind::Add => AluOp::Add,
            BatchKind::And => AluOp::And,
            BatchKind::Or => AluOp::Or,
            BatchKind::Xor => AluOp::Xor,
        }
    }

    /// Identity operand: a row carrying the identity is unaffected by
    /// the batch (used to fill untouched rows of a dense batch).
    pub fn identity(self, q: usize) -> u32 {
        match self {
            BatchKind::Add | BatchKind::Or | BatchKind::Xor => 0,
            BatchKind::And => bits::mask(q),
        }
    }

    /// Coalesce two operands targeting the same row within one batch.
    pub fn coalesce(self, a: u32, b: u32, q: usize) -> u32 {
        match self {
            BatchKind::Add => bits::add_mod(a, b, q),
            BatchKind::And => a & b,
            BatchKind::Or => (a | b) & bits::mask(q),
            BatchKind::Xor => (a ^ b) & bits::mask(q),
        }
    }
}

/// One row-update request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpdateRequest {
    /// Logical row across all banks.
    pub row: usize,
    pub op: UpdateOp,
    pub operand: u32,
}

impl UpdateRequest {
    pub fn add(row: usize, operand: u32) -> Self {
        UpdateRequest { row, op: UpdateOp::Add, operand }
    }

    pub fn sub(row: usize, operand: u32) -> Self {
        UpdateRequest { row, op: UpdateOp::Sub, operand }
    }
}

/// What a sealed batch committed as — the payload a [`Ticket`]
/// resolves to. One `Commit` is shared by every request folded into
/// the batch (coalescing merges waiter lists, so commit metadata is
/// per batch, not per request).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Commit {
    /// Shard that sealed and applied the batch.
    pub shard: usize,
    /// Per-shard commit sequence number, assigned at seal time.
    /// Starts at 1 and increases by 1 per sealed batch; tickets for
    /// one shard therefore resolve in nondecreasing `commit_seq`
    /// order (per-shard FIFO).
    pub commit_seq: u64,
    /// Why the batch sealed (size / kind change / deadline / forced).
    pub seal_reason: SealReason,
    /// Distinct rows the batch's requests touched.
    pub rows: usize,
    /// Requests folded into the batch (>= `rows` when coalescing hit).
    pub requests: usize,
    /// Rows that carried a non-identity operand, as measured by the
    /// backend during the apply (bank clock gating sees these).
    pub rows_active: usize,
    /// Modeled macro latency of the batch apply (ns).
    pub modeled_ns: f64,
    /// Shift cycles of the slowest active bank.
    pub cycles: u64,
    /// Banks that actually executed (the rest were clock-gated).
    pub banks_active: usize,
}

/// Outcome of a [`WaitHub::wait_seq_until`] sequence wait.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SeqWait {
    /// The watermark reached the requested sequence; carries the
    /// watermark observed.
    Reached(u64),
    /// The deadline elapsed first.
    TimedOut,
    /// The hub closed (worker exited) below the requested sequence;
    /// carries the final watermark.
    Closed(u64),
}

/// Per-shard commit-epoch hub: one `(AtomicU64, Condvar)` shared by
/// every waiter attached to the shard — commit-sequence waiters
/// (`wait_seq`, drains, read-your-writes) and ticket waiters alike.
/// The shard worker publishes each seal's `commit_seq` here with a
/// single `notify_all`, amortizing the wake across the whole waiter
/// batch.
///
/// Ordering guarantee: the worker stores every ticket state
/// (`Release`) *before* `publish`, and `publish` bumps the epoch and
/// brackets `notify_all` with the hub mutex. A waiter that re-checks
/// its predicate under the hub mutex therefore either sees the new
/// state or is registered on the condvar before the notify — a wake
/// can never be lost between the poll and the park.
#[derive(Debug)]
pub(crate) struct WaitHub {
    /// Highest published commit sequence (the shard's commit epoch).
    committed: AtomicU64,
    /// Set when the shard worker exits; waiters below the final
    /// watermark must error instead of waiting forever.
    closed: AtomicBool,
    m: Mutex<()>,
    cv: Condvar,
}

impl WaitHub {
    pub(crate) fn new() -> Self {
        WaitHub {
            committed: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            m: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Highest commit sequence published so far.
    pub(crate) fn committed(&self) -> u64 {
        self.committed.load(Ordering::Acquire)
    }

    pub(crate) fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Publish a new watermark and wake every waiter once. Sequences
    /// only move forward (`fetch_max`), so late publishes can't
    /// regress the epoch.
    pub(crate) fn publish(&self, seq: u64) {
        self.committed.fetch_max(seq, Ordering::AcqRel);
        self.wake_all();
    }

    /// Mark the hub closed (worker exit) and release every waiter.
    pub(crate) fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.wake_all();
    }

    /// Wake all parked waiters without changing any state — used by
    /// ticket resolution/drop so state stores published before this
    /// call become visible to woken waiters.
    pub(crate) fn wake_all(&self) {
        // The empty lock/unlock bracket orders this notify against a
        // waiter that checked its predicate but hasn't parked yet.
        drop(self.m.lock().expect("wait hub mutex poisoned"));
        self.cv.notify_all();
    }

    /// Block until the watermark reaches `seq`, the deadline passes,
    /// or the hub closes.
    pub(crate) fn wait_seq_until(&self, seq: u64, deadline: Option<Instant>) -> SeqWait {
        loop {
            let c = self.committed();
            if c >= seq {
                return SeqWait::Reached(c);
            }
            if self.is_closed() {
                return SeqWait::Closed(c);
            }
            let guard = self.m.lock().expect("wait hub mutex poisoned");
            // Re-check under the hub mutex (see the ordering note on
            // the type).
            let c = self.committed();
            if c >= seq {
                return SeqWait::Reached(c);
            }
            if self.is_closed() {
                return SeqWait::Closed(c);
            }
            match deadline {
                None => drop(self.cv.wait(guard).expect("wait hub mutex poisoned")),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return SeqWait::TimedOut;
                    }
                    drop(self.cv.wait_timeout(guard, d - now).expect("wait hub mutex poisoned"));
                }
            }
        }
    }
}

const TICKET_PENDING: u8 = 0;
const TICKET_DONE: u8 = 1;
/// The notifier was dropped without resolving: the batch (or the
/// command carrying the request) died before the backend applied.
const TICKET_DROPPED: u8 = 2;

#[derive(Debug)]
struct TicketShared {
    /// TICKET_PENDING → TICKET_DONE | TICKET_DROPPED, written once by
    /// the single notifier owner with Release; readers load Acquire
    /// and only touch `commit` after observing TICKET_DONE.
    state: AtomicU8,
    commit: UnsafeCell<MaybeUninit<Commit>>,
    hub: Arc<WaitHub>,
}

// The commit cell is written exactly once (by the notifier, before
// its Release store of TICKET_DONE) and read only after an Acquire
// load observes TICKET_DONE — classic one-shot publication.
unsafe impl Send for TicketShared {}
unsafe impl Sync for TicketShared {}

impl TicketShared {
    fn read_commit(&self) -> Commit {
        unsafe { (*self.commit.get()).assume_init() }
    }
}

/// Waiter half of a completion ticket (see the module docs).
#[derive(Debug)]
pub struct Ticket {
    shared: Arc<TicketShared>,
}

impl Ticket {
    /// Block until the request's batch commits. Errors if the engine
    /// dropped the batch before applying it (shutdown race, backend
    /// fault) — never hangs, because dropping the notifier resolves
    /// the ticket too.
    pub fn wait(&self) -> Result<Commit> {
        // An unbounded wait_until only returns on resolution.
        Ok(self.wait_until(None)?.expect("unbounded wait resolves"))
    }

    /// [`Self::wait`] with a bounded wait: `Ok(Some(commit))` once the
    /// batch commits, `Ok(None)` if `timeout` elapses first, `Err` if
    /// the engine dropped the batch. Lets callers interleave the wait
    /// with cancellation checks.
    pub fn wait_timeout(&self, timeout: std::time::Duration) -> Result<Option<Commit>> {
        self.wait_until(Some(Instant::now() + timeout))
    }

    /// Shared wait loop: `deadline = None` blocks until resolution.
    /// Polls the ticket's atomic state first; parks on the shared hub
    /// condvar only while still pending.
    fn wait_until(&self, deadline: Option<Instant>) -> Result<Option<Commit>> {
        loop {
            match self.shared.state.load(Ordering::Acquire) {
                TICKET_DONE => return Ok(Some(self.shared.read_commit())),
                TICKET_DROPPED => {
                    bail!("ticket dropped: the engine never committed the request's batch")
                }
                _ => {}
            }
            let hub = &self.shared.hub;
            let guard = hub.m.lock().map_err(|_| anyhow!("ticket state poisoned"))?;
            // Re-check under the hub mutex: a resolver that stored
            // state before our lock is seen here; one that stores
            // after will take the mutex before notifying.
            match self.shared.state.load(Ordering::Acquire) {
                TICKET_DONE => return Ok(Some(self.shared.read_commit())),
                TICKET_DROPPED => {
                    bail!("ticket dropped: the engine never committed the request's batch")
                }
                _ => {}
            }
            match deadline {
                None => {
                    drop(hub.cv.wait(guard).map_err(|_| anyhow!("ticket state poisoned"))?);
                }
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Ok(None);
                    }
                    drop(
                        hub.cv
                            .wait_timeout(guard, d - now)
                            .map_err(|_| anyhow!("ticket state poisoned"))?,
                    );
                }
            }
        }
    }

    /// Non-blocking probe: `Some(commit)` once resolved, `None` while
    /// the batch is still open or in flight.
    pub fn try_get(&self) -> Option<Commit> {
        match self.shared.state.load(Ordering::Acquire) {
            TICKET_DONE => Some(self.shared.read_commit()),
            _ => None,
        }
    }

    /// Has the ticket reached a terminal state (committed or dropped)?
    pub fn is_resolved(&self) -> bool {
        self.shared.state.load(Ordering::Acquire) != TICKET_PENDING
    }
}

/// Resolver half of a completion ticket. Created by [`ticket`], rides
/// the open batch through the batcher, resolved exactly once by the
/// shard worker after the backend applies the sealed batch.
#[derive(Debug)]
pub struct TicketNotifier {
    shared: Arc<TicketShared>,
    submitted_at: Instant,
    resolved: bool,
}

impl TicketNotifier {
    /// When the ticketed request was submitted (for submit→resolve
    /// wall-clock latency accounting).
    pub fn submitted_at(&self) -> Instant {
        self.submitted_at
    }

    /// Resolve the ticket with its batch's commit metadata. Consumes
    /// the notifier, so a ticket resolves exactly once.
    pub fn resolve(mut self, commit: Commit) {
        self.resolve_quiet(commit);
        self.shared.hub.wake_all();
    }

    /// Store the commit without waking anybody — the shard worker's
    /// batch-wake path: resolve every waiter of a seal quietly, then
    /// wake the shared hub once via [`WaitHub::publish`].
    pub(crate) fn resolve_quiet(&mut self, commit: Commit) {
        if self.resolved {
            return;
        }
        unsafe { (*self.shared.commit.get()).write(commit) };
        self.shared.state.store(TICKET_DONE, Ordering::Release);
        self.resolved = true;
    }
}

impl Drop for TicketNotifier {
    fn drop(&mut self) {
        if self.resolved {
            return;
        }
        self.shared.state.store(TICKET_DROPPED, Ordering::Release);
        self.shared.hub.wake_all();
    }
}

/// Create a connected (waiter, resolver) ticket pair with a private
/// wake hub. The submit timestamp is taken now.
pub fn ticket() -> (Ticket, TicketNotifier) {
    ticket_on(Arc::new(WaitHub::new()))
}

/// [`ticket`] attached to an existing hub — the engine passes each
/// shard's hub so one seal's `publish` wakes the whole waiter batch.
pub(crate) fn ticket_on(hub: Arc<WaitHub>) -> (Ticket, TicketNotifier) {
    let shared = Arc::new(TicketShared {
        state: AtomicU8::new(TICKET_PENDING),
        commit: UnsafeCell::new(MaybeUninit::uninit()),
        hub,
    });
    (
        Ticket { shared: Arc::clone(&shared) },
        TicketNotifier { shared, submitted_at: Instant::now(), resolved: false },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_normalizes_to_add_complement() {
        let op = UpdateOp::Sub;
        assert_eq!(op.normalized_operand(1, 16), 0xFFFF);
        assert_eq!(op.normalized_operand(0, 16), 0);
        assert_eq!(op.kind(), BatchKind::Add);
    }

    #[test]
    fn op_names_round_trip() {
        for op in [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor] {
            assert_eq!(UpdateOp::parse(op.name()), Some(op));
        }
        assert_eq!(UpdateOp::parse("nand"), None);
        assert_eq!(UpdateOp::parse(""), None);
    }

    #[test]
    fn identities_are_neutral() {
        for kind in [BatchKind::Add, BatchKind::And, BatchKind::Or, BatchKind::Xor] {
            let id = kind.identity(8);
            for v in [0u32, 1, 0x7F, 0xFF] {
                let out = match kind {
                    BatchKind::Add => bits::add_mod(v, id, 8),
                    BatchKind::And => v & id,
                    BatchKind::Or => (v | id) & 0xFF,
                    BatchKind::Xor => (v ^ id) & 0xFF,
                };
                assert_eq!(out, v, "{kind:?}");
            }
        }
    }

    fn demo_commit(seq: u64) -> Commit {
        Commit {
            shard: 0,
            commit_seq: seq,
            seal_reason: SealReason::Forced,
            rows: 1,
            requests: 1,
            rows_active: 1,
            modeled_ns: 20.0,
            cycles: 16,
            banks_active: 1,
        }
    }

    #[test]
    fn ticket_resolves_with_commit() {
        let (t, n) = ticket();
        assert!(!t.is_resolved());
        assert!(t.try_get().is_none());
        n.resolve(demo_commit(7));
        assert!(t.is_resolved());
        assert_eq!(t.try_get().unwrap().commit_seq, 7);
        assert_eq!(t.wait().unwrap().commit_seq, 7);
        // wait() is idempotent — the commit stays readable.
        assert_eq!(t.wait().unwrap().commit_seq, 7);
    }

    #[test]
    fn dropped_notifier_errors_instead_of_hanging() {
        let (t, n) = ticket();
        drop(n);
        assert!(t.is_resolved());
        assert!(t.try_get().is_none());
        assert!(t.wait().is_err());
    }

    #[test]
    fn ticket_wait_timeout_bounds_the_wait() {
        let (t, n) = ticket();
        let dt = std::time::Duration::from_millis(5);
        assert_eq!(t.wait_timeout(dt).unwrap(), None, "pending times out");
        n.resolve(demo_commit(9));
        assert_eq!(t.wait_timeout(dt).unwrap().unwrap().commit_seq, 9);
        let (t2, n2) = ticket();
        drop(n2);
        assert!(t2.wait_timeout(dt).is_err(), "dropped errors immediately");
    }

    #[test]
    fn ticket_wait_blocks_until_cross_thread_resolve() {
        let (t, n) = ticket();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            n.resolve(demo_commit(3));
        });
        assert_eq!(t.wait().unwrap().commit_seq, 3);
        h.join().unwrap();
    }

    #[test]
    fn batch_wake_resolves_many_tickets_with_one_publish() {
        // The worker path: resolve_quiet every waiter, then one
        // hub.publish — every waiter must observe its commit.
        let hub = Arc::new(WaitHub::new());
        let pairs: Vec<_> = (0..16).map(|_| ticket_on(Arc::clone(&hub))).collect();
        let mut notifiers = Vec::new();
        let mut tickets = Vec::new();
        for (t, n) in pairs {
            tickets.push(t);
            notifiers.push(n);
        }
        let waiters: Vec<_> = tickets
            .into_iter()
            .map(|t| std::thread::spawn(move || t.wait().map(|c| c.commit_seq)))
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(5));
        for mut n in notifiers {
            n.resolve_quiet(demo_commit(11));
        }
        hub.publish(11);
        for w in waiters {
            assert_eq!(w.join().unwrap().unwrap(), 11);
        }
        assert_eq!(hub.committed(), 11);
    }

    #[test]
    fn wait_hub_sequence_waits() {
        let hub = Arc::new(WaitHub::new());
        assert_eq!(
            hub.wait_seq_until(1, Some(Instant::now() + std::time::Duration::from_millis(5))),
            SeqWait::TimedOut
        );
        hub.publish(3);
        assert_eq!(hub.wait_seq_until(2, None), SeqWait::Reached(3));
        // Publishes never regress the epoch.
        hub.publish(1);
        assert_eq!(hub.committed(), 3);
        let h2 = Arc::clone(&hub);
        let waiter = std::thread::spawn(move || h2.wait_seq_until(10, None));
        std::thread::sleep(std::time::Duration::from_millis(5));
        hub.close();
        assert_eq!(waiter.join().unwrap(), SeqWait::Closed(3));
    }

    #[test]
    fn coalescing_matches_sequential_application() {
        // Applying two coalesced operands in one batch == applying them
        // in two batches, for every kind.
        let q = 8;
        for kind in [BatchKind::Add, BatchKind::And, BatchKind::Or, BatchKind::Xor] {
            for (v, a, b) in [(0x5Au32, 0x0Fu32, 0x33u32), (0xFF, 0x01, 0x80)] {
                let apply = |x: u32, o: u32| match kind {
                    BatchKind::Add => bits::add_mod(x, o, q),
                    BatchKind::And => x & o,
                    BatchKind::Or => (x | o) & 0xFF,
                    BatchKind::Xor => (x ^ o) & 0xFF,
                };
                let sequential = apply(apply(v, a), b);
                let coalesced = apply(v, kind.coalesce(a, b, q));
                assert_eq!(sequential, coalesced, "{kind:?} v={v:#x}");
            }
        }
    }
}
