//! Request types for the concurrent update engine.
//!
//! The paper motivates FAST with streams of small row updates (database
//! delta updates, graph feature updates). A request is one q-bit update
//! to one logical row; the coordinator's job is to pack many of them
//! into fully-concurrent FAST batch ops.

use crate::fastmem::AluOp;
use crate::util::bits;

/// The update operation carried by a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UpdateOp {
    /// row += operand (mod 2^q)
    Add,
    /// row -= operand (mod 2^q)
    Sub,
    And,
    Or,
    Xor,
}

impl UpdateOp {
    /// The ALU configuration implementing this op. `Sub` is executed as
    /// `Add` of the negated operand so Add/Sub share batches.
    pub fn alu_op(self) -> AluOp {
        match self {
            UpdateOp::Add | UpdateOp::Sub => AluOp::Add,
            UpdateOp::And => AluOp::And,
            UpdateOp::Or => AluOp::Or,
            UpdateOp::Xor => AluOp::Xor,
        }
    }

    /// Batch *kind*: requests of the same kind can share one FAST batch.
    pub fn kind(self) -> BatchKind {
        match self {
            UpdateOp::Add | UpdateOp::Sub => BatchKind::Add,
            UpdateOp::And => BatchKind::And,
            UpdateOp::Or => BatchKind::Or,
            UpdateOp::Xor => BatchKind::Xor,
        }
    }

    /// Normalize the operand for batching: Sub becomes Add of the
    /// two's complement.
    pub fn normalized_operand(self, operand: u32, q: usize) -> u32 {
        match self {
            UpdateOp::Sub => bits::sub_mod(0, operand, q),
            _ => operand & bits::mask(q),
        }
    }

    /// Stable wire spelling (used by the trace format and reports).
    pub fn name(self) -> &'static str {
        match self {
            UpdateOp::Add => "add",
            UpdateOp::Sub => "sub",
            UpdateOp::And => "and",
            UpdateOp::Or => "or",
            UpdateOp::Xor => "xor",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn parse(s: &str) -> Option<UpdateOp> {
        match s {
            "add" => Some(UpdateOp::Add),
            "sub" => Some(UpdateOp::Sub),
            "and" => Some(UpdateOp::And),
            "or" => Some(UpdateOp::Or),
            "xor" => Some(UpdateOp::Xor),
            _ => None,
        }
    }
}

/// Kind of a coalesced batch (one kind per FAST batch op).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchKind {
    Add,
    And,
    Or,
    Xor,
}

impl BatchKind {
    pub fn alu_op(self) -> AluOp {
        match self {
            BatchKind::Add => AluOp::Add,
            BatchKind::And => AluOp::And,
            BatchKind::Or => AluOp::Or,
            BatchKind::Xor => AluOp::Xor,
        }
    }

    /// Identity operand: a row carrying the identity is unaffected by
    /// the batch (used to fill untouched rows of a dense batch).
    pub fn identity(self, q: usize) -> u32 {
        match self {
            BatchKind::Add | BatchKind::Or | BatchKind::Xor => 0,
            BatchKind::And => bits::mask(q),
        }
    }

    /// Coalesce two operands targeting the same row within one batch.
    pub fn coalesce(self, a: u32, b: u32, q: usize) -> u32 {
        match self {
            BatchKind::Add => bits::add_mod(a, b, q),
            BatchKind::And => a & b,
            BatchKind::Or => (a | b) & bits::mask(q),
            BatchKind::Xor => (a ^ b) & bits::mask(q),
        }
    }
}

/// One row-update request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UpdateRequest {
    /// Logical row across all banks.
    pub row: usize,
    pub op: UpdateOp,
    pub operand: u32,
}

impl UpdateRequest {
    pub fn add(row: usize, operand: u32) -> Self {
        UpdateRequest { row, op: UpdateOp::Add, operand }
    }

    pub fn sub(row: usize, operand: u32) -> Self {
        UpdateRequest { row, op: UpdateOp::Sub, operand }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sub_normalizes_to_add_complement() {
        let op = UpdateOp::Sub;
        assert_eq!(op.normalized_operand(1, 16), 0xFFFF);
        assert_eq!(op.normalized_operand(0, 16), 0);
        assert_eq!(op.kind(), BatchKind::Add);
    }

    #[test]
    fn op_names_round_trip() {
        for op in [UpdateOp::Add, UpdateOp::Sub, UpdateOp::And, UpdateOp::Or, UpdateOp::Xor] {
            assert_eq!(UpdateOp::parse(op.name()), Some(op));
        }
        assert_eq!(UpdateOp::parse("nand"), None);
        assert_eq!(UpdateOp::parse(""), None);
    }

    #[test]
    fn identities_are_neutral() {
        for kind in [BatchKind::Add, BatchKind::And, BatchKind::Or, BatchKind::Xor] {
            let id = kind.identity(8);
            for v in [0u32, 1, 0x7F, 0xFF] {
                let out = match kind {
                    BatchKind::Add => bits::add_mod(v, id, 8),
                    BatchKind::And => v & id,
                    BatchKind::Or => (v | id) & 0xFF,
                    BatchKind::Xor => (v ^ id) & 0xFF,
                };
                assert_eq!(out, v, "{kind:?}");
            }
        }
    }

    #[test]
    fn coalescing_matches_sequential_application() {
        // Applying two coalesced operands in one batch == applying them
        // in two batches, for every kind.
        let q = 8;
        for kind in [BatchKind::Add, BatchKind::And, BatchKind::Or, BatchKind::Xor] {
            for (v, a, b) in [(0x5Au32, 0x0Fu32, 0x33u32), (0xFF, 0x01, 0x80)] {
                let apply = |x: u32, o: u32| match kind {
                    BatchKind::Add => bits::add_mod(x, o, q),
                    BatchKind::And => x & o,
                    BatchKind::Or => (x | o) & 0xFF,
                    BatchKind::Xor => (x ^ o) & 0xFF,
                };
                let sequential = apply(apply(v, a), b);
                let coalesced = apply(v, kind.coalesce(a, b, q));
                assert_eq!(sequential, coalesced, "{kind:?} v={v:#x}");
            }
        }
    }
}
