//! The coalescing batcher: turns a stream of single-row updates into
//! dense, fully-concurrent FAST batch operations.
//!
//! Invariants (property-tested in rust/tests/):
//!   1. *Semantics*: applying the flushed batches in order is
//!      equivalent to applying every accepted request in arrival order.
//!   2. *One kind per batch*: a FAST batch op configures all row ALUs
//!      identically, so a batch holds only one [`BatchKind`]; a request
//!      of a different kind seals the current batch.
//!   3. *Coalescing*: same-kind updates to the same row merge
//!      algebraically (Add sums, And intersects, ...), so a batch never
//!      carries more than one operand per row.

use super::request::{BatchKind, TicketNotifier, UpdateRequest};
use crate::util::bits;

/// A sealed, dense batch ready for execution.
#[derive(Debug)]
pub struct Batch {
    pub kind: BatchKind,
    /// Dense operand vector, identity-filled for untouched rows.
    pub operands: Vec<u32>,
    /// Number of distinct rows carrying a non-identity update.
    pub rows_touched: usize,
    /// Number of requests folded into this batch.
    pub requests: usize,
    /// Completion tickets riding this batch: one notifier per ticketed
    /// request absorbed (coalescing merges waiter lists — same-row
    /// merges keep every waiter). The engine resolves them after the
    /// backend applies; if the batch is dropped instead, the notifier
    /// `Drop` impl wakes the waiters with an error.
    pub waiters: Vec<TicketNotifier>,
}

/// Why a batch was sealed (group-commit accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SealReason {
    /// A request of a different kind arrived.
    KindChange,
    /// The touched-row threshold was reached (size seal).
    Full,
    /// The group-commit deadline expired (bounded staleness).
    Deadline,
    /// The caller forced a flush (read, write, explicit flush,
    /// shutdown).
    Forced,
}

/// The batcher over a logical row space of `rows` rows.
#[derive(Debug)]
pub struct Batcher {
    rows: usize,
    q: usize,
    /// Seal when this many distinct rows are touched (None = only on
    /// kind change / force).
    seal_at_rows: Option<usize>,
    current: Option<OpenBatch>,
}

#[derive(Debug)]
struct OpenBatch {
    kind: BatchKind,
    operands: Vec<u32>,
    touched: Vec<bool>,
    rows_touched: usize,
    requests: usize,
    waiters: Vec<TicketNotifier>,
}

impl OpenBatch {
    fn new(kind: BatchKind, rows: usize, q: usize) -> Self {
        OpenBatch {
            kind,
            operands: vec![kind.identity(q); rows],
            touched: vec![false; rows],
            rows_touched: 0,
            requests: 0,
            waiters: Vec::new(),
        }
    }

    fn seal(self) -> Batch {
        Batch {
            kind: self.kind,
            operands: self.operands,
            rows_touched: self.rows_touched,
            requests: self.requests,
            waiters: self.waiters,
        }
    }
}

impl Batcher {
    pub fn new(rows: usize, q: usize, seal_at_rows: Option<usize>) -> Self {
        assert!(rows >= 1);
        let _ = bits::mask(q);
        if let Some(n) = seal_at_rows {
            assert!(n >= 1, "seal threshold must be positive");
        }
        Batcher { rows, q, seal_at_rows, current: None }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn q(&self) -> usize {
        self.q
    }

    /// Rows touched in the open batch (0 if none).
    pub fn pending_rows(&self) -> usize {
        self.current.as_ref().map_or(0, |b| b.rows_touched)
    }

    /// Requests folded into the open batch.
    pub fn pending_requests(&self) -> usize {
        self.current.as_ref().map_or(0, |b| b.requests)
    }

    /// Is `row` touched by the open batch? A read of an untouched row
    /// already sees the backend's current value, so the engine only
    /// seals for read-your-writes when this is true.
    pub fn touches(&self, row: usize) -> bool {
        assert!(row < self.rows, "row {row} out of range {}", self.rows);
        self.current.as_ref().is_some_and(|b| b.touched[row])
    }

    /// Feed one request. Returns a sealed batch if this request forced
    /// a seal (the request itself is always absorbed — into the next
    /// batch when the current one seals).
    pub fn push(&mut self, req: UpdateRequest) -> Option<(Batch, SealReason)> {
        self.push_ticketed(req, None)
    }

    /// [`Self::push`] with an optional completion ticket. The waiter
    /// attaches to whichever batch absorbs the request: the open batch
    /// (possibly freshly opened after a kind-change seal), or — when
    /// this very request trips the size seal — the sealed batch
    /// returned from this call.
    pub fn push_ticketed(
        &mut self,
        req: UpdateRequest,
        waiter: Option<TicketNotifier>,
    ) -> Option<(Batch, SealReason)> {
        assert!(req.row < self.rows, "row {} out of range {}", req.row, self.rows);
        let kind = req.op.kind();
        let operand = req.op.normalized_operand(req.operand, self.q);

        let mut sealed = None;
        if let Some(cur) = &self.current {
            if cur.kind != kind {
                sealed = Some((self.force_flush().expect("open batch"), SealReason::KindChange));
            }
        }
        let cur = self
            .current
            .get_or_insert_with(|| OpenBatch::new(kind, self.rows, self.q));
        debug_assert_eq!(cur.kind, kind);
        cur.operands[req.row] = kind.coalesce(cur.operands[req.row], operand, self.q);
        if !cur.touched[req.row] {
            cur.touched[req.row] = true;
            cur.rows_touched += 1;
        }
        cur.requests += 1;
        if let Some(w) = waiter {
            cur.waiters.push(w);
        }

        if sealed.is_none() {
            if let Some(limit) = self.seal_at_rows {
                if cur.rows_touched >= limit {
                    return self
                        .force_flush()
                        .map(|b| (b, SealReason::Full));
                }
            }
        }
        sealed
    }

    /// Seal and return the open batch, if any.
    pub fn force_flush(&mut self) -> Option<Batch> {
        self.current.take().map(OpenBatch::seal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::UpdateOp;

    #[test]
    fn coalesces_same_row_adds() {
        let mut b = Batcher::new(8, 16, None);
        assert!(b.push(UpdateRequest::add(3, 10)).is_none());
        assert!(b.push(UpdateRequest::add(3, 5)).is_none());
        assert!(b.push(UpdateRequest::add(1, 7)).is_none());
        let batch = b.force_flush().unwrap();
        assert_eq!(batch.kind, BatchKind::Add);
        assert_eq!(batch.operands[3], 15);
        assert_eq!(batch.operands[1], 7);
        assert_eq!(batch.operands[0], 0);
        assert_eq!(batch.rows_touched, 2);
        assert_eq!(batch.requests, 3);
    }

    #[test]
    fn sub_folds_into_add_batch() {
        let mut b = Batcher::new(4, 16, None);
        b.push(UpdateRequest::add(0, 10));
        b.push(UpdateRequest::sub(0, 3));
        let batch = b.force_flush().unwrap();
        assert_eq!(batch.operands[0], 7);
        assert_eq!(batch.requests, 2);
    }

    #[test]
    fn kind_change_seals() {
        let mut b = Batcher::new(4, 8, None);
        b.push(UpdateRequest::add(0, 1));
        let (sealed, reason) = b
            .push(UpdateRequest { row: 1, op: UpdateOp::Xor, operand: 0xFF })
            .expect("kind change must seal");
        assert_eq!(reason, SealReason::KindChange);
        assert_eq!(sealed.kind, BatchKind::Add);
        assert_eq!(sealed.rows_touched, 1);
        // The xor landed in the new open batch.
        assert_eq!(b.pending_rows(), 1);
        let next = b.force_flush().unwrap();
        assert_eq!(next.kind, BatchKind::Xor);
        assert_eq!(next.operands[1], 0xFF);
    }

    #[test]
    fn seals_when_full() {
        let mut b = Batcher::new(8, 8, Some(2));
        assert!(b.push(UpdateRequest::add(0, 1)).is_none());
        let (sealed, reason) = b.push(UpdateRequest::add(5, 2)).expect("full");
        assert_eq!(reason, SealReason::Full);
        assert_eq!(sealed.rows_touched, 2);
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn same_row_repeat_does_not_advance_fullness() {
        let mut b = Batcher::new(8, 8, Some(2));
        assert!(b.push(UpdateRequest::add(0, 1)).is_none());
        assert!(b.push(UpdateRequest::add(0, 1)).is_none());
        assert!(b.push(UpdateRequest::add(0, 1)).is_none());
        assert_eq!(b.pending_rows(), 1);
        assert_eq!(b.pending_requests(), 3);
    }

    #[test]
    fn and_batch_identity_fill() {
        let mut b = Batcher::new(4, 8, None);
        b.push(UpdateRequest { row: 2, op: UpdateOp::And, operand: 0x0F });
        let batch = b.force_flush().unwrap();
        assert_eq!(batch.kind, BatchKind::And);
        assert_eq!(batch.operands, vec![0xFF, 0xFF, 0x0F, 0xFF]);
    }

    #[test]
    fn empty_flush_is_none() {
        let mut b = Batcher::new(4, 8, None);
        assert!(b.force_flush().is_none());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range_row() {
        let mut b = Batcher::new(4, 8, None);
        b.push(UpdateRequest::add(4, 1));
    }

    #[test]
    fn touches_tracks_only_open_batch_rows() {
        let mut b = Batcher::new(8, 8, None);
        assert!(!b.touches(3));
        b.push(UpdateRequest::add(3, 1));
        assert!(b.touches(3));
        assert!(!b.touches(4));
        b.force_flush();
        assert!(!b.touches(3), "sealed batches no longer pend");
    }

    #[test]
    fn coalescing_merges_waiter_lists() {
        use crate::coordinator::request::ticket;
        let mut b = Batcher::new(8, 8, None);
        let (t1, n1) = ticket();
        let (t2, n2) = ticket();
        // Two ticketed requests coalesce onto the same row: the sealed
        // batch must carry BOTH waiters.
        b.push_ticketed(UpdateRequest::add(2, 1), Some(n1));
        b.push_ticketed(UpdateRequest::add(2, 4), Some(n2));
        let batch = b.force_flush().unwrap();
        assert_eq!(batch.rows_touched, 1);
        assert_eq!(batch.waiters.len(), 2);
        // Dropping the un-resolved batch must wake both waiters with
        // an error (never hang).
        drop(batch);
        assert!(t1.wait().is_err());
        assert!(t2.wait().is_err());
    }

    #[test]
    fn size_seal_carries_the_tripping_requests_waiter() {
        use crate::coordinator::request::ticket;
        let mut b = Batcher::new(8, 8, Some(2));
        let (_t1, n1) = ticket();
        let (_t2, n2) = ticket();
        assert!(b.push_ticketed(UpdateRequest::add(0, 1), Some(n1)).is_none());
        let (sealed, reason) = b
            .push_ticketed(UpdateRequest::add(5, 2), Some(n2))
            .expect("size seal");
        assert_eq!(reason, SealReason::Full);
        assert_eq!(sealed.waiters.len(), 2, "the sealing request rides the sealed batch");
        assert_eq!(b.pending_rows(), 0);
    }

    #[test]
    fn kind_change_seal_splits_waiters_between_batches() {
        use crate::coordinator::request::ticket;
        let mut b = Batcher::new(8, 8, None);
        let (_ta, na) = ticket();
        let (_tb, nb) = ticket();
        b.push_ticketed(UpdateRequest::add(0, 1), Some(na));
        let (sealed, _) = b
            .push_ticketed(
                UpdateRequest { row: 1, op: UpdateOp::Xor, operand: 0x1 },
                Some(nb),
            )
            .expect("kind change seals");
        assert_eq!(sealed.waiters.len(), 1, "old batch keeps its own waiters");
        let next = b.force_flush().unwrap();
        assert_eq!(next.waiters.len(), 1, "new batch holds the xor's waiter");
    }
}
