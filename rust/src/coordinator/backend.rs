//! Execution backends for the update engine.
//!
//! The engine is generic over *what actually applies a batch*:
//!
//! - [`FastBackend`] — the behavioural FAST bank set (word-fast by
//!   default; phase-accurate per [`Fidelity`])
//! - [`BitPlaneBackend`] — the bit-sliced tier: one transposed
//!   [`BitPlaneArray`] spanning every bank, applying a batch to all
//!   enabled rows in O(width · rows/64) word ops with per-bank
//!   clock gating expressed as lane masks
//! - [`XlaBackend`] — the AOT-compiled Pallas/JAX artifact executed via
//!   PJRT (the functional fast-path; cross-validates the behavioural
//!   model at scale)
//! - [`DigitalBackend`] — the paper's near-memory digital baseline, for
//!   apples-to-apples workload comparisons through the same coordinator
//!
//! Backends are constructed *inside* the engine worker thread (see
//! `engine.rs`) so non-`Send` resources like PJRT executables never
//! cross threads.

use anyhow::Context;

use crate::baseline::DigitalEngine;
use crate::energy::{Cost, DigitalModel, FastModel};
use crate::fastmem::{BitPlaneArray, Fidelity};
use crate::query::{banked_cost, plane_reduce, scalar_reduce, QueryOutcome, QuerySpec};
use crate::runtime::Runtime;
use crate::Result;

use super::bank::BankSet;
use super::request::BatchKind;

/// Split a logical row count into the fewest equal banks that fit the
/// 128-row macro height (shared by every FAST-shaped backend).
fn bank_split(rows: usize) -> (usize, usize) {
    assert!(rows >= 1);
    // Starting at ceil(rows/128) guarantees rows/banks <= 128; the
    // loop terminates because banks == rows always divides.
    let mut banks = rows.div_ceil(crate::MACRO_ROWS);
    while rows % banks != 0 {
        banks += 1;
    }
    (banks, rows / banks)
}

/// Result of applying one dense batch — the per-batch apply metadata
/// the engine stamps onto completion tickets (`request::Commit`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AppliedBatch {
    pub cost: Cost,
    pub cycles: u64,
    pub banks_active: usize,
    /// Rows carrying a non-identity operand, as the backend saw them
    /// (its clock-gating scan counts these anyway).
    pub rows_active: usize,
}

/// Count of non-identity operands (shared by backends that don't scan
/// per bank).
fn count_active(operands: &[u32], ident: u32) -> usize {
    operands.iter().filter(|&&o| o != ident).count()
}

/// A batch executor over a logical row space.
pub trait Backend {
    fn name(&self) -> &'static str;
    fn rows(&self) -> usize;
    fn q(&self) -> usize;
    fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<AppliedBatch>;
    fn read_row(&mut self, row: usize) -> Result<u32>;
    fn write_row(&mut self, row: usize, value: u32) -> Result<()>;
    fn snapshot(&mut self) -> Result<Vec<u32>>;

    /// Execute one in-array reduction (see [`crate::query`] for the
    /// grammar and the rotate-read cost closed form). Read-only: the
    /// array state, its lifetime toggle counter and the conventional
    /// port counters must all be untouched afterwards — the pass's
    /// activity lives in the returned [`QueryOutcome`] only. The FAST
    /// tiers must account identically (values, report AND modeled
    /// cost, bit for bit); the digital baseline answers the same value
    /// and report with its own sweep-read cost profile.
    fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        let _ = spec;
        anyhow::bail!("backend {} does not support in-array queries", self.name())
    }

    /// Restore recovered state before serving (durability recovery
    /// preload). Default: conventional-port writes of the non-zero
    /// rows. Backends with workload-modeling counters should override
    /// with a non-counting path — recovery is not workload, and the
    /// port/energy counters must keep modeling only what clients
    /// actually issued ([`FastBackend`] pokes via the toggle-neutral
    /// `BankSet::poke_row`; the bit-plane and host-state backends have
    /// no counting write path, so the default is already neutral).
    fn preload(&mut self, state: &[u32]) -> Result<()> {
        anyhow::ensure!(
            state.len() == self.rows(),
            "preload state has {} rows, backend has {}",
            state.len(),
            self.rows()
        );
        for (row, &v) in state.iter().enumerate() {
            if v != 0 {
                self.write_row(row, v)?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Behavioural FAST backend
// ---------------------------------------------------------------------------

/// Behavioural FAST macro banks (word-fast or phase-accurate tier).
pub struct FastBackend {
    banks: BankSet,
    fidelity: Fidelity,
}

impl FastBackend {
    pub fn new(banks: usize, rows_per_bank: usize, q: usize) -> Self {
        Self::with_fidelity(banks, rows_per_bank, q, Fidelity::WordFast)
    }

    /// Bank set executing batches at the given fidelity tier. (For the
    /// bit-plane tier prefer [`BitPlaneBackend`], which transposes the
    /// *whole* bank set into one plane stack instead of per-bank.)
    pub fn with_fidelity(
        banks: usize,
        rows_per_bank: usize,
        q: usize,
        fidelity: Fidelity,
    ) -> Self {
        FastBackend {
            banks: BankSet::with_fidelity(banks, rows_per_bank, q, fidelity),
            fidelity,
        }
    }

    /// Size a bank set to an arbitrary logical row count (the shape a
    /// shard of a striped row space gets): the fewest equal banks such
    /// that no bank exceeds the 128-row macro height. Powers of two
    /// and multiples of 128 get the natural layout (e.g. 1024 → 8×128,
    /// 32 → 1×32); awkward counts split further (e.g. 1025 → 25×41)
    /// rather than ever modeling an impossible >128-row macro.
    pub fn with_rows(rows: usize, q: usize) -> Self {
        Self::with_rows_fidelity(rows, q, Fidelity::WordFast)
    }

    /// [`Self::with_rows`] at an explicit fidelity tier.
    pub fn with_rows_fidelity(rows: usize, q: usize, fidelity: Fidelity) -> Self {
        let (banks, rows_per_bank) = bank_split(rows);
        FastBackend::with_fidelity(banks, rows_per_bank, q, fidelity)
    }
}

impl Backend for FastBackend {
    fn name(&self) -> &'static str {
        match self.fidelity {
            Fidelity::PhaseAccurate => "fast-phase-accurate",
            // Historical name, kept stable for stats consumers.
            Fidelity::WordFast => "fast-behavioural",
            Fidelity::BitPlane => "fast-behavioural-bitplane",
        }
    }

    fn rows(&self) -> usize {
        self.banks.rows()
    }

    fn q(&self) -> usize {
        self.banks.q()
    }

    fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<AppliedBatch> {
        let rep = self.banks.apply(kind, operands)?;
        Ok(AppliedBatch {
            cost: rep.cost,
            cycles: rep.cycles,
            banks_active: rep.banks_active,
            rows_active: rep.rows_active,
        })
    }

    fn read_row(&mut self, row: usize) -> Result<u32> {
        self.banks.read_row(row)
    }

    fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
        self.banks.write_row(row, value)
    }

    fn snapshot(&mut self) -> Result<Vec<u32>> {
        Ok(self.banks.snapshot())
    }

    fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        // Scalar reference path: decoded words via the non-counting
        // peek (queries are in-array reads, not conventional-port
        // traffic), reduced on the host with the canonical pass
        // accounting; cost charged per active bank exactly like the
        // update path.
        let values = self.banks.peek_rows();
        let (value, report) = scalar_reduce(spec, &values, self.banks.q())?;
        let rpb = self.banks.rows() / self.banks.banks();
        let (banks_active, cost) = banked_cost(
            &FastModel::default(),
            spec,
            self.banks.rows(),
            rpb,
            self.banks.q(),
        );
        Ok(QueryOutcome { value, report, banks_active, cost })
    }

    fn preload(&mut self, state: &[u32]) -> Result<()> {
        anyhow::ensure!(
            state.len() == self.banks.rows(),
            "preload state has {} rows, backend has {}",
            state.len(),
            self.banks.rows()
        );
        // Non-counting restore: recovery is not workload, so the port
        // and toggle counters must not see these writes.
        for (row, &v) in state.iter().enumerate() {
            if v != 0 {
                self.banks.poke_row(row, v)?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Bit-plane (bit-sliced) backend
// ---------------------------------------------------------------------------

/// The bit-plane fidelity tier behind the coordinator API: the whole
/// logical row space lives in one transposed [`BitPlaneArray`], and a
/// dense batch commits to every enabled row in O(q · rows/64) word
/// ops. Banks whose operand slice is all-identity are clock-gated
/// exactly like [`super::bank::BankSet`] gates them — expressed here
/// as cleared bits in the enabled-row lane mask — and the modeled cost
/// is accounted identically (per active bank), so swapping tiers never
/// changes the energy numbers.
pub struct BitPlaneBackend {
    plane: BitPlaneArray,
    banks: usize,
    rows_per_bank: usize,
    q: usize,
    model: FastModel,
    /// Scratch lane mask rebuilt per batch (no per-call allocation).
    enable: Vec<u64>,
}

impl BitPlaneBackend {
    pub fn new(banks: usize, rows_per_bank: usize, q: usize) -> Self {
        assert!(banks >= 1 && rows_per_bank >= 1);
        let rows = banks * rows_per_bank;
        BitPlaneBackend {
            plane: BitPlaneArray::new(rows, &[q]),
            banks,
            rows_per_bank,
            q,
            model: FastModel::default(),
            enable: vec![0u64; rows.div_ceil(64)],
        }
    }

    /// Same bank-splitting policy as [`FastBackend::with_rows`].
    pub fn with_rows(rows: usize, q: usize) -> Self {
        let (banks, rows_per_bank) = bank_split(rows);
        BitPlaneBackend::new(banks, rows_per_bank, q)
    }
}

impl Backend for BitPlaneBackend {
    fn name(&self) -> &'static str {
        "fast-bitplane"
    }

    fn rows(&self) -> usize {
        self.plane.rows()
    }

    fn q(&self) -> usize {
        self.q
    }

    fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<AppliedBatch> {
        anyhow::ensure!(
            operands.len() == self.plane.rows(),
            "operand count {} != rows {}",
            operands.len(),
            self.plane.rows()
        );
        let ident = kind.identity(self.q);
        let rpb = self.rows_per_bank;
        self.enable.fill(0);
        let mut banks_active = 0usize;
        let mut rows_active = 0usize;
        for b in 0..self.banks {
            let slice = &operands[b * rpb..(b + 1) * rpb];
            let active = count_active(slice, ident);
            if active == 0 {
                continue; // clock-gated bank
            }
            banks_active += 1;
            rows_active += active;
            for r in b * rpb..(b + 1) * rpb {
                self.enable[r / 64] |= 1u64 << (r % 64);
            }
        }
        if banks_active == 0 {
            return Ok(AppliedBatch::default());
        }
        let rep = self
            .plane
            .apply_masked(kind.alu_op(), operands, &self.enable);
        // Cost accounting mirrors BankSet::apply term by term (summed
        // per active bank, latency = max) so the downstream energy
        // numbers are bit-identical across tiers.
        let mut cost = Cost::default();
        for _ in 0..banks_active {
            let c = self.model.batch_op(rpb, self.q);
            cost.energy_fj += c.energy_fj;
            cost.latency_ns = cost.latency_ns.max(c.latency_ns);
        }
        Ok(AppliedBatch { cost, cycles: rep.cycles, banks_active, rows_active })
    }

    fn read_row(&mut self, row: usize) -> Result<u32> {
        anyhow::ensure!(row < self.plane.rows(), "row {row} out of range");
        Ok(self.plane.read_word(row, 0))
    }

    fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
        anyhow::ensure!(row < self.plane.rows(), "row {row} out of range");
        self.plane.write_word(row, 0, value & crate::util::bits::mask(self.q));
        Ok(())
    }

    fn snapshot(&mut self) -> Result<Vec<u32>> {
        // Block transpose-out: O(q · rows/64) instead of per-row
        // single-bit probing.
        let mut out = vec![0u32; self.plane.rows()];
        self.plane.export_to(|r, _s, w| out[r] = w);
        Ok(out)
    }

    fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        // Plane-wise path: the reduction evaluates straight from the
        // bit planes; cost accounting mirrors the FAST scalar tiers
        // term by term so the numbers are bit-identical across tiers.
        let (value, report) = plane_reduce(&self.plane, 0, spec)?;
        let (banks_active, cost) = banked_cost(
            &self.model,
            spec,
            self.plane.rows(),
            self.rows_per_bank,
            self.q,
        );
        Ok(QueryOutcome { value, report, banks_active, cost })
    }
}

// ---------------------------------------------------------------------------
// XLA (PJRT) backend
// ---------------------------------------------------------------------------

/// Functional FAST model: state lives host-side, batches execute through
/// the AOT-compiled Pallas kernel artifacts. Costs are modeled with the
/// same calibrated FastModel (the artifact computes *results*, the
/// energy model computes *costs*).
pub struct XlaBackend {
    runtime: Runtime,
    state: Vec<u32>,
    q: usize,
    rows: usize,
    model: FastModel,
    /// artifact name per batch kind, resolved at construction.
    art_add: String,
    art_and: String,
    art_or: String,
    art_xor: String,
}

impl XlaBackend {
    /// Load artifacts for a `rows`-row, q-bit logical array. `rows` must
    /// match an available artifact family (128 or 1024 for add; logic
    /// artifacts exist at 128×16).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>, rows: usize, q: usize) -> Result<Self> {
        let runtime = Runtime::load_dir(&artifact_dir)?;
        let art_add = format!("fast_add_{rows}x{q}");
        runtime
            .get(&art_add)
            .with_context(|| format!("no add artifact for {rows}x{q}"))?;
        let b = XlaBackend {
            runtime,
            state: vec![0; rows],
            q,
            rows,
            model: FastModel::default(),
            art_add,
            art_and: format!("fast_and_{rows}x{q}"),
            art_or: format!("fast_or_{rows}x{q}"),
            art_xor: format!("fast_xor_{rows}x{q}"),
        };
        Ok(b)
    }

    fn artifact_for(&self, kind: BatchKind) -> &str {
        match kind {
            BatchKind::Add => &self.art_add,
            BatchKind::And => &self.art_and,
            BatchKind::Or => &self.art_or,
            BatchKind::Xor => &self.art_xor,
        }
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "fast-xla"
    }

    fn rows(&self) -> usize {
        self.rows
    }

    fn q(&self) -> usize {
        self.q
    }

    fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<AppliedBatch> {
        anyhow::ensure!(operands.len() == self.rows, "operand count mismatch");
        let art = self.runtime.get(self.artifact_for(kind))?;
        self.state = art.exec2(&self.state, operands)?;
        Ok(AppliedBatch {
            cost: self.model.batch_op(self.rows.min(128), self.q),
            cycles: self.q as u64,
            banks_active: self.rows.div_ceil(128),
            rows_active: count_active(operands, kind.identity(self.q)),
        })
    }

    fn read_row(&mut self, row: usize) -> Result<u32> {
        anyhow::ensure!(row < self.rows, "row {row} out of range");
        Ok(self.state[row])
    }

    fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
        anyhow::ensure!(row < self.rows, "row {row} out of range");
        self.state[row] = value & crate::util::bits::mask(self.q);
        Ok(())
    }

    fn snapshot(&mut self) -> Result<Vec<u32>> {
        Ok(self.state.clone())
    }

    fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        // Host-side state, scalar reference semantics; cost modeled
        // like this backend's apply (one 128-row macro pass).
        let (value, report) = scalar_reduce(spec, &self.state, self.q)?;
        Ok(QueryOutcome {
            value,
            report,
            banks_active: self.rows.div_ceil(128),
            cost: self.model.batch_op(self.rows.min(128), self.q),
        })
    }
}

// ---------------------------------------------------------------------------
// Digital baseline backend
// ---------------------------------------------------------------------------

/// The near-memory digital baseline behind the same coordinator API.
/// (Costs come from the `DigitalEngine`'s own sweep reports.)
pub struct DigitalBackend {
    engine: DigitalEngine,
    model: DigitalModel,
}

impl DigitalBackend {
    pub fn new(rows: usize, q: usize) -> Self {
        DigitalBackend {
            engine: DigitalEngine::new(rows, q),
            model: DigitalModel::default(),
        }
    }
}

impl Backend for DigitalBackend {
    fn name(&self) -> &'static str {
        "digital-baseline"
    }

    fn rows(&self) -> usize {
        self.engine.rows()
    }

    fn q(&self) -> usize {
        self.engine.width()
    }

    fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<AppliedBatch> {
        let rep = self.engine.batch_apply(kind.alu_op(), operands);
        Ok(AppliedBatch {
            cost: rep.cost,
            cycles: rep.rows, // one pipeline slot per row
            banks_active: 1,
            rows_active: count_active(operands, kind.identity(self.q())),
        })
    }

    fn read_row(&mut self, row: usize) -> Result<u32> {
        anyhow::ensure!(row < self.engine.rows(), "row {row} out of range");
        Ok(self.engine.read_row(row))
    }

    fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
        anyhow::ensure!(row < self.engine.rows(), "row {row} out of range");
        self.engine.write_row(row, value);
        Ok(())
    }

    fn snapshot(&mut self) -> Result<Vec<u32>> {
        Ok(self.engine.snapshot())
    }

    fn query(&mut self, spec: &QuerySpec) -> Result<QueryOutcome> {
        // Same value and canonical pass report as every other backend
        // (the report describes the reduction, not the substrate), but
        // the digital cost is a serial read sweep: one 6T SRAM word
        // read per enabled row, latencies summed — no row-parallel
        // rotation to hide behind.
        let values = self.engine.snapshot();
        let q = self.engine.width();
        let (value, report) = scalar_reduce(spec, &values, q)?;
        let read = self.model.read_word_sram(self.engine.rows(), q);
        let n = report.rows_active as f64;
        Ok(QueryOutcome {
            value,
            report,
            banks_active: 1,
            cost: Cost {
                energy_fj: n * read.energy_fj,
                latency_ns: n * read.latency_ns,
            },
        })
    }

    // Note: the digital baseline has no clock gating — `batch_apply`
    // sweeps every row even for sparse batches, which is exactly the
    // cost asymmetry the paper exploits.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits;
    use crate::util::rng::Rng;

    fn exercise(backend: &mut dyn Backend) {
        let rows = backend.rows();
        let q = backend.q();
        let mut rng = Rng::new(11);
        let init: Vec<u32> = (0..rows)
            .map(|_| rng.below(bits::mask(q) as u64 + 1) as u32)
            .collect();
        for (r, &v) in init.iter().enumerate() {
            backend.write_row(r, v).unwrap();
        }
        let deltas: Vec<u32> = (0..rows)
            .map(|_| rng.below(bits::mask(q) as u64 + 1) as u32)
            .collect();
        let rep = backend.apply(BatchKind::Add, &deltas).unwrap();
        assert!(rep.cost.latency_ns > 0.0);
        let snap = backend.snapshot().unwrap();
        for r in 0..rows {
            assert_eq!(snap[r], bits::add_mod(init[r], deltas[r], q), "row {r}");
        }
    }

    #[test]
    fn fast_backend_semantics() {
        let mut b = FastBackend::new(2, 32, 16);
        exercise(&mut b);
        assert_eq!(b.name(), "fast-behavioural");
    }

    #[test]
    fn bitplane_backend_semantics() {
        let mut b = BitPlaneBackend::new(2, 32, 16);
        exercise(&mut b);
        assert_eq!(b.name(), "fast-bitplane");
    }

    #[test]
    fn phase_fidelity_backend_semantics() {
        let mut b = FastBackend::with_rows_fidelity(64, 16, Fidelity::PhaseAccurate);
        exercise(&mut b);
        assert_eq!(b.name(), "fast-phase-accurate");
    }

    #[test]
    fn bitplane_backend_matches_fast_backend_costs_and_state() {
        let mut fast = FastBackend::new(4, 32, 16);
        let mut plane = BitPlaneBackend::new(4, 32, 16);
        let mut rng = Rng::new(55);
        for round in 0..6 {
            // Rounds 0/1 dense, later rounds sparse (bank gating).
            let ops: Vec<u32> = (0..128)
                .map(|r| {
                    if round < 2 || r % 37 == 0 {
                        rng.below(1 << 16) as u32
                    } else {
                        0
                    }
                })
                .collect();
            let kind = if round % 2 == 0 { BatchKind::Add } else { BatchKind::Xor };
            let rf = fast.apply(kind, &ops).unwrap();
            let rp = plane.apply(kind, &ops).unwrap();
            assert_eq!(rf.banks_active, rp.banks_active, "round {round}");
            assert_eq!(rf.cycles, rp.cycles, "round {round}");
            assert_eq!(rf.cost, rp.cost, "costs must be bit-identical");
            assert_eq!(
                fast.snapshot().unwrap(),
                plane.snapshot().unwrap(),
                "round {round}"
            );
        }
    }

    #[test]
    fn bitplane_backend_gates_identity_banks() {
        let mut b = BitPlaneBackend::new(4, 16, 16);
        let mut ops = vec![0u32; 64];
        ops[5] = 9; // only bank 0 touched
        let rep = b.apply(BatchKind::Add, &ops).unwrap();
        assert_eq!(rep.banks_active, 1);
        assert_eq!(rep.cycles, 16);
        let one_bank = FastModel::default().batch_op(16, 16).energy_fj;
        assert!((rep.cost.energy_fj - one_bank).abs() < 1e-9);
        // All-identity batches are free.
        let rep = b.apply(BatchKind::Add, &[0u32; 64]).unwrap();
        assert_eq!(rep, AppliedBatch::default());
    }

    #[test]
    fn digital_backend_semantics() {
        let mut b = DigitalBackend::new(64, 16);
        exercise(&mut b);
    }

    #[test]
    fn query_identical_across_backends() {
        use crate::query::{seeded_mask, QuerySpec, Reduction};
        let rows = 96;
        let q = 16;
        let mut fast = FastBackend::new(3, 32, q);
        let mut plane = BitPlaneBackend::new(3, 32, q);
        let mut dig = DigitalBackend::new(rows, q);
        let mut rng = Rng::new(77);
        let state: Vec<u32> = (0..rows).map(|_| rng.below(1 << q) as u32).collect();
        for (r, &v) in state.iter().enumerate() {
            for b in [&mut fast as &mut dyn Backend, &mut plane, &mut dig] {
                b.write_row(r, v).unwrap();
            }
        }
        let specs = [
            QuerySpec::all(Reduction::Popcount),
            QuerySpec::all(Reduction::Sum),
            QuerySpec::all(Reduction::Min),
            QuerySpec::all(Reduction::Max),
            QuerySpec::all(Reduction::RangeCount { lo: 100, hi: 40000 }),
            QuerySpec::masked(Reduction::Sum, seeded_mask(5, 40, rows)),
            QuerySpec::masked(
                Reduction::Dot { vec: crate::query::broadcast_vec(9, rows, q) },
                seeded_mask(5, 60, rows),
            ),
        ];
        for spec in &specs {
            let qf = fast.query(spec).unwrap();
            let qp = plane.query(spec).unwrap();
            let qd = dig.query(spec).unwrap();
            // Values + canonical pass report identical on ALL backends.
            assert_eq!(qf.value, qp.value, "{:?}", spec.red.name());
            assert_eq!(qf.value, qd.value, "{:?}", spec.red.name());
            assert_eq!(qf.report, qp.report, "{:?}", spec.red.name());
            assert_eq!(qf.report, qd.report, "{:?}", spec.red.name());
            // Modeled cost bit-identical across the FAST tiers; the
            // digital sweep pays more latency for any real scan.
            assert_eq!(qf.banks_active, qp.banks_active);
            assert_eq!(qf.cost, qp.cost, "{:?}", spec.red.name());
            if qd.report.rows_active > 8 {
                assert!(qd.cost.latency_ns > qf.cost.latency_ns);
            }
        }
        // Queries are read-only: state survives untouched everywhere.
        assert_eq!(fast.snapshot().unwrap(), state);
        assert_eq!(plane.snapshot().unwrap(), state);
        assert_eq!(dig.snapshot().unwrap(), state);
    }

    #[test]
    fn digital_costs_more_latency_than_fast() {
        let mut f = FastBackend::new(1, 128, 16);
        let mut d = DigitalBackend::new(128, 16);
        let deltas = vec![1u32; 128];
        let cf = f.apply(BatchKind::Add, &deltas).unwrap();
        let cd = d.apply(BatchKind::Add, &deltas).unwrap();
        assert!(cd.cost.latency_ns > 20.0 * cf.cost.latency_ns);
    }
}
