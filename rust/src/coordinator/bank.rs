//! Bank management: a logical row space striped over 128-row FAST
//! macros, executed concurrently.
//!
//! The chip showcases one 128×16 macro; a deployment stacks many.
//! The bank manager slices a dense batch into per-macro sub-batches,
//! *skips banks whose slice is all-identity* (their shift clock is
//! gated — no cycles, no energy), and runs the touched banks on worker
//! threads. Latency of a multi-bank batch is the max over banks, since
//! banks are physically independent arrays.

use crate::energy::{Cost, FastModel};
use crate::fastmem::{BatchReport, FastArray, Fidelity};
use crate::Result;

use super::request::BatchKind;

/// Outcome of applying one dense batch across the bank set — the
/// per-batch apply metadata completion tickets surface (see
/// `coordinator::request::Commit`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BankApply {
    /// Banks that actually executed (non-identity slices).
    pub banks_active: usize,
    /// Rows carrying a non-identity operand (the rows the active
    /// banks' row-ALUs effectively updated; identity-filled rows ride
    /// along for free).
    pub rows_active: usize,
    /// Shift cycles of the slowest active bank.
    pub cycles: u64,
    /// Modeled cost (energy summed, latency = max over banks).
    pub cost: Cost,
}

/// A set of identical FAST macros forming one logical array.
pub struct BankSet {
    arrays: Vec<FastArray>,
    rows_per_bank: usize,
    q: usize,
    model: FastModel,
}

impl BankSet {
    /// `banks` macros of `rows_per_bank` rows × `q` columns on the
    /// word-fast tier.
    pub fn new(banks: usize, rows_per_bank: usize, q: usize) -> Self {
        Self::with_fidelity(banks, rows_per_bank, q, Fidelity::WordFast)
    }

    /// Bank set whose macros execute batches at the given fidelity
    /// tier (each bank is its own [`FastArray`], so the tier applies
    /// per bank).
    pub fn with_fidelity(
        banks: usize,
        rows_per_bank: usize,
        q: usize,
        fidelity: Fidelity,
    ) -> Self {
        assert!(banks >= 1);
        BankSet {
            arrays: (0..banks)
                .map(|_| FastArray::with_fidelity(rows_per_bank, q, fidelity))
                .collect(),
            rows_per_bank,
            q,
            model: FastModel::default(),
        }
    }

    /// Non-counting snapshot of every row (cf. [`Self::snapshot`],
    /// which models real conventional-port reads).
    pub fn peek_rows(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.rows());
        for a in &self.arrays {
            v.extend(a.peek_rows());
        }
        v
    }

    pub fn rows(&self) -> usize {
        self.arrays.len() * self.rows_per_bank
    }

    pub fn banks(&self) -> usize {
        self.arrays.len()
    }

    pub fn q(&self) -> usize {
        self.q
    }

    #[inline]
    fn locate(&self, row: usize) -> (usize, usize) {
        (row / self.rows_per_bank, row % self.rows_per_bank)
    }

    pub fn read_row(&mut self, row: usize) -> Result<u32> {
        let (b, r) = self.locate(row);
        anyhow::ensure!(b < self.arrays.len(), "row {row} out of range");
        Ok(self.arrays[b].read_word(r, 0)?)
    }

    pub fn write_row(&mut self, row: usize, value: u32) -> Result<()> {
        let (b, r) = self.locate(row);
        anyhow::ensure!(b < self.arrays.len(), "row {row} out of range");
        Ok(self.arrays[b].write_word(r, 0, value)?)
    }

    /// Non-counting row write (cf. [`FastArray::poke_word`]): restores
    /// state without touching port or toggle counters — the durability
    /// recovery preload path.
    pub fn poke_row(&mut self, row: usize, value: u32) -> Result<()> {
        let (b, r) = self.locate(row);
        anyhow::ensure!(b < self.arrays.len(), "row {row} out of range");
        Ok(self.arrays[b].poke_word(r, 0, value)?)
    }

    pub fn snapshot(&mut self) -> Vec<u32> {
        let mut v = Vec::with_capacity(self.rows());
        for a in &mut self.arrays {
            v.extend(a.snapshot());
        }
        v
    }

    pub fn load(&mut self, words: &[u32]) {
        assert_eq!(words.len(), self.rows());
        for (i, a) in self.arrays.iter_mut().enumerate() {
            a.load(&words[i * self.rows_per_bank..(i + 1) * self.rows_per_bank]);
        }
    }

    /// Apply one dense batch (one operand per logical row). Banks whose
    /// slice is entirely the identity are clock-gated. Touched banks run
    /// concurrently on scoped threads.
    pub fn apply(&mut self, kind: BatchKind, operands: &[u32]) -> Result<BankApply> {
        anyhow::ensure!(
            operands.len() == self.rows(),
            "operand count {} != rows {}",
            operands.len(),
            self.rows()
        );
        let ident = kind.identity(self.q);
        let rpb = self.rows_per_bank;
        let alu = kind.alu_op();

        // Partition: (bank index, slice) for banks with work. Touched
        // banks run on scoped threads when the host has spare cores;
        // on a single-core host thread spawn is pure overhead, so run
        // inline (the banks are still *architecturally* concurrent —
        // latency is max(), not sum()).
        let mut reports: Vec<Option<BatchReport>> = vec![None; self.arrays.len()];
        let parallel = std::thread::available_parallelism()
            .map(|n| n.get() > 1)
            .unwrap_or(false);
        let mut rows_active = 0usize;
        let mut jobs: Vec<(&mut FastArray, &mut Option<BatchReport>, &[u32])> = Vec::new();
        for (bi, (array, out)) in self
            .arrays
            .iter_mut()
            .zip(reports.iter_mut())
            .enumerate()
        {
            let slice = &operands[bi * rpb..(bi + 1) * rpb];
            let active = slice.iter().filter(|&&o| o != ident).count();
            if active == 0 {
                continue; // clock-gated bank
            }
            rows_active += active;
            jobs.push((array, out, slice));
        }
        let run = |array: &mut FastArray, slice: &[u32]| match alu {
            crate::fastmem::AluOp::Add => array.batch_add(slice),
            op => array.batch_logic(op, slice),
        };
        if parallel && jobs.len() > 1 {
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for (array, out, slice) in jobs {
                    handles.push(scope.spawn(move || {
                        *out = Some(run(array, slice));
                    }));
                }
                for h in handles {
                    h.join().expect("bank worker panicked");
                }
            });
        } else {
            for (array, out, slice) in jobs {
                *out = Some(run(array, slice));
            }
        }

        let mut out = BankApply { rows_active, ..BankApply::default() };
        for report in reports.into_iter().flatten() {
            out.banks_active += 1;
            out.cycles = out.cycles.max(report.cycles);
            let c = self.model.batch_op(rpb, self.q);
            out.cost.energy_fj += c.energy_fj;
            out.cost.latency_ns = out.cost.latency_ns.max(c.latency_ns);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits;
    use crate::util::rng::Rng;

    #[test]
    fn striping_roundtrip() {
        let mut b = BankSet::new(4, 128, 16);
        assert_eq!(b.rows(), 512);
        b.write_row(0, 1).unwrap();
        b.write_row(127, 2).unwrap();
        b.write_row(128, 3).unwrap(); // first row of bank 1
        b.write_row(511, 4).unwrap(); // last row of bank 3
        assert_eq!(b.read_row(0).unwrap(), 1);
        assert_eq!(b.read_row(127).unwrap(), 2);
        assert_eq!(b.read_row(128).unwrap(), 3);
        assert_eq!(b.read_row(511).unwrap(), 4);
    }

    #[test]
    fn apply_spans_banks_correctly() {
        let mut b = BankSet::new(2, 16, 16);
        let mut rng = Rng::new(3);
        let init: Vec<u32> = (0..32).map(|_| rng.below(1 << 16) as u32).collect();
        let deltas: Vec<u32> = (0..32).map(|_| rng.below(1 << 16) as u32).collect();
        b.load(&init);
        let rep = b.apply(BatchKind::Add, &deltas).unwrap();
        assert_eq!(rep.banks_active, 2);
        for r in 0..32 {
            assert_eq!(b.read_row(r).unwrap(), bits::add_mod(init[r], deltas[r], 16));
        }
    }

    #[test]
    fn identity_banks_are_clock_gated() {
        let mut b = BankSet::new(4, 16, 16);
        let mut deltas = vec![0u32; 64];
        deltas[5] = 9; // only bank 0 touched
        let rep = b.apply(BatchKind::Add, &deltas).unwrap();
        assert_eq!(rep.banks_active, 1);
        assert_eq!(rep.rows_active, 1, "one non-identity operand");
        assert_eq!(rep.cycles, 16);
        // Energy charged for one bank only.
        let one_bank = FastModel::default().batch_op(16, 16).energy_fj;
        assert!((rep.cost.energy_fj - one_bank).abs() < 1e-9);
    }

    #[test]
    fn all_identity_batch_is_free() {
        let mut b = BankSet::new(2, 16, 16);
        let rep = b.apply(BatchKind::Add, &vec![0; 32]).unwrap();
        assert_eq!(rep.banks_active, 0);
        assert_eq!(rep.cost.energy_fj, 0.0);
    }

    #[test]
    fn and_identity_is_mask() {
        let mut b = BankSet::new(2, 16, 8);
        b.load(&vec![0xAB; 32]);
        let mut ops = vec![0xFFu32; 32]; // AND identity
        ops[20] = 0x0F;
        let rep = b.apply(BatchKind::And, &ops).unwrap();
        assert_eq!(rep.banks_active, 1); // only bank 1 touched
        assert_eq!(b.read_row(20).unwrap(), 0xAB & 0x0F);
        assert_eq!(b.read_row(0).unwrap(), 0xAB);
    }

    #[test]
    fn multi_bank_latency_is_max_not_sum() {
        let mut b = BankSet::new(8, 128, 16);
        let deltas = vec![1u32; 1024];
        let rep = b.apply(BatchKind::Add, &deltas).unwrap();
        let single = FastModel::default().batch_op(128, 16);
        assert_eq!(rep.banks_active, 8);
        assert!((rep.cost.latency_ns - single.latency_ns).abs() < 1e-9);
        assert!((rep.cost.energy_fj - 8.0 * single.energy_fj).abs() < 1e-6);
    }
}
