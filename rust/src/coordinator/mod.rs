//! Layer-3 coordinator: the sharded concurrent update engine in front
//! of the FAST macros (the system half of the paper's contribution).
//!
//! Pipeline: requests → shard router (`row & (shards-1)`) → per-shard
//! admission (bounded queue) → per-shard [`Batcher`] (coalesce per row,
//! one kind per batch, group-commit seal policy, per-shard commit
//! sequence numbers at seal time) → [`BankSet`] / backend
//! (fully-concurrent batch execution, per-bank clock gating) →
//! completion-[`Ticket`] resolution + metrics.
//!
//! - [`request`] — update ops, batch kinds, coalescing algebra,
//!   completion tickets ([`Ticket`] / [`Commit`])
//! - [`batcher`] — the coalescing batcher, seal reasons, waiter lists
//! - [`bank`] — striping across 128-row macros, parallel execution
//! - [`backend`] — behavioural / bit-plane / XLA-PJRT / digital-baseline
//!   executors (fidelity tier selectable per shard)
//! - [`engine`] — shard workers, seal policy, backpressure, commit
//!   sequencing (`wait_seq`, `drain_shard`), in-array queries
//!   (`submit_query`, sequenced against each shard's commits), stats

pub mod backend;
pub mod bank;
pub mod batcher;
pub mod engine;
pub mod request;

pub use backend::{
    AppliedBatch, Backend, BitPlaneBackend, DigitalBackend, FastBackend, XlaBackend,
};
pub use bank::{BankApply, BankSet};
pub use batcher::{Batch, Batcher, SealReason};
pub use engine::{
    BackendFactory, CommitListener, EngineBusy, EngineConfig, EngineMetrics, EngineReadOnly,
    EngineStats, QueryResult, QueryTicket, ShardPlan, UpdateEngine,
};
pub use request::{ticket, BatchKind, Commit, Ticket, TicketNotifier, UpdateOp, UpdateRequest};
