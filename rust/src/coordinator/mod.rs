//! Layer-3 coordinator: the concurrent update engine in front of the
//! FAST macros (the system half of the paper's contribution).
//!
//! Pipeline: requests → admission (bounded queue) → [`Batcher`]
//! (coalesce per row, one kind per batch) → [`BankSet`] / backend
//! (fully-concurrent batch execution, per-bank clock gating) → metrics.
//!
//! - [`request`] — update ops, batch kinds, coalescing algebra
//! - [`batcher`] — the coalescing batcher and its seal policy
//! - [`bank`] — striping across 128-row macros, parallel execution
//! - [`backend`] — behavioural / XLA-PJRT / digital-baseline executors
//! - [`engine`] — worker thread, flush policy, backpressure, stats

pub mod backend;
pub mod bank;
pub mod batcher;
pub mod engine;
pub mod request;

pub use backend::{AppliedBatch, Backend, DigitalBackend, FastBackend, XlaBackend};
pub use bank::{BankApply, BankSet};
pub use batcher::{Batch, Batcher, SealReason};
pub use engine::{EngineConfig, EngineMetrics, EngineStats, UpdateEngine};
pub use request::{BatchKind, UpdateOp, UpdateRequest};
