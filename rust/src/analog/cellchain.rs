//! Transient simulation of a chain of shiftable cells driven by the
//! three-phase clock — regenerates the waveforms of Fig. 7 (shift) and
//! Fig. 8 (4-bit add through the row ALU).
//!
//! Each cell is the Fig. 3(a) netlist:
//!
//!   X ──invA──> Y ──sw(φ2)──> W ──invB──> Z ──sw(φ2d)──> X
//!                     ▲ feedback loop closes progressively ▲
//!   Z(left) ──TG(φ1)──> X(right)        (inter-cell transfer)
//!
//! During φ1 the loop is open at both intra switches; the remnant
//! charge on W keeps invB driving the old datum on Z (the property the
//! paper exploits), while X samples the upstream Z. φ2 then propagates
//! the new X through the loop, and φ2d closes it for static restore.
//!
//! The row ALU is injected digitally (its analog behaviour is ordinary
//! static CMOS, not the interesting dynamic part): the MSB cell's X is
//! driven through the φ1 transmission gate by the ALU output computed
//! from the LSB cell's Z.

use super::circuit::{Circuit, Element};
use super::waveform::{Waveform, WaveformSet};
use crate::fastmem::alu::{AluOp, RowAlu};
use crate::timing::{ClockConfig, ClockGen};

/// Device parameters for the transient model (65 nm-class).
#[derive(Debug, Clone, PartialEq)]
pub struct CellDeviceParams {
    pub vdd: f64,
    /// Inverter trip point (V). Monte Carlo shifts this per instance.
    pub trip: f64,
    /// Inverter drive resistance (kΩ).
    pub r_inv_kohm: f64,
    /// Transmission-gate / NMOS switch on-resistance (kΩ).
    pub r_sw_kohm: f64,
    /// Node capacitances (fF).
    pub c_x_ff: f64,
    pub c_y_ff: f64,
    pub c_w_ff: f64,
    pub c_z_ff: f64,
    /// Dynamic-node leakage (nA) on X and W.
    pub i_leak_na: f64,
}

impl Default for CellDeviceParams {
    fn default() -> Self {
        CellDeviceParams {
            vdd: 1.0,
            trip: 0.5,
            r_inv_kohm: 4.0,
            r_sw_kohm: 2.0,
            c_x_ff: 1.2,
            c_y_ff: 1.0,
            c_w_ff: 1.2,
            c_z_ff: 1.6, // Z also drives the downstream TG
            i_leak_na: 0.5,
        }
    }
}

/// Node handles for one simulated cell.
#[derive(Debug, Clone, Copy)]
pub struct CellNodes {
    pub x: usize,
    pub y: usize,
    pub w: usize,
    pub z: usize,
    sw_phi2: usize,
    sw_phi2d: usize,
    tg_phi1: usize,
}

/// A transient-simulated chain of `n` cells with an optional row ALU
/// closing the loop (LSB Z -> ALU -> MSB X).
pub struct CellChain {
    pub circuit: Circuit,
    pub cells: Vec<CellNodes>,
    /// Per-cell trip points (can be perturbed for Monte Carlo).
    trips: Vec<f64>,
    params: CellDeviceParams,
    alu: Option<RowAlu>,
    /// ALU-output driver element (drives MSB X during φ1).
    alu_driver: usize,
    clock: ClockGen,
}

impl CellChain {
    /// Build an `n`-cell chain. `trip_offsets[i]` shifts cell i's
    /// inverter trip points (mismatch); pass `&[]` for nominal.
    pub fn new(
        n: usize,
        params: CellDeviceParams,
        clock_cfg: ClockConfig,
        alu_op: Option<AluOp>,
        trip_offsets: &[f64],
    ) -> Self {
        assert!(n >= 2, "chain needs at least 2 cells");
        assert!(trip_offsets.is_empty() || trip_offsets.len() == n);
        let clock = ClockGen::new(clock_cfg).expect("valid clock config");
        let mut circuit = Circuit::new();
        let mut cells = Vec::with_capacity(n);
        let mut trips = Vec::with_capacity(n);

        for i in 0..n {
            let trip = params.trip + trip_offsets.get(i).copied().unwrap_or(0.0);
            trips.push(trip);
            let x = circuit.add_node(format!("X{i}"), params.c_x_ff, 0.0);
            let y = circuit.add_node(format!("Y{i}"), params.c_y_ff, params.vdd);
            let w = circuit.add_node(format!("W{i}"), params.c_w_ff, params.vdd);
            let z = circuit.add_node(format!("Z{i}"), params.c_z_ff, 0.0);
            circuit.add_element(Element::Inverter {
                input: x,
                out: y,
                vdd: params.vdd,
                trip,
                r_drive_kohm: params.r_inv_kohm,
            });
            circuit.add_element(Element::Inverter {
                input: w,
                out: z,
                vdd: params.vdd,
                trip,
                r_drive_kohm: params.r_inv_kohm,
            });
            let sw_phi2 = circuit.add_element(Element::Switch {
                a: y,
                b: w,
                r_on_kohm: params.r_sw_kohm,
                closed: false,
            });
            // φ2d switch: Z back to X (loop closure).
            let sw_phi2d = circuit.add_element(Element::Switch {
                a: z,
                b: x,
                r_on_kohm: params.r_sw_kohm,
                closed: false,
            });
            circuit.add_element(Element::Leak { node: x, i_na: params.i_leak_na });
            circuit.add_element(Element::Leak { node: w, i_na: params.i_leak_na });
            cells.push(CellNodes { x, y, w, z, sw_phi2, sw_phi2d, tg_phi1: usize::MAX });
        }
        // Inter-cell transmission gates: Z[i+1] -> X[i] (data moves
        // toward the ALU at index 0; MSB slot is the last cell).
        for i in 0..n {
            let upstream_z = if i + 1 < n { Some(cells[i + 1].z) } else { None };
            if let Some(zu) = upstream_z {
                let tg = circuit.add_element(Element::Switch {
                    a: zu,
                    b: cells[i].x,
                    r_on_kohm: params.r_sw_kohm,
                    closed: false,
                });
                cells[i].tg_phi1 = tg;
            }
        }
        // ALU output driver into the MSB cell's X (through the φ1 TG,
        // modelled as an activatable driver).
        let msb_x = cells[n - 1].x;
        let alu_driver = circuit.add_element(Element::Driver {
            node: msb_x,
            v: 0.0,
            r_kohm: params.r_sw_kohm,
            active: false,
        });
        CellChain {
            circuit,
            cells,
            trips,
            params,
            alu: alu_op.map(RowAlu::new),
            alu_driver,
            clock,
        }
    }

    /// Load a word into the chain (bit i -> cell i, LSB at cell 0) by
    /// forcing the static nodes.
    pub fn load_word(&mut self, word: u32) {
        for (i, cell) in self.cells.iter().enumerate() {
            let bit = (word >> i) & 1;
            let (vz, vx) = if bit == 1 {
                (self.params.vdd, self.params.vdd)
            } else {
                (0.0, 0.0)
            };
            self.circuit.nodes[cell.x].v = vx;
            self.circuit.nodes[cell.y].v = self.params.vdd - vx;
            self.circuit.nodes[cell.w].v = self.params.vdd - vz;
            self.circuit.nodes[cell.z].v = vz;
        }
    }

    /// Digital readout: bit i from cell i's Z node.
    pub fn read_word(&self) -> u32 {
        let mut w = 0;
        for (i, cell) in self.cells.iter().enumerate() {
            if self.circuit.voltage(cell.z) > self.trips[i] {
                w |= 1 << i;
            }
        }
        w
    }

    /// Run `cycles` shift cycles feeding `operand` bits LSB-first into
    /// the ALU (ignored without an ALU — pure rotation via pass-through
    /// of the LSB Z). Captures the requested node voltages.
    ///
    /// Returns the waveform set (clock phases + selected nodes).
    pub fn run_cycles(
        &mut self,
        cycles: usize,
        operand: u32,
        capture: &[(&str, usize)],
        samples_per_cycle: usize,
    ) -> WaveformSet {
        let period = self.clock.config().period_ns;
        let mut set = WaveformSet::new();
        let mut phase_traces = [
            Waveform::new("phi1"),
            Waveform::new("phi2"),
            Waveform::new("phi2d"),
        ];
        let mut node_traces: Vec<Waveform> =
            capture.iter().map(|(n, _)| Waveform::new(*n)).collect();

        // Stability: stay under 0.15 × the stiffest RC in the netlist
        // (smallest on-resistance into the smallest capacitance).
        let r_min = self.params.r_sw_kohm.min(self.params.r_inv_kohm);
        let c_min = self
            .params
            .c_x_ff
            .min(self.params.c_y_ff)
            .min(self.params.c_w_ff)
            .min(self.params.c_z_ff);
        let dt_stable = 0.15 * r_min * c_min * 1e-3;
        let dt = (period / samples_per_cycle as f64).min(dt_stable);
        let mut t = 0.0;
        for cycle in 0..cycles {
            // ALU evaluation for this cycle, from the LSB cell's datum.
            let a = if self.circuit.voltage(self.cells[0].z) > self.trips[0] {
                1u8
            } else {
                0u8
            };
            let b = ((operand >> cycle) & 1) as u8;
            let out_bit = match &mut self.alu {
                Some(alu) => alu.eval(a, b),
                None => a, // pure rotate
            };
            let v_alu = if out_bit == 1 { self.params.vdd } else { 0.0 };
            self.circuit.set_driver(self.alu_driver, Some(v_alu), false);

            let t_end = (cycle + 1) as f64 * period;
            let mut prev_phi2d = self.clock.levels(t).phi2d;
            while t < t_end - 1e-12 {
                let lv = self.clock.levels(t);
                for cell in &self.cells {
                    self.circuit.set_switch(cell.sw_phi2, lv.phi2);
                    self.circuit.set_switch(cell.sw_phi2d, lv.phi2d);
                    if cell.tg_phi1 != usize::MAX {
                        self.circuit.set_switch(cell.tg_phi1, lv.phi1);
                    }
                }
                // ALU drives the MSB X only while φ1 is high.
                self.circuit.set_driver(self.alu_driver, None, lv.phi1);
                self.circuit.step(dt);
                t += dt;
                // Sample traces.
                phase_traces[0].push(t, if lv.phi1 { self.params.vdd } else { 0.0 });
                phase_traces[1].push(t, if lv.phi2 { self.params.vdd } else { 0.0 });
                phase_traces[2].push(t, if lv.phi2d { self.params.vdd } else { 0.0 });
                for (k, (_, node)) in capture.iter().enumerate() {
                    node_traces[k].push(t, self.circuit.voltage(*node));
                }
                // Carry commits on φ2d falling edge (Fig. 5b).
                let now_phi2d = lv.phi2d;
                if prev_phi2d && !now_phi2d {
                    if let Some(alu) = &mut self.alu {
                        alu.commit_carry();
                    }
                }
                prev_phi2d = now_phi2d;
            }
            // End-of-cycle safety: ensure carry committed even if the
            // last φ2d falling edge landed exactly on the boundary.
            if let Some(alu) = &mut self.alu {
                alu.commit_carry();
            }
        }
        for p in phase_traces {
            set.add(p);
        }
        for n in node_traces {
            set.add(n);
        }
        set
    }

    /// Node id of cell `i`'s X (dynamic) node.
    pub fn x_node(&self, i: usize) -> usize {
        self.cells[i].x
    }

    /// Node id of cell `i`'s Z (output) node.
    pub fn z_node(&self, i: usize) -> usize {
        self.cells[i].z
    }
}

/// Convenience: the Fig. 7 experiment — a 4-cell chain doing a pure
/// rotation, returning clock + per-cell Z waveforms.
pub fn fig7_shift_waveforms(period_ns: f64) -> (WaveformSet, u32, u32) {
    let mut chain = CellChain::new(
        4,
        CellDeviceParams::default(),
        ClockConfig::nominal(period_ns),
        None,
        &[],
    );
    let init = 0b0101u32;
    chain.load_word(init);
    let capture: Vec<(String, usize)> = (0..4)
        .map(|i| (format!("Z{i}"), chain.z_node(i)))
        .collect();
    let cap_refs: Vec<(&str, usize)> =
        capture.iter().map(|(s, n)| (s.as_str(), *n)).collect();
    let set = chain.run_cycles(4, 0, &cap_refs, 400);
    (set, init, chain.read_word())
}

/// The Fig. 8 experiment — a 4-cell chain with an FA row-ALU executing
/// a 4-bit add with write-back.
pub fn fig8_add_waveforms(period_ns: f64, a: u32, b: u32) -> (WaveformSet, u32) {
    let mut chain = CellChain::new(
        4,
        CellDeviceParams::default(),
        ClockConfig::nominal(period_ns),
        Some(AluOp::Add),
        &[],
    );
    chain.load_word(a & 0xF);
    let capture: Vec<(String, usize)> = (0..4)
        .map(|i| (format!("Z{i}"), chain.z_node(i)))
        .collect();
    let cap_refs: Vec<(&str, usize)> =
        capture.iter().map(|(s, n)| (s.as_str(), *n)).collect();
    let set = chain.run_cycles(4, b & 0xF, &cap_refs, 400);
    (set, chain.read_word())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_cycle_rotation_is_identity() {
        let (_set, init, after) = fig7_shift_waveforms(1.25);
        assert_eq!(after, init, "4 shifts of a 4-cell loop must restore 0b0101");
    }

    #[test]
    fn single_cycle_rotates_by_one() {
        let mut chain = CellChain::new(
            4,
            CellDeviceParams::default(),
            ClockConfig::nominal(1.25),
            None,
            &[],
        );
        chain.load_word(0b0001);
        chain.run_cycles(1, 0, &[], 400);
        // LSB exits cell0, re-enters at MSB: 0b0001 -> 0b1000.
        assert_eq!(chain.read_word(), 0b1000);
    }

    #[test]
    fn analog_add_matches_arithmetic() {
        for (a, b) in [(0b0011u32, 0b0001u32), (0b0101, 0b0110), (0b1111, 0b0001)] {
            let (_set, result) = fig8_add_waveforms(1.25, a, b);
            assert_eq!(result, (a + b) & 0xF, "a={a:#06b} b={b:#06b}");
        }
    }

    #[test]
    fn waveforms_capture_phases_and_nodes() {
        let (set, _, _) = fig7_shift_waveforms(1.25);
        assert!(set.get("phi1").is_some());
        assert!(set.get("phi2d").is_some());
        let z0 = set.get("Z0").unwrap();
        assert!(z0.len() > 100);
        // Signal must actually swing.
        assert!(z0.max() > 0.8 && z0.min() < 0.2);
    }

    #[test]
    fn remnant_charge_presents_old_datum_during_phi1() {
        // Mid-φ1, the Z node of a cell holding 1 must still read high
        // even though its loop is open — the paper's core mechanism.
        let mut chain = CellChain::new(
            4,
            CellDeviceParams::default(),
            ClockConfig::nominal(1.25),
            None,
            &[],
        );
        chain.load_word(0b1111);
        // Run a quarter period (inside φ1).
        let period = 1.25;
        let dt = 3e-4;
        let mut t = 0.0;
        while t < 0.25 * period {
            let lv = ClockGen::new(ClockConfig::nominal(period)).unwrap().levels(t);
            for cell in &chain.cells {
                chain.circuit.set_switch(cell.sw_phi2, lv.phi2);
                chain.circuit.set_switch(cell.sw_phi2d, lv.phi2d);
                if cell.tg_phi1 != usize::MAX {
                    chain.circuit.set_switch(cell.tg_phi1, lv.phi1);
                }
            }
            chain.circuit.step(dt);
            t += dt;
        }
        for i in 0..4 {
            assert!(
                chain.circuit.voltage(chain.z_node(i)) > 0.8,
                "cell {i} lost its datum during φ1"
            );
        }
    }
}
