//! Dynamic-node leakage and retention (paper Fig. 12, left panel):
//! "the charge stored in the start point of the disconnected inverters
//! loop in FAST SRAM will leak slowly."
//!
//! The dominant mechanism is subthreshold conduction through the off
//! NMOS intra-cell switch, with a DIBL-driven supply dependence:
//!     I_leak(VDD) = I0 · exp(k_dibl · (VDD − VDD0))
//! The node must stay above the inverter trip point (≈ VDD/2) for the
//! open-loop window, giving the retention time
//!     t_ret = C_node · (VDD − V_trip) / I_leak(VDD).

use super::circuit::{Circuit, Element};
use super::waveform::Waveform;

/// Analytic leakage/retention model of the dynamic node.
#[derive(Debug, Clone, PartialEq)]
pub struct RetentionModel {
    /// Dynamic node capacitance (fF).
    pub c_node_ff: f64,
    /// Leakage at the nominal supply (nA).
    pub i_leak_nominal_na: f64,
    /// DIBL exponent (1/V).
    pub k_dibl: f64,
    /// Nominal supply the leakage is referenced to.
    pub vdd_nominal: f64,
    /// Trip point as a fraction of VDD.
    pub trip_frac: f64,
}

impl Default for RetentionModel {
    fn default() -> Self {
        RetentionModel {
            c_node_ff: 1.2,
            i_leak_nominal_na: 0.5,
            k_dibl: 1.8,
            vdd_nominal: 1.0,
            trip_frac: 0.5,
        }
    }
}

impl RetentionModel {
    /// Leakage current at a given supply (nA).
    pub fn i_leak_na(&self, vdd: f64) -> f64 {
        self.i_leak_nominal_na * (self.k_dibl * (vdd - self.vdd_nominal)).exp()
    }

    /// Retention time (ns): how long the dynamic node stays above the
    /// trip point after the loop opens at full VDD.
    pub fn retention_ns(&self, vdd: f64) -> f64 {
        let dv = vdd * (1.0 - self.trip_frac);
        // Q = C·ΔV [fF·V = fC]; t = Q/I [fC/nA = 1e-15/1e-9 s = µs];
        // in ns: ×1e3... fC/nA = 1µs? 1e-15 C / 1e-9 A = 1e-6 s = 1e3 ns.
        self.c_node_ff * dv / self.i_leak_na(vdd) * 1e3
    }

    /// Simulated decay trace of the dynamic node (Fig. 12's slow leak),
    /// via the RC circuit simulator rather than the analytic form.
    pub fn decay_waveform(&self, vdd: f64, t_ns: f64, samples: usize) -> Waveform {
        let mut c = Circuit::new();
        let n = c.add_node("X_dyn", self.c_node_ff, vdd);
        c.add_element(Element::Leak { node: n, i_na: self.i_leak_na(vdd) });
        let mut w = Waveform::new("X_dyn");
        w.push(0.0, vdd);
        let step = t_ns / samples as f64;
        let mut t = 0.0;
        for _ in 0..samples {
            // Leak-only circuits have no conducting RC; integrate with
            // the sample step directly (linear discharge).
            let mut remaining = step;
            while remaining > 0.0 {
                let dt = remaining.min(1.0);
                c.step(dt);
                remaining -= dt;
            }
            t += step;
            w.push(t, c.voltage(n));
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retention_is_microseconds_at_nominal() {
        let m = RetentionModel::default();
        let t = m.retention_ns(1.0);
        // 1.2fF·0.5V / 0.5nA = 1.2µs.
        assert!((t - 1200.0).abs() < 1.0, "retention {t} ns");
    }

    #[test]
    fn retention_far_exceeds_shift_cycle() {
        // The margin that makes the dynamic scheme viable: the open-loop
        // window at 800 MHz is ~0.6 ns; retention is ~1.2 µs — 3 orders.
        let m = RetentionModel::default();
        assert!(m.retention_ns(1.0) > 1000.0 * 0.625);
    }

    #[test]
    fn higher_vdd_leaks_more_but_starts_higher() {
        let m = RetentionModel::default();
        assert!(m.i_leak_na(1.2) > m.i_leak_na(1.0));
        assert!(m.i_leak_na(0.8) < m.i_leak_na(1.0));
    }

    #[test]
    fn decay_waveform_matches_analytic_slope() {
        let m = RetentionModel::default();
        let w = m.decay_waveform(1.0, 1200.0, 120);
        // After t_ret the node should be right at the trip point.
        let v_end = *w.v.last().unwrap();
        assert!((v_end - 0.5).abs() < 0.02, "v(t_ret) = {v_end}");
        // Monotone non-increasing.
        for pair in w.v.windows(2) {
            assert!(pair[1] <= pair[0] + 1e-12);
        }
    }

    #[test]
    fn decay_slower_at_lower_vdd() {
        let m = RetentionModel::default();
        // Lower VDD leaks exponentially less; even with a lower starting
        // voltage the retention is longer.
        assert!(m.retention_ns(0.8) > m.retention_ns(1.0));
    }
}
