//! Waveform capture and rendering for the transient figures
//! (Figs. 7, 8, 12): CSV export for plotting and a terminal ASCII view.

use std::fmt::Write as _;

/// One named analog trace.
#[derive(Debug, Clone, Default)]
pub struct Waveform {
    pub name: String,
    pub t_ns: Vec<f64>,
    pub v: Vec<f64>,
}

impl Waveform {
    pub fn new(name: impl Into<String>) -> Self {
        Waveform { name: name.into(), t_ns: Vec::new(), v: Vec::new() }
    }

    pub fn push(&mut self, t_ns: f64, v: f64) {
        self.t_ns.push(t_ns);
        self.v.push(v);
    }

    pub fn len(&self) -> usize {
        self.t_ns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t_ns.is_empty()
    }

    /// Value at (or just before) time t, by binary search.
    pub fn at(&self, t_ns: f64) -> Option<f64> {
        if self.is_empty() || t_ns < self.t_ns[0] {
            return None;
        }
        let idx = match self
            .t_ns
            .binary_search_by(|x| x.partial_cmp(&t_ns).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        };
        Some(self.v[idx])
    }

    pub fn min(&self) -> f64 {
        self.v.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.v.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// A bundle of traces sharing a time base.
#[derive(Debug, Clone, Default)]
pub struct WaveformSet {
    pub traces: Vec<Waveform>,
}

impl WaveformSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, w: Waveform) {
        self.traces.push(w);
    }

    pub fn get(&self, name: &str) -> Option<&Waveform> {
        self.traces.iter().find(|w| w.name == name)
    }

    /// CSV: time column + one column per trace (sampled on the first
    /// trace's time base).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str("t_ns");
        for w in &self.traces {
            out.push(',');
            out.push_str(&w.name);
        }
        out.push('\n');
        if self.traces.is_empty() {
            return out;
        }
        let base = &self.traces[0];
        for (i, &t) in base.t_ns.iter().enumerate() {
            let _ = write!(out, "{t:.5}");
            for w in &self.traces {
                let v = if std::ptr::eq(w, base) {
                    w.v[i]
                } else {
                    w.at(t).unwrap_or(f64::NAN)
                };
                let _ = write!(out, ",{v:.5}");
            }
            out.push('\n');
        }
        out
    }

    /// Compact ASCII oscillogram: each trace rendered as a row of block
    /// characters over `width` time bins (mean per bin, scaled to the
    /// trace's own min/max).
    pub fn render_ascii(&self, width: usize) -> String {
        const LEVELS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let mut out = String::new();
        for w in &self.traces {
            if w.is_empty() {
                continue;
            }
            let (lo, hi) = (w.min(), w.max());
            let span = (hi - lo).max(1e-12);
            let t0 = w.t_ns[0];
            let t1 = *w.t_ns.last().unwrap();
            let _ = write!(out, "{:>10} ", w.name);
            for b in 0..width {
                let ta = t0 + (t1 - t0) * b as f64 / width as f64;
                let tb = t0 + (t1 - t0) * (b + 1) as f64 / width as f64;
                let last = b == width - 1;
                let mut sum = 0.0;
                let mut n = 0;
                for (i, &t) in w.t_ns.iter().enumerate() {
                    if t >= ta && (t < tb || (last && t <= tb)) {
                        sum += w.v[i];
                        n += 1;
                    }
                }
                let v = if n > 0 { sum / n as f64 } else { w.at(ta).unwrap_or(lo) };
                let lvl = (((v - lo) / span) * (LEVELS.len() - 1) as f64).round() as usize;
                out.push(LEVELS[lvl.min(LEVELS.len() - 1)]);
            }
            let _ = writeln!(out, "  [{lo:.2}V..{hi:.2}V]");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp() -> Waveform {
        let mut w = Waveform::new("ramp");
        for i in 0..=10 {
            w.push(i as f64, i as f64 * 0.1);
        }
        w
    }

    #[test]
    fn at_interpolates_step_style() {
        let w = ramp();
        assert_eq!(w.at(-1.0), None);
        assert_eq!(w.at(0.0), Some(0.0));
        assert_eq!(w.at(5.5), Some(0.5));
        assert_eq!(w.at(100.0), Some(1.0));
    }

    #[test]
    fn min_max() {
        let w = ramp();
        assert_eq!(w.min(), 0.0);
        assert!((w.max() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut s = WaveformSet::new();
        s.add(ramp());
        let mut w2 = Waveform::new("const");
        w2.push(0.0, 0.7);
        w2.push(10.0, 0.7);
        s.add(w2);
        let csv = s.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "t_ns,ramp,const");
        assert_eq!(lines.len(), 12); // header + 11 samples
        assert!(lines[1].starts_with("0.00000,0.00000,0.7"));
    }

    #[test]
    fn ascii_renders_all_traces() {
        let mut s = WaveformSet::new();
        s.add(ramp());
        let art = s.render_ascii(20);
        assert!(art.contains("ramp"));
        assert!(art.contains('█'));
        assert!(art.contains('▁'));
    }
}
