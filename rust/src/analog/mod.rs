//! Analog substrate: the SPICE stand-in for the paper's transient,
//! noise-margin and Monte Carlo results (Figs. 7, 8, 12).
//!
//! - [`circuit`] — fixed-timestep RC network simulator
//! - [`cellchain`] — the Fig. 3a cell netlist chained into a row
//! - [`waveform`] — trace capture, CSV, ASCII rendering
//! - [`leak`] — dynamic-node retention model
//! - [`montecarlo`] — mismatch sampling, eye pattern, noise margin

pub mod cellchain;
pub mod circuit;
pub mod leak;
pub mod montecarlo;
pub mod waveform;

pub use cellchain::{fig7_shift_waveforms, fig8_add_waveforms, CellChain, CellDeviceParams};
pub use circuit::{Circuit, Element};
pub use leak::RetentionModel;
pub use montecarlo::{McResult, McSample, MonteCarlo, VariationParams};
pub use waveform::{Waveform, WaveformSet};
