//! Monte Carlo mismatch analysis (paper Fig. 12): eye pattern of the
//! in-row shift under device variation, and the worst-case noise
//! margin ("There is still a 300 mV noise margin in the worst case").
//!
//! Variation model: the Pelgrom mismatch of the inverter pairs shifts
//! each cell's trip point by a normal offset (σ ≈ 55 mV for the
//! minimum-size devices the cell uses at 65 nm — calibrated so the
//! worst case over ~500 samples lands at the paper's ~300 mV margin);
//! switch resistance and node capacitance vary a few percent. For each sample we run the transient shift and record
//! the dynamic node's voltage at the sampling instant (φ2 rising edge),
//! building the eye. The noise margin per sample is the distance from
//! the sampled level to the (shifted) trip point.

use super::cellchain::{CellChain, CellDeviceParams};
use crate::timing::ClockConfig;
use crate::util::rng::Rng;
use crate::util::stats;

/// Variation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct VariationParams {
    /// σ of the inverter trip-point offset (V).
    pub sigma_trip: f64,
    /// Relative σ of switch on-resistance.
    pub sigma_r_rel: f64,
    /// Relative σ of node capacitance.
    pub sigma_c_rel: f64,
}

impl Default for VariationParams {
    fn default() -> Self {
        VariationParams { sigma_trip: 0.055, sigma_r_rel: 0.05, sigma_c_rel: 0.03 }
    }
}

/// Per-sample outcome.
#[derive(Debug, Clone, Copy)]
pub struct McSample {
    /// Voltage of the sampled high level at the φ2 decision instant.
    pub v_high: f64,
    /// Voltage of the sampled low level.
    pub v_low: f64,
    /// Shifted trip point of the receiving inverter.
    pub trip: f64,
    /// Whether the shifted word was still correct after a full rotation.
    pub functional: bool,
}

impl McSample {
    /// Noise margin: min distance from either level to the trip point.
    pub fn noise_margin(&self) -> f64 {
        (self.v_high - self.trip).min(self.trip - self.v_low)
    }
}

/// Aggregate Monte Carlo result.
#[derive(Debug, Clone)]
pub struct McResult {
    pub samples: Vec<McSample>,
}

impl McResult {
    pub fn worst_margin(&self) -> f64 {
        self.samples
            .iter()
            .map(McSample::noise_margin)
            .fold(f64::INFINITY, f64::min)
    }

    pub fn mean_margin(&self) -> f64 {
        let v: Vec<f64> = self.samples.iter().map(McSample::noise_margin).collect();
        stats::mean(&v)
    }

    pub fn yield_frac(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().filter(|s| s.functional).count() as f64 / self.samples.len() as f64
    }

    /// Eye opening: (min sampled high) − (max sampled low).
    pub fn eye_opening(&self) -> f64 {
        let min_high = self
            .samples
            .iter()
            .map(|s| s.v_high)
            .fold(f64::INFINITY, f64::min);
        let max_low = self
            .samples
            .iter()
            .map(|s| s.v_low)
            .fold(f64::NEG_INFINITY, f64::max);
        min_high - max_low
    }
}

/// The Fig. 12 experiment.
#[derive(Debug, Clone)]
pub struct MonteCarlo {
    pub device: CellDeviceParams,
    pub variation: VariationParams,
    pub clock: ClockConfig,
}

impl Default for MonteCarlo {
    fn default() -> Self {
        MonteCarlo {
            device: CellDeviceParams::default(),
            variation: VariationParams::default(),
            clock: ClockConfig::nominal(1.25), // 800 MHz @ 1.0 V
        }
    }
}

impl MonteCarlo {
    /// Run `n` mismatch samples on a 4-cell chain shifting the worst
    /// pattern (alternating 0101 — every transfer toggles).
    pub fn run(&self, n: usize, seed: u64) -> McResult {
        let mut rng = Rng::new(seed);
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            // Sample per-cell trip offsets and global R/C scale.
            let trip_offsets: Vec<f64> = (0..4)
                .map(|_| rng.normal_ms(0.0, self.variation.sigma_trip))
                .collect();
            let mut dev = self.device.clone();
            let r_scale = (1.0 + rng.normal_ms(0.0, self.variation.sigma_r_rel)).max(0.5);
            let c_scale = (1.0 + rng.normal_ms(0.0, self.variation.sigma_c_rel)).max(0.5);
            dev.r_sw_kohm *= r_scale;
            dev.r_inv_kohm *= r_scale;
            dev.c_x_ff *= c_scale;
            dev.c_w_ff *= c_scale;

            let mut chain = CellChain::new(4, dev, self.clock, None, &trip_offsets);
            let pattern = 0b0101u32;
            chain.load_word(pattern);

            // One cycle while watching the receiving cell's X at the φ2
            // decision instant.
            let x1 = chain.x_node(1); // receives a 1 (from cell 2's Z=1)
            let x0 = chain.x_node(0); // receives a 0 (from cell 1's Z=0)
            let decision_t = self.clock.period_ns / 2.0; // φ2 rising
            let captures = [("x1", x1), ("x0", x0)];
            let set = chain.run_cycles(1, 0, &captures, 800);
            let v_high = set.get("x1").and_then(|w| w.at(decision_t)).unwrap_or(0.0);
            let v_low = set
                .get("x0")
                .and_then(|w| w.at(decision_t))
                .unwrap_or(self.device.vdd);

            // Functional check: 3 more cycles completes the rotation.
            chain.run_cycles(3, 0, &[], 400);
            let functional = chain.read_word() == pattern;

            let trip = self.device.trip + trip_offsets[1];
            samples.push(McSample { v_high, v_low, trip, functional });
        }
        McResult { samples }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_sample_has_wide_margin() {
        let mc = MonteCarlo::default();
        let novar = MonteCarlo {
            variation: VariationParams { sigma_trip: 0.0, sigma_r_rel: 0.0, sigma_c_rel: 0.0 },
            ..mc
        };
        let r = novar.run(3, 1);
        assert!(r.yield_frac() == 1.0);
        // Nominal margin should be a healthy fraction of VDD/2.
        assert!(r.worst_margin() > 0.35, "nominal margin {}", r.worst_margin());
    }

    #[test]
    fn worst_case_margin_near_300mv() {
        // The paper's claim: ≥300 mV worst-case margin under mismatch.
        let mc = MonteCarlo::default();
        let r = mc.run(200, 42);
        let worst = r.worst_margin();
        assert!(
            (0.25..0.45).contains(&worst),
            "worst-case margin {worst} V (paper: ~0.3 V)"
        );
        assert_eq!(r.yield_frac(), 1.0, "all samples must stay functional");
    }

    #[test]
    fn eye_stays_open() {
        let mc = MonteCarlo::default();
        let r = mc.run(100, 7);
        assert!(r.eye_opening() > 0.5, "eye opening {}", r.eye_opening());
    }

    #[test]
    fn more_variation_shrinks_margin() {
        let base = MonteCarlo::default();
        let wild = MonteCarlo {
            variation: VariationParams {
                sigma_trip: 0.10,
                sigma_r_rel: 0.15,
                sigma_c_rel: 0.10,
            },
            ..base.clone()
        };
        let m_base = base.run(100, 3).worst_margin();
        let m_wild = wild.run(100, 3).worst_margin();
        assert!(m_wild < m_base, "wild {m_wild} >= base {m_base}");
    }
}
