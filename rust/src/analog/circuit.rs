//! Fixed-timestep RC network simulator — the SPICE stand-in.
//!
//! Scope: exactly what the paper's transient figures need. Nodes are
//! capacitors to ground; elements are resistive switches, CMOS
//! inverters (modelled as a trip-point comparator driving the output
//! node toward VDD/GND through an on-resistance), ideal voltage
//! drivers, and constant leakage sinks. Integration is explicit Euler
//! with a timestep much smaller than any RC in the netlist (validated
//! by construction: `Circuit::step` asserts dt < 0.2·min(RC)).
//!
//! Units: volts, nanoseconds, kilo-ohms, femto-farads ⇒ current in
//! µA·(1e-3) … to keep it simple we work in (V, ns, kΩ, fF):
//! I = V/R [V/kΩ = mA], dV = I·dt/C [mA·ns/fF = V·1e3] — so a factor
//! of 1e3 applies; the constant is folded into `step`.

/// Node index newtype for readability.
pub type NodeId = usize;

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    /// Capacitance to ground (fF).
    pub c_ff: f64,
    /// Voltage (V).
    pub v: f64,
}

/// Circuit elements.
#[derive(Debug, Clone)]
pub enum Element {
    /// Resistive switch between two nodes; conducts when `closed`.
    Switch {
        a: NodeId,
        b: NodeId,
        r_on_kohm: f64,
        closed: bool,
    },
    /// CMOS inverter: drives `out` toward (in < trip ? vdd : 0)
    /// through `r_drive_kohm`.
    Inverter {
        input: NodeId,
        out: NodeId,
        vdd: f64,
        /// Trip point (V) — mismatch shifts this in Monte Carlo runs.
        trip: f64,
        r_drive_kohm: f64,
    },
    /// Ideal driver pinning a node toward `v` through `r_kohm` while
    /// `active`.
    Driver {
        node: NodeId,
        v: f64,
        r_kohm: f64,
        active: bool,
    },
    /// Constant leakage sink (nA) pulling the node toward ground.
    Leak { node: NodeId, i_na: f64 },
}

/// The RC network.
#[derive(Debug, Clone, Default)]
pub struct Circuit {
    pub nodes: Vec<Node>,
    pub elements: Vec<Element>,
}

impl Circuit {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_node(&mut self, name: impl Into<String>, c_ff: f64, v0: f64) -> NodeId {
        assert!(c_ff > 0.0, "node needs positive capacitance");
        self.nodes.push(Node { name: name.into(), c_ff, v: v0 });
        self.nodes.len() - 1
    }

    pub fn add_element(&mut self, e: Element) -> usize {
        self.elements.push(e);
        self.elements.len() - 1
    }

    pub fn set_switch(&mut self, idx: usize, closed: bool) {
        match &mut self.elements[idx] {
            Element::Switch { closed: c, .. } => *c = closed,
            _ => panic!("element {idx} is not a switch"),
        }
    }

    pub fn set_driver(&mut self, idx: usize, v: Option<f64>, active: bool) {
        match &mut self.elements[idx] {
            Element::Driver { v: dv, active: a, .. } => {
                if let Some(nv) = v {
                    *dv = nv;
                }
                *a = active;
            }
            _ => panic!("element {idx} is not a driver"),
        }
    }

    pub fn voltage(&self, n: NodeId) -> f64 {
        self.nodes[n].v
    }

    /// Smallest RC product (ns) across conducting paths — the stiffness
    /// bound for the integrator.
    pub fn min_rc_ns(&self) -> f64 {
        let mut min_rc = f64::INFINITY;
        let mut consider = |r_kohm: f64, n: NodeId| {
            // kΩ·fF = 1e3·1e-15 s = 1e-12 s = 1e-3 ns.
            let rc_ns = r_kohm * self.nodes[n].c_ff * 1e-3;
            if rc_ns < min_rc {
                min_rc = rc_ns;
            }
        };
        for e in &self.elements {
            match *e {
                Element::Switch { a, b, r_on_kohm, closed } if closed => {
                    consider(r_on_kohm, a);
                    consider(r_on_kohm, b);
                }
                Element::Inverter { out, r_drive_kohm, .. } => consider(r_drive_kohm, out),
                Element::Driver { node, r_kohm, active, .. } if active => consider(r_kohm, node),
                _ => {}
            }
        }
        min_rc
    }

    /// Advance one Euler step of `dt_ns`. Panics if dt is too large for
    /// the stiffest conducting RC (guards against silent instability).
    pub fn step(&mut self, dt_ns: f64) {
        debug_assert!(
            dt_ns <= 0.2 * self.min_rc_ns(),
            "dt {dt_ns} ns too large for min RC {} ns",
            self.min_rc_ns()
        );
        // Accumulate currents (mA) into each node.
        let mut i_ma = vec![0.0f64; self.nodes.len()];
        for e in &self.elements {
            match *e {
                Element::Switch { a, b, r_on_kohm, closed } => {
                    if closed {
                        let i = (self.nodes[a].v - self.nodes[b].v) / r_on_kohm;
                        i_ma[a] -= i;
                        i_ma[b] += i;
                    }
                }
                Element::Inverter { input, out, vdd, trip, r_drive_kohm } => {
                    let target = if self.nodes[input].v < trip { vdd } else { 0.0 };
                    let i = (target - self.nodes[out].v) / r_drive_kohm;
                    i_ma[out] += i;
                }
                Element::Driver { node, v, r_kohm, active } => {
                    if active {
                        let i = (v - self.nodes[node].v) / r_kohm;
                        i_ma[node] += i;
                    }
                }
                Element::Leak { node, i_na } => {
                    // Subthreshold sink; stops at ground.
                    if self.nodes[node].v > 0.0 {
                        i_ma[node] -= i_na * 1e-6;
                    }
                }
            }
        }
        // dV = I dt / C with unit factor: mA·ns/fF = 1e-3·1e-9/1e-15 V = 1e3 V.
        for (n, node) in self.nodes.iter_mut().enumerate() {
            node.v += i_ma[n] * dt_ns / node.c_ff * 1e3;
        }
    }

    /// Run for `t_ns` with automatic step sizing (0.1·min RC, capped).
    pub fn run(&mut self, t_ns: f64, mut on_sample: impl FnMut(f64, &Circuit)) {
        let mut t = 0.0;
        while t < t_ns {
            let dt = (0.1 * self.min_rc_ns()).min(t_ns - t).min(0.01);
            self.step(dt);
            t += dt;
            on_sample(t, self);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rc_charge_follows_exponential() {
        // Driver (1V, 10kΩ) into a 10fF node: τ = 0.1 ns.
        let mut c = Circuit::new();
        let n = c.add_node("n", 10.0, 0.0);
        c.add_element(Element::Driver { node: n, v: 1.0, r_kohm: 10.0, active: true });
        let tau = 0.1;
        let mut t = 0.0;
        while t < tau {
            c.step(1e-3);
            t += 1e-3;
        }
        // After one τ the node should be at ~63.2%.
        assert!((c.voltage(n) - 0.632).abs() < 0.02, "v = {}", c.voltage(n));
    }

    #[test]
    fn switch_equalizes_charge() {
        let mut c = Circuit::new();
        let a = c.add_node("a", 10.0, 1.0);
        let b = c.add_node("b", 10.0, 0.0);
        let sw = c.add_element(Element::Switch { a, b, r_on_kohm: 5.0, closed: false });
        // Open: nothing moves.
        for _ in 0..100 {
            c.step(1e-3);
        }
        assert_eq!(c.voltage(a), 1.0);
        // Closed: equal caps converge to the midpoint.
        c.set_switch(sw, true);
        for _ in 0..10_000 {
            c.step(1e-3);
        }
        assert!((c.voltage(a) - 0.5).abs() < 0.01);
        assert!((c.voltage(b) - 0.5).abs() < 0.01);
    }

    #[test]
    fn inverter_inverts() {
        let mut c = Circuit::new();
        let input = c.add_node("in", 1.0, 0.0);
        let out = c.add_node("out", 5.0, 0.0);
        c.add_element(Element::Inverter {
            input,
            out,
            vdd: 1.0,
            trip: 0.5,
            r_drive_kohm: 5.0,
        });
        for _ in 0..20_000 {
            c.step(5e-4);
        }
        assert!(c.voltage(out) > 0.95, "low in -> high out, got {}", c.voltage(out));
        c.nodes[input].v = 1.0;
        for _ in 0..20_000 {
            c.step(5e-4);
        }
        assert!(c.voltage(out) < 0.05, "high in -> low out, got {}", c.voltage(out));
    }

    #[test]
    fn leak_discharges_and_stops_at_ground() {
        let mut c = Circuit::new();
        let n = c.add_node("dyn", 1.0, 1.0);
        c.add_element(Element::Leak { node: n, i_na: 0.5 });
        // I = 0.5 nA on 1 fF: dV/dt = 0.5 V/µs ⇒ 0.5 V after 1 µs.
        let mut t = 0.0;
        while t < 1000.0 {
            c.step(0.01);
            t += 0.01;
        }
        let v = c.voltage(n);
        assert!((v - 0.5).abs() < 0.02, "after 1µs leak: {v}");
        while t < 10_000.0 {
            c.step(0.01);
            t += 0.01;
        }
        assert!(c.voltage(n) >= -0.01, "leak must stop at ground");
    }

    #[test]
    fn min_rc_tracks_conducting_elements_only() {
        let mut c = Circuit::new();
        let a = c.add_node("a", 1.0, 0.0);
        let b = c.add_node("b", 1.0, 0.0);
        let sw = c.add_element(Element::Switch { a, b, r_on_kohm: 1.0, closed: false });
        assert_eq!(c.min_rc_ns(), f64::INFINITY);
        c.set_switch(sw, true);
        assert!((c.min_rc_ns() - 1e-3).abs() < 1e-12);
    }
}
