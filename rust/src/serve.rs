//! `fast serve` — the service front-end over the update engine: a
//! std-only, newline-delimited request/response protocol
//! (`fast-serve-v1`) served over TCP (multiple concurrent clients) or
//! stdio (one session, handy for pipes and CI).
//!
//! ## Protocol (`fast-serve-v1`)
//!
//! Every non-empty request line gets exactly one response line,
//! `OK …` or `ERR …`. Data-plane lines ARE `fast-trace-v1` event
//! objects (parsed by [`TraceEvent::parse_line`] — the serve wire
//! format and the trace file format are the same grammar):
//!
//! ```text
//! {"t":"u","o":"add","r":5,"v":3}   update  → SUB: OK on admission
//!                                             CMT: OK shard=.. seq=.. after commit
//! {"t":"w","r":0,"v":17}            absolute write → OK
//! {"t":"f"}                         barrier: drain every shard → OK drained seq=..
//! ```
//!
//! Control-plane lines are plain words:
//!
//! ```text
//! HELLO                 → OK fast-serve-v1 rows=R q=Q shards=S backend=B
//! MODE SUB | MODE CMT   per-connection submission mode (default CMT):
//!                       SUB  = fire-and-forget (ack on admission),
//!                       CMT  = wait-for-ticket (ack carries the commit:
//!                              shard, commit_seq, seal reason, rows,
//!                              modeled ns)
//! READ <row>            → OK <value>      (read-your-writes, per shard+row)
//! WAIT <shard> <seq>    → OK <committed>  (blocks via UpdateEngine::wait_seq)
//! DRAIN <shard>         → OK <seq>        (per-shard drain)
//! DIGEST                → OK <fnv64-hex of the row state snapshot>
//! DIGEST CRC            → OK <crc32-hex of the row state bytes (LE)>
//! QRY <reduction>       → OK qry <name> value=.. rows=.. cycles=..
//!                         toggles=.. alu=.. banks=.. energy_fj=..
//!                         ns=.. seq=<s0,s1,..>
//!                       in-array reduction (`popcount | sum | min |
//!                       max | range <lo> <hi> | dot <seed>`, optional
//!                       trailing `mask <seed> <pct>` — the
//!                       `crate::query::parse_spec` grammar). Sequenced
//!                       against each shard's commits: the value
//!                       reflects exactly the updates whose acks the
//!                       client saw before sending the QRY, and the
//!                       observed per-shard commit seqs are reported.
//! STATS                 → OK <one-line JSON engine stats>
//! PROMOTE               → OK promoted epoch=<E>  (follower only: stop
//!                         replicating, fence a new epoch, accept writes)
//! QUIT                  → OK bye          (closes this connection)
//! SHUTDOWN              → OK draining     (server drains every shard and exits)
//! ```
//!
//! ## Multi-tenant serves (`fast serve --tenants`)
//!
//! A multi-tenant serve hosts a [`TenantRegistry`] instead of a single
//! engine (see `crate::tenant`). Four more control verbs manage it:
//!
//! ```text
//! TENANT USE <name>                       → OK tenant=<n> rows=R q=Q quota=K
//!                                           (binds this session; HELLO/READ/
//!                                           DIGEST/QRY/STATS now act on it)
//! TENANT CREATE <name> <rows> <q> [quota] → OK created tenant=…
//! TENANT DROP <name>                      → OK dropped tenant=…
//! TENANT LIST                             → OK tenants=N name:rows:q:quota …
//! ```
//!
//! Event lines may carry an explicit `"tenant":"<name>"` field that
//! overrides the session binding per line (parsed by
//! [`TraceEvent::parse_line_routed`], with row/value validated against
//! *that tenant's* rows and q). `STATS` with no tenant bound answers
//! the registry-wide JSON: every tenant's spec plus its full engine
//! stats object (per-tenant counters and latency histograms).
//!
//! Backpressure maps to protocol errors: when a shard's admission
//! queue is full, the update line answers `ERR busy …` and the client
//! retries — the server never buffers unboundedly on behalf of a
//! client. Engine errors (bad row, shut-down engine) answer `ERR …`
//! on the offending line; the connection stays usable. More typed
//! `ERR` classes let clients react without string-matching prose: a
//! replication follower answers every update/write line with
//! `ERR readonly …` until promoted; a blocked `WAIT`/CMT aborted
//! by server shutdown answers `ERR shutdown …` within one wait-poll
//! interval of the stop flag rising; a row over its tenant's admission
//! quota answers `ERR quota …`; and an event line carrying a field
//! outside the `fast-trace-v1` grammar answers `ERR badfield …`
//! instead of silently ignoring the field (which is what makes the
//! `tenant` field safe to introduce: an old server rejects it loudly).
//!
//! Shutdown is a clean drain: new connections stop being accepted,
//! open sessions wind down, every shard is drained (per-shard — the
//! engine has no whole-engine flush), and the final [`EngineStats`]
//! (including per-shard submit→commit latency histograms) is returned
//! to the caller, which `fast serve --stats-json` prints as JSON.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context};

use crate::apps::trace::{state_digest, BadField, Trace, TraceEvent};
use crate::coordinator::{
    EngineBusy, EngineReadOnly, EngineStats, SealReason, Ticket, UpdateEngine, UpdateRequest,
};
use crate::metrics::LatencySummary;
use crate::replication::{FollowerHandle, ReplListener, ReplSnapshot, ReplStats};
use crate::telemetry::expo::{self, Scope, TenantMeta};
use crate::telemetry::server::{MetricsRender, MetricsServer};
use crate::telemetry::TelemetrySnapshot;
use crate::tenant::{QuotaExceeded, TenantHandle, TenantRegistry, TenantSpec};
use crate::util::rng::Rng;
use crate::Result;

/// Is this submit error transient backpressure (retry) rather than a
/// terminal engine failure?
fn is_busy(e: &anyhow::Error) -> bool {
    e.root_cause().downcast_ref::<EngineBusy>().is_some()
}

/// Is this a read-only (replication follower) rejection? Typed on the
/// wire as `ERR readonly …` so clients know the server exists and is
/// healthy — they should redirect writes to the primary, not retry.
fn is_readonly(e: &anyhow::Error) -> bool {
    e.root_cause().downcast_ref::<EngineReadOnly>().is_some()
}

/// Is this a tenant over-admission rejection? Typed as `ERR quota …`:
/// the connection stays usable (like `ERR busy`, unlike terminal
/// `ERR`s), but blind retries of the same row will keep failing — the
/// remedy is a larger quota or a different row.
fn is_quota(e: &anyhow::Error) -> bool {
    e.root_cause().downcast_ref::<QuotaExceeded>().is_some()
}

/// Is this an unknown/malformed-field parse rejection? Typed as
/// `ERR badfield …` so a client that sent a field this server does not
/// understand (e.g. `tenant` to a single-tenant serve) learns so
/// explicitly instead of having the field silently ignored.
fn is_badfield(e: &anyhow::Error) -> bool {
    e.root_cause().downcast_ref::<BadField>().is_some()
}

/// How often blocked protocol waits (`WAIT`, CMT commits) re-check the
/// server-wide stop flag, so a waiting client can never block shutdown.
const WAIT_POLL: Duration = Duration::from_millis(200);

/// Cap on a blocked wait in a session with no server stop flag (stdio,
/// tests). Those transports are lockstep — the blocked handler is the
/// same thread that would read the input able to satisfy the wait — so
/// only background seal policy can release it; past this cap, fail the
/// wait instead of hanging the session forever.
const LONE_SESSION_WAIT_CAP: Duration = Duration::from_secs(30);

/// Protocol tag answered by `HELLO`; bump on breaking changes.
pub const PROTOCOL: &str = "fast-serve-v1";

/// Per-connection submission mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Fire-and-forget: an update line is acked on admission.
    Sub,
    /// Wait-for-ticket: an update line is acked after its batch
    /// commits, with the commit metadata.
    Cmt,
}

/// What the connection loop should do after answering one line.
#[derive(Debug)]
pub enum Action {
    /// Send the reply, keep the session open.
    Reply(String),
    /// Send the reply, close this connection.
    Quit(String),
    /// Send the reply, then drain and stop the whole server.
    Shutdown(String),
}

fn seal_reason_name(r: SealReason) -> &'static str {
    match r {
        SealReason::Full => "full",
        SealReason::KindChange => "kind-change",
        SealReason::Deadline => "deadline",
        SealReason::Forced => "forced",
    }
}

/// Replication context a session may carry: the follower handle (for
/// `PROMOTE`) and the shared counters (for `STATS`). Present on both
/// roles — a primary has stats but no follower handle.
#[derive(Clone)]
pub struct SessionRepl {
    pub follower: Option<Arc<FollowerHandle>>,
    pub stats: Arc<ReplStats>,
}

/// What a serve fronts: one engine (the classic shape) or a registry
/// of named tenants, each with its own engine. Cloned per connection.
#[derive(Clone)]
pub enum ServeTarget {
    /// Single-engine serve: every line acts on this engine.
    Engine(Arc<UpdateEngine>),
    /// Multi-tenant serve: lines route by session binding
    /// (`TENANT USE`) or per-line `"tenant"` field.
    Tenants(Arc<TenantRegistry>),
}

/// A resolved routing decision: the single engine, or one tenant's
/// handle. Mutations on the tenant arm go through the handle so the
/// admission quota applies; read-side verbs use [`Self::engine`].
enum RouteTarget {
    Single(Arc<UpdateEngine>),
    Tenant(Arc<TenantHandle>),
}

impl RouteTarget {
    fn engine(&self) -> &UpdateEngine {
        match self {
            RouteTarget::Single(e) => e,
            RouteTarget::Tenant(h) => h.engine(),
        }
    }

    fn submit(&self, req: UpdateRequest) -> Result<()> {
        match self {
            RouteTarget::Single(e) => e.submit(req),
            RouteTarget::Tenant(h) => h.submit(req),
        }
    }

    fn submit_ticketed(&self, req: UpdateRequest) -> Result<Ticket> {
        match self {
            RouteTarget::Single(e) => e.submit_ticketed(req),
            RouteTarget::Tenant(h) => h.submit_ticketed(req),
        }
    }

    fn write(&self, row: usize, value: u32) -> Result<()> {
        match self {
            RouteTarget::Single(e) => e.write(row, value),
            RouteTarget::Tenant(h) => h.write(row, value),
        }
    }
}

/// One protocol session (per connection). Pure request→response logic;
/// transports (TCP, stdio, tests) feed it lines.
pub struct Session {
    target: ServeTarget,
    mode: Mode,
    /// Active tenant bound by `TENANT USE` (multi-tenant serves only).
    tenant: Option<String>,
    /// Server-wide shutdown flag (TCP sessions): blocked waits poll it
    /// so a client parked in `WAIT`/CMT cannot deadlock the shutdown
    /// join. `None` for stdio/test sessions, whose blocked waits are
    /// instead capped at [`LONE_SESSION_WAIT_CAP`] (lockstep transport
    /// — later input cannot satisfy a blocked wait).
    stop: Option<Arc<AtomicBool>>,
    /// Replication context (`--follower` / `--repl-listen` serves).
    repl: Option<SessionRepl>,
}

impl Session {
    pub fn new(engine: Arc<UpdateEngine>) -> Self {
        Self::new_with(ServeTarget::Engine(engine))
    }

    /// A session over any serve target (single engine or tenants).
    pub fn new_with(target: ServeTarget) -> Self {
        Session { target, mode: Mode::Cmt, tenant: None, stop: None, repl: None }
    }

    /// A session that aborts blocked waits once `stop` is set.
    pub fn with_stop(engine: Arc<UpdateEngine>, stop: Arc<AtomicBool>) -> Self {
        Self::with_stop_target(ServeTarget::Engine(engine), stop)
    }

    /// [`Self::with_stop`] over any serve target.
    pub fn with_stop_target(target: ServeTarget, stop: Arc<AtomicBool>) -> Self {
        Session { target, mode: Mode::Cmt, tenant: None, stop: Some(stop), repl: None }
    }

    /// Attach replication context (builder style).
    pub fn with_repl(mut self, repl: Option<SessionRepl>) -> Self {
        self.repl = repl;
        self
    }

    /// Resolve the engine the control-plane verbs act on: the single
    /// engine, or the session's active tenant.
    fn active(&self) -> Result<RouteTarget> {
        match &self.target {
            ServeTarget::Engine(e) => Ok(RouteTarget::Single(Arc::clone(e))),
            ServeTarget::Tenants(reg) => {
                let name = self.tenant.as_deref().ok_or_else(|| {
                    anyhow!("no tenant bound to this session (TENANT USE <name>)")
                })?;
                Ok(RouteTarget::Tenant(reg.get(name)?))
            }
        }
    }

    /// Abort a blocked wait when the server is shutting down (TCP), or
    /// when a stop-less session has waited past the lockstep cap. The
    /// shutdown abort is TYPED: the reply line starts `ERR shutdown`
    /// so a client parked in WAIT/CMT on a dead shard gets a
    /// machine-readable abort within one [`WAIT_POLL`] of the stop
    /// flag, instead of riding out the lockstep cap.
    fn check_wait(&self, started: Instant, what: &str) -> Result<()> {
        match &self.stop {
            Some(stop) => ensure!(
                !stop.load(Ordering::SeqCst),
                "shutdown: server is draining; aborted the wait for {what}"
            ),
            None => ensure!(
                started.elapsed() < LONE_SESSION_WAIT_CAP,
                "wait for {what} timed out after {}s (single-session transport: \
                 later input cannot satisfy a blocked wait)",
                LONE_SESSION_WAIT_CAP.as_secs()
            ),
        }
        Ok(())
    }

    /// Handle one non-empty request line.
    pub fn handle(&mut self, line: &str) -> Action {
        match self.dispatch(line.trim()) {
            Ok(action) => action,
            // Typed, retryable rejections keep a machine-readable
            // prefix (like `ERR busy` / `ERR readonly`): over-quota
            // rows and out-of-grammar fields are client-correctable,
            // not server failures.
            Err(e) if is_quota(&e) => {
                Action::Reply(format!("ERR quota {}", one_line(&format!("{e:#}"))))
            }
            Err(e) if is_badfield(&e) => {
                Action::Reply(format!("ERR badfield {}", one_line(&format!("{e:#}"))))
            }
            // One response line per request line: flatten the error.
            Err(e) => Action::Reply(format!("ERR {}", one_line(&format!("{e:#}")))),
        }
    }

    fn dispatch(&mut self, line: &str) -> Result<Action> {
        if line.starts_with('{') {
            return self.handle_event(line);
        }
        let mut parts = line.split_whitespace();
        let cmd = parts.next().unwrap_or("");
        let reply = match cmd {
            "HELLO" => match (&self.target, &self.tenant) {
                // Unbound multi-tenant session: announce the registry.
                (ServeTarget::Tenants(reg), None) => {
                    format!("OK {PROTOCOL} tenants={} bind=TENANT-USE", reg.len())
                }
                _ => {
                    let t = self.active()?;
                    let cfg = t.engine().config();
                    let backend = t.engine().stats().backend;
                    let tenant = match &self.tenant {
                        Some(n) => format!(" tenant={n}"),
                        None => String::new(),
                    };
                    format!(
                        "OK {PROTOCOL} rows={} q={} shards={} backend={backend}{tenant}",
                        cfg.rows, cfg.q, cfg.shards
                    )
                }
            },
            "TENANT" => {
                let ServeTarget::Tenants(reg) = &self.target else {
                    bail!(
                        "TENANT verbs need a multi-tenant serve \
                         (start with `fast serve --tenants`)"
                    )
                };
                match parts.next() {
                    Some("USE") => {
                        let name =
                            parts.next().ok_or_else(|| anyhow!("usage: TENANT USE <name>"))?;
                        let h = reg.get(name)?;
                        let s = h.spec().clone();
                        self.tenant = Some(s.name.clone());
                        format!(
                            "OK tenant={} rows={} q={} quota={}",
                            s.name, s.rows, s.q, s.quota_rows
                        )
                    }
                    Some("CREATE") => {
                        let usage = "TENANT CREATE <name> <rows> <q> [quota]";
                        let name = parts.next().ok_or_else(|| anyhow!("usage: {usage}"))?;
                        let rows = int_arg(parts.next(), usage)?;
                        let q = int_arg(parts.next(), usage)?;
                        let quota = match parts.next() {
                            Some(tok) => {
                                tok.parse().map_err(|_| anyhow!("usage: {usage}"))?
                            }
                            None => rows,
                        };
                        reg.create(TenantSpec::with_quota(name, rows, q, quota)?)?;
                        format!("OK created tenant={name} rows={rows} q={q} quota={quota}")
                    }
                    Some("DROP") => {
                        let name =
                            parts.next().ok_or_else(|| anyhow!("usage: TENANT DROP <name>"))?;
                        reg.drop_tenant(name)?;
                        if self.tenant.as_deref() == Some(name) {
                            self.tenant = None;
                        }
                        format!("OK dropped tenant={name}")
                    }
                    Some("LIST") => {
                        let specs = reg.list();
                        let mut line = format!("OK tenants={}", specs.len());
                        for s in &specs {
                            line.push_str(&format!(
                                " {}:{}:{}:{}",
                                s.name, s.rows, s.q, s.quota_rows
                            ));
                        }
                        line
                    }
                    other => bail!("TENANT expects USE|CREATE|DROP|LIST, got {other:?}"),
                }
            }
            "MODE" => match parts.next() {
                Some("SUB") => {
                    self.mode = Mode::Sub;
                    "OK mode=SUB".to_string()
                }
                Some("CMT") => {
                    self.mode = Mode::Cmt;
                    "OK mode=CMT".to_string()
                }
                other => bail!("MODE expects SUB or CMT, got {other:?}"),
            },
            "READ" => {
                let row = int_arg(parts.next(), "READ <row>")?;
                format!("OK {}", self.active()?.engine().read(row)?)
            }
            "WAIT" => {
                let shard = int_arg(parts.next(), "WAIT <shard> <seq>")?;
                let seq = int_arg(parts.next(), "WAIT <shard> <seq>")? as u64;
                let t = self.active()?;
                let started = Instant::now();
                loop {
                    if let Some(committed) =
                        t.engine().wait_seq_timeout(shard, seq, WAIT_POLL)?
                    {
                        break format!("OK {committed}");
                    }
                    self.check_wait(started, &format!("shard {shard} reaches commit_seq {seq}"))?;
                }
            }
            "DRAIN" => {
                let shard = int_arg(parts.next(), "DRAIN <shard>")?;
                format!("OK {}", self.active()?.engine().drain_shard(shard)?)
            }
            "DIGEST" => {
                let snap = self.active()?.engine().snapshot()?;
                match parts.next() {
                    // `DIGEST CRC`: CRC32 over the state's LE bytes —
                    // the same util::crc32 that frames the WAL, so an
                    // external tool can cross-check either fingerprint.
                    Some(arg) if arg.eq_ignore_ascii_case("crc") => {
                        let crc = snap
                            .iter()
                            .fold(crate::util::crc32::Crc32::new(), |c, w| {
                                c.update(&w.to_le_bytes())
                            })
                            .finish();
                        format!("OK {crc:08x}")
                    }
                    Some(other) => bail!("DIGEST takes no argument or CRC, got {other:?}"),
                    None => format!("OK {:016x}", state_digest(&snap)),
                }
            }
            "QRY" => {
                let mut tokens: Vec<&str> = parts.collect();
                // Optional leading `tenant=<name>` token scopes the
                // reduction to that tenant's rows, overriding the
                // session binding.
                let t = match tokens.first().and_then(|tok| tok.strip_prefix("tenant=")) {
                    Some(name) => {
                        let ServeTarget::Tenants(reg) = &self.target else {
                            bail!(
                                "QRY tenant= scoping needs a multi-tenant serve \
                                 (start with `fast serve --tenants`)"
                            )
                        };
                        let h = reg.get(name)?;
                        tokens.remove(0);
                        RouteTarget::Tenant(h)
                    }
                    None => self.active()?,
                };
                let cfg = t.engine().config();
                // A malformed line fails here with a typed message and
                // becomes a single `ERR …` reply via `handle` — the
                // session never hangs on a bad query.
                let spec = crate::query::parse_spec(&tokens, cfg.rows, cfg.q)?;
                let r = t.engine().submit_query(&spec)?.wait()?;
                let seqs: Vec<String> =
                    r.shard_seqs.iter().map(u64::to_string).collect();
                format!(
                    "OK qry {} value={} rows={} cycles={} toggles={} alu={} \
                     banks={} energy_fj={:.3} ns={:.3} seq={}",
                    spec.red.name(),
                    r.value,
                    r.report.rows_active,
                    r.report.cycles,
                    r.report.cell_toggles,
                    r.report.alu_evals,
                    r.banks_active,
                    r.cost.energy_fj,
                    r.cost.latency_ns,
                    seqs.join(",")
                )
            }
            "STATS" => match (&self.target, &self.tenant) {
                // Unbound multi-tenant session: the registry-wide view
                // (every tenant's spec + full per-engine stats).
                (ServeTarget::Tenants(reg), None) => {
                    format!("OK {}", stats_json_tenants(&reg.stats()))
                }
                _ => {
                    let t = self.active()?;
                    let repl = self.repl.as_ref().map(|r| r.stats.snapshot());
                    format!("OK {}", stats_json_with_repl(&t.engine().stats(), repl.as_ref()))
                }
            },
            "METRICS" => {
                // The Prometheus text exposition over the wire: the
                // same families `GET /metrics` serves, terminated by
                // the `# EOF` line so line-protocol clients know where
                // the multi-line reply ends. Scope resolution mirrors
                // STATS: a bound session (or single-engine serve)
                // renders one scope; an unbound tenant session renders
                // every tenant as a labelled scope.
                let repl = self.repl.as_ref().map(|r| r.stats.snapshot());
                let text = match (&self.target, &self.tenant) {
                    (ServeTarget::Tenants(reg), None) => {
                        render_metrics_tenants(reg, repl.as_ref())
                    }
                    _ => match self.active()? {
                        RouteTarget::Single(e) => render_metrics_engine(&e, repl.as_ref()),
                        RouteTarget::Tenant(h) => render_metrics_handle(&h, repl.as_ref()),
                    },
                };
                return Ok(Action::Reply(text));
            }
            "PROMOTE" => match &self.repl {
                Some(SessionRepl { follower: Some(f), .. }) => {
                    let epoch = f.promote().context("promoting this follower")?;
                    format!("OK promoted epoch={epoch}")
                }
                _ => bail!(
                    "PROMOTE only applies to a replication follower \
                     (start with `fast serve --follower <primary-addr>`)"
                ),
            },
            "QUIT" => return Ok(Action::Quit("OK bye".to_string())),
            "SHUTDOWN" => return Ok(Action::Shutdown("OK draining".to_string())),
            other => bail!("unknown command {other:?} (try HELLO)"),
        };
        Ok(Action::Reply(reply))
    }

    fn handle_event(&mut self, line: &str) -> Result<Action> {
        // Parse AND route in one step: on a multi-tenant serve the
        // row/value validation must use the routed tenant's shape
        // (per-line "tenant" field wins over the session binding), and
        // mutations go through the tenant handle so quotas apply.
        let (target, event) = match &self.target {
            ServeTarget::Engine(e) => {
                let cfg = e.config();
                // Canonical lines parse allocation-free; anything else
                // falls back to the full grammar with identical errors.
                let event = TraceEvent::parse_line_fast(line, cfg.rows, cfg.q)?;
                (RouteTarget::Single(Arc::clone(e)), event)
            }
            ServeTarget::Tenants(reg) => {
                let bound = self.tenant.clone();
                let resolve = |t: Option<&str>| -> Result<Arc<TenantHandle>> {
                    let name = t.or(bound.as_deref()).ok_or_else(|| {
                        anyhow!(
                            "no tenant for this event line (TENANT USE <name>, or \
                             add a \"tenant\" field)"
                        )
                    })?;
                    reg.get(name)
                };
                let (tenant, event) = TraceEvent::parse_line_routed(line, &|t| {
                    let cfg = resolve(t)?.engine().config();
                    Ok((cfg.rows, cfg.q))
                })?;
                (RouteTarget::Tenant(resolve(tenant.as_deref())?), event)
            }
        };
        let reply = match event {
            TraceEvent::Update(req) => match self.mode {
                // Backpressure (queue full) is a retryable protocol
                // error; anything else (engine shut down, dead shard)
                // is terminal and reported as a plain ERR so clients
                // fail fast instead of retrying. (Over-quota rows
                // propagate as errors and get their typed `ERR quota`
                // prefix in `handle`.)
                Mode::Sub => match target.submit(req) {
                    Ok(()) => "OK".to_string(),
                    Err(e) if is_busy(&e) => {
                        format!("ERR busy {}", one_line(&format!("{e:#}")))
                    }
                    Err(e) if is_readonly(&e) => {
                        format!("ERR readonly {}", one_line(&format!("{e:#}")))
                    }
                    Err(e) => return Err(e),
                },
                Mode::Cmt => match target.submit_ticketed(req) {
                    Ok(ticket) => {
                        let started = Instant::now();
                        loop {
                            if let Some(c) = ticket.wait_timeout(WAIT_POLL)? {
                                break format!(
                                    "OK shard={} seq={} reason={} rows={} ns={:.1}",
                                    c.shard,
                                    c.commit_seq,
                                    seal_reason_name(c.seal_reason),
                                    c.rows,
                                    c.modeled_ns
                                );
                            }
                            self.check_wait(started, "the update commits")?;
                        }
                    }
                    Err(e) if is_busy(&e) => {
                        format!("ERR busy {}", one_line(&format!("{e:#}")))
                    }
                    Err(e) if is_readonly(&e) => {
                        format!("ERR readonly {}", one_line(&format!("{e:#}")))
                    }
                    Err(e) => return Err(e),
                },
            },
            TraceEvent::Write { row, value } => match target.write(row, value) {
                Ok(()) => "OK".to_string(),
                Err(e) if is_readonly(&e) => {
                    format!("ERR readonly {}", one_line(&format!("{e:#}")))
                }
                Err(e) => return Err(e),
            },
            TraceEvent::Flush => {
                // Barrier: the routed engine's explicit whole-engine
                // barrier, built from per-shard drains. Scoped to one
                // tenant on a multi-tenant serve — tenants are
                // isolated, so there is no cross-tenant barrier.
                let seqs: Vec<String> =
                    target.engine().drain_all()?.iter().map(u64::to_string).collect();
                format!("OK drained seq={}", seqs.join(","))
            }
        };
        Ok(Action::Reply(reply))
    }
}

fn int_arg(tok: Option<&str>, usage: &str) -> Result<usize> {
    tok.ok_or_else(|| anyhow!("usage: {usage}"))?
        .parse()
        .map_err(|_| anyhow!("usage: {usage}"))
}

fn one_line(s: &str) -> String {
    s.replace(['\n', '\r'], " ")
}

/// Outcome of a serve run, returned after the clean drain.
#[derive(Debug)]
pub struct ServeReport {
    /// Final engine statistics (commit histograms included).
    pub stats: EngineStats,
    /// Last committed seq per shard after the shutdown drain.
    pub drained_seq: Vec<u64>,
    /// Final replication snapshot (follower or repl-listening primary).
    pub repl: Option<ReplSnapshot>,
}

/// Everything a replicated serve owns on top of the engine: the shared
/// stats, the follower loop (follower role), and the repl listener
/// (primary role). The transport stops/drops all of it before the
/// final engine drain — component order matters, see [`serve_tcp_with`].
pub struct ServeRepl {
    pub stats: Arc<ReplStats>,
    pub follower: Option<Arc<FollowerHandle>>,
    pub repl_listener: Option<ReplListener>,
    /// Shared with the follower loop's `on_fail_stop`: when divergence
    /// fail-stops the follower, this flag shuts the whole serve down
    /// (a follower that cannot trust its state must stop serving it).
    pub fail_stop: Option<Arc<AtomicBool>>,
}

impl ServeRepl {
    fn session(&self) -> SessionRepl {
        SessionRepl { follower: self.follower.clone(), stats: Arc::clone(&self.stats) }
    }

    /// Stop the moving parts and return the last snapshot. Consumes
    /// self so the follower's engine Arc is dropped before the
    /// transport's final `finish` (which requires sole ownership).
    fn wind_down(self) -> ReplSnapshot {
        if let Some(f) = &self.follower {
            f.stop();
        }
        drop(self.repl_listener);
        let snap = self.stats.snapshot();
        drop(self.follower);
        snap
    }
}

/// Outcome of a multi-tenant serve run: every tenant's spec and final
/// engine stats (name-sorted), collected after the per-tenant drains.
#[derive(Debug)]
pub struct TenantServeReport {
    pub tenants: Vec<(TenantSpec, EngineStats)>,
}

/// Drain every shard, collect stats, shut the engine down. Errors here
/// (a shard worker died, a drain failed) propagate to the caller so
/// `fast serve` exits nonzero on an unclean drain.
fn finish(engine: Arc<UpdateEngine>) -> Result<ServeReport> {
    let engine = Arc::try_unwrap(engine)
        .map_err(|_| anyhow!("connection threads still hold the engine at shutdown"))?;
    let drained_seq = engine
        .drain_all()
        .context("draining the shards at shutdown")?;
    let stats = engine.stats();
    engine.shutdown()?;
    Ok(ServeReport { stats, drained_seq, repl: None })
}

/// The multi-tenant [`finish`]: drain every tenant, snapshot its
/// stats, shut every engine down cleanly (WAL barriers included).
fn finish_tenants(reg: Arc<TenantRegistry>) -> Result<TenantServeReport> {
    let reg = Arc::try_unwrap(reg)
        .map_err(|_| anyhow!("connection threads still hold the tenant registry at shutdown"))?;
    reg.drain_all().context("draining the tenants at shutdown")?;
    let tenants = reg.stats();
    reg.shutdown()?;
    Ok(TenantServeReport { tenants })
}

/// Serve one session over stdin/stdout (EOF = clean shutdown).
pub fn serve_stdio(engine: UpdateEngine) -> Result<ServeReport> {
    serve_stdio_with(Arc::new(engine), None)
}

/// Feed stdin lines to one session until EOF/QUIT/SHUTDOWN — the
/// transport shared by the single-engine and tenant stdio serves.
fn stdio_loop(session: &mut Session) -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line.context("reading stdin")?;
        if line.trim().is_empty() {
            continue;
        }
        let action = session.handle(&line);
        let mut out = stdout.lock();
        match action {
            Action::Reply(r) => {
                writeln!(out, "{r}")?;
                out.flush()?;
            }
            Action::Quit(r) | Action::Shutdown(r) => {
                writeln!(out, "{r}")?;
                out.flush()?;
                break;
            }
        }
    }
    Ok(())
}

/// [`serve_stdio`] with replication context (follower/primary roles).
/// Takes the engine as an `Arc` because a follower's replication loop
/// shares it; [`finish`] still requires every other clone dropped by
/// shutdown, which [`ServeRepl::wind_down`] guarantees.
pub fn serve_stdio_with(engine: Arc<UpdateEngine>, repl: Option<ServeRepl>) -> Result<ServeReport> {
    let mut session =
        Session::new(Arc::clone(&engine)).with_repl(repl.as_ref().map(ServeRepl::session));
    stdio_loop(&mut session)?;
    drop(session);
    let repl_snap = repl.map(ServeRepl::wind_down);
    let mut report = finish(engine)?;
    report.repl = repl_snap;
    Ok(report)
}

/// [`serve_stdio`] over a tenant registry (`fast serve --tenants
/// --stdio`): one session, EOF = clean shutdown of every tenant.
pub fn serve_stdio_tenants(reg: Arc<TenantRegistry>) -> Result<TenantServeReport> {
    let mut session = Session::new_with(ServeTarget::Tenants(Arc::clone(&reg)));
    stdio_loop(&mut session)?;
    drop(session);
    finish_tenants(reg)
}

/// Serve the protocol on an already-bound listener until a client
/// sends `SHUTDOWN`. Accepts any number of concurrent connections
/// (thread per connection; the engine's shard workers are the
/// concurrency bottleneck by design, not the session threads).
pub fn serve_tcp(engine: UpdateEngine, listener: TcpListener) -> Result<ServeReport> {
    serve_tcp_with(Arc::new(engine), listener, None)
}

/// [`serve_tcp`] over a tenant registry (`fast serve --tenants`):
/// sessions bind tenants with `TENANT USE` (or per-line `"tenant"`
/// fields) and the shutdown drain covers every tenant.
pub fn serve_tcp_tenants(
    reg: Arc<TenantRegistry>,
    listener: TcpListener,
) -> Result<TenantServeReport> {
    serve_tcp_tenants_observed(reg, listener, None)
}

/// [`serve_tcp_tenants`] with an optional live metrics endpoint
/// (`--metrics-listen`). The metrics server is stopped BEFORE the
/// registry teardown: its renderer closure holds a registry `Arc`,
/// and `finish_tenants` needs sole ownership.
pub fn serve_tcp_tenants_observed(
    reg: Arc<TenantRegistry>,
    listener: TcpListener,
    metrics: Option<MetricsServer>,
) -> Result<TenantServeReport> {
    accept_loop(ServeTarget::Tenants(Arc::clone(&reg)), &listener, None)?;
    if let Some(m) = metrics {
        m.stop();
    }
    finish_tenants(reg)
}

/// [`serve_tcp`] with replication context (the `Arc` is shared with a
/// follower's replication loop). Wind-down order at shutdown: join the
/// session threads, stop the follower loop / repl listener (dropping
/// their engine references), snapshot the repl counters, then drain +
/// shut down the engine.
pub fn serve_tcp_with(
    engine: Arc<UpdateEngine>,
    listener: TcpListener,
    repl: Option<ServeRepl>,
) -> Result<ServeReport> {
    serve_tcp_observed(engine, listener, repl, None)
}

/// [`serve_tcp_with`] plus an optional live metrics endpoint
/// (`--metrics-listen`). Wind-down order at shutdown: join the
/// session threads, stop+join the metrics server (its renderer
/// closure holds an engine `Arc` that [`finish`]'s sole-ownership
/// check must see released), stop the replication parts, then drain +
/// shut down the engine.
pub fn serve_tcp_observed(
    engine: Arc<UpdateEngine>,
    listener: TcpListener,
    repl: Option<ServeRepl>,
    metrics: Option<MetricsServer>,
) -> Result<ServeReport> {
    accept_loop(ServeTarget::Engine(Arc::clone(&engine)), &listener, repl.as_ref())?;
    if let Some(m) = metrics {
        m.stop();
    }
    let repl_snap = repl.map(ServeRepl::wind_down);
    let mut report = finish(engine)?;
    report.repl = repl_snap;
    Ok(report)
}

/// The shared TCP accept loop: accept connections, spawn a session
/// thread per connection, stop when the server-wide stop flag rises
/// (SHUTDOWN or a replication fail-stop), join every session thread.
fn accept_loop(
    target: ServeTarget,
    listener: &TcpListener,
    repl: Option<&ServeRepl>,
) -> Result<()> {
    let addr = listener.local_addr().context("listener address")?;
    // Address the SHUTDOWN handler can actually reach to wake the
    // blocking accept below: an unspecified bind (0.0.0.0 / ::) is not
    // connectable on every platform, so wake via loopback instead.
    let wake_addr = {
        let ip = match addr.ip() {
            std::net::IpAddr::V4(v4) if v4.is_unspecified() => {
                std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
            }
            std::net::IpAddr::V6(v6) if v6.is_unspecified() => {
                std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
            }
            other => other,
        };
        SocketAddr::new(ip, addr.port())
    };
    // A replicated serve shares its stop flag with the follower loop's
    // fail-stop hook, and polls the accept with a short timeout so a
    // divergence fail-stop (which has no client connection to wake the
    // accept with) still brings the server down promptly.
    let stop = repl
        .and_then(|r| r.fail_stop.clone())
        .unwrap_or_else(|| Arc::new(AtomicBool::new(false)));
    if repl.is_some() {
        listener.set_nonblocking(true).context("repl serve accept polling")?;
    }
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            Err(_) => continue,
        };
        // The wake-up connection a SHUTDOWN handler makes lands here.
        if stop.load(Ordering::SeqCst) {
            break;
        }
        // Reap finished connection threads as we go, so a long-running
        // server under connection churn does not accumulate unjoined
        // thread handles.
        handles = handles
            .into_iter()
            .filter_map(|h| {
                if h.is_finished() {
                    let _ = h.join();
                    None
                } else {
                    Some(h)
                }
            })
            .collect();
        let target = target.clone();
        let stop = Arc::clone(&stop);
        let session_repl = repl.map(ServeRepl::session);
        handles.push(std::thread::spawn(move || {
            serve_conn(stream, target, stop, wake_addr, session_repl)
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// One TCP connection: read lines, answer lines. A short read timeout
/// lets idle connections notice a server-wide shutdown. `wake_addr` is
/// the connectable form of the listener address, used to wake the
/// blocking accept loop after SHUTDOWN.
fn serve_conn(
    stream: TcpStream,
    target: ServeTarget,
    stop: Arc<AtomicBool>,
    wake_addr: SocketAddr,
    repl: Option<SessionRepl>,
) {
    // Accepted sockets can inherit the listener's nonblocking mode on
    // some platforms; force blocking so the read timeout governs.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let _ = stream.set_nodelay(true);
    let reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader);
    let mut out = stream;
    let mut session = Session::with_stop_target(target, Arc::clone(&stop)).with_repl(repl);
    let mut buf = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match reader.read_line(&mut buf) {
            Ok(0) => return, // client hung up
            Ok(_) => {
                let action = if buf.trim().is_empty() {
                    buf.clear();
                    continue;
                } else {
                    session.handle(&buf)
                };
                buf.clear();
                let alive = match action {
                    Action::Reply(r) => writeln!(out, "{r}").is_ok(),
                    Action::Quit(r) => {
                        let _ = writeln!(out, "{r}");
                        false
                    }
                    Action::Shutdown(r) => {
                        let _ = writeln!(out, "{r}");
                        stop.store(true, Ordering::SeqCst);
                        // Wake the blocking accept loop.
                        let _ = TcpStream::connect_timeout(&wake_addr, Duration::from_secs(1));
                        false
                    }
                };
                if !alive {
                    return;
                }
            }
            // Timeout: partial bytes (if any) stay appended in `buf`;
            // keep reading until the newline arrives.
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return,
        }
    }
}

// ---------------------------------------------------------------------------
// Protocol client (`fast client` and the CI loopback smoke job)
// ---------------------------------------------------------------------------

/// Outcome of a client run.
#[derive(Debug)]
pub struct ClientReport {
    /// Final state digest (if `want_digest`).
    pub digest: Option<String>,
    /// Event lines acked by the server.
    pub acked: u64,
    /// `ERR busy` responses survived by retrying (backpressure).
    pub busy_retries: u64,
    /// Value the server answered for the `query` spec (if one was sent).
    pub query_value: Option<u64>,
}

/// Client-side handling of `ERR busy` backpressure (`fast client
/// --retries --backoff-us`): bounded attempts per event line, with
/// exponential backoff and uniform jitter between them. Terminal ERRs
/// (bad line, dead shard, `ERR readonly`) never retry.
#[derive(Debug, Clone, Copy)]
pub struct ClientRetry {
    /// Max `ERR busy` retries per event line before failing hard.
    pub retries: u64,
    /// Base backoff; attempt `n` sleeps `backoff_us << min(n, 10)` µs
    /// (capped at 100 ms) plus uniform jitter of up to half that.
    pub backoff_us: u64,
}

impl Default for ClientRetry {
    fn default() -> ClientRetry {
        ClientRetry { retries: 1000, backoff_us: 200 }
    }
}

/// Longest single backoff sleep, whatever the doubling says.
const CLIENT_BACKOFF_CAP_US: u64 = 100_000;

/// Drive a `fast serve` endpoint: stream a trace's event lines in
/// lockstep (one request line, one response line), drain, optionally
/// fetch the state digest, optionally run a `QRY` reduction and verify
/// it, optionally shut the server down. Retries the initial connect
/// (the CI smoke job races server startup) and — boundedly, with
/// jittered exponential backoff — `ERR busy` backpressure responses.
///
/// `query` is the reduction spec in CLI grammar (e.g. `"sum"`,
/// `"range 3 900 mask 7 50"`). The answer is checked against `expect`
/// when given, otherwise — when a trace was streamed — against a
/// host-side scalar oracle over the trace's reference state; any
/// mismatch is a hard error (nonzero `fast client` exit).
pub fn run_client(
    addr: &str,
    trace: Option<&Trace>,
    mode: Mode,
    want_digest: bool,
    query: Option<&str>,
    expect: Option<u64>,
    send_shutdown: bool,
) -> Result<ClientReport> {
    run_client_retry(
        addr,
        None,
        trace,
        mode,
        want_digest,
        query,
        expect,
        send_shutdown,
        ClientRetry::default(),
    )
}

/// [`run_client`] with explicit backpressure-retry tuning and an
/// optional tenant binding (`fast client --tenant <name>`): the
/// session sends `TENANT USE` *before* `HELLO`, so the banner's
/// rows/q shape check validates against the tenant's shape.
#[allow(clippy::too_many_arguments)]
pub fn run_client_retry(
    addr: &str,
    tenant: Option<&str>,
    trace: Option<&Trace>,
    mode: Mode,
    want_digest: bool,
    query: Option<&str>,
    expect: Option<u64>,
    send_shutdown: bool,
    retry: ClientRetry,
) -> Result<ClientReport> {
    let stream = connect_with_retry(addr, Duration::from_secs(10))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut out = stream;
    let mut roundtrip = |line: &str| -> Result<String> {
        writeln!(out, "{line}").context("sending request line")?;
        let mut reply = String::new();
        let n = reader.read_line(&mut reply).context("reading reply")?;
        ensure!(n > 0, "server closed the connection");
        Ok(reply.trim_end().to_string())
    };

    if let Some(name) = tenant {
        let reply = roundtrip(&format!("TENANT USE {name}"))?;
        ensure!(reply.starts_with("OK"), "TENANT USE {name} failed: {reply}");
    }
    let hello = roundtrip("HELLO")?;
    ensure!(
        hello.starts_with(&format!("OK {PROTOCOL}")),
        "unexpected banner: {hello}"
    );
    if let Some(t) = trace {
        ensure!(
            hello.contains(&format!(" rows={} ", t.rows)) && hello.contains(&format!(" q={} ", t.q)),
            "server shape does not match the trace ({hello}; trace {}x{})",
            t.rows,
            t.q
        );
    }
    let mode_line = match mode {
        Mode::Sub => "MODE SUB",
        Mode::Cmt => "MODE CMT",
    };
    let reply = roundtrip(mode_line)?;
    ensure!(reply.starts_with("OK"), "MODE failed: {reply}");

    let mut acked = 0u64;
    let mut busy_retries = 0u64;
    // Deterministic jitter source (this is a test/CI-facing client; a
    // fixed seed keeps runs reproducible while still decorrelating the
    // retry storms of concurrent clients via their distinct schedules).
    let mut jitter = Rng::new(0xC11E_17);
    if let Some(t) = trace {
        for e in &t.events {
            let line = e.to_json_line();
            let mut attempt = 0u64;
            loop {
                let reply = roundtrip(&line)?;
                if reply.starts_with("OK") {
                    acked += 1;
                    break;
                }
                if reply.starts_with("ERR busy") {
                    busy_retries += 1;
                    attempt += 1;
                    ensure!(
                        attempt <= retry.retries,
                        "server still busy after {attempt} retries for one line \
                         (raise --retries / --backoff-us or slow the stream): {reply}"
                    );
                    // Exponential backoff with uniform jitter: base
                    // doubles per attempt, capped so a long busy spell
                    // polls at ~10 Hz instead of stalling for seconds.
                    let base = retry
                        .backoff_us
                        .saturating_mul(1u64 << attempt.min(10))
                        .min(CLIENT_BACKOFF_CAP_US);
                    let sleep_us = base + jitter.below(base / 2 + 1);
                    std::thread::sleep(Duration::from_micros(sleep_us));
                    continue;
                }
                bail!("server rejected {line:?}: {reply}");
            }
        }
        // Final barrier so the digest sees everything.
        let reply = roundtrip("{\"t\":\"f\"}")?;
        ensure!(reply.starts_with("OK"), "final drain failed: {reply}");
    }

    let digest = if want_digest {
        // A missing or malformed digest line is a hard failure: the
        // caller asked for a verifiable fingerprint, so a half-failed
        // stream must exit nonzero rather than print nothing.
        let reply = roundtrip("DIGEST")?;
        let hex = reply
            .strip_prefix("OK ")
            .ok_or_else(|| anyhow!("DIGEST failed: {reply}"))?;
        ensure!(
            hex.len() == 16 && hex.chars().all(|c| c.is_ascii_hexdigit()),
            "malformed digest {hex:?}"
        );
        Some(hex.to_string())
    } else {
        None
    };

    let query_value = if let Some(q) = query {
        let reply = roundtrip(&format!("QRY {q}"))?;
        ensure!(reply.starts_with("OK qry "), "QRY failed: {reply}");
        let value = reply
            .split_ascii_whitespace()
            .find_map(|tok| tok.strip_prefix("value="))
            .ok_or_else(|| anyhow!("QRY reply has no value field: {reply}"))?
            .parse::<u64>()
            .with_context(|| format!("parsing QRY value from {reply:?}"))?;
        // Oracle: an explicit expectation wins; otherwise replay the
        // trace on the host and reduce its reference state with the
        // scalar implementation.
        let want = match (expect, trace) {
            (Some(w), _) => Some(w),
            (None, Some(t)) => {
                let tokens: Vec<&str> = q.split_ascii_whitespace().collect();
                let spec = crate::query::parse_spec(&tokens, t.rows, t.q)?;
                let (w, _) = crate::query::scalar_reduce(&spec, &t.reference_state(), t.q)?;
                Some(w)
            }
            (None, None) => None,
        };
        if let Some(w) = want {
            ensure!(
                value == w,
                "query mismatch: server answered {value}, oracle says {w} (QRY {q})"
            );
        }
        Some(value)
    } else {
        None
    };

    if send_shutdown {
        let reply = roundtrip("SHUTDOWN")?;
        ensure!(reply.starts_with("OK"), "SHUTDOWN failed: {reply}");
    } else {
        let _ = roundtrip("QUIT");
    }
    Ok(ClientReport { digest, acked, busy_retries, query_value })
}

/// `fast promote --connect <addr>`: ask a follower serve to stop
/// replicating, fence a new epoch, and start accepting writes. Returns
/// the fenced epoch. Any `ERR …` reply (not a follower, promote
/// failed) is a hard error.
pub fn run_promote(addr: &str) -> Result<u64> {
    let stream = connect_with_retry(addr, Duration::from_secs(10))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut out = stream;
    writeln!(out, "PROMOTE").context("sending PROMOTE")?;
    let mut reply = String::new();
    let n = reader.read_line(&mut reply).context("reading PROMOTE reply")?;
    ensure!(n > 0, "server closed the connection before answering PROMOTE");
    let reply = reply.trim_end();
    let epoch = reply
        .strip_prefix("OK promoted epoch=")
        .ok_or_else(|| anyhow!("PROMOTE failed: {reply}"))?
        .parse::<u64>()
        .with_context(|| format!("parsing promoted epoch from {reply:?}"))?;
    let _ = writeln!(out, "QUIT");
    Ok(epoch)
}

/// `fast tenant create|drop|list --connect <addr>`: run one `TENANT …`
/// control line against a live multi-tenant serve and return the
/// server's `OK …` reply line. Any `ERR …` reply is a hard error.
pub fn run_tenant_cmd(addr: &str, line: &str) -> Result<String> {
    let stream = connect_with_retry(addr, Duration::from_secs(10))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut out = stream;
    writeln!(out, "{line}").context("sending TENANT line")?;
    let mut reply = String::new();
    let n = reader.read_line(&mut reply).context("reading TENANT reply")?;
    ensure!(n > 0, "server closed the connection before answering {line:?}");
    let reply = reply.trim_end().to_string();
    ensure!(reply.starts_with("OK"), "{line:?} failed: {reply}");
    let _ = writeln!(out, "QUIT");
    Ok(reply)
}

fn connect_with_retry(addr: &str, budget: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + budget;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("connecting to {addr}: {e}"));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Stats JSON — the one schema-versioned serializer behind every stats
// surface: the `STATS` protocol verb (bound or unbound), the
// `--stats-json` shutdown snapshots (single-engine, `--tenants`, and
// replicated), all emit objects stamped `"schema":"fast-stats-v1"` as
// their first key. The schema tag names the *shape contract*: every
// key that existed before the tag is unchanged, so pre-schema parsers
// (and the CI greps) keep working, while new parsers can dispatch on
// the version instead of sniffing keys.
// ---------------------------------------------------------------------------

/// Schema tag stamped on every stats JSON object; bump on any
/// key-breaking change.
pub const STATS_SCHEMA: &str = "fast-stats-v1";

fn latency_json(l: &LatencySummary) -> String {
    format!(
        "{{\"count\":{},\"mean_ns\":{:.1},\"p50_ns\":{},\"p95_ns\":{},\"p99_ns\":{},\"max_ns\":{}}}",
        l.count, l.mean_ns, l.p50_ns, l.p95_ns, l.p99_ns, l.max_ns
    )
}

/// One-line JSON rendering of [`EngineStats`] — the `STATS` protocol
/// reply and the `fast serve --stats-json` shutdown snapshot. Keys are
/// stable; per-shard commit latency is reported both wall-clock and
/// modeled (p50/p95/p99). Equivalent to
/// [`stats_json_with_repl`]`(s, None)` — one serializer, no role.
pub fn stats_json(s: &EngineStats) -> String {
    stats_json_with_repl(s, None)
}

/// The shared field body of the single-engine schema: everything
/// between the opening `"schema"` key and the optional repl splice.
fn stats_fields(s: &EngineStats) -> String {
    let mut shards = String::new();
    for (i, sc) in s.shards.iter().enumerate() {
        if i > 0 {
            shards.push(',');
        }
        shards.push_str(&format!(
            "{{\"shard\":{i},\"requests\":{},\"batches_sealed\":{},\"sealed_full\":{},\
             \"sealed_kind_change\":{},\"sealed_deadline\":{},\"sealed_forced\":{},\
             \"coalesce_hits\":{},\"rows_updated\":{},\"queue_depth\":{},\
             \"queue_high_water\":{},\"commit_seq\":{},\"tickets_resolved\":{},\
             \"queries\":{},\"submit_spins\":{},\"park_events\":{},\"wake_batch\":{},\
             \"query_wall_ns\":{},\
             \"commit_wall_ns\":{},\"commit_modeled_ns\":{},\"wal_records\":{},\
             \"wal_bytes\":{},\"wal_fsyncs\":{},\"wal_rotations\":{},\"wal_fsync_ns\":{},\
             \"wal_coalesced_writes\":{},\"wal_coalesced_frames\":{}}}",
            sc.requests,
            sc.batches_sealed,
            sc.sealed_full,
            sc.sealed_kind_change,
            sc.sealed_deadline,
            sc.sealed_forced,
            sc.coalesce_hits,
            sc.rows_updated,
            sc.queue_depth,
            sc.queue_high_water,
            sc.commit_seq,
            sc.tickets_resolved,
            sc.queries,
            sc.submit_spins,
            sc.park_events,
            latency_json(&sc.wake_batch),
            latency_json(&sc.query_wall),
            latency_json(&sc.commit_wall),
            latency_json(&sc.commit_modeled),
            sc.wal_records,
            sc.wal_bytes,
            sc.wal_fsyncs,
            sc.wal_rotations,
            latency_json(&sc.wal_fsync),
            sc.wal_coalesced_writes,
            sc.wal_coalesced_frames,
        ));
    }
    let wal_records: u64 = s.shards.iter().map(|sc| sc.wal_records).sum();
    let wal_bytes: u64 = s.shards.iter().map(|sc| sc.wal_bytes).sum();
    let wal_fsyncs: u64 = s.shards.iter().map(|sc| sc.wal_fsyncs).sum();
    format!(
        "\"backend\":\"{}\",\"submitted\":{},\"completed\":{},\"rejected\":{},\
         \"batches\":{},\"rows_updated\":{},\"rows_per_batch\":{:.2},\
         \"modeled_ns\":{:.1},\"modeled_energy_pj\":{:.3},\"queue_depth\":{},\
         \"tickets_resolved\":{},\"queries\":{},\
         \"submit_spins\":{},\"park_events\":{},\
         \"wal_records\":{wal_records},\
         \"wal_bytes\":{wal_bytes},\"wal_fsyncs\":{wal_fsyncs},\
         \"wal_coalesced_writes\":{},\"wal_coalesced_frames\":{},\
         \"apply_wall_ns\":{},\"shards\":[{}]",
        s.backend,
        s.submitted,
        s.completed,
        s.rejected,
        s.batches,
        s.rows_updated,
        s.rows_per_batch,
        s.modeled_ns,
        s.modeled_energy_pj,
        s.queue_depth,
        s.tickets_resolved,
        s.queries,
        s.submit_spins,
        s.park_events,
        s.wal_coalesced_writes,
        s.wal_coalesced_frames,
        latency_json(&s.apply_wall),
        shards
    )
}

/// JSON rendering of a [`ReplSnapshot`] — the `"repl"` object spliced
/// into the stats JSON on replicated serves (follower or repl-serving
/// primary). Per-shard lag is both logical (`lag_lsn` = primary tail −
/// applied) and wall-clock (`lag_wall_ms` since the last local apply).
fn repl_json(r: &ReplSnapshot) -> String {
    let mut shards = String::new();
    for (i, sh) in r.shards.iter().enumerate() {
        if i > 0 {
            shards.push(',');
        }
        shards.push_str(&format!(
            "{{\"shard\":{},\"applied_lsn\":{},\"primary_lsn\":{},\
             \"lag_lsn\":{},\"lag_wall_ms\":{}}}",
            sh.shard, sh.applied_lsn, sh.primary_lsn, sh.lag_lsn, sh.lag_wall_ms
        ));
    }
    let failed = match &r.failed {
        Some(msg) => format!("\"{}\"", one_line(msg).replace('\\', "\\\\").replace('"', "\\\"")),
        None => "null".to_string(),
    };
    format!(
        "{{\"epoch\":{},\"connected\":{},\"reconnects\":{},\"frames_applied\":{},\
         \"dup_frames\":{},\"wire_errors\":{},\"digests_verified\":{},\
         \"failed\":{failed},\"shards\":[{shards}]}}",
        r.epoch,
        r.connected,
        r.reconnects,
        r.frames_applied,
        r.dup_frames,
        r.wire_errors,
        r.digests_verified,
    )
}

/// Registry-wide stats JSON for a multi-tenant serve: every tenant's
/// spec plus its full [`stats_json`] object, name-sorted — the `STATS`
/// reply on an unbound tenant session and the `fast serve --tenants
/// --stats-json` shutdown snapshot. Per-tenant counters and latency
/// histograms come from each tenant's own engine, so the schema inside
/// `"stats"` is exactly the single-engine schema (each embedded object
/// carries its own `"schema"` tag; the wrapper is tagged too).
pub fn stats_json_tenants(stats: &[(TenantSpec, EngineStats)]) -> String {
    let mut body = format!("{{\"schema\":\"{STATS_SCHEMA}\",\"tenants\":[");
    for (i, (spec, s)) in stats.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&format!(
            "{{\"name\":\"{}\",\"rows\":{},\"q\":{},\"quota\":{},\"stats\":{}}}",
            spec.name,
            spec.rows,
            spec.q,
            spec.quota_rows,
            stats_json(s)
        ));
    }
    body.push_str("]}");
    body
}

/// THE stats serializer: the single-engine schema plus — when the
/// serve carries a replication role — a `"role"` key (`"follower"` or
/// `"primary"`) and the `"repl"` counters object, spliced after
/// `"shards"`. Every pre-existing key is untouched, so anything
/// parsing the non-replicated schema keeps working; [`stats_json`] is
/// exactly this with `repl = None`, byte for byte.
pub fn stats_json_with_repl(s: &EngineStats, repl: Option<&ReplSnapshot>) -> String {
    let mut body = format!("{{\"schema\":\"{STATS_SCHEMA}\",{}", stats_fields(s));
    if let Some(r) = repl {
        body.push_str(&format!(",\"role\":\"{}\",\"repl\":{}", r.role, repl_json(r)));
    }
    body.push('}');
    body
}

// ---------------------------------------------------------------------------
// Metrics exposition glue — one render path behind both transports
// (the METRICS wire verb and `GET /metrics` on `--metrics-listen`).
// ---------------------------------------------------------------------------

/// Render the full Prometheus exposition for a single-engine serve:
/// one unlabelled scope from the engine's stats + telemetry snapshot,
/// plus the replication families (zero-filled when `repl` is absent —
/// the family set never depends on the deployment shape).
pub fn render_metrics_engine(engine: &UpdateEngine, repl: Option<&ReplSnapshot>) -> String {
    let stats = engine.stats();
    let tel = engine.telemetry().snapshot();
    expo::render(&[Scope { tenant: None, stats: &stats, tel: Some(&tel) }], repl)
}

/// Render the exposition for a multi-tenant serve: one
/// `tenant`-labelled scope per live tenant (name-sorted), each with
/// its own engine stats and telemetry snapshot.
pub fn render_metrics_tenants(reg: &TenantRegistry, repl: Option<&ReplSnapshot>) -> String {
    let handles = reg.handles();
    let stats: Vec<EngineStats> = handles.iter().map(|h| h.engine().stats()).collect();
    let tels: Vec<TelemetrySnapshot> =
        handles.iter().map(|h| h.engine().telemetry().snapshot()).collect();
    let scopes: Vec<Scope<'_>> = handles
        .iter()
        .zip(stats.iter().zip(&tels))
        .map(|(h, (s, t))| {
            let spec = h.spec();
            Scope {
                tenant: Some(TenantMeta {
                    name: spec.name.clone(),
                    rows: spec.rows,
                    q: spec.q,
                    quota_rows: spec.quota_rows,
                }),
                stats: s,
                tel: Some(t),
            }
        })
        .collect();
    expo::render(&scopes, repl)
}

/// Render one tenant's scope (tenant-labelled) — the bound-session
/// arm of the `METRICS` verb.
fn render_metrics_handle(h: &TenantHandle, repl: Option<&ReplSnapshot>) -> String {
    let stats = h.engine().stats();
    let tel = h.engine().telemetry().snapshot();
    let spec = h.spec();
    expo::render(
        &[Scope {
            tenant: Some(TenantMeta {
                name: spec.name.clone(),
                rows: spec.rows,
                q: spec.q,
                quota_rows: spec.quota_rows,
            }),
            stats: &stats,
            tel: Some(&tel),
        }],
        repl,
    )
}

/// The `GET /metrics` renderer for a single-engine serve, as the
/// closure [`MetricsServer::start`] wants. Holds the engine (and
/// optional repl stats) alive until [`MetricsServer::stop`] drops it —
/// which is why the observed serve transports stop the metrics server
/// before their final `finish`.
pub fn metrics_render_engine(
    engine: Arc<UpdateEngine>,
    repl: Option<Arc<ReplStats>>,
) -> MetricsRender {
    Arc::new(move || {
        let snap = repl.as_ref().map(|r| r.snapshot());
        render_metrics_engine(&engine, snap.as_ref())
    })
}

/// The `GET /metrics` renderer for a `--tenants` serve.
pub fn metrics_render_tenants(reg: Arc<TenantRegistry>) -> MetricsRender {
    Arc::new(move || render_metrics_tenants(&reg, None))
}

// ---------------------------------------------------------------------------
// Stats client (`fast stats --connect`)
// ---------------------------------------------------------------------------

/// One scrape over the wire: connect, send `METRICS`, read the
/// exposition through its `# EOF` terminator, parse it.
fn scrape_metrics(addr: &str) -> Result<expo::Scrape> {
    let stream = connect_with_retry(addr, Duration::from_secs(10))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut out = stream;
    writeln!(out, "METRICS").context("sending METRICS")?;
    let mut text = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).context("reading METRICS reply")?;
        ensure!(n > 0, "server closed the connection mid-exposition");
        text.push_str(&line);
        if line.trim_end() == "# EOF" {
            break;
        }
    }
    let _ = writeln!(out, "QUIT");
    expo::parse_text(&text)
}

/// `fast stats --connect HOST:PORT [--watch]`: scrape the `METRICS`
/// verb and render the load-bearing families as a table. A single
/// shot reports cumulative totals plus the server's own rate window;
/// `--watch` re-scrapes every `interval` (`count` times) and renders
/// the scrape-to-scrape deltas as live rates.
pub fn run_stats_client(
    addr: &str,
    watch: bool,
    interval: Duration,
    count: usize,
) -> Result<()> {
    let iterations = if watch { count.max(2) } else { 1 };
    let mut prev: Option<(Instant, expo::Scrape)> = None;
    for i in 0..iterations {
        if i > 0 {
            std::thread::sleep(interval);
        }
        let at = Instant::now();
        let scrape = scrape_metrics(addr)?;
        let mut rows: Vec<(String, String)> = Vec::new();
        let t = |name: &str| scrape.total(name);
        rows.push(("completed".into(), format!("{:.0}", t("fast_requests_completed_total"))));
        rows.push(("submitted".into(), format!("{:.0}", t("fast_requests_submitted_total"))));
        rows.push(("rejected".into(), format!("{:.0}", t("fast_requests_rejected_total"))));
        rows.push(("batches".into(), format!("{:.0}", t("fast_batches_sealed_total"))));
        rows.push(("queue depth".into(), format!("{:.0}", t("fast_queue_depth"))));
        rows.push(("wal bytes".into(), format!("{:.0}", t("fast_wal_bytes_total"))));
        rows.push(("repl lag (lsn)".into(), format!("{:.0}", t("fast_repl_lag_lsn"))));
        rows.push(("spans sampled".into(), format!("{:.0}", t("fast_spans_sampled_total"))));
        match &prev {
            Some((t0, p)) => {
                let dt = at.duration_since(*t0).as_secs_f64();
                if dt > 0.0 {
                    let rate =
                        |name: &str| (scrape.total(name) - p.total(name)).max(0.0) / dt;
                    rows.push((
                        "ops/s (delta)".into(),
                        format!("{:.0}", rate("fast_requests_completed_total")),
                    ));
                    rows.push((
                        "wal B/s (delta)".into(),
                        format!("{:.0}", rate("fast_wal_bytes_total")),
                    ));
                    rows.push((
                        "batches/s (delta)".into(),
                        format!("{:.1}", rate("fast_batches_sealed_total")),
                    ));
                }
            }
            None => {
                // First scrape: fall back to the server's own rate
                // window (the telemetry series).
                rows.push(("ops/s (server)".into(), format!("{:.0}", t("fast_ops_per_sec"))));
                rows.push((
                    "wal B/s (server)".into(),
                    format!("{:.0}", t("fast_wal_bytes_per_sec")),
                ));
            }
        }
        print!("{}", crate::metrics::render_table(&format!("fast stats @ {addr}"), &rows));
        prev = Some((at, scrape));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::trace::uniform_trace;
    use crate::coordinator::{EngineConfig, FastBackend, ShardPlan};
    use crate::util::json::Json;

    fn engine(rows: usize, q: usize, shards: usize) -> Arc<UpdateEngine> {
        let cfg = EngineConfig::sharded(rows, q, shards);
        Arc::new(
            UpdateEngine::start(cfg, |p: &ShardPlan| {
                Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
            })
            .unwrap(),
        )
    }

    fn reply(s: &mut Session, line: &str) -> String {
        match s.handle(line) {
            Action::Reply(r) => r,
            other => panic!("expected Reply, got {other:?}"),
        }
    }

    #[test]
    fn session_speaks_the_protocol() {
        let e = engine(64, 8, 2);
        let mut s = Session::new(Arc::clone(&e));
        let banner = reply(&mut s, "HELLO");
        assert!(banner.starts_with("OK fast-serve-v1 rows=64 q=8 shards=2"), "{banner}");

        // CMT is the default: an update line answers with its commit.
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":7}");
        assert!(r.starts_with("OK shard=1 seq="), "{r}");
        assert_eq!(reply(&mut s, "READ 3"), "OK 7");

        // SUB mode acks on admission.
        assert_eq!(reply(&mut s, "MODE SUB"), "OK mode=SUB");
        assert_eq!(reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":1}"), "OK");
        // Barrier drains both shards and reports their seqs.
        let r = reply(&mut s, "{\"t\":\"f\"}");
        assert!(r.starts_with("OK drained seq="), "{r}");
        assert_eq!(reply(&mut s, "READ 3"), "OK 8");

        // Writes, waits, digests, stats.
        assert_eq!(reply(&mut s, "{\"t\":\"w\",\"r\":0,\"v\":200}"), "OK");
        let r = reply(&mut s, "WAIT 1 1");
        assert!(r.starts_with("OK "), "{r}");
        let r = reply(&mut s, "DIGEST");
        assert!(r.len() == 3 + 16, "{r}");
        let r = reply(&mut s, "STATS");
        let json = Json::parse(r.strip_prefix("OK ").unwrap()).unwrap();
        assert_eq!(json.get("backend").and_then(Json::as_str), Some("fast-behavioural"));

        // Errors keep the session alive, one line per request.
        assert!(reply(&mut s, "BOGUS").starts_with("ERR "));
        assert!(reply(&mut s, "READ 9999").starts_with("ERR "));
        assert!(reply(&mut s, "{\"t\":\"u\",\"o\":\"nand\",\"r\":0,\"v\":1}").starts_with("ERR "));
        assert_eq!(reply(&mut s, "READ 3"), "OK 8");

        match s.handle("QUIT") {
            Action::Quit(r) => assert_eq!(r, "OK bye"),
            other => panic!("{other:?}"),
        }
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn qry_round_trips_and_malformed_lines_get_typed_errors() {
        let e = engine(64, 8, 2);
        let mut s = Session::new(Arc::clone(&e));
        reply(&mut s, "{\"t\":\"w\",\"r\":3,\"v\":7}");
        reply(&mut s, "{\"t\":\"w\",\"r\":10,\"v\":200}");

        // One reply line per QRY; the value matches a hand computation.
        let r = reply(&mut s, "QRY sum");
        assert!(r.starts_with("OK qry sum value=207 "), "{r}");
        assert!(r.contains(" rows=64 ") && r.contains(" banks="), "{r}");
        // Two shards → two comma-joined observed commit seqs.
        let seqs = r.split(" seq=").nth(1).unwrap();
        assert_eq!(seqs.split(',').count(), 2, "{r}");

        assert!(reply(&mut s, "QRY popcount").contains(" value=6 "));
        assert!(reply(&mut s, "QRY max").contains(" value=200 "));
        assert!(reply(&mut s, "QRY range 1 100").contains(" value=1 "));
        // A 100% mask enables every row: same sum as unmasked.
        assert!(reply(&mut s, "QRY sum mask 5 100").contains(" value=207 "));

        // Malformed queries answer a single typed ERR line — the
        // session stays alive, it never hangs or dies.
        for bad in [
            "QRY",
            "QRY median",
            "QRY range 9",
            "QRY range a b",
            "QRY dot",
            "QRY sum mask 1",
            "QRY sum mask 1 200",
            "QRY sum trailing",
        ] {
            let r = reply(&mut s, bad);
            assert!(r.starts_with("ERR "), "{bad:?} -> {r}");
        }
        assert_eq!(reply(&mut s, "READ 3"), "OK 7");

        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn tcp_loopback_client_matches_reference_digest() {
        let trace = uniform_trace(64, 8, 600, 23);
        let want = format!("{:016x}", state_digest(&trace.reference_state()));

        let cfg = EngineConfig::sharded(64, 8, 2);
        let eng = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve_tcp(eng, listener));

        // The client also runs a masked reduction; `run_client` checks
        // the answer against a host-side scalar oracle over the
        // trace's reference state and fails hard on mismatch.
        let report = run_client(
            &addr,
            Some(&trace),
            Mode::Cmt,
            true,
            Some("range 1 200 mask 5 50"),
            None,
            true,
        )
        .unwrap();
        assert_eq!(report.digest.as_deref(), Some(want.as_str()));
        assert_eq!(report.acked, trace.events.len() as u64);
        assert!(report.query_value.is_some());

        let served = server.join().unwrap().unwrap();
        assert_eq!(served.stats.completed, trace.updates() as u64);
        assert_eq!(served.drained_seq.len(), 2);
        assert!(served.stats.shards.iter().any(|s| s.commit_wall.count > 0));
    }

    #[test]
    fn tcp_sub_mode_and_second_client_shutdown() {
        let trace = uniform_trace(32, 8, 200, 5);
        let want = format!("{:016x}", state_digest(&trace.reference_state()));

        let cfg = EngineConfig::sharded(32, 8, 1);
        let eng = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve_tcp(eng, listener));

        // First client streams in SUB mode and quits without shutdown.
        let first = run_client(&addr, Some(&trace), Mode::Sub, true, None, None, false).unwrap();
        assert_eq!(first.digest.as_deref(), Some(want.as_str()));
        // Second client connects afterwards and shuts the server down.
        let second = run_client(&addr, None, Mode::Cmt, true, None, None, true).unwrap();
        assert_eq!(second.digest.as_deref(), Some(want.as_str()));

        let served = server.join().unwrap().unwrap();
        assert_eq!(served.stats.completed, trace.updates() as u64);
    }

    #[test]
    fn waiting_client_cannot_block_shutdown() {
        use std::io::{BufRead, BufReader, Write};
        let cfg = EngineConfig::sharded(32, 8, 1);
        let eng = UpdateEngine::start(cfg, |p: &ShardPlan| {
            Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
        })
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve_tcp(eng, listener));

        // Client A parks in a WAIT for a seq that will never commit.
        let mut a = TcpStream::connect(&addr).unwrap();
        writeln!(a, "WAIT 0 999").unwrap();
        std::thread::sleep(Duration::from_millis(50));

        // Client B shuts the server down; the join must not deadlock
        // on A's blocked session thread.
        run_client(&addr, None, Mode::Cmt, false, None, None, true).unwrap();
        let report = server.join().unwrap().unwrap();
        assert_eq!(report.stats.completed, 0);

        // A's wait was aborted with the TYPED shutdown error (or the
        // socket closed); either way it did not hang the server, and
        // any reply A got is machine-classifiable as a shutdown abort.
        let mut reply = String::new();
        let n = BufReader::new(&mut a).read_line(&mut reply).unwrap_or(0);
        if n > 0 {
            assert!(reply.starts_with("ERR shutdown"), "{reply}");
        }
    }

    #[test]
    fn blocked_wait_aborts_typed_and_fast_when_the_stop_flag_rises() {
        // Regression: SHUTDOWN during an in-flight WAIT/CMT used to
        // ride out the 30 s lone-session cap. With a server stop flag
        // the abort must be typed (`ERR shutdown …`) and land within a
        // few WAIT_POLL intervals, not the cap.
        let e = engine(32, 8, 1);
        let stop = Arc::new(AtomicBool::new(false));
        let mut s = Session::with_stop(Arc::clone(&e), Arc::clone(&stop));
        let flipper = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(100));
                stop.store(true, Ordering::SeqCst);
            })
        };
        let started = Instant::now();
        let r = reply(&mut s, "WAIT 0 999");
        let waited = started.elapsed();
        flipper.join().unwrap();
        assert!(r.starts_with("ERR shutdown"), "{r}");
        assert!(
            waited < Duration::from_secs(5),
            "typed shutdown abort took {waited:?} (should be ~one WAIT_POLL)"
        );
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn digest_crc_line_speaks_crc32() {
        let e = engine(16, 8, 1);
        let mut s = Session::new(Arc::clone(&e));
        reply(&mut s, "{\"t\":\"w\",\"r\":0,\"v\":171}");
        reply(&mut s, "{\"t\":\"w\",\"r\":3,\"v\":5}");
        let r = reply(&mut s, "DIGEST CRC");
        let hex = r.strip_prefix("OK ").unwrap();
        assert_eq!(hex.len(), 8, "{r}");
        // Independent computation over the LE state bytes.
        let mut state = vec![0u32; 16];
        state[0] = 171;
        state[3] = 5;
        let mut bytes = Vec::new();
        for w in &state {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(
            u32::from_str_radix(hex, 16).unwrap(),
            crate::util::crc32::crc32(&bytes)
        );
        // lowercase arg works, junk arg errors.
        assert!(reply(&mut s, "DIGEST crc").starts_with("OK "));
        assert!(reply(&mut s, "DIGEST nope").starts_with("ERR "));
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    /// A scripted fake server: replies `banner` to HELLO, "OK" to
    /// MODE, and the scripted answer to everything else.
    fn fake_server(answers: Vec<(&'static str, &'static str)>) -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            let mut line = String::new();
            while reader.read_line(&mut line).unwrap_or(0) > 0 {
                let req = line.trim().to_string();
                line.clear();
                let reply = if req == "HELLO" {
                    format!("OK {PROTOCOL} rows=8 q=8 shards=1 backend=fake")
                } else if req.starts_with("MODE") {
                    "OK mode".to_string()
                } else {
                    answers
                        .iter()
                        .find(|(prefix, _)| req.starts_with(prefix))
                        .map(|(_, r)| r.to_string())
                        .unwrap_or_else(|| "OK".to_string())
                };
                if writeln!(out, "{reply}").is_err() {
                    break;
                }
            }
        });
        addr
    }

    #[test]
    fn client_fails_hard_when_the_digest_line_is_missing() {
        // The CI loopback job pipes the client's stdout into a diff;
        // an ERR on DIGEST must exit nonzero, never print nothing and
        // succeed.
        let addr = fake_server(vec![("DIGEST", "ERR no digest for you")]);
        let err = run_client(&addr, None, Mode::Cmt, true, None, None, false).unwrap_err();
        assert!(format!("{err:#}").contains("DIGEST failed"), "{err:#}");
    }

    #[test]
    fn client_fails_hard_on_terminal_err_mid_stream() {
        // Terminal (non-busy) ERR on an event line: fail fast, do not
        // retry, exit nonzero.
        let addr = fake_server(vec![("{", "ERR shard 0 is down")]);
        let trace = uniform_trace(8, 8, 10, 3);
        let err = run_client(&addr, Some(&trace), Mode::Cmt, false, None, None, false).unwrap_err();
        assert!(format!("{err:#}").contains("rejected"), "{err:#}");
    }

    #[test]
    fn client_fails_hard_on_malformed_digest() {
        let addr = fake_server(vec![("DIGEST", "OK not-a-digest!!")]);
        let err = run_client(&addr, None, Mode::Cmt, true, None, None, false).unwrap_err();
        assert!(format!("{err:#}").contains("malformed digest"), "{err:#}");
    }

    #[test]
    fn client_fails_hard_on_query_oracle_mismatch() {
        // A server answering the wrong reduction value must make
        // `fast client --query … --expect …` exit nonzero.
        let addr = fake_server(vec![(
            "QRY",
            "OK qry sum value=999 rows=8 cycles=8 toggles=0 alu=0 \
             banks=1 energy_fj=0.000 ns=0.000 seq=0",
        )]);
        let err =
            run_client(&addr, None, Mode::Cmt, false, Some("sum"), Some(42), false).unwrap_err();
        assert!(format!("{err:#}").contains("query mismatch"), "{err:#}");

        // An ERR reply to the QRY line is also terminal.
        let addr = fake_server(vec![("QRY", "ERR queries are off today")]);
        let err =
            run_client(&addr, None, Mode::Cmt, false, Some("sum"), None, false).unwrap_err();
        assert!(format!("{err:#}").contains("QRY failed"), "{err:#}");
    }

    /// A fake server that answers the first `busy_count` event lines
    /// with `ERR busy …` and everything after with OK — the stateful
    /// counterpart of [`fake_server`] for retry-policy tests.
    fn busy_then_ok_server(busy_count: usize) -> String {
        use std::sync::atomic::AtomicUsize;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            let busy_left = AtomicUsize::new(busy_count);
            let mut line = String::new();
            while reader.read_line(&mut line).unwrap_or(0) > 0 {
                let req = line.trim().to_string();
                line.clear();
                let reply = if req == "HELLO" {
                    format!("OK {PROTOCOL} rows=8 q=8 shards=1 backend=fake")
                } else if req.starts_with('{')
                    && busy_left
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
                        .is_ok()
                {
                    "ERR busy queue full on shard 0".to_string()
                } else {
                    "OK".to_string()
                };
                if writeln!(out, "{reply}").is_err() {
                    break;
                }
            }
        });
        addr
    }

    #[test]
    fn client_retries_busy_with_bounded_backoff_then_succeeds() {
        // Three ERR busy replies, then OK: the default policy retries
        // through them and reports exactly three backpressure retries.
        let addr = busy_then_ok_server(3);
        let trace = uniform_trace(8, 8, 2, 11);
        let retry = ClientRetry { retries: 10, backoff_us: 50 };
        let report =
            run_client_retry(&addr, None, Some(&trace), Mode::Sub, false, None, None, false, retry)
                .unwrap();
        assert_eq!(report.busy_retries, 3);
        assert_eq!(report.acked, trace.events.len() as u64);
    }

    #[test]
    fn client_busy_retry_budget_is_a_hard_bound() {
        // More consecutive busys than the budget: fail hard with an
        // actionable message instead of spinning for a million tries.
        let addr = busy_then_ok_server(usize::MAX);
        let trace = uniform_trace(8, 8, 2, 11);
        let retry = ClientRetry { retries: 2, backoff_us: 50 };
        let err =
            run_client_retry(&addr, None, Some(&trace), Mode::Sub, false, None, None, false, retry)
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("still busy after 2 retries"), "{msg}");
        assert!(msg.contains("--retries"), "{msg}");
    }

    #[test]
    fn readonly_engine_answers_typed_err_readonly_and_promote_needs_a_follower() {
        // A read-only engine (the state a follower serves in) rejects
        // every mutation line with a typed `ERR readonly …`, keeps
        // serving reads, and refuses PROMOTE when no follower handle
        // is attached (a primary, or a bare read-only engine).
        let mut cfg = EngineConfig::sharded(32, 8, 1);
        cfg.read_only = true;
        let e = Arc::new(
            UpdateEngine::start(cfg, |p: &ShardPlan| {
                Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
            })
            .unwrap(),
        );
        let mut s = Session::new(Arc::clone(&e));
        for (line, label) in [
            ("{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":7}", "CMT update"),
            ("{\"t\":\"w\",\"r\":0,\"v\":17}", "write"),
        ] {
            let r = reply(&mut s, line);
            assert!(r.starts_with("ERR readonly"), "{label}: {r}");
        }
        assert_eq!(reply(&mut s, "MODE SUB"), "OK mode=SUB");
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":7}");
        assert!(r.starts_with("ERR readonly"), "SUB update: {r}");
        assert_eq!(reply(&mut s, "READ 3"), "OK 0");
        let r = reply(&mut s, "PROMOTE");
        assert!(r.starts_with("ERR ") && r.contains("--follower"), "{r}");
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn stats_json_with_repl_splices_role_and_lag_without_breaking_the_schema() {
        use crate::replication::ReplStats;
        let e = engine(32, 8, 2);
        let stats = ReplStats::new("follower", 2);
        stats.record_applied(0, 5);
        stats.record_primary_tail(0, 9);
        let snap = stats.snapshot();
        let text = stats_json_with_repl(&e.stats(), Some(&snap));
        let json = Json::parse(&text).unwrap();
        // Pre-existing keys survive the splice…
        assert!(json.get("tickets_resolved").and_then(Json::as_usize).is_some());
        assert!(json.get("wal_records").and_then(Json::as_usize).is_some());
        // …and the replication block parses with per-shard lag.
        assert_eq!(json.get("role").and_then(Json::as_str), Some("follower"));
        let repl = json.get("repl").unwrap();
        assert_eq!(repl.get("epoch").and_then(Json::as_usize), Some(0));
        let shards = repl.get("shards").and_then(Json::as_arr).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("applied_lsn").and_then(Json::as_usize), Some(5));
        assert_eq!(shards[0].get("lag_lsn").and_then(Json::as_usize), Some(4));
        // Without a repl role the output is byte-identical to the
        // legacy schema.
        assert_eq!(stats_json_with_repl(&e.stats(), None), stats_json(&e.stats()));
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn busy_classification_distinguishes_backpressure_from_terminal_errors() {
        // Only EngineBusy (queue full) is retryable; terminal errors
        // (bad row, shut-down engine) must NOT classify as busy, so
        // clients fail fast instead of spinning on retries.
        assert!(is_busy(&anyhow::Error::new(EngineBusy)));
        let e = engine(32, 8, 1);
        let err = e
            .submit(crate::coordinator::UpdateRequest::add(999, 1))
            .unwrap_err();
        assert!(!is_busy(&err), "row-range error is terminal: {err:#}");
        drop(Session::new(Arc::clone(&e)));
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn stats_json_is_parseable_and_carries_commit_histograms() {
        let e = engine(64, 8, 2);
        let mut s = Session::new(Arc::clone(&e));
        reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":1,\"v\":3}");
        assert!(reply(&mut s, "QRY popcount").starts_with("OK qry "));
        let text = stats_json(&e.stats());
        let json = Json::parse(&text).unwrap();
        assert_eq!(json.get("tickets_resolved").and_then(Json::as_usize), Some(1));
        // One engine query fans out to both shard workers.
        assert_eq!(json.get("queries").and_then(Json::as_usize), Some(2));
        let shards = json.get("shards").and_then(Json::as_arr).unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].get("queries").and_then(Json::as_usize), Some(1));
        assert!(shards[0]
            .get("query_wall_ns")
            .and_then(|l| l.get("count"))
            .and_then(Json::as_usize)
            .is_some());
        assert!(shards[1]
            .get("commit_wall_ns")
            .and_then(|l| l.get("p95_ns"))
            .and_then(Json::as_usize)
            .is_some());
        // WAL counters are always present (0 on a volatile engine).
        assert_eq!(json.get("wal_records").and_then(Json::as_usize), Some(0));
        assert_eq!(shards[0].get("wal_fsyncs").and_then(Json::as_usize), Some(0));
        assert!(shards[0]
            .get("wal_fsync_ns")
            .and_then(|l| l.get("p99_ns"))
            .and_then(Json::as_usize)
            .is_some());
        // Contention and coalescing counters: the CI perf-smoke job
        // greps these keys, so their presence IS the contract.
        for key in ["submit_spins", "park_events", "wal_coalesced_writes", "wal_coalesced_frames"]
        {
            assert!(json.get(key).and_then(Json::as_usize).is_some(), "missing {key}");
            assert!(shards[0].get(key).and_then(Json::as_usize).is_some(), "missing shard {key}");
        }
        // One ticketed commit resolved → exactly one wake-batch sample
        // somewhere; the histogram's "ns" fields carry waiter counts.
        let wakes: usize = shards
            .iter()
            .map(|sc| {
                sc.get("wake_batch")
                    .and_then(|l| l.get("count"))
                    .and_then(Json::as_usize)
                    .expect("wake_batch histogram present")
            })
            .sum();
        assert_eq!(wakes, 1);
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    fn registry(specs: &[(&str, usize, usize)]) -> Arc<TenantRegistry> {
        let reg = TenantRegistry::volatile(|spec: &TenantSpec| {
            let cfg = EngineConfig::new(spec.rows, spec.q);
            UpdateEngine::start(cfg, |p: &ShardPlan| {
                Ok(Box::new(FastBackend::with_rows(p.rows, p.q)))
            })
        });
        for (name, rows, q) in specs {
            reg.create(TenantSpec::new(name, *rows, *q).unwrap()).unwrap();
        }
        Arc::new(reg)
    }

    fn shutdown_registry(reg: Arc<TenantRegistry>) {
        Arc::try_unwrap(reg)
            .unwrap_or_else(|_| panic!("sole registry owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn tenant_sessions_create_use_route_and_drop_over_the_protocol() {
        let reg = registry(&[]);
        let mut s = Session::new_with(ServeTarget::Tenants(Arc::clone(&reg)));

        // Unbound session: banner announces the registry; engine verbs
        // need a binding first.
        assert_eq!(reply(&mut s, "HELLO"), "OK fast-serve-v1 tenants=0 bind=TENANT-USE");
        assert!(reply(&mut s, "READ 0").contains("TENANT USE"), "unbound READ must say how");

        // Create two tenants of different precision over the wire.
        assert_eq!(
            reply(&mut s, "TENANT CREATE db 64 4"),
            "OK created tenant=db rows=64 q=4 quota=64"
        );
        assert_eq!(
            reply(&mut s, "TENANT CREATE nn 32 16 8"),
            "OK created tenant=nn rows=32 q=16 quota=8"
        );
        assert_eq!(reply(&mut s, "TENANT LIST"), "OK tenants=2 db:64:4:64 nn:32:16:8");
        assert!(reply(&mut s, "TENANT CREATE db 8 8").starts_with("ERR "), "dup name");
        assert!(reply(&mut s, "TENANT CREATE x 8 5").starts_with("ERR "), "bad q");

        // Bind and speak the normal protocol against the tenant.
        assert_eq!(reply(&mut s, "TENANT USE db"), "OK tenant=db rows=64 q=4 quota=64");
        let banner = reply(&mut s, "HELLO");
        assert!(banner.starts_with("OK fast-serve-v1 rows=64 q=4 "), "{banner}");
        assert!(banner.ends_with(" tenant=db"), "{banner}");
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":7}");
        assert!(r.starts_with("OK shard="), "{r}");
        assert_eq!(reply(&mut s, "READ 3"), "OK 7");
        // Value validation uses the bound tenant's q (4 bits), not a
        // global default.
        assert!(reply(&mut s, "{\"t\":\"w\",\"r\":0,\"v\":16}").starts_with("ERR "), "q=4 mask");

        // A per-line tenant field overrides the binding — and its
        // value validates against THAT tenant's q (16 bits).
        let r = reply(&mut s, "{\"t\":\"w\",\"r\":3,\"v\":60000,\"tenant\":\"nn\"}");
        assert_eq!(r, "OK");
        assert_eq!(reply(&mut s, "READ 3"), "OK 7", "db row untouched by nn write");
        assert!(reply(&mut s, "QRY tenant=nn sum").contains(" value=60000 "), "scoped QRY");
        assert!(reply(&mut s, "QRY sum").contains(" value=7 "), "bound QRY");

        // Per-tenant digests differ; both are well-formed.
        let d_db = reply(&mut s, "DIGEST");
        assert_eq!(d_db.len(), 3 + 16, "{d_db}");

        // Unbound STATS answers the registry-wide JSON.
        s.tenant = None;
        let r = reply(&mut s, "STATS");
        let json = Json::parse(r.strip_prefix("OK ").unwrap()).unwrap();
        let tenants = json.get("tenants").and_then(Json::as_arr).unwrap();
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants[0].get("name").and_then(Json::as_str), Some("db"));
        assert_eq!(tenants[0].get("q").and_then(Json::as_usize), Some(4));
        assert!(tenants[0].get("stats").and_then(|s| s.get("submitted")).is_some());

        // Dropping the bound tenant clears the binding; the survivor
        // keeps its state.
        assert_eq!(reply(&mut s, "TENANT USE db"), "OK tenant=db rows=64 q=4 quota=64");
        assert_eq!(reply(&mut s, "TENANT DROP db"), "OK dropped tenant=db");
        assert!(reply(&mut s, "READ 0").contains("TENANT USE"), "binding cleared");
        assert_eq!(reply(&mut s, "TENANT USE nn"), "OK tenant=nn rows=32 q=16 quota=8");
        assert_eq!(reply(&mut s, "READ 3"), "OK 60000");

        drop(s);
        shutdown_registry(reg);
    }

    #[test]
    fn quota_and_badfield_are_typed_and_keep_the_session_alive() {
        let reg = registry(&[]);
        let mut s = Session::new_with(ServeTarget::Tenants(Arc::clone(&reg)));
        assert_eq!(
            reply(&mut s, "TENANT CREATE t 64 8 16"),
            "OK created tenant=t rows=64 q=8 quota=16"
        );
        assert_eq!(reply(&mut s, "TENANT USE t"), "OK tenant=t rows=64 q=8 quota=16");

        // In-quota rows work in both modes; over-quota rows answer the
        // typed `ERR quota` prefix and the session stays usable.
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":15,\"v\":1}");
        assert!(r.starts_with("OK shard="), "{r}");
        for line in [
            "{\"t\":\"u\",\"o\":\"add\",\"r\":16,\"v\":1}",
            "{\"t\":\"w\",\"r\":63,\"v\":1}",
        ] {
            let r = reply(&mut s, line);
            assert!(r.starts_with("ERR quota "), "{line} -> {r}");
        }
        assert_eq!(reply(&mut s, "MODE SUB"), "OK mode=SUB");
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":40,\"v\":1}");
        assert!(r.starts_with("ERR quota "), "SUB over-quota: {r}");
        assert_eq!(reply(&mut s, "READ 15"), "OK 1", "session survives quota rejections");

        // Unknown fields answer the typed `ERR badfield` prefix.
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":0,\"v\":1,\"nonce\":9}");
        assert!(r.starts_with("ERR badfield "), "{r}");
        drop(s);
        shutdown_registry(reg);

        // On a single-engine serve the `tenant` field itself is out of
        // grammar — the forward-compatibility contract: an old server
        // rejects it loudly instead of applying the line to the wrong
        // row space.
        let e = engine(16, 8, 1);
        let mut s = Session::new(Arc::clone(&e));
        let r = reply(&mut s, "{\"t\":\"w\",\"r\":0,\"v\":1,\"tenant\":\"a\"}");
        assert!(r.starts_with("ERR badfield "), "{r}");
        assert!(r.contains("tenant"), "{r}");
        assert!(reply(&mut s, "TENANT LIST").contains("--tenants"), "typed TENANT refusal");
        assert_eq!(reply(&mut s, "READ 0"), "OK 0", "row 0 untouched");
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn tcp_tenant_clients_stream_disjoint_traces_and_digests_match() {
        let trace_a = uniform_trace(64, 8, 300, 31);
        let trace_b = uniform_trace(32, 8, 200, 32);
        let want_a = format!("{:016x}", state_digest(&trace_a.reference_state()));
        let want_b = format!("{:016x}", state_digest(&trace_b.reference_state()));

        let reg = registry(&[("a", 64, 8), ("b", 32, 8)]);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || serve_tcp_tenants(reg, listener));

        let retry = ClientRetry::default();
        let ra = run_client_retry(
            &addr, Some("a"), Some(&trace_a), Mode::Cmt, true, Some("sum"), None, false, retry,
        )
        .unwrap();
        assert_eq!(ra.digest.as_deref(), Some(want_a.as_str()));
        let rb = run_client_retry(
            &addr, Some("b"), Some(&trace_b), Mode::Sub, true, None, None, true, retry,
        )
        .unwrap();
        assert_eq!(rb.digest.as_deref(), Some(want_b.as_str()));

        let report = server.join().unwrap().unwrap();
        assert_eq!(report.tenants.len(), 2);
        assert_eq!(report.tenants[0].0.name, "a");
        assert_eq!(report.tenants[0].1.completed, trace_a.updates() as u64);
        assert_eq!(report.tenants[1].0.name, "b");
        assert_eq!(report.tenants[1].1.completed, trace_b.updates() as u64);
    }

    #[test]
    fn metrics_verb_exposes_every_documented_family() {
        let e = engine(64, 8, 2);
        let mut s = Session::new(Arc::clone(&e));
        for row in 0..16 {
            let r = reply(&mut s, &format!("{{\"t\":\"u\",\"o\":\"add\",\"r\":{row},\"v\":1}}"));
            assert!(r.starts_with("OK shard="), "{r}");
        }
        reply(&mut s, "{\"t\":\"f\"}");

        let text = reply(&mut s, "METRICS");
        assert!(text.trim_end().ends_with("# EOF"), "exposition must end with # EOF");
        let scrape = expo::parse_text(&text).unwrap();
        for family in expo::DOCUMENTED_FAMILIES {
            assert!(scrape.has_family(family), "missing documented family {family}");
        }
        assert!(
            scrape.total("fast_requests_completed_total") >= 16.0,
            "completed counter must reflect the session's traffic"
        );
        // No repl attached: the lag gauge is present but zero-valued.
        assert_eq!(scrape.total("fast_repl_lag_lsn"), 0.0);
        drop(s);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }

    #[test]
    fn metrics_verb_labels_tenant_scopes() {
        let reg = registry(&[("db", 64, 8), ("nn", 32, 8)]);
        let mut s = Session::new_with(ServeTarget::Tenants(Arc::clone(&reg)));
        reply(&mut s, "TENANT USE db");
        let r = reply(&mut s, "{\"t\":\"u\",\"o\":\"add\",\"r\":3,\"v\":7}");
        assert!(r.starts_with("OK shard="), "{r}");
        reply(&mut s, "{\"t\":\"f\"}");

        // Bound session: one unlabelled-equivalent scope for the bound
        // tenant still carries its tenant label.
        let bound = expo::parse_text(&reply(&mut s, "METRICS")).unwrap();
        assert!(
            bound.value("fast_requests_completed_total", &[("tenant", "db")]).is_some(),
            "bound METRICS must label its scope with the tenant"
        );

        // Unbound session: every tenant appears as a labelled scope,
        // and the tenant-spec families join the exposition.
        let mut unbound = Session::new_with(ServeTarget::Tenants(Arc::clone(&reg)));
        let scrape = expo::parse_text(&reply(&mut unbound, "METRICS")).unwrap();
        for family in expo::TENANT_FAMILIES {
            assert!(scrape.has_family(family), "missing tenant family {family}");
        }
        assert_eq!(
            scrape.value("fast_requests_completed_total", &[("tenant", "db")]),
            Some(1.0)
        );
        assert_eq!(
            scrape.value("fast_requests_completed_total", &[("tenant", "nn")]),
            Some(0.0)
        );
        assert_eq!(scrape.value("fast_tenant_rows", &[("tenant", "nn")]), Some(32.0));
        drop(s);
        drop(unbound);
        shutdown_registry(reg);
    }

    #[test]
    fn stats_schema_tag_is_the_first_key_of_every_stats_object() {
        let e = engine(32, 8, 1);
        let single = stats_json(&e.stats());
        assert!(
            single.starts_with("{\"schema\":\"fast-stats-v1\","),
            "schema tag must lead the single-engine object: {}",
            &single[..60.min(single.len())]
        );
        let reg = registry(&[("db", 32, 8)]);
        let wrapper = stats_json_tenants(&reg.stats());
        assert!(
            wrapper.starts_with("{\"schema\":\"fast-stats-v1\",\"tenants\":["),
            "schema tag must lead the tenants wrapper: {}",
            &wrapper[..60.min(wrapper.len())]
        );
        // The embedded per-tenant stats objects are themselves tagged.
        let json = Json::parse(&wrapper).unwrap();
        let tenants = json.get("tenants").and_then(Json::as_arr).unwrap();
        assert_eq!(
            tenants[0].get("stats").and_then(|s| s.get("schema")).and_then(Json::as_str),
            Some("fast-stats-v1")
        );
        shutdown_registry(reg);
        Arc::try_unwrap(e)
            .unwrap_or_else(|_| panic!("sole owner"))
            .shutdown()
            .unwrap();
    }
}
