//! Measured-performance harnesses behind `fast bench`: the shard
//! scaling grid (`fast bench engine` / `cargo bench --bench
//! shard_scaling` → `BENCH_shard_scaling.json`) and the telemetry
//! overhead A/B (`fast bench telemetry` →
//! `BENCH_telemetry_overhead.json`).
//!
//! ## What it measures
//!
//! A seeded open-loop producer grid: every (producers × shards) cell
//! starts a fresh engine, replays pre-generated per-producer update
//! streams through `submit_many` chunks, and reports
//!
//! - end-to-end throughput (ops/s over the submit+drain wall),
//! - submit-path wall latency per chunk (p50/p95/p99 — the number the
//!   lock-free admission ring is supposed to move),
//! - the engine's contention counters (`submit_spins`, `park_events`,
//!   wake-batch histogram) so a regression shows up in the JSON
//!   without a profiler.
//!
//! Streams are pre-generated from a fixed seed, so every cell sees an
//! identical offered load and run-to-run diffs are measurement noise,
//! not workload noise.
//!
//! ## The contract
//!
//! `BENCH_shard_scaling.json` at the repo root says
//! `"status": "measured"` only when this harness actually ran — the
//! committed placeholder says `pending-measurement`, and CI's
//! perf-smoke job fails if it still does after running the harness.
//! The scaling acceptance (8-shard ≥ 3× 1-shard throughput at 8
//! producers) is *recorded*, and only judged on hosts with enough
//! parallelism for the question to be meaningful.

use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::{EngineConfig, FastBackend, UpdateEngine, UpdateRequest};
use crate::metrics::LatencySummary;
use crate::util::rng::Rng;
use crate::util::stats::LatencyHistogram;
use crate::Result;

/// Grid shape and offered load for one harness run.
#[derive(Debug, Clone)]
pub struct GridConfig {
    pub rows: usize,
    pub q: usize,
    /// Producer-thread counts to sweep (outer grid axis).
    pub producer_counts: Vec<usize>,
    /// Engine shard counts to sweep (inner grid axis).
    pub shard_counts: Vec<usize>,
    /// Updates each producer submits per cell.
    pub updates_per_producer: usize,
    /// `submit_many` chunk size (one submit-wall sample per chunk).
    pub chunk: usize,
    /// Seed for the pre-generated streams.
    pub seed: u64,
    /// Smoke mode (reduced load, `FAST_BENCH_SMOKE=1`).
    pub smoke: bool,
}

impl GridConfig {
    /// The standard 1/2/4/8 × 1/2/4/8 grid; `FAST_BENCH_SMOKE=1` (any
    /// value but "0") shrinks the offered load for CI smoke runs.
    pub fn standard() -> GridConfig {
        let smoke = std::env::var("FAST_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
        GridConfig {
            rows: 1024,
            q: 16,
            producer_counts: vec![1, 2, 4, 8],
            shard_counts: vec![1, 2, 4, 8],
            updates_per_producer: if smoke { 5_000 } else { 50_000 },
            chunk: 512,
            seed: 7700,
            smoke,
        }
    }
}

/// One (producers × shards) cell's measurements.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub producers: usize,
    pub shards: usize,
    pub wall_ms: f64,
    pub ops_per_sec: f64,
    pub batches: u64,
    pub rows_per_batch: f64,
    /// Per-chunk `submit_many` wall latency.
    pub submit_wall: LatencySummary,
    pub submit_spins: u64,
    pub park_events: u64,
    /// Wake-batch histogram: count = seals that woke ≥ 1 ticket,
    /// mean = waiters woken per such seal.
    pub wake_batch_count: u64,
    pub wake_batch_mean: f64,
    pub rejected: u64,
}

/// A full grid run plus the environment it ran in.
#[derive(Debug, Clone)]
pub struct GridReport {
    pub cfg: GridConfig,
    pub host_parallelism: usize,
    pub cells: Vec<CellResult>,
}

/// Run one cell: fresh engine, pre-generated streams, blocking
/// `submit_many` chunks with one submit-wall sample per chunk.
fn run_cell(cfg: &GridConfig, producers: usize, shards: usize) -> Result<CellResult> {
    let mut ecfg = EngineConfig::sharded(cfg.rows, cfg.q, shards);
    ecfg.seal_deadline = Duration::from_micros(200);
    ecfg.queue_cap = 16_384;
    let engine = UpdateEngine::start(ecfg, |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })?;

    let streams: Vec<Vec<UpdateRequest>> = (0..producers)
        .map(|t| {
            let mut rng = Rng::new(cfg.seed + t as u64);
            (0..cfg.updates_per_producer)
                .map(|_| {
                    UpdateRequest::add(
                        rng.below(cfg.rows as u64) as usize,
                        1 + rng.below(99) as u32,
                    )
                })
                .collect()
        })
        .collect();

    let submit_hist = Mutex::new(LatencyHistogram::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            let submit_hist = &submit_hist;
            scope.spawn(move || {
                let mut local = LatencyHistogram::new();
                for chunk in stream.chunks(cfg.chunk) {
                    let c0 = Instant::now();
                    engine.submit_many(chunk.to_vec()).expect("bench submit");
                    local.record(c0.elapsed().as_nanos() as u64);
                }
                submit_hist.lock().expect("bench hist").merge(&local);
            });
        }
    });
    engine.drain_all()?;
    let wall = t0.elapsed();

    let s = engine.stats();
    let total = (producers * cfg.updates_per_producer) as u64;
    anyhow::ensure!(s.completed == total, "offered {total}, completed {}", s.completed);
    let hist = submit_hist.into_inner().expect("bench hist");
    let wake_count: u64 = s.shards.iter().map(|sc| sc.wake_batch.count).sum();
    let wake_sum: f64 = s
        .shards
        .iter()
        .map(|sc| sc.wake_batch.mean_ns * sc.wake_batch.count as f64)
        .sum();
    let out = CellResult {
        producers,
        shards,
        wall_ms: wall.as_secs_f64() * 1e3,
        ops_per_sec: total as f64 / wall.as_secs_f64(),
        batches: s.batches,
        rows_per_batch: s.rows_per_batch,
        submit_wall: LatencySummary {
            count: hist.count(),
            mean_ns: hist.mean_ns(),
            p50_ns: hist.percentile_ns(50.0),
            p95_ns: hist.percentile_ns(95.0),
            p99_ns: hist.percentile_ns(99.0),
            max_ns: hist.max_ns(),
        },
        submit_spins: s.submit_spins,
        park_events: s.park_events,
        wake_batch_count: wake_count,
        wake_batch_mean: if wake_count > 0 { wake_sum / wake_count as f64 } else { 0.0 },
        rejected: s.rejected,
    };
    engine.shutdown()?;
    Ok(out)
}

/// Run the full grid. Each cell gets one unmeasured warm-up pass in
/// full mode (skipped in smoke mode — CI wants the wall clock, not the
/// precision).
pub fn run_engine_grid(cfg: &GridConfig) -> Result<GridReport> {
    let host_parallelism =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut cells = Vec::new();
    for &producers in &cfg.producer_counts {
        for &shards in &cfg.shard_counts {
            if !cfg.smoke {
                let _ = run_cell(cfg, producers, shards)?;
            }
            cells.push(run_cell(cfg, producers, shards)?);
        }
    }
    Ok(GridReport { cfg: cfg.clone(), host_parallelism, cells })
}

impl GridReport {
    fn cell(&self, producers: usize, shards: usize) -> Option<&CellResult> {
        self.cells.iter().find(|c| c.producers == producers && c.shards == shards)
    }

    /// The scaling acceptance: at 8 producers, 8-shard throughput /
    /// 1-shard throughput. `None` when the grid lacks those cells.
    pub fn scaling_ratio(&self) -> Option<f64> {
        let one = self.cell(8, 1)?.ops_per_sec;
        let eight = self.cell(8, 8)?.ops_per_sec;
        (one > 0.0).then(|| eight / one)
    }

    /// Whether the acceptance is judgeable here: a smoke run measures
    /// wiring (not performance), and a host without 8-way parallelism
    /// cannot exhibit 8-shard scaling.
    pub fn acceptance_judgeable(&self) -> bool {
        !self.cfg.smoke && self.host_parallelism >= 8 && self.scaling_ratio().is_some()
    }

    /// Human-readable table, one line per cell.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "engine grid: {} rows x {} bits, {} updates/producer, chunk {}, seed {} \
             (host parallelism {}{})\n",
            self.cfg.rows,
            self.cfg.q,
            self.cfg.updates_per_producer,
            self.cfg.chunk,
            self.cfg.seed,
            self.host_parallelism,
            if self.cfg.smoke { ", smoke" } else { "" },
        ));
        for c in &self.cells {
            out.push_str(&format!(
                "p{} x s{}: {:>9.1} ms | {:>11.0} ops/s | submit p50/p95/p99 \
                 {}/{}/{} ns | spins {} parks {} | wake-batch {:.1} avg\n",
                c.producers,
                c.shards,
                c.wall_ms,
                c.ops_per_sec,
                c.submit_wall.p50_ns,
                c.submit_wall.p95_ns,
                c.submit_wall.p99_ns,
                c.submit_spins,
                c.park_events,
                c.wake_batch_mean,
            ));
        }
        match (self.scaling_ratio(), self.acceptance_judgeable()) {
            (Some(r), true) => out.push_str(&format!(
                "acceptance: 8-shard/1-shard at 8 producers = {r:.2}x (target >= 3x) -> {}\n",
                if r >= 3.0 { "PASS" } else { "FAIL" }
            )),
            (Some(r), false) => out.push_str(&format!(
                "acceptance: ratio {r:.2}x recorded, not judged \
                 (smoke mode or < 8-way host)\n"
            )),
            (None, _) => out.push_str("acceptance: grid lacks the 8x1 / 8x8 cells\n"),
        }
        out
    }

    /// The `BENCH_shard_scaling.json` document. `"status": "measured"`
    /// is the contract CI greps for — only a real run produces it.
    pub fn render_json(&self) -> String {
        let mut cells = String::new();
        for c in &self.cells {
            if !cells.is_empty() {
                cells.push_str(",\n");
            }
            cells.push_str(&format!(
                "    {{\"producers\": {}, \"shards\": {}, \"wall_ms\": {:.3}, \
                 \"ops_per_sec\": {:.0}, \"batches\": {}, \"rows_per_batch\": {:.2}, \
                 \"submit_wall_ns\": {{\"count\": {}, \"mean\": {:.1}, \"p50\": {}, \
                 \"p95\": {}, \"p99\": {}, \"max\": {}}}, \
                 \"submit_spins\": {}, \"park_events\": {}, \
                 \"wake_batch\": {{\"count\": {}, \"mean_waiters\": {:.2}}}, \
                 \"rejected\": {}}}",
                c.producers,
                c.shards,
                c.wall_ms,
                c.ops_per_sec,
                c.batches,
                c.rows_per_batch,
                c.submit_wall.count,
                c.submit_wall.mean_ns,
                c.submit_wall.p50_ns,
                c.submit_wall.p95_ns,
                c.submit_wall.p99_ns,
                c.submit_wall.max_ns,
                c.submit_spins,
                c.park_events,
                c.wake_batch_count,
                c.wake_batch_mean,
                c.rejected,
            ));
        }
        let (ratio, pass) = match (self.scaling_ratio(), self.acceptance_judgeable()) {
            (Some(r), true) => (format!("{r:.3}"), (r >= 3.0).to_string()),
            (Some(r), false) => (format!("{r:.3}"), "null".to_string()),
            (None, _) => ("null".to_string(), "null".to_string()),
        };
        format!(
            "{{\n  \"bench\": \"shard_scaling\",\n  \"status\": \"measured\",\n  \
             \"mode\": \"{}\",\n  \"rows\": {},\n  \"q\": {},\n  \
             \"updates_per_producer\": {},\n  \"chunk\": {},\n  \"seed\": {},\n  \
             \"host_parallelism\": {},\n  \"cells\": [\n{cells}\n  ],\n  \
             \"acceptance\": {{\"criterion\": \"ops_per_sec(8 producers, 8 shards) >= \
             3x ops_per_sec(8 producers, 1 shard)\", \"ratio\": {ratio}, \
             \"pass\": {pass}}}\n}}\n",
            if self.cfg.smoke { "smoke" } else { "full" },
            self.cfg.rows,
            self.cfg.q,
            self.cfg.updates_per_producer,
            self.cfg.chunk,
            self.cfg.seed,
            self.host_parallelism,
        )
    }

    /// Write the JSON document to `path`.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        use anyhow::Context;
        std::fs::write(path, self.render_json())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Telemetry overhead: the always-on claim, measured
// ---------------------------------------------------------------------------

/// Shape and load for the telemetry-overhead A/B run
/// (`fast bench telemetry` → `BENCH_telemetry_overhead.json`): one
/// representative contended cell run twice — telemetry on at the
/// default sample rate, then hard-disabled — under the identical
/// seeded offered load.
#[derive(Debug, Clone)]
pub struct OverheadConfig {
    pub rows: usize,
    pub q: usize,
    pub producers: usize,
    pub shards: usize,
    pub updates_per_producer: usize,
    pub chunk: usize,
    pub seed: u64,
    /// Sample rate for the tracing-on leg (power of two).
    pub sample_rate: u64,
    pub smoke: bool,
}

impl OverheadConfig {
    /// The shipped A/B cell: 4 producers × 4 shards — enough
    /// contention that a lock or allocation on the submit path would
    /// show up — at the default 1-in-64 sample rate.
    /// `FAST_BENCH_SMOKE=1` shrinks the load for CI smoke runs.
    pub fn standard() -> OverheadConfig {
        let smoke = std::env::var("FAST_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
        OverheadConfig {
            rows: 1024,
            q: 16,
            producers: 4,
            shards: 4,
            updates_per_producer: if smoke { 10_000 } else { 200_000 },
            chunk: 512,
            seed: 7701,
            sample_rate: 64,
            smoke,
        }
    }
}

/// One leg (tracing on or off) of the A/B run.
#[derive(Debug, Clone)]
pub struct OverheadLeg {
    pub enabled: bool,
    pub wall_ms: f64,
    pub ops_per_sec: f64,
    /// Per-chunk `submit_many` wall latency.
    pub submit_wall: LatencySummary,
    pub spans_sampled: u64,
    pub spans_dropped: u64,
}

/// The A/B result: identical offered load, telemetry on vs off.
#[derive(Debug, Clone)]
pub struct OverheadReport {
    pub cfg: OverheadConfig,
    pub host_parallelism: usize,
    pub on: OverheadLeg,
    pub off: OverheadLeg,
}

fn run_overhead_leg(cfg: &OverheadConfig, enabled: bool) -> Result<OverheadLeg> {
    let mut ecfg = EngineConfig::sharded(cfg.rows, cfg.q, cfg.shards);
    ecfg.seal_deadline = Duration::from_micros(200);
    ecfg.queue_cap = 16_384;
    ecfg.telemetry.enabled = enabled;
    ecfg.telemetry.sample_rate = cfg.sample_rate;
    let engine = UpdateEngine::start(ecfg, |plan| {
        Ok(Box::new(FastBackend::with_rows(plan.rows, plan.q)))
    })?;

    let streams: Vec<Vec<UpdateRequest>> = (0..cfg.producers)
        .map(|t| {
            let mut rng = Rng::new(cfg.seed + t as u64);
            (0..cfg.updates_per_producer)
                .map(|_| {
                    UpdateRequest::add(
                        rng.below(cfg.rows as u64) as usize,
                        1 + rng.below(99) as u32,
                    )
                })
                .collect()
        })
        .collect();

    let submit_hist = Mutex::new(LatencyHistogram::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for stream in &streams {
            let engine = &engine;
            let submit_hist = &submit_hist;
            scope.spawn(move || {
                let mut local = LatencyHistogram::new();
                for chunk in stream.chunks(cfg.chunk) {
                    let c0 = Instant::now();
                    engine.submit_many(chunk.to_vec()).expect("bench submit");
                    local.record(c0.elapsed().as_nanos() as u64);
                }
                submit_hist.lock().expect("bench hist").merge(&local);
            });
        }
    });
    engine.drain_all()?;
    let wall = t0.elapsed();

    let s = engine.stats();
    let total = (cfg.producers * cfg.updates_per_producer) as u64;
    anyhow::ensure!(s.completed == total, "offered {total}, completed {}", s.completed);
    let tel = engine.telemetry().snapshot();
    let hist = submit_hist.into_inner().expect("bench hist");
    let out = OverheadLeg {
        enabled,
        wall_ms: wall.as_secs_f64() * 1e3,
        ops_per_sec: total as f64 / wall.as_secs_f64(),
        submit_wall: LatencySummary {
            count: hist.count(),
            mean_ns: hist.mean_ns(),
            p50_ns: hist.percentile_ns(50.0),
            p95_ns: hist.percentile_ns(95.0),
            p99_ns: hist.percentile_ns(99.0),
            max_ns: hist.max_ns(),
        },
        spans_sampled: tel.spans_sampled,
        spans_dropped: tel.spans_dropped,
    };
    engine.shutdown()?;
    Ok(out)
}

/// Run the A/B: tracing-on first, then tracing-off, identical streams.
/// Full mode gives each leg one unmeasured warm-up pass.
pub fn run_telemetry_overhead(cfg: &OverheadConfig) -> Result<OverheadReport> {
    let host_parallelism =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if !cfg.smoke {
        let _ = run_overhead_leg(cfg, true)?;
        let _ = run_overhead_leg(cfg, false)?;
    }
    let on = run_overhead_leg(cfg, true)?;
    let off = run_overhead_leg(cfg, false)?;
    Ok(OverheadReport { cfg: cfg.clone(), host_parallelism, on, off })
}

impl OverheadReport {
    /// Tracing-on throughput as a fraction of tracing-off: 1.0 = free,
    /// 0.95 = tracing costs 5% of throughput.
    pub fn on_off_ratio(&self) -> f64 {
        if self.off.ops_per_sec > 0.0 { self.on.ops_per_sec / self.off.ops_per_sec } else { 0.0 }
    }

    /// Whether the ≤ budget claim is judgeable here (a smoke run
    /// measures wiring, not performance).
    pub fn judgeable(&self) -> bool {
        !self.cfg.smoke && self.host_parallelism >= self.cfg.producers + self.cfg.shards
    }

    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "telemetry overhead: {} producers x {} shards, {} updates/producer, \
             sample 1/{} (host parallelism {}{})\n",
            self.cfg.producers,
            self.cfg.shards,
            self.cfg.updates_per_producer,
            self.cfg.sample_rate,
            self.host_parallelism,
            if self.cfg.smoke { ", smoke" } else { "" },
        ));
        for leg in [&self.on, &self.off] {
            out.push_str(&format!(
                "tracing {}: {:>9.1} ms | {:>11.0} ops/s | submit p50/p99 {}/{} ns \
                 | {} span(s) sampled, {} dropped\n",
                if leg.enabled { "on " } else { "off" },
                leg.wall_ms,
                leg.ops_per_sec,
                leg.submit_wall.p50_ns,
                leg.submit_wall.p99_ns,
                leg.spans_sampled,
                leg.spans_dropped,
            ));
        }
        out.push_str(&format!(
            "on/off throughput ratio: {:.3}{}\n",
            self.on_off_ratio(),
            if self.judgeable() { "" } else { " (recorded, not judged: smoke or small host)" }
        ));
        out
    }

    /// The `BENCH_telemetry_overhead.json` document. `"status":
    /// "measured"` is the CI grep contract — only a real run says it.
    pub fn render_json(&self) -> String {
        let leg = |l: &OverheadLeg| {
            format!(
                "{{\"enabled\": {}, \"wall_ms\": {:.3}, \"ops_per_sec\": {:.0}, \
                 \"submit_wall_ns\": {{\"count\": {}, \"mean\": {:.1}, \"p50\": {}, \
                 \"p95\": {}, \"p99\": {}, \"max\": {}}}, \
                 \"spans_sampled\": {}, \"spans_dropped\": {}}}",
                l.enabled,
                l.wall_ms,
                l.ops_per_sec,
                l.submit_wall.count,
                l.submit_wall.mean_ns,
                l.submit_wall.p50_ns,
                l.submit_wall.p95_ns,
                l.submit_wall.p99_ns,
                l.submit_wall.max_ns,
                l.spans_sampled,
                l.spans_dropped,
            )
        };
        format!(
            "{{\n  \"bench\": \"telemetry_overhead\",\n  \"status\": \"measured\",\n  \
             \"mode\": \"{}\",\n  \"rows\": {},\n  \"q\": {},\n  \"producers\": {},\n  \
             \"shards\": {},\n  \"updates_per_producer\": {},\n  \"chunk\": {},\n  \
             \"seed\": {},\n  \"sample_rate\": {},\n  \"host_parallelism\": {},\n  \
             \"tracing_on\": {},\n  \"tracing_off\": {},\n  \
             \"acceptance\": {{\"criterion\": \"ops_per_sec(tracing on) >= \
             0.95x ops_per_sec(tracing off)\", \"on_off_ratio\": {:.4}, \"pass\": {}}}\n}}\n",
            if self.cfg.smoke { "smoke" } else { "full" },
            self.cfg.rows,
            self.cfg.q,
            self.cfg.producers,
            self.cfg.shards,
            self.cfg.updates_per_producer,
            self.cfg.chunk,
            self.cfg.seed,
            self.cfg.sample_rate,
            self.host_parallelism,
            leg(&self.on),
            leg(&self.off),
            self.on_off_ratio(),
            if self.judgeable() { (self.on_off_ratio() >= 0.95).to_string() } else { "null".to_string() },
        )
    }

    /// Write the JSON document to `path`.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        use anyhow::Context;
        std::fs::write(path, self.render_json())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> GridConfig {
        GridConfig {
            rows: 64,
            q: 8,
            producer_counts: vec![1, 2],
            shard_counts: vec![1, 2],
            updates_per_producer: 400,
            chunk: 64,
            seed: 9,
            smoke: true,
        }
    }

    #[test]
    fn grid_runs_and_reports_every_cell() {
        let rep = run_engine_grid(&tiny_cfg()).unwrap();
        assert_eq!(rep.cells.len(), 4);
        for c in &rep.cells {
            assert!(c.ops_per_sec > 0.0);
            assert_eq!(c.rejected, 0, "blocking submits never reject");
            assert!(c.submit_wall.count > 0);
            assert!(c.submit_wall.p99_ns >= c.submit_wall.p50_ns);
        }
    }

    #[test]
    fn json_carries_the_measured_contract_and_percentiles() {
        use crate::util::json::Json;
        let rep = run_engine_grid(&tiny_cfg()).unwrap();
        let text = rep.render_json();
        assert!(
            text.contains("\"status\": \"measured\""),
            "the exact status spelling is the CI grep contract"
        );
        let j = Json::parse(&text).unwrap();
        let cells = j.get("cells").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 4);
        for c in cells {
            for key in ["producers", "shards", "submit_spins", "park_events"] {
                assert!(c.get(key).and_then(Json::as_usize).is_some(), "missing {key}");
            }
            let sw = c.get("submit_wall_ns").unwrap();
            for key in ["p50", "p95", "p99"] {
                assert!(sw.get(key).and_then(Json::as_usize).is_some(), "missing {key}");
            }
            assert!(c.get("ops_per_sec").and_then(Json::as_f64).is_some());
        }
        // Small grid: acceptance must be recorded as unjudgeable, not
        // silently passed.
        let acc = j.get("acceptance").unwrap();
        assert!(acc.get("ratio").is_some());
        // Deterministic seed: two renders of the same report agree.
        assert_eq!(text, rep.render_json());
    }

    fn tiny_overhead_cfg() -> OverheadConfig {
        OverheadConfig {
            rows: 64,
            q: 8,
            producers: 2,
            shards: 2,
            updates_per_producer: 400,
            chunk: 64,
            seed: 11,
            sample_rate: 4,
            smoke: true,
        }
    }

    #[test]
    fn overhead_ab_runs_both_legs_under_identical_load() {
        let rep = run_telemetry_overhead(&tiny_overhead_cfg()).unwrap();
        assert!(rep.on.enabled && !rep.off.enabled);
        assert!(rep.on.ops_per_sec > 0.0 && rep.off.ops_per_sec > 0.0);
        assert!(rep.on.spans_sampled > 0, "rate 1/4 over 800 updates must sample spans");
        assert_eq!(rep.off.spans_sampled, 0, "the off leg must not sample at all");
        assert!(rep.on_off_ratio() > 0.0);
        assert!(!rep.judgeable(), "smoke mode is never judgeable");
    }

    #[test]
    fn overhead_json_carries_the_measured_contract() {
        use crate::util::json::Json;
        let rep = run_telemetry_overhead(&tiny_overhead_cfg()).unwrap();
        let text = rep.render_json();
        assert!(
            text.contains("\"status\": \"measured\""),
            "the exact status spelling is the CI grep contract"
        );
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").and_then(Json::as_str), Some("telemetry_overhead"));
        for key in ["tracing_on", "tracing_off"] {
            let leg = j.get(key).unwrap();
            assert!(leg.get("ops_per_sec").and_then(Json::as_f64).is_some());
            assert!(leg.get("spans_sampled").and_then(Json::as_usize).is_some());
            assert!(
                leg.get("submit_wall_ns").and_then(|s| s.get("p99")).is_some(),
                "submit percentiles must survive serialization"
            );
        }
        let acc = j.get("acceptance").unwrap();
        assert!(acc.get("on_off_ratio").and_then(Json::as_f64).is_some());
        // Smoke runs record the ratio but never judge it.
        assert!(acc.get("pass").is_some());
    }
}
