//! Minimal command-line argument parser (clap is not in the offline
//! vendor set — DESIGN.md §7). Supports `cmd --flag value --switch
//! positional` style with typed accessors and a usage renderer.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::Result;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// `--key value` pairs; bare `--switch` maps to "true".
    pub flags: BTreeMap<String, String>,
    /// Remaining positional tokens after the command.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I, S>(tokens: I) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = tokens.into_iter().map(Into::into).peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                // --key=value or --key value or --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

/// Usage text for the `fast` binary.
pub fn usage() -> String {
    "\
fast — FAST SRAM reproduction CLI (TCAS-II 2022)

USAGE: fast <command> [--flags]

experiment commands (regenerate the paper's tables/figures):
  table1       [--rows 128] [--q 16]      Table I comparison
  fig10                                   energy/latency vs bit width
  fig11                                   latency + efficiency vs rows
  fig12        [--samples 500] [--seed 42] Monte Carlo noise margin
  fig13                                   shmoo plot (VDD x freq)
  fig14        [--rows 128] [--cols 16]   area breakdown
  waveforms    [--period 1.25] [--csv dir] Figs. 7-8 transients
  apps         [--rows 128] [--q 16] [--updates 20000]
                                          workload comparison (E-APP)

system commands:
  serve        [--rows 1024] [--q 16] [--banks 8] [--updates 100000]
               [--backend fast|digital|xla]
               [--fidelity phase|word|bitplane]
                                       model tier for --backend fast: phase-accurate,
                                       word-fast (default), or bit-plane (bit-sliced,
                                       64 rows per machine word)
               [--shards 1]            worker shards (power of two; rows % shards == 0)
               [--seal-deadline-us 100] group-commit deadline for open batches
               [--seal-rows N]         size seal: batch seals at N touched rows
               run the update engine demo
  validate     [--artifacts artifacts] [--trials 3]
               cross-check XLA artifacts vs host semantics
  info         [--artifacts artifacts]   list loaded artifacts
  help                                   this text
"
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_command_flags_positional() {
        // Note: a bare `--switch` followed by a non-flag token consumes
        // it as a value (schema-less parsing) — put switches last or
        // use `--switch=true`.
        let a = Args::parse(["serve", "--rows", "256", "extra", "--verbose"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 256);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(["x", "--q=32"]).unwrap();
        assert_eq!(a.get_usize("q", 0).unwrap(), 32);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(["cmd"]).unwrap();
        assert_eq!(a.get_usize("rows", 128).unwrap(), 128);
        assert_eq!(a.get_f64("period", 1.25).unwrap(), 1.25);
        assert_eq!(a.get_str("backend", "fast"), "fast");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(["cmd", "--rows", "abc"]).unwrap();
        assert!(a.get_usize("rows", 1).is_err());
    }

    #[test]
    fn switch_at_end() {
        let a = Args::parse(["cmd", "--fast"]).unwrap();
        assert!(a.get_bool("fast"));
    }
}
