//! Minimal command-line argument parser (clap is not in the offline
//! vendor set — DESIGN.md §7). Supports `cmd --flag value --switch
//! positional` style with typed accessors and a usage renderer.

use std::collections::BTreeMap;

use anyhow::anyhow;

use crate::Result;

/// Parsed command line.
///
/// Semantics (schema-less, so fully deterministic from the tokens):
/// the first non-flag token is the subcommand, later non-flag tokens
/// are positional; `--key=value` and `--key value` set flags (a `=` in
/// the value survives: only the first `=` splits); a bare `--switch`
/// maps to `"true"` unless the next token is a non-flag, which it
/// consumes as its value — put switches last or use `--switch=true`;
/// a repeated flag keeps the **last** value; a bare `--` ends flag
/// parsing — every later token is treated as a plain operand even if
/// it starts with `--`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Args {
    /// First non-flag token (the subcommand).
    pub command: Option<String>,
    /// `--key value` pairs; bare `--switch` maps to "true".
    pub flags: BTreeMap<String, String>,
    /// Remaining positional tokens after the command.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]). Never
    /// fails — the grammar above covers every token sequence — but
    /// stays `Result` so typed accessors and callers share one shape.
    pub fn parse<I, S>(tokens: I) -> Result<Args>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let mut it = tokens.into_iter().map(Into::into).peekable();
        let mut operands_only = false;
        let operand = |out: &mut Args, tok: String| {
            if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        };
        while let Some(tok) = it.next() {
            if operands_only {
                operand(&mut out, tok);
                continue;
            }
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    // Bare `--`: conventional end-of-flags terminator.
                    operands_only = true;
                    continue;
                }
                // --key=value or --key value or --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                operand(&mut out, tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Resolve a renamed flag: prefer the `new` spelling, fall back to
    /// the deprecated `old` one. The second field reports how the old
    /// spelling was used, so callers can emit a one-line deprecation
    /// warning (see `--flush-us` → `--seal-deadline-us` on
    /// `fast serve`).
    pub fn get_renamed(&self, new: &str, old: &str) -> (Option<&str>, RenamedUse) {
        let new_v = self.get(new);
        let old_v = self.get(old);
        match (new_v, old_v) {
            (Some(v), Some(_)) => (Some(v), RenamedUse::Both),
            (Some(v), None) => (Some(v), RenamedUse::NewOnly),
            (None, Some(v)) => (Some(v), RenamedUse::LegacyOnly),
            (None, None) => (None, RenamedUse::Neither),
        }
    }
}

/// How a renamed flag pair was spelled on the command line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RenamedUse {
    Neither,
    NewOnly,
    /// Only the deprecated spelling appeared (warn, honour it).
    LegacyOnly,
    /// Both appeared: the new spelling wins (warn about the loser).
    Both,
}

impl RenamedUse {
    /// Should the caller print a deprecation warning?
    pub fn deprecated(self) -> bool {
        matches!(self, RenamedUse::LegacyOnly | RenamedUse::Both)
    }
}

/// Usage text for the `fast` binary.
pub fn usage() -> String {
    "\
fast — FAST SRAM reproduction CLI (TCAS-II 2022)

USAGE: fast <command> [--flags]

experiment commands (regenerate the paper's tables/figures):
  table1       [--rows 128] [--q 16]      Table I comparison
  fig10                                   energy/latency vs bit width
  fig11                                   latency + efficiency vs rows
  fig12        [--samples 500] [--seed 42] Monte Carlo noise margin
  fig13                                   shmoo plot (VDD x freq)
  fig14        [--rows 128] [--cols 16]   area breakdown
  waveforms    [--period 1.25] [--csv dir] Figs. 7-8 transients
  apps         [--rows 128] [--q 16] [--updates 20000]
                                          workload comparison (E-APP)
  train        [--rows 128] [--q 8] [--epochs 2] [--steps 4] [--shards 1]
               [--seed 30311] [--density 1.0] [--no-assert]
                                       VGG-7-shaped 8-bit weight-update task on
                                       FAST vs the digital baseline through the
                                       same coordinator; asserts the paper-anchored
                                       bars (speed >= 50x, energy >= 3x) unless
                                       --no-assert

system commands:
  serve        [--listen 127.0.0.1:4750 | --stdio] [--stats-json]
               [--rows 1024] [--q 16] [--banks 8]
               [--backend fast|digital|xla]
               [--fidelity phase|word|bitplane]
                                       model tier for --backend fast: phase-accurate,
                                       word-fast (default), or bit-plane (bit-sliced,
                                       64 rows per machine word)
               [--shards 1]            worker shards (power of two; rows % shards == 0)
               [--tenants]             multi-tenant mode: host any number of
                                       named tenants (column families), each an
                                       isolated row space with its own
                                       precision q in {4,8,16}, row quota,
                                       engine, and — durable mode — WAL
                                       subdirectory <wal-dir>/tenants/<name>/
                                       (the registry manifest tenants.json
                                       lives in the root; every tenant is
                                       recovered before connections). Sessions
                                       administer and bind with TENANT
                                       CREATE/USE/DROP/LIST, event lines may
                                       route via a "tenant" field, QRY scopes
                                       with tenant=<name>, over-quota rows
                                       answer retryable ERR quota, unknown
                                       event fields answer ERR badfield, and
                                       --stats-json reports per-tenant
                                       counters and latency histograms
               [--seal-deadline-us 100] group-commit deadline for open batches
                                       (--flush-us is the deprecated spelling; kept
                                       as an alias, --seal-deadline-us wins)
               [--seal-rows N]         size seal: batch seals at N touched rows
               [--wal-dir DIR]         durable mode: recover DIR (snapshot +
                                       per-shard WAL tail, torn tails repaired)
                                       BEFORE accepting connections, then log
                                       every commit/write, one coalesced fsync
                                       per group-commit seal
               [--fsync always|interval|off]  when WAL records hit disk
                                       (default interval; needs --wal-dir)
               [--fsync-interval-us 2000]     coalescing window for interval
               [--wal-segment-bytes 4194304]  segment rotation threshold
               [--repl-listen HOST:PORT] primary role: ship sealed WAL frames
                                       (fast-repl-v1) to any number of
                                       followers; needs --wal-dir
               [--metrics-listen HOST:PORT] telemetry endpoint: serve the
                                       Prometheus text exposition on
                                       GET /metrics (every counter,
                                       per-stage span latency histograms,
                                       rate gauges; one labelled scope per
                                       tenant under --tenants); the same
                                       text answers the METRICS verb on the
                                       line protocol (needs the TCP serve)
               [--follower HOST:PORT]  follower role: stream the primary's
                                       WAL, apply through recovery onto a
                                       live engine, serve reads at the
                                       applied watermark, answer writes with
                                       ERR readonly until promoted; resumes
                                       from its own --wal-dir (required)
                                       after a restart, reconnects with
                                       capped backoff, and FAIL-STOPS (exit
                                       nonzero) if digests show divergence
               run the fast-serve-v1 front-end: a line protocol speaking
               fast-trace-v1 events over TCP (multi-client) or stdio, with
               per-connection MODE SUB (fire-and-forget) / MODE CMT
               (wait-for-ticket: replies carry shard, commit_seq, seal
               reason, modeled ns), READ/WAIT/DRAIN/DIGEST [CRC]/QRY/STATS
               (QRY runs an in-array reduction sequenced against the
               commit stream — grammar under `fast query`),
               ERR-busy backpressure, and a clean per-shard drain on
               SHUTDOWN; --stats-json includes WAL counters and fsync
               latency histograms when durable
  client       --connect HOST:PORT [--in TRACE] [--mode sub|cmt]
               [--tenant NAME]         bind the session to a tenant of a
                                       --tenants serve before streaming (the
                                       trace, digest and query are scoped to it)
               [--digest] [--query \"SPEC\"] [--expect N] [--shutdown]
               [--retries 1000] [--backoff-us 200]
               drive a running `fast serve`: stream a recorded trace through
               the protocol, print the final state digest, optionally shut
               the server down; ERR busy backpressure is retried up to
               --retries times per line with jittered exponential backoff
               from --backoff-us (capped at 100 ms); exits nonzero on any
               terminal (non-busy) ERR — including ERR readonly from a
               follower — or when the requested digest never arrives;
               --query runs a QRY reduction after the stream and verifies
               the answer against --expect (or, with --in, against a
               host-side scalar oracle over the trace), exiting nonzero on
               mismatch
  tenant       create NAME [--rows 128] [--q 8] [--quota ROWS]
               drop NAME | list
               with --connect HOST:PORT: administer a live
               `fast serve --tenants` over the wire; with --wal-dir DIR:
               operate offline on a registry root (the engine flags above
               apply; offline mode takes each tenant's single-writer lock,
               so a live serve on the same root blocks it); drop deletes
               the tenant's WAL subdirectory — drop + create is the
               resize/reprecision path
  stats        --connect HOST:PORT [--watch] [--interval-ms 1000] [--count N]
               scrape a live serve's METRICS verb and render the headline
               counters (completed, rejected, batches, queue depth, WAL
               bytes, repl lag, sampled spans) as a table; --watch
               re-scrapes every --interval-ms and reports scrape-to-scrape
               deltas as live rates (ops/s, WAL B/s, batches/s), --count
               bounds the number of scrapes for scripted runs
  promote      --connect HOST:PORT    tell a follower serve to stop
                                       replicating, fence a new epoch, and
                                       accept writes (failover); prints the
                                       fenced epoch
  query        SPEC [--in TRACE | --updates 5000 --seed 66] [--verify]
               [--rows 1024] [--q 16] [--banks 8] [--shards 1]
               [--backend fast|digital|xla] [--fidelity phase|word|bitplane]
               stream a workload into the engine, then run one in-array
               reduction over the committed state and print its value with
               the plane-wise cost accounting (shift cycles, cell toggles,
               ALU evaluations, modeled energy/latency, observed per-shard
               commit seqs); SPEC is
                 popcount | sum | min | max | range LO HI | dot SEED
               with an optional trailing `mask SEED PCT` row-lane mask;
               --verify re-runs the reduction on a host-side scalar oracle
               over the workload's reference state and exits nonzero on any
               value or accounting divergence
  bench        engine [--out PATH]     measured-performance grid: seeded
                                       open-loop load, 1/2/4/8 producers x
                                       1/2/4/8 shards, ops/s + submit-wall
                                       p50/p95/p99 + contention counters,
                                       written to BENCH_shard_scaling.json
                                       with status=measured
                                       (FAST_BENCH_SMOKE=1 shrinks the load)
               telemetry [--out PATH]  telemetry-overhead A/B: one contended
                                       cell run tracing-on (sample 1/64)
                                       then tracing-off under identical
                                       seeded load; ops/s for each leg and
                                       the on/off ratio written to
                                       BENCH_telemetry_overhead.json
  wal          inspect --dir DIR       summarize a WAL directory (segments,
                                       per-shard commit_seq/lsn watermarks,
                                       snapshot, recovered-state digest,
                                       per-segment coalescing stats)
               verify --dir DIR [--digest-only]
                                       read-only integrity check: exits
                                       nonzero if records are unreachable
                                       beyond a bad frame (a torn final
                                       tail is a note, not an error)
               compact --dir DIR       write a full-state snapshot, then
                                       delete the segments (and older
                                       snapshots) it covers (takes the
                                       dir's single-writer lock, so a
                                       live serve blocks it)
               repair --dir DIR        destructive: truncate at the first
                                       bad frame ANYWHERE and drop the
                                       segments it strands — explicit
                                       data-loss acceptance for mid-log
                                       corruption a durable engine start
                                       refuses to repair silently
               export --dir DIR --out FILE [--name wal-export]
                                       convert the WAL to a fast-trace-v1
                                       trace whose replay reproduces the
                                       recovered state bit for bit
                                       (`fast trace replay --digest-only`
                                       independently audits recovery)
  trace record --out FILE [--workload vgg7|uniform] [--rows 128] [--q 8]
               vgg7 (default): the train flags apply — [--epochs 2]
                 [--steps 4] [--density 1.0] [--seed 30311]
               uniform: [--updates 5000] [--seed 66]
                                       record a deterministic workload trace
  trace replay --in FILE [--backend fast|bitplane|digital]
               [--fidelity phase|word|bitplane] [--shards 1] [--verify]
               [--digest-only]         print just the final-state digest
                                       replay a trace bit-identically onto any
                                       backend / fidelity / shard configuration
  validate     [--artifacts artifacts] [--trials 3]
               cross-check XLA artifacts vs host semantics
  info         [--artifacts artifacts]   list loaded artifacts
  help                                   this text
"
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_command_flags_positional() {
        // Note: a bare `--switch` followed by a non-flag token consumes
        // it as a value (schema-less parsing) — put switches last or
        // use `--switch=true`.
        let a = Args::parse(["serve", "--rows", "256", "extra", "--verbose"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 256);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(["x", "--q=32"]).unwrap();
        assert_eq!(a.get_usize("q", 0).unwrap(), 32);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(["cmd"]).unwrap();
        assert_eq!(a.get_usize("rows", 128).unwrap(), 128);
        assert_eq!(a.get_f64("period", 1.25).unwrap(), 1.25);
        assert_eq!(a.get_str("backend", "fast"), "fast");
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(["cmd", "--rows", "abc"]).unwrap();
        assert!(a.get_usize("rows", 1).is_err());
    }

    #[test]
    fn switch_at_end() {
        let a = Args::parse(["cmd", "--fast"]).unwrap();
        assert!(a.get_bool("fast"));
    }

    #[test]
    fn equals_in_value_survives() {
        // Only the FIRST '=' splits key from value.
        let a = Args::parse(["c", "--expr=a=b=c", "--empty="]).unwrap();
        assert_eq!(a.get("expr"), Some("a=b=c"));
        assert_eq!(a.get("empty"), Some(""));
    }

    #[test]
    fn repeated_flag_last_wins() {
        let a = Args::parse(["c", "--k", "1", "--k=2", "--k", "3"]).unwrap();
        assert_eq!(a.get("k"), Some("3"));
    }

    #[test]
    fn bare_double_dash_ends_flag_parsing() {
        // The defect this satellite fixed: `--` used to be a hard
        // error; it now terminates flag parsing like getopt.
        let a = Args::parse(["serve", "--rows", "8", "--", "--not-a-flag", "x"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 8);
        assert_eq!(a.positional, vec!["--not-a-flag", "x"]);
        // Before any operand, the first post-`--` token is the command.
        let b = Args::parse(["--", "serve", "extra"]).unwrap();
        assert_eq!(b.command.as_deref(), Some("serve"));
        assert_eq!(b.positional, vec!["extra"]);
        assert!(b.flags.is_empty());
        // A switch immediately before `--` stays a switch.
        let c = Args::parse(["c", "--verbose", "--", "pos"]).unwrap();
        assert!(c.get_bool("verbose"));
        assert_eq!(c.positional, vec!["pos"]);
    }

    #[test]
    fn switch_before_positional_consumes_it() {
        // Documented schema-less behaviour, pinned down: a bare flag
        // followed by a non-flag token takes it as a value.
        let a = Args::parse(["c", "--switch", "positional"]).unwrap();
        assert_eq!(a.get("switch"), Some("positional"));
        assert!(a.positional.is_empty());
    }

    // ---- property tests (in-repo quickprop; satellite: cli parsing) ----

    use crate::util::quickprop::{check, Gen};

    /// A flag key with no '=', '-' or whitespace.
    fn gen_key(g: &mut Gen, i: usize) -> String {
        format!("k{}{}", i, g.u32_below(1000))
    }

    /// A value from an alphabet that stresses the parser: '=', '-',
    /// digits, letters — but never a leading "--" (values are only
    /// ambiguous in `--key value` form, which round-trip avoids).
    fn gen_value(g: &mut Gen) -> String {
        let alphabet = ['a', 'Z', '0', '9', '=', '-', '.', '_', '%'];
        let len = g.usize_in(0, 6);
        (0..len).map(|_| *g.choose(&alphabet)).collect()
    }

    fn gen_operand(g: &mut Gen, i: usize) -> String {
        format!("p{}{}", i, g.u32_below(1000))
    }

    #[test]
    fn prop_parse_never_fails() {
        // Any token soup — flags, values, bare dashes, `--`, unicode —
        // must parse without error (the grammar is total).
        check("parse is total", 400, |g| {
            let pool = [
                "--", "--k", "--k=v", "-x", "x", "=", "--=", "--a=b=c", "héllo", "--9",
            ];
            let tokens = g.vec_of(12, |g| g.choose(&pool).to_string());
            Args::parse(tokens).is_ok()
        });
    }

    #[test]
    fn prop_structured_command_lines_round_trip() {
        // command + `--key=value` flags + `--` + operands reparses to
        // exactly the structure it was built from.
        check("args round-trip", 300, |g| {
            let command = format!("cmd{}", g.u32_below(100));
            let nflags = g.usize_in(0, 4);
            let flags: BTreeMap<String, String> =
                (0..nflags).map(|i| (gen_key(g, i), gen_value(g))).collect();
            let npos = g.usize_in(0, 3);
            let positional: Vec<String> = (0..npos).map(|i| gen_operand(g, i)).collect();

            let mut tokens = vec![command.clone()];
            for (k, v) in &flags {
                tokens.push(format!("--{k}={v}"));
            }
            tokens.push("--".to_string());
            tokens.extend(positional.iter().cloned());

            let parsed = Args::parse(tokens).unwrap();
            parsed
                == Args {
                    command: Some(command),
                    flags,
                    positional,
                }
        });
    }

    #[test]
    fn prop_space_form_equals_equals_form() {
        // `--key value` and `--key=value` parse identically whenever
        // the value is not flag-shaped.
        check("space form == equals form", 300, |g| {
            let key = gen_key(g, 0);
            let mut value = gen_value(g);
            if value.starts_with("--") || value.is_empty() {
                value = format!("v{value}");
            }
            let a = Args::parse(["c".to_string(), format!("--{key}"), value.clone()]).unwrap();
            let b = Args::parse(["c".to_string(), format!("--{key}={value}")]).unwrap();
            a == b && a.get(&key) == Some(value.as_str())
        });
    }

    // ---- renamed-flag resolution (satellite: --flush-us deprecation) ----

    #[test]
    fn renamed_flag_resolution_cases() {
        let neither = Args::parse(["serve"]).unwrap();
        assert_eq!(
            neither.get_renamed("seal-deadline-us", "flush-us"),
            (None, RenamedUse::Neither)
        );
        let new_only = Args::parse(["serve", "--seal-deadline-us", "250"]).unwrap();
        assert_eq!(
            new_only.get_renamed("seal-deadline-us", "flush-us"),
            (Some("250"), RenamedUse::NewOnly)
        );
        let legacy = Args::parse(["serve", "--flush-us", "99"]).unwrap();
        let (v, used) = legacy.get_renamed("seal-deadline-us", "flush-us");
        assert_eq!((v, used), (Some("99"), RenamedUse::LegacyOnly));
        assert!(used.deprecated());
        // Conflict: the new spelling wins regardless of order.
        for tokens in [
            ["serve", "--flush-us", "99", "--seal-deadline-us", "250"],
            ["serve", "--seal-deadline-us", "250", "--flush-us", "99"],
        ] {
            let both = Args::parse(tokens).unwrap();
            let (v, used) = both.get_renamed("seal-deadline-us", "flush-us");
            assert_eq!((v, used), (Some("250"), RenamedUse::Both));
            assert!(used.deprecated());
        }
        assert!(!RenamedUse::NewOnly.deprecated());
        assert!(!RenamedUse::Neither.deprecated());
    }

    #[test]
    fn prop_renamed_flag_prefers_new_and_flags_legacy() {
        // For any pair of values and any spelling combination, the
        // resolution is total, the new spelling wins when present, and
        // `deprecated()` fires iff the old spelling appeared.
        check("renamed flag resolution", 300, |g| {
            let new_val = format!("n{}", g.u32_below(1000));
            let old_val = format!("o{}", g.u32_below(1000));
            let use_new = g.bool();
            let use_old = g.bool();
            let mut tokens = vec!["serve".to_string()];
            if use_old {
                tokens.push(format!("--flush-us={old_val}"));
            }
            if use_new {
                tokens.push(format!("--seal-deadline-us={new_val}"));
            }
            let args = Args::parse(tokens).unwrap();
            let (v, used) = args.get_renamed("seal-deadline-us", "flush-us");
            let want_v = match (use_new, use_old) {
                (true, _) => Some(new_val.as_str()),
                (false, true) => Some(old_val.as_str()),
                (false, false) => None,
            };
            v == want_v && used.deprecated() == use_old
        });
    }

    #[test]
    fn prop_tokens_after_double_dash_are_never_flags() {
        check("post-`--` tokens are operands", 300, |g| {
            let n = g.usize_in(1, 6);
            let tail: Vec<String> = (0..n)
                .map(|i| {
                    if g.bool() {
                        format!("--flag{i}")
                    } else {
                        gen_operand(g, i)
                    }
                })
                .collect();
            let mut tokens = vec!["cmd".to_string(), "--".to_string()];
            tokens.extend(tail.iter().cloned());
            let parsed = Args::parse(tokens).unwrap();
            parsed.flags.is_empty()
                && parsed.command.as_deref() == Some("cmd")
                && parsed.positional == tail
        });
    }
}
