//! Metrics: throughput counters, latency histograms, energy accounting
//! and plain-text report rendering for the coordinator and benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::stats::LatencyHistogram;

/// Lock-free counters shared across coordinator workers.
#[derive(Debug, Default)]
pub struct Counters {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub requests_coalesced: AtomicU64,
    pub batches_flushed: AtomicU64,
    pub rows_updated: AtomicU64,
    pub shift_cycles: AtomicU64,
    pub reconfigs: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            requests_submitted: Self::get(&self.requests_submitted),
            requests_completed: Self::get(&self.requests_completed),
            requests_rejected: Self::get(&self.requests_rejected),
            requests_coalesced: Self::get(&self.requests_coalesced),
            batches_flushed: Self::get(&self.batches_flushed),
            rows_updated: Self::get(&self.rows_updated),
            shift_cycles: Self::get(&self.shift_cycles),
            reconfigs: Self::get(&self.reconfigs),
        }
    }
}

/// Plain-data snapshot of the counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub requests_submitted: u64,
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub requests_coalesced: u64,
    pub batches_flushed: u64,
    pub rows_updated: u64,
    pub shift_cycles: u64,
    pub reconfigs: u64,
}

impl CounterSnapshot {
    /// Mean rows per flushed batch — the coordinator's key efficiency
    /// figure (FAST amortizes one q-cycle batch over many rows).
    pub fn rows_per_batch(&self) -> f64 {
        if self.batches_flushed == 0 {
            return 0.0;
        }
        self.rows_updated as f64 / self.batches_flushed as f64
    }
}

/// Modeled energy accumulator (fJ) — fed from `energy::Cost` values.
#[derive(Debug, Default)]
pub struct EnergyAccount {
    total_fj: AtomicU64, // stored as millis of fJ for atomic adds
}

impl EnergyAccount {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_fj(&self, fj: f64) {
        debug_assert!(fj >= 0.0);
        self.total_fj
            .fetch_add((fj * 1000.0).round() as u64, Ordering::Relaxed);
    }

    pub fn total_fj(&self) -> f64 {
        self.total_fj.load(Ordering::Relaxed) as f64 / 1000.0
    }

    pub fn total_pj(&self) -> f64 {
        self.total_fj() / 1000.0
    }
}

/// Wall-clock stopwatch with a latency histogram.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    hist: std::sync::Mutex<LatencyHistogram>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_ns(t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn record_ns(&self, ns: u64) {
        self.hist.lock().expect("recorder poisoned").record(ns);
    }

    pub fn summary(&self) -> LatencySummary {
        let h = self.hist.lock().expect("recorder poisoned");
        LatencySummary {
            count: h.count(),
            mean_ns: h.mean_ns(),
            p50_ns: h.percentile_ns(50.0),
            p99_ns: h.percentile_ns(99.0),
            max_ns: h.max_ns(),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

/// Render a two-column report table (used by the CLI and benches).
pub fn render_table(title: &str, rows: &[(String, String)]) -> String {
    let key_w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0).max(8);
    let val_w = rows.iter().map(|(_, v)| v.len()).max().unwrap_or(0).max(8);
    let mut out = String::new();
    out.push_str(&format!("┌─ {title} {}┐\n", "─".repeat((key_w + val_w + 5).saturating_sub(title.len() + 3))));
    for (k, v) in rows {
        out.push_str(&format!("│ {k:<key_w$} │ {v:>val_w$} │\n"));
    }
    out.push_str(&format!("└{}┘\n", "─".repeat(key_w + val_w + 6)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_roundtrip() {
        let c = Counters::new();
        Counters::inc(&c.requests_submitted, 5);
        Counters::inc(&c.batches_flushed, 2);
        Counters::inc(&c.rows_updated, 200);
        let s = c.snapshot();
        assert_eq!(s.requests_submitted, 5);
        assert_eq!(s.rows_per_batch(), 100.0);
    }

    #[test]
    fn rows_per_batch_empty_is_zero() {
        assert_eq!(CounterSnapshot::default().rows_per_batch(), 0.0);
    }

    #[test]
    fn energy_account_accumulates() {
        let e = EnergyAccount::new();
        e.add_fj(380.0);
        e.add_fj(0.5);
        assert!((e.total_fj() - 380.5).abs() < 1e-9);
        assert!((e.total_pj() - 0.3805).abs() < 1e-9);
    }

    #[test]
    fn latency_recorder_times_closures() {
        let r = LatencyRecorder::new();
        let v = r.time(|| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            42
        });
        assert_eq!(v, 42);
        let s = r.summary();
        assert_eq!(s.count, 1);
        assert!(s.mean_ns >= 1_000_000.0);
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "demo",
            &[("alpha".into(), "1".into()), ("beta".into(), "22".into())],
        );
        assert!(t.contains("alpha"));
        assert!(t.contains("22"));
        assert!(t.lines().count() >= 4);
    }
}
